"""Shared benchmark utilities: VAE training on synthetic MNIST, baseline
compressors, timing."""

from __future__ import annotations

import time
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import baselines as baseline_lib
from repro.data import synthetic_mnist
from repro.models import vae as vae_lib
from repro.optim import adamw


def train_vae(cfg: vae_lib.VAEConfig, *, steps: int = 1500,
              batch: int = 128, n_train: int = 8000, seed: int = 0,
              lr: float = 1e-3) -> Tuple[dict, float]:
    """Train the paper's VAE on synthetic MNIST; returns (params,
    final test -ELBO bits/dim)."""
    train_imgs, _ = synthetic_mnist.load("train", n_train, seed)
    if cfg.likelihood == "bernoulli":
        train_imgs = synthetic_mnist.binarize(train_imgs, seed)
    test_imgs, _ = synthetic_mnist.load("test", 1024, seed)
    if cfg.likelihood == "bernoulli":
        test_imgs = synthetic_mnist.binarize(test_imgs, seed + 1)

    params = vae_lib.init(jax.random.PRNGKey(seed), cfg)
    opt = adamw.AdamW(learning_rate=adamw.cosine_lr(lr, 100, steps))
    state = opt.init(params)

    @jax.jit
    def step(params, state, key, batch_imgs):
        loss, grads = jax.value_and_grad(vae_lib.loss)(
            params, cfg, key, batch_imgs)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed + 1)
    for i in range(steps):
        idx = rng.integers(0, len(train_imgs), batch)
        key, sub = jax.random.split(key)
        params, state, loss = step(
            params, state, sub, jnp.asarray(train_imgs[idx], jnp.int32))

    keys = jax.random.split(jax.random.PRNGKey(seed + 2), 8)
    elbos = [float(vae_lib.elbo_bits_per_dim(
        params, cfg, k, jnp.asarray(test_imgs, jnp.int32))) for k in keys]
    return params, float(np.mean(elbos))


def baseline_rates(images: np.ndarray, binary: bool,
                   **kwargs) -> Dict[str, float]:
    """bits/dim for generic compressors on the (bit-packed) test set
    (delegates to ``repro.data.baselines``; ``with_png=True`` adds the
    per-image PNG rows)."""
    return baseline_lib.baseline_rates(images, binary, **kwargs)


def timer(fn: Callable, *args, repeats: int = 3) -> Tuple[float, object]:
    """Median wall time (us) of fn(*args) with block_until_ready."""
    out = None
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready()
            if hasattr(x, "block_until_ready") else x, out)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times)), out
