"""Hierarchical vs single-layer rates + the Bit-Swap clean-bit bound.

Reports, on the shared synthetic-MNIST bench workload:

  * bits/dim of the 2-level convolutional HVAE (Bit-Swap codec) vs the
    paper's single-layer dense VAE (BBANS codec), both measured as the
    achieved container ``net_bits`` - not just -ELBO - so the
    discretization penalty is included;
  * lossless round-trips at two image shapes from ONE set of HVAE
    params (the fully convolutional / HiLLoC "any size" property);
  * the *initial-bits overhead per level*: the minimal clean-bit supply
    (in 16-bit chunks) the encoder needs for one datapoint, as a
    function of hierarchy depth L. Bit-Swap's interleaved schedule
    keeps this roughly flat in L (bounded by one layer's posterior),
    where the naive all-posteriors-first schedule grows linearly.

Run: PYTHONPATH=src python -m benchmarks.run --only hvae_rate
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import train_vae
from repro import codecs
from repro.configs import hvae_img
from repro.data import images as img_data
from repro.models import hvae as hvae_lib
from repro.models import vae as vae_lib
from repro.optim import adamw


def train_hvae(cfg: hvae_lib.HVAEConfig, *, steps: int = 1200,
               batch: int = 64, n_train: int = 4000, seed: int = 0,
               lr: float = 2e-3) -> Tuple[dict, float]:
    """Train an HVAE on the shared synthetic workload; returns
    (params, test -ELBO bits/dim at 28x28)."""
    binary = cfg.likelihood == "bernoulli"
    train_imgs = img_data.load("train", n_train, seed, hw=(28, 28),
                               binarized=binary)
    test_imgs = img_data.load("test", 256, seed + 1, hw=(28, 28),
                              binarized=binary)
    params = hvae_lib.init(jax.random.PRNGKey(seed), cfg)
    opt = adamw.AdamW(learning_rate=adamw.cosine_lr(lr, 100, steps))
    state = opt.init(params)

    @jax.jit
    def step(params, state, key, imgs):
        loss, grads = jax.value_and_grad(hvae_lib.loss)(
            params, cfg, key, imgs)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed + 1)
    for _ in range(steps):
        idx = rng.integers(0, len(train_imgs), batch)
        key, sub = jax.random.split(key)
        params, state, _ = step(params, state, sub,
                                jnp.asarray(train_imgs[idx], jnp.int32))
    keys = jax.random.split(jax.random.PRNGKey(seed + 2), 4)
    bpd = [float(hvae_lib.elbo_bits_per_dim(
        params, cfg, k, jnp.asarray(test_imgs, jnp.int32))) for k in keys]
    return params, float(np.mean(bpd))


def _measured_rate(codec, data, lanes: int, seed: int = 0
                   ) -> Tuple[float, bool, int]:
    """(achieved bits/dim, lossless?, wire bytes) via the container."""
    chained = codecs.Chained(codec, data.shape[0])
    blob, info = codecs.compress(chained, data, lanes=lanes, seed=seed,
                                 with_info=True)
    out = codecs.decompress(chained, blob)
    return (info["net_bits"] / data.size,
            bool(jnp.array_equal(out, data)), len(blob))


def min_clean_chunks(codec, datapoint, lanes: int, *, seed: int = 0,
                     hi: int = 512) -> int:
    """Smallest per-lane clean-bit supply (16-bit chunks) that encodes
    one datapoint without underflow - the transient demand the paper's
    'initial bits' must cover."""
    lo, hi_b = 0, hi
    cap = max(2048, hi + 1024)

    def clean(chunks: int) -> bool:
        stack = codecs.fresh_stack(lanes, cap, seed=seed,
                                   init_chunks=chunks)
        out = codec.push(stack, datapoint)
        return not int(jnp.sum(out.underflows)) \
            and not int(jnp.sum(out.overflows))

    if not clean(hi_b):
        return hi_b  # saturated; report the cap
    while lo < hi_b:
        mid = (lo + hi_b) // 2
        if clean(mid):
            hi_b = mid
        else:
            lo = mid + 1
    return hi_b


def run(train_steps: int = 1200, n_images: int = 64,
        shapes: Tuple[Tuple[int, int], ...] = ((28, 28), (40, 24)),
        seed: int = 0) -> List[Dict]:
    rows: List[Dict] = []
    lanes = 16
    n_chain = max(1, n_images // lanes)

    # Shared bench workload: binarized synthetic MNIST at 28x28.
    bench = img_data.load("test", n_chain * lanes, seed + 7, hw=(28, 28),
                          binarized=True)
    data28 = jnp.asarray(
        bench.reshape(n_chain, lanes, 28, 28), jnp.int32)

    # --- single-layer dense VAE baseline (the paper's model) -------------
    vae_cfg = vae_lib.paper_config("bernoulli")
    vae_params, vae_elbo = train_vae(vae_cfg, steps=train_steps,
                                     seed=seed)
    vae_codec = vae_lib.make_bb_codec(vae_params, vae_cfg)
    flat28 = data28.reshape(n_chain, lanes, 28 * 28)
    chained = codecs.Chained(vae_codec, n_chain)
    blob, info = codecs.compress(chained, flat28, lanes=lanes, seed=seed,
                                 with_info=True)
    vae_rate = info["net_bits"] / flat28.size
    vae_lossless = bool(jnp.array_equal(
        codecs.decompress(chained, blob), flat28))
    rows.append({"model": "vae-L1", "elbo_bpd": vae_elbo,
                 "coded_bpd": vae_rate, "lossless": vae_lossless})

    # --- 2-level convolutional HVAE -------------------------------------
    hcfg = hvae_img.SMALL2
    hparams, h_elbo = train_hvae(hcfg, steps=train_steps, seed=seed)
    for hw in shapes:
        if hw == (28, 28):
            data = data28
        else:
            raw = img_data.load("test", lanes, seed + 8, hw=hw,
                                binarized=True)
            data = jnp.asarray(raw.reshape(1, lanes, *hw), jnp.int32)
        codec = hvae_lib.make_bitswap_codec(hparams, hcfg, hw)
        per_dp = data.reshape(data.shape[0], lanes, *hw)
        rate, lossless, wire = _measured_rate(codec, per_dp, lanes,
                                              seed=seed)
        rows.append({"model": f"hvae-L2-{hw[0]}x{hw[1]}",
                     "elbo_bpd": h_elbo if hw == (28, 28) else -1.0,
                     "coded_bpd": rate, "lossless": lossless,
                     "wire_bytes": wire})

    # --- initial-bits overhead per level (the Bit-Swap bound) -----------
    # The paper's "extra information" cost: the minimal clean-bit supply
    # a fresh chain needs. Bit-Swap's interleaving keeps it bounded by
    # ONE layer's posterior, so going L=2 -> L=3 should cost ~nothing
    # extra, while each level adds a full posterior of latents. Probed
    # at 16x16 (the demand is a per-layer quantity; the trend vs. L is
    # the point); BOTH hierarchy depths are trained with the same
    # budget so the comparison measures depth, not training state. The
    # L=1 dense-VAE row is the same probe for the paper's model at its
    # native 784-dim input.
    one28 = data28[0][:4]  # [4, 28, 28]
    probe16 = jnp.asarray(
        img_data.load("test", 4, seed + 9, hw=(16, 16), binarized=True),
        jnp.int32)
    demand_rows = []
    chunks_l1 = min_clean_chunks(vae_codec, one28.reshape(4, 28 * 28),
                                 4, seed=seed, hi=256)
    demand_rows.append({"model": "vae-L1 (latent 40)",
                        "init_chunks_per_lane": chunks_l1,
                        "init_bits_per_lane": chunks_l1 * 16})
    hparams3, _ = train_hvae(hvae_img.SMALL3, steps=train_steps,
                             seed=seed)
    for levels, p_l, cfg_l in ((2, hparams, hvae_img.SMALL2),
                               (3, hparams3, hvae_img.SMALL3)):
        codec_l = hvae_lib.make_bitswap_codec(p_l, cfg_l, (16, 16))
        chunks = min_clean_chunks(codec_l, probe16, 4, seed=seed, hi=256)
        demand_rows.append({"model": f"hvae-L{levels} (16x16 probe)",
                            "init_chunks_per_lane": chunks,
                            "init_bits_per_lane": chunks * 16})
    rows.extend(demand_rows)
    return rows
