"""Bits-back gain over direct LM-ANS on per-sequence-structured data.

Corpus: each sequence is drawn wholly from one of 4 Markov regimes.
A causal LM must *infer* the regime from early tokens (paying extra bits
at the sequence start); a LatentLM encodes the regime in a per-sequence
latent whose net cost is the KL (bits-back refunds the rest) - the
paper's mechanism, on an assigned backbone.

Reported: plain-LM CE/token vs LatentLM -ELBO/token (analytic, stable),
plus a chained BB-ANS roundtrip (exactness + achieved rate).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs
from repro.configs import base as cfg_base
from repro.core import lm_codec
from repro.data import tokens as tok_data
from repro.models import latent_lm, transformer
from repro.optim import adamw
from repro.train import trainer


def regime_corpus(n_seqs: int, seq_len: int, vocab: int = 64,
                  n_regimes: int = 4, seed: int = 0):
    """[n_seqs, seq_len] int32, each row from one Markov regime."""
    rng = np.random.default_rng(seed)
    mats = [tok_data.make_transition_matrix(vocab, alpha=1.1,
                                            seed=seed + 17 * r)
            for r in range(n_regimes)]
    cdfs = [np.cumsum(m, axis=1) for m in mats]
    out = np.empty((n_seqs, seq_len), np.int32)
    regimes = rng.integers(0, n_regimes, n_seqs)
    for i in range(n_seqs):
        cdf = cdfs[regimes[i]]
        t = rng.integers(vocab)
        for j in range(seq_len):
            t = int(np.searchsorted(cdf[t], rng.random()))
            out[i, j] = t
    return out, regimes


def run(train_steps: int = 300, seq_len: int = 32, seed: int = 0):
    vocab = 64
    bb = dataclasses.replace(
        cfg_base.reduced(cfg_base.get("smollm-360m"), layers=2, width=96),
        vocab=vocab, loss_chunk=seq_len)
    data, _ = regime_corpus(2048, seq_len, vocab, seed=seed)
    test, _ = regime_corpus(256, seq_len, vocab, seed=seed + 1)
    test_j = jnp.asarray(test)

    # --- plain LM ---
    opt = trainer.make_optimizer(bb, lr=3e-3, total_steps=train_steps)
    state = trainer.init_state(jax.random.PRNGKey(seed), bb, opt)
    step = jax.jit(trainer.make_train_step(bb, opt))
    rng = np.random.default_rng(seed)
    for i in range(train_steps):
        idx = rng.integers(0, len(data), 32)
        state, m = step(state, {"tokens": jnp.asarray(data[idx])})
    lm_bits = lm_codec.expected_bits(state.params, bb, test_j) / test.size

    # --- LatentLM (same backbone size + per-sequence latent) ---
    lcfg = latent_lm.LatentLMConfig(backbone=bb, latent_dim=8, n_prefix=1,
                                    lat_bits=8)
    lparams = latent_lm.init(jax.random.PRNGKey(seed + 1), lcfg)
    lopt = adamw.AdamW(learning_rate=adamw.cosine_lr(
        3e-3, 50, train_steps))
    lstate = lopt.init(lparams)

    @jax.jit
    def lstep(params, ostate, key, batch):
        (l, metrics), grads = jax.value_and_grad(
            latent_lm.loss, has_aux=True)(params, lcfg, key, batch)
        params, ostate = lopt.update(grads, ostate, params)
        return params, ostate, l

    key = jax.random.PRNGKey(seed + 2)
    for i in range(train_steps):
        idx = rng.integers(0, len(data), 32)
        key, sub = jax.random.split(key)
        lparams, lstate, l = lstep(lparams, lstate, sub,
                                   jnp.asarray(data[idx]))
    keys = jax.random.split(jax.random.PRNGKey(seed + 3), 8)
    elbos = [float(jnp.mean(latent_lm.elbo(lparams, lcfg, k, test_j)))
             for k in keys]
    latent_bits = -float(np.mean(elbos)) / (seq_len * np.log(2.0))

    # --- BB-ANS roundtrip on a short chain (exactness + rate) ---
    lanes, n_chain = 4, 4
    chain = jnp.asarray(test[:lanes * n_chain].reshape(n_chain, lanes,
                                                       seq_len))
    codec = codecs.Chained(
        latent_lm.make_bb_codec(lparams, lcfg, seq_len=seq_len),
        n_chain, scan=False)
    blob, info = codecs.compress(codec, chain, lanes=lanes, seed=9,
                                 init_chunks=64, capacity=8192,
                                 with_info=True)
    bb_rate = info["net_bits"] / chain.size
    out = codecs.decompress(codec, blob)
    exact = bool(jnp.array_equal(out, chain))

    return [{
        "bench": "latent_lm_gain",
        "plain_lm_bpt": lm_bits,
        "latent_lm_elbo_bpt": latent_bits,
        "gain_bpt": lm_bits - latent_bits,
        "bbans_measured_bpt": bb_rate,
        "lossless": exact,
    }]


def main():
    for r in run():
        print(f"latent_lm_gain,plain={r['plain_lm_bpt']:.4f},"
              f"latent_elbo={r['latent_lm_elbo_bpt']:.4f},"
              f"gain={r['gain_bpt']:+.4f},"
              f"bbans={r['bbans_measured_bpt']:.4f},"
              f"lossless={r['lossless']}")


if __name__ == "__main__":
    main()
