"""Dataset-scale sharded compression rate - the Table-1 benchmark.

Streams the synthetic-MNIST test corpus through the lane-sharded
BB-ANS pipeline (``repro.shard_codec``: per-shard BBX2 segments
gathered into one BBX3 corpus) and reports the achieved *wire*
bits/dim - every byte of framing included - against the generic
compressors of the paper's Table 1 (gzip, bz2, lzma, per-image PNG
proxy). Asserts the paper's headline: BB-ANS beats gzip and bz2.

The shard count is fixed (8) rather than tied to the local device
count: wire bytes depend only on the shard layout, so this bench
produces identical blobs on 1 device and on 8 (the determinism
contract; proved in tests/test_shard_codec.py).

Run: PYTHONPATH=src python -m benchmarks.run --only dataset_rate
CLI twin: PYTHONPATH=src python -m repro.launch.compress
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax.numpy as jnp

from repro import shard_codec
from repro.data import baselines as baseline_lib
from repro.launch import compress as compress_cli


def run(train_steps: int = 1500, n_images: int = 2048,
        lanes: int = 8, n_shards: int = 8, block_symbols: int = 32,
        seed: int = 0, arch: str = "vae-bernoulli") -> List[Dict]:
    make_codec, binary, elbo = compress_cli.train_dataset_model(
        arch, steps=train_steps, seed=seed)
    imgs, data, _ = compress_cli.load_corpus(arch, n_images, lanes)
    codec = make_codec()

    t0 = time.time()
    blob = compress_cli.compress_corpus(
        codec, data, n_shards=n_shards, block_symbols=block_symbols,
        seed=seed)
    t_enc = time.time() - t0
    bpd = len(blob) * 8 / imgs.size

    t0 = time.time()
    out = shard_codec.decompress_dataset(codec, blob, compile=True)
    t_dec = time.time() - t0
    lossless = bool(jnp.array_equal(out, data))
    assert lossless, "dataset_rate: sharded decode mismatch"

    # proxy-PNG only: the bench rows must match with or without PIL
    rates = baseline_lib.baseline_rates(imgs, binary, with_png=True,
                                        try_real_png=False)
    assert bpd < rates["gzip"] and bpd < rates["bz2"], (
        f"dataset_rate: BB-ANS {bpd:.4f} bits/dim must beat "
        f"gzip {rates['gzip']:.4f} and bz2 {rates['bz2']:.4f}")

    info = shard_codec.corpus_info(blob)
    rows: List[Dict] = [{
        "path": "bbans-sharded", "arch": arch,
        "bpd": bpd, "elbo_bpd": elbo,
        "wire_bytes": len(blob),
        "index_bytes": info["index_bytes"],
        "n_images": n_images,
        "enc_mb_per_s": imgs.size / 1e6 / t_enc,
        "dec_mb_per_s": imgs.size / 1e6 / t_dec,
        "lossless": lossless,
        "beats_gzip": bool(bpd < rates["gzip"]),
        "beats_bz2": bool(bpd < rates["bz2"]),
    }]
    rows += [{"path": name, "arch": arch, "bpd": rate}
             for name, rate in sorted(rates.items())]
    return rows
