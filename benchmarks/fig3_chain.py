"""Paper Figure 3: moving-average compression rate along the BB-ANS chain.

Shows the chain settling to the steady-state rate (clean-bit seeding is
amortized). Emits CSV rows: image_index, cumulative_bpd, window_bpd.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import codecs
from repro.core import ans
from repro.data import synthetic_mnist
from repro.models import vae as vae_lib


def run(n_images: int = 480, lanes: int = 16, train_steps: int = 1200,
        seed: int = 0, window: int = 8):
    cfg = vae_lib.paper_config("bernoulli")
    params, neg_elbo = common.train_vae(cfg, steps=train_steps, seed=seed)
    imgs, _ = synthetic_mnist.load("test", n_images, seed)
    imgs = synthetic_mnist.binarize(imgs, seed + 1)
    n_chain = n_images // lanes
    data = jnp.asarray(imgs[:n_chain * lanes].reshape(n_chain, lanes, -1),
                       jnp.int32)
    codec = vae_lib.make_bb_codec(params, cfg)
    stack = codecs.fresh_stack(lanes, n_chain * 256 + 512, seed=5,
                               init_chunks=32)

    rows = []
    bits_prev = float(ans.stack_content_bits(stack))
    bits0 = bits_prev
    per_step = []
    for i in range(n_chain):
        stack = codec.push(stack, data[i])
        bits_now = float(ans.stack_content_bits(stack))
        step_bpd = (bits_now - bits_prev) / (lanes * cfg.input_dim)
        per_step.append(step_bpd)
        cum_bpd = (bits_now - bits0) / ((i + 1) * lanes * cfg.input_dim)
        win = float(np.mean(per_step[-window:]))
        rows.append((i * lanes, cum_bpd, win))
        bits_prev = bits_now
    return rows, neg_elbo


def main():
    rows, neg_elbo = run()
    print(f"fig3,neg_elbo_bpd={neg_elbo:.4f}")
    for i, cum, win in rows:
        print(f"fig3,{i},{cum:.4f},{win:.4f}")


if __name__ == "__main__":
    main()
