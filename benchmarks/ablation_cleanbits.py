"""Section 2.5 ablations: latent precision sweep + clean-bit seeding.

(a) lat_bits sweep (paper 2.5.1: diminishing returns past ~12-16 bits);
(b) seeding with clean bits vs cold-start (paper 3.2: ~hundreds of bits
    needed to avoid initial-chain inefficiency/underflow).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from benchmarks import common
from repro import codecs
from repro.core import ans
from repro.data import synthetic_mnist
from repro.models import vae as vae_lib


def run(train_steps: int = 1000, n_images: int = 128, lanes: int = 16,
        seed: int = 0):
    base = vae_lib.paper_config("bernoulli")
    params, neg_elbo = common.train_vae(base, steps=train_steps, seed=seed)
    imgs, _ = synthetic_mnist.load("test", n_images, seed)
    imgs = synthetic_mnist.binarize(imgs, seed + 1)
    n_chain = n_images // lanes
    data = jnp.asarray(imgs[:n_chain * lanes].reshape(n_chain, lanes, -1),
                       jnp.int32)
    rows = []
    cap = n_chain * 300 + 512
    for lat_bits in (6, 8, 10, 12):
        cfg = dataclasses.replace(base, lat_bits=lat_bits)
        codec = codecs.Chained(vae_lib.make_bb_codec(params, cfg), n_chain)
        _, info = codecs.compress(codec, data, lanes=lanes, seed=7,
                                  capacity=cap, with_info=True)
        rows.append({"ablation": "lat_bits", "value": lat_bits,
                     "bpd": info["net_bits"] / data.size,
                     "neg_elbo": neg_elbo})
    for n_seed_chunks in (0, 8, 32):
        # Cold or undersized seeding *intends* dirty pops, so this arm
        # drives the codec below the container (which refuses to emit a
        # dirty blob) and reports the observed underflows.
        codec = vae_lib.make_bb_codec(params, base)
        chained = codecs.Chained(codec, n_chain)
        stack = codecs.fresh_stack(lanes, cap, seed=7,
                                   init_chunks=n_seed_chunks)
        b0 = float(ans.stack_content_bits(stack))
        stack = chained.push(stack, data)
        rate = (float(ans.stack_content_bits(stack)) - b0) / data.size
        rows.append({"ablation": "seed_chunks", "value": n_seed_chunks,
                     "bpd": rate,
                     "underflows": int(jnp.sum(stack.underflows))})
    return rows


def main():
    for r in run():
        extra = (f",underflows={r['underflows']}"
                 if "underflows" in r else "")
        print(f"ablation,{r['ablation']},{r['value']},bpd={r['bpd']:.4f}"
              + extra)


if __name__ == "__main__":
    main()
