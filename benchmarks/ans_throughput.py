"""ANS coder throughput (symbols/s) - core jnp path and the dispatched
kernel path (``kernels.dispatch`` resolves the backend: the pure-XLA
twin on CPU, compiled Pallas on accelerators; the Pallas interpreter is
timed separately as the explicitly-pinned oracle row).

Two parts: the static-table categorical coder (the original rows) and
the *dynamic-leaf* Gaussian path - per-position ``DiscretizedGaussian``
interpreted one symbol at a time vs the codec compiler's fused
multi-step kernels (``push_many`` + ``pop_many_grid``), with MB/s of
produced wire and the compiled/interpreted speedup. Pin every
dispatched row to one backend with ``REPRO_KERNEL_BACKEND=xla`` (the
CI smoke step does) or ``--backend``."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import codecs
from repro.core import ans
from repro.kernels import dispatch
from repro.kernels.ans import ops as ans_ops


def _dynamic_gauss_rows(lanes: int, steps: int, seed: int):
    """The dynamic-leaf path: a ``Repeat`` of per-position Gaussians."""
    rng = np.random.default_rng(seed + 1)
    mu = jnp.asarray(rng.normal(0, 1, (lanes, steps)), jnp.float32)
    sigma = jnp.asarray(rng.uniform(0.1, 1.5, (lanes, steps)), jnp.float32)
    bits = 10
    rep = codecs.Repeat(
        lambda d: codecs.DiscretizedGaussian(mu[:, d], sigma[:, d], bits),
        steps)
    # donate=False: the same input stack is timed repeatedly here.
    prog = codecs.compile(rep, donate=False)
    x = jnp.asarray(rng.integers(0, 1 << bits, (lanes, steps)), jnp.int32)
    stack = ans.make_stack(lanes, steps + 8, key=jax.random.PRNGKey(2))
    stack = ans.seed_stack(stack, jax.random.PRNGKey(3), 4)

    full = prog.push(stack, x)              # warm the compiled program
    prog.pop(full)
    us_pi, ref = common.timer(lambda: rep.push(stack, x))
    us_pc, out = common.timer(lambda: prog.push(stack, x))
    assert bool(jnp.array_equal(out.head, ref.head)), "push parity"
    wire_mb = float(jnp.sum(out.ptr - stack.ptr)) * 2 / 1e6
    us_di, _ = common.timer(lambda: rep.pop(full))
    us_dc, _ = common.timer(lambda: prog.pop(full))

    n = lanes * steps
    n_dev = jax.device_count()
    return [
        {"path": "gauss-interpreted", "us": us_pi,
         "msym_per_s": n / us_pi, "mb_per_s": wire_mb / (us_pi / 1e6),
         "pop_us": us_di, "pop_msym_per_s": n / us_di},
        {"path": "gauss-compiled", "us": us_pc,
         "msym_per_s": n / us_pc, "mb_per_s": wire_mb / (us_pc / 1e6),
         "enc_mb_per_s_per_device": wire_mb / (us_pc / 1e6) / n_dev,
         "dec_mb_per_s_per_device": wire_mb / (us_dc / 1e6) / n_dev,
         "pop_us": us_dc, "pop_msym_per_s": n / us_dc,
         "speedup_push": us_pi / us_pc, "speedup_pop": us_di / us_dc},
    ]


def run(lanes: int = 256, steps: int = 256, seed: int = 0):
    rng = np.random.default_rng(seed)
    probs = rng.dirichlet(np.ones(16), size=lanes).astype(np.float32)
    table = ans.probs_to_starts(jnp.asarray(probs), 14)
    syms = jnp.asarray(rng.integers(0, 16, (steps, lanes)), jnp.int32)
    tab = np.asarray(table)
    idx = np.arange(lanes)[None]
    starts = jnp.asarray(tab[idx, np.asarray(syms)], jnp.uint32)
    freqs = jnp.asarray(tab[idx, np.asarray(syms) + 1] -
                        tab[idx, np.asarray(syms)], jnp.uint32)

    stack = ans.make_stack(lanes, steps + 8, key=jax.random.PRNGKey(1))

    @jax.jit
    def core_push(stack):
        def body(t, st):
            return ans.push(st, starts[t], freqs[t], 14)
        return jax.lax.fori_loop(0, steps, body, stack)

    us_core, _ = common.timer(core_push, stack)
    # The dispatched row runs whatever backend resolve() picks (XLA twin
    # on CPU); the interpret row pins the historical Pallas-interpreter
    # oracle so the committed baseline row stays comparable. Both are
    # jitted - that is how every production caller reaches these ops.
    d = dispatch.resolve("push_many", lanes=lanes)
    push_jit = jax.jit(ans_ops.push_many,
                       static_argnames=("precision", "backend"))
    push_jit(stack, starts, freqs, 14, backend=d)            # warm
    us_kernel, _ = common.timer(
        lambda s: push_jit(s, starts, freqs, 14, backend=d), stack)
    push_jit(stack, starts, freqs, 14, backend="interpret")  # warm
    us_interp, _ = common.timer(
        lambda s: push_jit(s, starts, freqs, 14,
                           backend="interpret"), stack)
    n = lanes * steps
    return [{"path": "core-jnp", "us": us_core,
             "msym_per_s": n / us_core},
            {"path": f"kernel-{d.backend}", "us": us_kernel,
             "msym_per_s": n / us_kernel},
            {"path": "pallas-interpret", "us": us_interp,
             "msym_per_s": n / us_interp}] \
        + _dynamic_gauss_rows(lanes, steps, seed)


def main():
    import argparse
    import contextlib
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None,
                    choices=sorted(dispatch.BACKENDS),
                    help="pin every dispatched op to one backend "
                         "(same effect as REPRO_KERNEL_BACKEND)")
    args = ap.parse_args()
    ctx = dispatch.use_backend(args.backend) if args.backend \
        else contextlib.nullcontext()
    with ctx:
        for r in run():
            print(f"ans_throughput,{r['path']},us={r['us']:.0f},"
                  f"Msym/s={r['msym_per_s']:.2f}")


if __name__ == "__main__":
    main()
