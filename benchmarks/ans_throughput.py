"""ANS coder throughput (symbols/s) - core jnp path and the Pallas
kernel path (interpret mode on CPU: correctness-representative, not
perf-representative; the table reports both with that caveat).

Two parts: the static-table categorical coder (the original rows) and
the *dynamic-leaf* Gaussian path - per-position ``DiscretizedGaussian``
interpreted one symbol at a time vs the codec compiler's fused
multi-step kernels (``push_many`` + ``pop_many_grid``), with MB/s of
produced wire and the compiled/interpreted speedup."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import codecs
from repro.core import ans
from repro.kernels.ans import ops as ans_ops


def _dynamic_gauss_rows(lanes: int, steps: int, seed: int):
    """The dynamic-leaf path: a ``Repeat`` of per-position Gaussians."""
    rng = np.random.default_rng(seed + 1)
    mu = jnp.asarray(rng.normal(0, 1, (lanes, steps)), jnp.float32)
    sigma = jnp.asarray(rng.uniform(0.1, 1.5, (lanes, steps)), jnp.float32)
    bits = 10
    rep = codecs.Repeat(
        lambda d: codecs.DiscretizedGaussian(mu[:, d], sigma[:, d], bits),
        steps)
    # donate=False: the same input stack is timed repeatedly here.
    prog = codecs.compile(rep, donate=False)
    x = jnp.asarray(rng.integers(0, 1 << bits, (lanes, steps)), jnp.int32)
    stack = ans.make_stack(lanes, steps + 8, key=jax.random.PRNGKey(2))
    stack = ans.seed_stack(stack, jax.random.PRNGKey(3), 4)

    full = prog.push(stack, x)              # warm the compiled program
    prog.pop(full)
    us_pi, ref = common.timer(lambda: rep.push(stack, x))
    us_pc, out = common.timer(lambda: prog.push(stack, x))
    assert bool(jnp.array_equal(out.head, ref.head)), "push parity"
    wire_mb = float(jnp.sum(out.ptr - stack.ptr)) * 2 / 1e6
    us_di, _ = common.timer(lambda: rep.pop(full))
    us_dc, _ = common.timer(lambda: prog.pop(full))

    n = lanes * steps
    return [
        {"path": "gauss-interpreted", "us": us_pi,
         "msym_per_s": n / us_pi, "mb_per_s": wire_mb / (us_pi / 1e6),
         "pop_us": us_di, "pop_msym_per_s": n / us_di},
        {"path": "gauss-compiled", "us": us_pc,
         "msym_per_s": n / us_pc, "mb_per_s": wire_mb / (us_pc / 1e6),
         "pop_us": us_dc, "pop_msym_per_s": n / us_dc,
         "speedup_push": us_pi / us_pc, "speedup_pop": us_di / us_dc},
    ]


def run(lanes: int = 256, steps: int = 256, seed: int = 0):
    rng = np.random.default_rng(seed)
    probs = rng.dirichlet(np.ones(16), size=lanes).astype(np.float32)
    table = ans.probs_to_starts(jnp.asarray(probs), 14)
    syms = jnp.asarray(rng.integers(0, 16, (steps, lanes)), jnp.int32)
    tab = np.asarray(table)
    idx = np.arange(lanes)[None]
    starts = jnp.asarray(tab[idx, np.asarray(syms)], jnp.uint32)
    freqs = jnp.asarray(tab[idx, np.asarray(syms) + 1] -
                        tab[idx, np.asarray(syms)], jnp.uint32)

    stack = ans.make_stack(lanes, steps + 8, key=jax.random.PRNGKey(1))

    @jax.jit
    def core_push(stack):
        def body(t, st):
            return ans.push(st, starts[t], freqs[t], 14)
        return jax.lax.fori_loop(0, steps, body, stack)

    us_core, _ = common.timer(core_push, stack)
    us_kernel, _ = common.timer(
        lambda s: ans_ops.push_many(s, starts, freqs, 14), stack)
    n = lanes * steps
    return [{"path": "core-jnp", "us": us_core,
             "msym_per_s": n / us_core},
            {"path": "pallas-interpret", "us": us_kernel,
             "msym_per_s": n / us_kernel}] \
        + _dynamic_gauss_rows(lanes, steps, seed)


def main():
    for r in run():
        print(f"ans_throughput,{r['path']},us={r['us']:.0f},"
              f"Msym/s={r['msym_per_s']:.2f}")


if __name__ == "__main__":
    main()
