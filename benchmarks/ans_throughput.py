"""ANS coder throughput (symbols/s) - core jnp path and the Pallas
kernel path (interpret mode on CPU: correctness-representative, not
perf-representative; the table reports both with that caveat)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import ans
from repro.kernels.ans import ops as ans_ops


def run(lanes: int = 256, steps: int = 256, seed: int = 0):
    rng = np.random.default_rng(seed)
    probs = rng.dirichlet(np.ones(16), size=lanes).astype(np.float32)
    table = ans.probs_to_starts(jnp.asarray(probs), 14)
    syms = jnp.asarray(rng.integers(0, 16, (steps, lanes)), jnp.int32)
    tab = np.asarray(table)
    idx = np.arange(lanes)[None]
    starts = jnp.asarray(tab[idx, np.asarray(syms)], jnp.uint32)
    freqs = jnp.asarray(tab[idx, np.asarray(syms) + 1] -
                        tab[idx, np.asarray(syms)], jnp.uint32)

    stack = ans.make_stack(lanes, steps + 8, key=jax.random.PRNGKey(1))

    @jax.jit
    def core_push(stack):
        def body(t, st):
            return ans.push(st, starts[t], freqs[t], 14)
        return jax.lax.fori_loop(0, steps, body, stack)

    us_core, _ = common.timer(core_push, stack)
    us_kernel, _ = common.timer(
        lambda s: ans_ops.push_many(s, starts, freqs, 14), stack)
    n = lanes * steps
    return [{"path": "core-jnp", "us": us_core,
             "msym_per_s": n / us_core},
            {"path": "pallas-interpret", "us": us_kernel,
             "msym_per_s": n / us_kernel}]


def main():
    for r in run():
        print(f"ans_throughput,{r['path']},us={r['us']:.0f},"
              f"Msym/s={r['msym_per_s']:.2f}")


if __name__ == "__main__":
    main()
