"""Paper Table 3: predicted BB-ANS rates for a *better* model.

The paper predicts BB-ANS rates for PixelVAE from its reported ELBO,
arguing the coder gap stays negligible. We reproduce the methodology at
our scale: train a larger VAE (hidden 400, latent 80), verify the gap is
still ~0, and report predicted = measured for the small model vs the big
model's ELBO-based prediction and its measured rate.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from benchmarks import common
from repro import codecs
from repro.data import synthetic_mnist
from repro.models import vae as vae_lib


def run(train_steps: int = 1500, n_images: int = 256, lanes: int = 16,
        seed: int = 0):
    small = vae_lib.paper_config("bernoulli")
    big = dataclasses.replace(small, hidden=400, latent=80)
    out = []
    for name, cfg in (("paper-vae", small), ("bigger-vae", big)):
        params, neg_elbo = common.train_vae(cfg, steps=train_steps,
                                            seed=seed)
        imgs, _ = synthetic_mnist.load("test", n_images, seed)
        imgs = synthetic_mnist.binarize(imgs, seed + 1)
        n_chain = n_images // lanes
        data = jnp.asarray(
            imgs[:n_chain * lanes].reshape(n_chain, lanes, -1), jnp.int32)
        codec = codecs.Chained(vae_lib.make_bb_codec(params, cfg), n_chain)
        _, info = codecs.compress(codec, data, lanes=lanes, seed=2,
                                  capacity=n_chain * 256 + 512,
                                  with_info=True)
        measured = info["net_bits"] / data.size
        out.append({"model": name, "predicted_bpd": neg_elbo,
                    "measured_bpd": measured,
                    "gap_pct": 100 * (measured - neg_elbo) /
                    max(neg_elbo, 1e-9)})
    return out


def main():
    for r in run():
        print(f"table3,{r['model']},predicted={r['predicted_bpd']:.4f},"
              f"measured={r['measured_bpd']:.4f},gap={r['gap_pct']:.2f}%")


if __name__ == "__main__":
    main()
