"""Throughput-regression differ: fresh ``BENCH_<name>.json`` files vs
the committed ``benchmarks/baselines/`` snapshot.

Rows are matched by their non-numeric identity fields (``bench``,
``path``, ``workload``, ...); numeric *throughput* fields (``mb_per_s``
/ ``msym_per_s`` suffixes, ``speedup_*``) regress when the fresh value
drops more than ``--tolerance`` (default 0.20 = the ISSUE-4 20% bar)
below baseline. Exit status is nonzero on any regression, so CI can
gate on it; CI passes a looser tolerance because hosted-runner hardware
varies run to run (see .github/workflows/ci.yml).

Usage:
    PYTHONPATH=src python -m benchmarks.compare --json-dir .
    PYTHONPATH=src python -m benchmarks.compare --update   # re-baseline
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import sys
from typing import Dict, Tuple

BASELINE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "baselines")

#: numeric row fields where higher is better and a drop is a regression.
#: ``mb_per_s_per_device`` (the headline codec metric since ISSUE-8)
#: matches via the ``per_device`` suffix.
_THROUGHPUT_SUFFIXES = ("mb_per_s", "msym_per_s", "per_device")
_THROUGHPUT_PREFIXES = ("speedup",)


def _is_throughput_key(key: str) -> bool:
    return key.endswith(_THROUGHPUT_SUFFIXES) or \
        key.startswith(_THROUGHPUT_PREFIXES)


def _row_key(row: dict) -> Tuple:
    """Identity of a row = its non-numeric fields, sorted."""
    return tuple(sorted((k, v) for k, v in row.items()
                        if not isinstance(v, (int, float))
                        or isinstance(v, bool)))


def _index(payload: dict) -> Dict[Tuple, dict]:
    return {_row_key(r): r for r in payload.get("rows", [])
            if isinstance(r, dict)}


def compare_file(fresh_path: str, base_path: str,
                 tolerance: float) -> list:
    """Return a list of regression strings (empty = clean)."""
    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(base_path) as f:
        base = json.load(f)
    if fresh.get("failed") or base.get("failed"):
        return [f"{os.path.basename(fresh_path)}: bench marked failed"]
    problems = []
    fresh_rows = _index(fresh)
    for key, brow in _index(base).items():
        frow = fresh_rows.get(key)
        if frow is None:
            problems.append(f"row {dict(key)} missing from fresh run")
            continue
        for field, bval in brow.items():
            if not _is_throughput_key(field):
                continue
            if not isinstance(bval, (int, float)) or bval <= 0:
                continue
            fval = frow.get(field)
            if not isinstance(fval, (int, float)):
                continue
            if fval < bval * (1.0 - tolerance):
                problems.append(
                    f"{dict(key)} {field}: {fval:.4g} < baseline "
                    f"{bval:.4g} (-{(1 - fval / bval) * 100:.1f}%, "
                    f"tolerance {tolerance * 100:.0f}%)")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-dir", default=".",
                    help="directory holding fresh BENCH_<name>.json")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional throughput drop (0.20=20%%)")
    ap.add_argument("--update", action="store_true",
                    help="copy fresh BENCH files into baselines/ "
                         "instead of comparing")
    args = ap.parse_args()

    fresh_files = sorted(glob.glob(
        os.path.join(args.json_dir, "BENCH_*.json")))
    if args.update:
        os.makedirs(BASELINE_DIR, exist_ok=True)
        for path in fresh_files:
            shutil.copy(path, BASELINE_DIR)
            print(f"baselined {os.path.basename(path)}")
        return

    failures = 0
    compared = 0
    for path in fresh_files:
        base = os.path.join(BASELINE_DIR, os.path.basename(path))
        if not os.path.exists(base):
            print(f"{os.path.basename(path)}: no baseline, skipped")
            continue
        compared += 1
        problems = compare_file(path, base, args.tolerance)
        if problems:
            failures += 1
            print(f"{os.path.basename(path)}: REGRESSED")
            for p in problems:
                print(f"  {p}")
        else:
            print(f"{os.path.basename(path)}: ok")
    if not compared:
        # A gate that compared nothing must not pass: baseline names
        # drifting out of sync with the bench output would otherwise
        # silently disable the regression check in CI.
        print("no BENCH files with baselines found", file=sys.stderr)
        sys.exit(2)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
