"""Throughput-regression differ: fresh ``BENCH_<name>.json`` files vs
the committed ``benchmarks/baselines/`` snapshot.

Rows are matched by their non-numeric identity fields (``bench``,
``path``, ``workload``, ...); numeric *throughput* fields (``mb_per_s``
/ ``msym_per_s`` suffixes, ``speedup_*``) regress when the fresh value
drops more than ``--tolerance`` (default 0.20 = the ISSUE-4 20% bar)
below baseline. Exit status is nonzero on any regression, so CI can
gate on it; CI passes a looser tolerance because hosted-runner hardware
varies run to run (see .github/workflows/ci.yml).

Per-metric tolerance overrides (``--metric-tolerance PATTERN=FRAC``,
repeatable) loosen or tighten the bar for fields matching ``PATTERN``
by prefix or suffix - e.g. ``--metric-tolerance speedup_fused=0.8``
for ratio metrics whose numerator AND denominator both move when the
kernel backend changes. A delta table of every
``*_mb_per_s_per_device`` field (baseline -> fresh, x-factor) prints
with each compared file, so CI logs show the headline throughput
movement at a glance.

Usage:
    PYTHONPATH=src python -m benchmarks.compare --json-dir .
    PYTHONPATH=src python -m benchmarks.compare --update   # re-baseline
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import sys
from typing import Dict, List, Tuple

BASELINE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "baselines")

#: numeric row fields where higher is better and a drop is a regression.
#: ``mb_per_s_per_device`` (the headline codec metric since ISSUE-8)
#: matches via the ``per_device`` suffix.
_THROUGHPUT_SUFFIXES = ("mb_per_s", "msym_per_s", "per_device")
_THROUGHPUT_PREFIXES = ("speedup",)

#: descriptive row fields excluded from row identity: newer bench runs
#: annotate rows with these, and the annotation must not orphan the
#: committed baseline rows that predate it.
_META_FIELDS = frozenset({"kernel_backend"})

#: fields whose delta prints with every compared file (the headline
#: codec throughput metric).
_DELTA_SUFFIX = "mb_per_s_per_device"


def _is_throughput_key(key: str) -> bool:
    return key.endswith(_THROUGHPUT_SUFFIXES) or \
        key.startswith(_THROUGHPUT_PREFIXES)


def _row_key(row: dict) -> Tuple:
    """Identity of a row = its non-numeric, non-meta fields, sorted."""
    return tuple(sorted((k, v) for k, v in row.items()
                        if k not in _META_FIELDS
                        and (not isinstance(v, (int, float))
                             or isinstance(v, bool))))


def parse_metric_tolerances(specs: List[str]) -> Dict[str, float]:
    """``["speedup=0.5", "p50_ms=1.0"]`` -> ``{"speedup": 0.5, ...}``."""
    out: Dict[str, float] = {}
    for spec in specs or []:
        pattern, _, frac = spec.partition("=")
        if not pattern or not frac:
            raise SystemExit(
                f"--metric-tolerance {spec!r}: expected PATTERN=FRAC")
        out[pattern] = float(frac)
    return out


def _tolerance_for(field: str, default: float,
                   overrides: Dict[str, float]) -> float:
    """Most specific (longest) matching override wins; else default."""
    best = None
    for pattern, frac in overrides.items():
        if field == pattern or field.startswith(pattern) \
                or field.endswith(pattern):
            if best is None or len(pattern) > len(best[0]):
                best = (pattern, frac)
    return best[1] if best is not None else default


def _index(payload: dict) -> Dict[Tuple, dict]:
    return {_row_key(r): r for r in payload.get("rows", [])
            if isinstance(r, dict)}


def compare_file(fresh_path: str, base_path: str, tolerance: float,
                 metric_tolerances: Dict[str, float] = None
                 ) -> Tuple[list, list]:
    """Compare one fresh BENCH file against its baseline.

    Returns ``(problems, deltas)``: regression strings (empty = clean)
    and printable ``*_mb_per_s_per_device`` delta-table lines.
    """
    overrides = metric_tolerances or {}
    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(base_path) as f:
        base = json.load(f)
    if fresh.get("failed") or base.get("failed"):
        return ([f"{os.path.basename(fresh_path)}: bench marked failed"],
                [])
    problems = []
    deltas = []
    fresh_rows = _index(fresh)
    for key, brow in _index(base).items():
        frow = fresh_rows.get(key)
        if frow is None:
            problems.append(f"row {dict(key)} missing from fresh run")
            continue
        ident = " ".join(str(v) for _, v in key)
        for field, bval in brow.items():
            if not _is_throughput_key(field):
                continue
            if not isinstance(bval, (int, float)) or bval <= 0:
                continue
            fval = frow.get(field)
            if not isinstance(fval, (int, float)):
                continue
            if field.endswith(_DELTA_SUFFIX):
                deltas.append(
                    f"{ident} {field}: {bval:.4g} -> {fval:.4g} "
                    f"(x{fval / bval:.2f})")
            tol = _tolerance_for(field, tolerance, overrides)
            if fval < bval * (1.0 - tol):
                problems.append(
                    f"{dict(key)} {field}: {fval:.4g} < baseline "
                    f"{bval:.4g} (-{(1 - fval / bval) * 100:.1f}%, "
                    f"tolerance {tol * 100:.0f}%)")
    return problems, deltas


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-dir", default=".",
                    help="directory holding fresh BENCH_<name>.json")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional throughput drop (0.20=20%%)")
    ap.add_argument("--metric-tolerance", action="append", default=[],
                    metavar="PATTERN=FRAC",
                    help="per-metric override, matched by prefix/suffix "
                         "(repeatable), e.g. speedup_fused=0.8")
    ap.add_argument("--update", action="store_true",
                    help="copy fresh BENCH files into baselines/ "
                         "instead of comparing")
    args = ap.parse_args()
    overrides = parse_metric_tolerances(args.metric_tolerance)

    fresh_files = sorted(glob.glob(
        os.path.join(args.json_dir, "BENCH_*.json")))
    if args.update:
        os.makedirs(BASELINE_DIR, exist_ok=True)
        for path in fresh_files:
            shutil.copy(path, BASELINE_DIR)
            print(f"baselined {os.path.basename(path)}")
        return

    failures = 0
    compared = 0
    for path in fresh_files:
        base = os.path.join(BASELINE_DIR, os.path.basename(path))
        if not os.path.exists(base):
            print(f"{os.path.basename(path)}: no baseline, skipped")
            continue
        compared += 1
        problems, deltas = compare_file(path, base, args.tolerance,
                                        overrides)
        if problems:
            failures += 1
            print(f"{os.path.basename(path)}: REGRESSED")
            for p in problems:
                print(f"  {p}")
        else:
            print(f"{os.path.basename(path)}: ok")
        for d in deltas:
            print(f"  {d}")
    if not compared:
        # A gate that compared nothing must not pass: baseline names
        # drifting out of sync with the bench output would otherwise
        # silently disable the regression check in CI.
        print("no BENCH files with baselines found", file=sys.stderr)
        sys.exit(2)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
