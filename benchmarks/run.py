"""Benchmark harness entry point - one function per paper table/figure
plus the framework's own perf benches. Prints ``name,...`` CSV lines
and, next to them, writes a machine-readable ``BENCH_<name>.json`` per
bench (rows + wall time) so the perf trajectory can be tracked across
commits; CI uploads the JSON files as artifacts.

Full runs: PYTHONPATH=src python -m benchmarks.run
Quick run: PYTHONPATH=src python -m benchmarks.run --quick
One bench: PYTHONPATH=src python -m benchmarks.run --only stream_throughput
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def _write_json(out_dir: str, name: str, payload: dict) -> str:
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=str)
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced image counts / training steps")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json-dir", default=".",
                    help="directory for the BENCH_<name>.json files")
    args = ap.parse_args()

    from benchmarks import (ablation_cleanbits, ans_throughput,
                            codec_compile, dataset_rate, fig3_chain,
                            hvae_rate, latent_lm_gain, lm_compression,
                            loadgen, stream_throughput, table2_rates,
                            table3_predict)

    q = args.quick
    benches = {
        "table2": lambda: table2_rates.run(
            n_images=128 if q else 512, train_steps=400 if q else 2500),
        "fig3": lambda: fig3_chain.run(
            n_images=128 if q else 480, train_steps=300 if q else 1200)[0],
        "table3": lambda: table3_predict.run(
            train_steps=300 if q else 1500, n_images=64 if q else 256),
        "ablation": lambda: ablation_cleanbits.run(
            train_steps=300 if q else 1000, n_images=64 if q else 128),
        "ans_throughput": lambda: ans_throughput.run(
            lanes=128 if q else 256, steps=64 if q else 256),
        "codec_compile": lambda: codec_compile.run(
            lanes=4 if q else 8, n_chain=2 if q else 4,
            hw=8 if q else 12),
        "lm_compression": lambda: lm_compression.run(
            train_steps=120 if q else 250),
        "latent_lm_gain": lambda: latent_lm_gain.run(
            train_steps=120 if q else 300),
        "hvae_rate": lambda: hvae_rate.run(
            train_steps=400 if q else 1500, n_images=32 if q else 128),
        "stream": lambda: stream_throughput.run(
            lanes=64 if q else 128, n_symbols=1024 if q else 4096,
            block=128 if q else 512, n_images=64 if q else 256,
            vae_lanes=16 if q else 32,
            train_steps=300 if q else 1500),
        "dataset_rate": lambda: dataset_rate.run(
            train_steps=300 if q else 1500,
            n_images=256 if q else 2048),
        "loadgen": lambda: loadgen.run(
            clients=4 if q else 8, block_symbols=8 if q else 16,
            max_blocks=3 if q else 5)
        + loadgen.run_cluster(
            clients=4 if q else 6, block_symbols=8 if q else 16,
            max_blocks=3 if q else 5),
    }
    # historical/module aliases for --only (e.g. CI's stream_throughput)
    aliases = {"stream_throughput": "stream", "table2_rates": "table2",
               "table3_predict": "table3"}
    only = aliases.get(args.only, args.only)
    if only and only not in benches:
        print(f"unknown bench {args.only!r}; choose from "
              f"{sorted(benches)}", file=sys.stderr)
        sys.exit(2)

    failures = 0
    for name, fn in benches.items():
        if only and name != only:
            continue
        t0 = time.time()
        try:
            rows = fn()
            dt = time.time() - t0
            us = dt * 1e6 / max(len(rows), 1)
            for row in rows:
                if isinstance(row, dict):
                    payload = ",".join(
                        f"{k}={v:.4f}" if isinstance(v, float) else
                        f"{k}={v}" for k, v in row.items())
                else:
                    payload = ",".join(
                        f"{v:.4f}" if isinstance(v, float) else str(v)
                        for v in row)
                print(f"{name},{us:.0f},{payload}", flush=True)
            path = _write_json(args.json_dir, name, {
                "bench": name, "quick": q, "elapsed_s": dt,
                "rows": [row if isinstance(row, dict)
                         else {"values": list(row)} for row in rows],
            })
            print(f"{name},json,{path}", flush=True)
        except Exception:
            failures += 1
            dt = time.time() - t0
            print(f"{name},FAILED", flush=True)
            traceback.print_exc()
            _write_json(args.json_dir, name, {
                "bench": name, "quick": q, "elapsed_s": dt,
                "failed": True, "error": traceback.format_exc(),
            })
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
