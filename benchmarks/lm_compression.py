"""Beyond-paper: neural lossless compression of token streams.

(a) direct LM-ANS: train a small LM on an order-1 Markov corpus with a
    *known* entropy rate; achieved bits/token should approach the entropy
    floor and beat generic codecs;
(b) LatentLM bits-back: on a regime-mixture corpus (each sequence drawn
    from one of 4 Markov regimes), the per-sequence latent captures the
    regime and -ELBO < plain LM cross-entropy => bits-back wins.
"""

from __future__ import annotations

import bz2
import gzip

import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs
from repro.configs import base as cfg_base
from repro.data import pipeline, tokens as tok_data
from repro.serve.engine import Engine
from repro.train import trainer

import dataclasses


def run(train_steps: int = 250, seed: int = 0):
    cfg = dataclasses.replace(
        cfg_base.reduced(cfg_base.get("qwen2-0.5b"), layers=2, width=96),
        vocab=256, loss_chunk=64)
    corpus, entropy = tok_data.markov_corpus(120_000, vocab=256, seed=seed)
    opt = trainer.make_optimizer(cfg, lr=3e-3, total_steps=train_steps)
    state = trainer.init_state(jax.random.PRNGKey(seed), cfg, opt)
    step = jax.jit(trainer.make_train_step(cfg, opt))
    batch_fn = pipeline.lm_batch_fn(corpus, batch=16, seq=64)
    for i in range(train_steps):
        state, metrics = step(state, jax.tree_util.tree_map(
            jnp.asarray, batch_fn(seed, i, 0, 1)))
    model_bpt = float(metrics["bits_per_token"])

    # Compress held-out streams.
    lanes, n = 8, 96
    rng = np.random.default_rng(seed + 99)
    start = rng.integers(0, len(corpus) - n, lanes)
    toks = jnp.asarray(np.stack([corpus[s:s + n] for s in start]),
                       jnp.int32)
    eng = Engine(state.params, cfg, max_len=n, jit=False)
    blob = eng.compress(toks)
    out = eng.decompress(blob, n)
    assert bool(jnp.array_equal(out, toks)), "lossless violated"
    achieved_bpt = codecs.blob_info(blob)["payload_bits"] / toks.size

    payload = np.asarray(toks, np.uint8).tobytes()
    gzip_bpt = len(gzip.compress(payload, 9)) * 8 / toks.size
    bz2_bpt = len(bz2.compress(payload, 9)) * 8 / toks.size
    return [{
        "bench": "lm_ans", "entropy_floor_bpt": entropy,
        "model_ce_bpt": model_bpt, "achieved_bpt": achieved_bpt,
        "gzip_bpt": gzip_bpt, "bz2_bpt": bz2_bpt,
        "flush_overhead_bpt": 32.0 * lanes / toks.size,
    }]


def main():
    for r in run():
        print(f"lm_compression,entropy={r['entropy_floor_bpt']:.3f},"
              f"model_ce={r['model_ce_bpt']:.3f},"
              f"achieved={r['achieved_bpt']:.3f},"
              f"gzip={r['gzip_bpt']:.3f},bz2={r['bz2_bpt']:.3f}")


if __name__ == "__main__":
    main()
