"""Streaming codec throughput + rate parity vs one-shot compression.

Two parts:

  * ``categorical`` - raw coder throughput (MB/s of produced wire,
    Msym/s) for the one-shot container vs the chunked ``repro.stream``
    path, python block coder and kernel (``push_many_table``/
    ``pop_many``) fast path. Run on CPU with the Pallas interpreter
    this is correctness-representative, not perf-representative.
  * ``vae_rate`` - the acceptance check for chunked streaming: the
    table2 VAE workload coded one-shot (``codecs.Chained`` +
    ``codecs.compress``) and streamed in >= 3 blocks with carried
    heads; reports both net rates (the -ELBO-comparable metric table2
    uses) and honest wire bits/dim including framing. The streamed
    net rate must track one-shot within ~1%.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import codecs, stream
from repro.core.distributions import Categorical
from repro.data import synthetic_mnist
from repro.models import vae as vae_lib


def _categorical_rows(lanes: int, n_symbols: int, block: int, seed: int):
    rng = np.random.default_rng(seed)
    probs = np.tile(rng.dirichlet(np.ones(32))[None], (lanes, 1))
    logits = jnp.asarray(np.log(probs + 1e-9), jnp.float32)
    codec = Categorical(logits, precision=14)
    syms = jnp.asarray(
        rng.choice(32, size=(n_symbols, lanes), p=probs[0]), jnp.int32)
    entropy = float(-np.sum(probs[0] * np.log2(probs[0])))

    rows = []

    def measure(name, fn):
        us, blob = common.timer(fn, repeats=3)
        wire_bits = len(blob) * 8
        rows.append({
            "bench": "categorical", "path": name,
            "mb_per_s": len(blob) / 1e6 / (us / 1e6),
            "msym_per_s": syms.size / us,
            "bits_per_sym": wire_bits / syms.size,
            "entropy": entropy,
        })
        return blob

    one = measure("oneshot", lambda: codecs.compress(
        stream.BlockChain(codec, n_symbols), syms, lanes=lanes,
        seed=None, init_chunks=0, capacity=n_symbols + 64))
    b_py = measure("stream-py", lambda: stream.encode_stream(
        codec, syms, lanes=lanes, block_symbols=block, seed=None,
        use_kernel=False))
    b_k = measure("stream-kernel", lambda: stream.encode_stream(
        codec, syms, lanes=lanes, block_symbols=block, seed=None,
        use_kernel=True))
    assert b_py == b_k, "kernel fast path must be byte-identical"
    b_c = measure("stream-compiled", lambda: stream.encode_stream(
        codec, syms, lanes=lanes, block_symbols=block, seed=None,
        compile=True))
    assert b_c == b_k, "compiled path must be byte-identical"

    out = stream.decode_stream(codec, b_k)
    assert bool(jnp.array_equal(out, syms)), "stream decode mismatch"
    out1 = codecs.decompress(stream.BlockChain(codec, n_symbols), one)
    assert bool(jnp.array_equal(out1, syms)), "one-shot decode mismatch"
    return rows


def _vae_rate_rows(n_images: int, lanes: int, train_steps: int,
                   seed: int):
    cfg = vae_lib.paper_config("beta_binomial")
    params, neg_elbo = common.train_vae(cfg, steps=train_steps, seed=seed)
    test_imgs, _ = synthetic_mnist.load("test", n_images, seed)
    n_chain = n_images // lanes
    data = jnp.asarray(
        test_imgs[:n_chain * lanes].reshape(n_chain, lanes, -1),
        jnp.int32)
    codec = vae_lib.make_bb_codec(params, cfg)
    cap = int(n_chain * 16384 / 16) + 256

    t0 = time.perf_counter()
    blob, info = codecs.compress(codecs.Chained(codec, n_chain), data,
                                 lanes=lanes, seed=9, capacity=cap,
                                 with_info=True)
    one_s = time.perf_counter() - t0
    one_rate = info["net_bits"] / data.size

    # Compiled one-shot: byte-identical wire, one fused jit program
    # (timed after a warmup encode so trace/compile cost is excluded).
    prog = codecs.compile(codecs.Chained(codec, n_chain))
    blob_c = codecs.compress(prog, data, lanes=lanes, seed=9,
                             capacity=cap)
    assert blob_c == blob, "compiled one-shot must be byte-identical"
    t0 = time.perf_counter()
    codecs.compress(prog, data, lanes=lanes, seed=9, capacity=cap)
    compiled_s = time.perf_counter() - t0

    block = max(1, n_chain // 4)   # >= 3 block boundaries
    t0 = time.perf_counter()
    enc = stream.StreamEncoder(codec, lanes=lanes, block_symbols=block,
                               seed=9, init_chunks=32)
    wire = enc.write(data) + enc.flush()
    stream_s = time.perf_counter() - t0
    stream_rate = enc.net_bits / data.size

    enc_c = stream.StreamEncoder(codec, lanes=lanes, block_symbols=block,
                                 seed=9, init_chunks=32, compile=True)
    wire_c = enc_c.write(data) + enc_c.flush()
    assert wire_c == wire, "compiled stream must be byte-identical"

    out = stream.decode_stream(codec, wire)
    assert bool(jnp.array_equal(out, data)), "streamed decode mismatch"

    return [{
        "bench": "vae_rate", "neg_elbo_bpd": neg_elbo,
        "oneshot_bpd": one_rate, "stream_bpd": stream_rate,
        "ratio": stream_rate / one_rate,
        "blocks": enc.n_blocks,
        "stream_wire_bpd": len(wire) * 8 / data.size,
        "oneshot_wire_bpd": len(blob) * 8 / data.size,
        "oneshot_s": one_s, "stream_s": stream_s,
        "compiled_oneshot_s": compiled_s,
        "speedup_compiled": one_s / compiled_s,
        "images": n_chain * lanes,
    }]


def run(lanes: int = 64, n_symbols: int = 2048, block: int = 256,
        n_images: int = 128, vae_lanes: int = 16,
        train_steps: int = 400, seed: int = 0):
    rows = _categorical_rows(lanes, n_symbols, block, seed)
    rows += _vae_rate_rows(n_images, vae_lanes, train_steps, seed)
    return rows


def main():
    for r in run():
        print(",".join(f"{k}={v:.4f}" if isinstance(v, float) else
                       f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
