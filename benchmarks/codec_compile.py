"""Compiled-vs-interpreted end-to-end codec latency (the compiler's
acceptance bench).

Two workloads, both through the one-call container so the timings are
what a service actually pays:

  * ``vae``  - the table2 MNIST VAE (BBANS over Gaussian posterior +
    Bernoulli pixels), chained over ``n_chain`` datapoints.
  * ``hvae`` - the 2-level Bit-Swap ResNet-VAE on HxW images (all-
    dynamic Gaussian grids - the paper path the compiler targets).

For each, the interpreted combinator tree and its ``codecs.compile``d
program encode and decode the same data; blobs are asserted
byte-identical, and the table reports wall time, MB/s of wire, and the
compiled/interpreted speedups. The ISSUE-4 acceptance bar is >= 3x on
the dynamic-leaf (Gaussian) paths at quick settings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import codecs
from repro.models import hvae, vae as vae_lib


def _roundtrip_rows(name: str, interp, prog, data, lanes: int,
                    kwargs: dict):
    """Time (encode, decode) x (interpreted, compiled); assert parity."""
    enc_i = lambda: codecs.compress(interp, data, lanes=lanes, **kwargs)
    enc_c = lambda: codecs.compress(prog, data, lanes=lanes, **kwargs)
    blob = enc_c()   # warm the compiled program (trace + compile once)
    assert blob == enc_i(), f"{name}: compiled wire differs"
    us_enc_i, _ = common.timer(enc_i)
    us_enc_c, _ = common.timer(enc_c)

    dec_i = lambda: codecs.decompress(interp, blob)
    dec_c = lambda: codecs.decompress(prog, blob)
    out = dec_c()    # warm decode
    assert bool(jnp.array_equal(out, data)), f"{name}: decode mismatch"
    us_dec_i, _ = common.timer(dec_i)
    us_dec_c, _ = common.timer(dec_c)

    mb = len(blob) / 1e6
    rows = []
    for path, ue, ud in (("interpreted", us_enc_i, us_dec_i),
                         ("compiled", us_enc_c, us_dec_c)):
        rows.append({
            "workload": name, "path": path,
            "encode_s": ue / 1e6, "decode_s": ud / 1e6,
            "enc_mb_per_s": mb / (ue / 1e6),
            "dec_mb_per_s": mb / (ud / 1e6),
        })
    rows[-1]["speedup_encode"] = us_enc_i / us_enc_c
    rows[-1]["speedup_decode"] = us_dec_i / us_dec_c
    return rows


def run(lanes: int = 4, n_chain: int = 2, hw: int = 8, seed: int = 0):
    rng = np.random.default_rng(seed)
    rows = []

    # table2 VAE workload (untrained params: latency only, rate is not
    # the point here; coding is bit-identical either way).
    cfg = vae_lib.paper_config("bernoulli")
    params = vae_lib.init(jax.random.PRNGKey(seed), cfg)
    data = jnp.asarray(
        rng.integers(0, 2, (n_chain, lanes, cfg.input_dim)), jnp.int32)
    chained = codecs.Chained(vae_lib.make_bb_codec(params, cfg), n_chain)
    prog = codecs.compile(chained)
    rows += _roundtrip_rows(
        "vae", chained, prog, data, lanes,
        dict(seed=seed, init_chunks=64, capacity=4096))

    # HVAE-L2 Bit-Swap workload: every layer a dynamic Gaussian grid.
    hcfg = hvae.HVAEConfig(levels=2, ch=8, z_ch=2, n_res=1)
    hparams = hvae.init(jax.random.PRNGKey(seed + 1), hcfg)
    imgs = jnp.asarray(
        rng.integers(0, 2, (n_chain, lanes, hw, hw)), jnp.int32)
    hcodec = codecs.Chained(
        hvae.make_bitswap_codec(hparams, hcfg, (hw, hw)), n_chain)
    hprog = codecs.compile(hcodec)
    rows += _roundtrip_rows(
        "hvae-l2", hcodec, hprog, imgs, lanes,
        dict(seed=seed, init_chunks=64, capacity=4096))
    return rows


def main():
    for r in run():
        print(",".join(f"{k}={v:.4f}" if isinstance(v, float) else
                       f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
