"""Compiled-vs-interpreted end-to-end codec latency (the compiler's
acceptance bench).

Workloads, all through the one-call container so the timings are what a
service actually pays:

  * ``vae``  - the table2 MNIST VAE (BBANS over Gaussian posterior +
    Bernoulli pixels), chained over ``n_chain`` datapoints.
  * ``hvae-l2`` - the 2-level Bit-Swap ResNet-VAE on HxW images (all-
    dynamic Gaussian grids - the paper path the compiler targets).
  * ``vae-fixedpoint`` / ``hvae-l2-fixedpoint`` - the same models with
    integer-quantized inference (``codecs.quantize``), where the model
    forward, bucketize, and ANS renorm all live in ONE jitted program
    per coder direction (``codecs.compile`` fuses ``FixedPointFn``
    children).

For each workload the interpreted tree and its compiled program encode
and decode the same data; blobs are asserted byte-identical (for the
fixed-point rows, the eager interpreter runs the very same quantized
integer network, so the fused wire is checked hex-for-hex against the
eager one). The headline metric is wire MB/s *per device*
(``enc_mb_per_s_per_device``/``dec_mb_per_s_per_device``); fixed-point
rows also report ``speedup_fused_vs_float_*`` - fused one-program
latency against the float compiled path - which the ISSUE-8 acceptance
bar requires to be >= 3x on both workloads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import codecs
from repro.kernels import dispatch
from repro.models import hvae, vae as vae_lib


def _roundtrip_rows(name: str, interp, prog, data, lanes: int,
                    kwargs: dict):
    """Time (encode, decode) x (interpreted, compiled); assert parity."""
    n_dev = jax.device_count()
    n_dp = data.shape[0] * data.shape[1]   # chained datapoints x lanes
    enc_i = lambda: codecs.compress(interp, data, lanes=lanes, **kwargs)
    enc_c = lambda: codecs.compress(prog, data, lanes=lanes, **kwargs)
    blob = enc_c()   # warm the compiled program (trace + compile once)
    assert blob == enc_i(), f"{name}: compiled wire differs"
    us_enc_i, _ = common.timer(enc_i)
    us_enc_c, _ = common.timer(enc_c)

    dec_i = lambda: codecs.decompress(interp, blob)
    dec_c = lambda: codecs.decompress(prog, blob)
    out = dec_c()    # warm decode
    assert bool(jnp.array_equal(out, data)), f"{name}: decode mismatch"
    us_dec_i, _ = common.timer(dec_i)
    us_dec_c, _ = common.timer(dec_c)

    mb = len(blob) / 1e6
    rows = []
    for path, ue, ud in (("interpreted", us_enc_i, us_dec_i),
                         ("compiled", us_enc_c, us_dec_c)):
        rows.append({
            "workload": name, "path": path,
            "encode_s": ue / 1e6, "decode_s": ud / 1e6,
            "enc_mb_per_s": mb / (ue / 1e6),
            "dec_mb_per_s": mb / (ud / 1e6),
            "enc_mb_per_s_per_device": mb / (ue / 1e6) / n_dev,
            "dec_mb_per_s_per_device": mb / (ud / 1e6) / n_dev,
            # roofline inputs (launch/roofline.py): wire size and how
            # many datapoints produced it, plus the coder backend the
            # dispatcher resolved for this run's lane count.
            "wire_mb": mb, "n_datapoints": n_dp,
            "kernel_backend": dispatch.resolve(
                "push_many", lanes=lanes).backend,
        })
    rows[-1]["speedup_encode"] = us_enc_i / us_enc_c
    rows[-1]["speedup_decode"] = us_dec_i / us_dec_c
    return rows


def run(lanes: int = 4, n_chain: int = 2, hw: int = 8, seed: int = 0):
    rng = np.random.default_rng(seed)
    rows = []

    # table2 VAE workload (untrained params: latency only, rate is not
    # the point here; coding is bit-identical either way).
    cfg = vae_lib.paper_config("bernoulli")
    params = vae_lib.init(jax.random.PRNGKey(seed), cfg)
    data = jnp.asarray(
        rng.integers(0, 2, (n_chain, lanes, cfg.input_dim)), jnp.int32)
    chained = codecs.Chained(vae_lib.make_bb_codec(params, cfg), n_chain)
    prog = codecs.compile(chained)
    kwargs = dict(seed=seed, init_chunks=64, capacity=4096)
    vae_rows = _roundtrip_rows("vae", chained, prog, data, lanes, kwargs)
    rows += vae_rows

    # Fixed-point VAE: fused single-program coder (model forward +
    # bucketize + renorm in one jit). Its interpreted twin runs the
    # same integer network eagerly, so wire parity is exact.
    q_chained = codecs.Chained(
        vae_lib.make_bb_codec_q(params, cfg), n_chain)
    q_prog = codecs.compile(q_chained)
    q_rows = _roundtrip_rows(
        "vae-fixedpoint", q_chained, q_prog, data, lanes, kwargs)
    q_rows[-1]["speedup_fused_vs_float_encode"] = \
        vae_rows[-1]["encode_s"] / q_rows[-1]["encode_s"]
    q_rows[-1]["speedup_fused_vs_float_decode"] = \
        vae_rows[-1]["decode_s"] / q_rows[-1]["decode_s"]
    rows += q_rows

    # HVAE-L2 Bit-Swap workload: every layer a dynamic Gaussian grid.
    hcfg = hvae.HVAEConfig(levels=2, ch=8, z_ch=2, n_res=1)
    hparams = hvae.init(jax.random.PRNGKey(seed + 1), hcfg)
    imgs = jnp.asarray(
        rng.integers(0, 2, (n_chain, lanes, hw, hw)), jnp.int32)
    hcodec = codecs.Chained(
        hvae.make_bitswap_codec(hparams, hcfg, (hw, hw)), n_chain)
    hprog = codecs.compile(hcodec)
    hvae_rows = _roundtrip_rows(
        "hvae-l2", hcodec, hprog, imgs, lanes, kwargs)
    rows += hvae_rows

    # Fixed-point HVAE: fused Bit-Swap schedule (int conv/deconv
    # resnet + LUT heads inside the coder program).
    hq_codec = codecs.Chained(
        hvae.make_bitswap_codec_q(hparams, hcfg, (hw, hw)), n_chain)
    hq_prog = codecs.compile(hq_codec)
    hq_rows = _roundtrip_rows(
        "hvae-l2-fixedpoint", hq_codec, hq_prog, imgs, lanes, kwargs)
    for r in hq_rows:
        r["hw"] = hw   # roofline input: image side of this run
    hq_rows[-1]["speedup_fused_vs_float_encode"] = \
        hvae_rows[-1]["encode_s"] / hq_rows[-1]["encode_s"]
    hq_rows[-1]["speedup_fused_vs_float_decode"] = \
        hvae_rows[-1]["decode_s"] / hq_rows[-1]["decode_s"]
    rows += hq_rows
    return rows


def main():
    for r in run():
        print(",".join(f"{k}={v:.4f}" if isinstance(v, float) else
                       f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
