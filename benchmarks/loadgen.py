"""Concurrent ragged-client load generator for the ``repro.gateway``
serving tier.

``clients`` asyncio clients, each with a different-length corpus, open
streaming sessions against one admission-controlled gateway and write
their blocks concurrently; the bench reports per-write latency
percentiles (``p50_ms``/``p99_ms``), end-to-end **goodput** (payload MB/s
actually delivered to finished, valid wires), and the single-client
synchronous streaming baseline on the same corpus for comparison
(``goodput_ratio`` - the acceptance bar is >= 0.9, i.e. the gateway's
scheduling overhead costs < 10%).

Wire bytes are asserted byte-identical to the synchronous
``CodecEngine.compress_stream`` path for every client - the gateway
schedules, it never recodes.

``run_cluster`` drives the same ragged clients through a multi-host
``GatewayCluster`` (one event loop per host) and **kills one host
mid-run**: the killed host's streams fail over to peers via replicated
recovery records, every finished wire is still asserted byte-identical
to the synchronous path, and the row reports cross-host goodput
against the same single-host synchronous baseline (the ISSUE-10
acceptance bar is ``goodput_ratio`` >= 0.85 with ``lane_leak`` 0).

Fields ending in ``mb_per_s`` are gated by ``benchmarks/compare.py``
against the committed baseline (CI's "Gateway smoke" and "Cluster
smoke" steps); latency fields are reported but not gated (they are not
higher-is-better).

Usage:
    PYTHONPATH=src python -m benchmarks.loadgen --quick
    PYTHONPATH=src python -m benchmarks.loadgen --quick --cluster
    PYTHONPATH=src python -m benchmarks.run --only loadgen
"""

from __future__ import annotations

import argparse
import asyncio
import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro import codecs
from repro.gateway import Backpressure, Gateway
from repro.serve import CodecEngine


def _family(bits: int = 8):
    def make(shape):
        n = int(np.prod(shape))
        return codecs.Shaped(
            codecs.Repeat(lambda d: codecs.Uniform(bits), n),
            tuple(shape))
    return make


def _percentile(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def run(clients: int = 6, lanes: int = 2, block_symbols: int = 16,
        shape=(8, 8), min_blocks: int = 2, max_blocks: int = 5,
        seed: int = 0, max_workers: int = 1):
    # One worker thread by default: CPU JAX is internally parallel, so
    # extra gateway workers only contend and blur the goodput-vs-
    # baseline comparison; the concurrency under test is admission.
    rng = np.random.default_rng(seed)
    eng = CodecEngine(_family(), seed=seed, init_chunks=0,
                      max_inflight_lanes=max(2, clients // 2) * lanes)
    corpora = []
    for _ in range(clients):
        k = int(rng.integers(min_blocks, max_blocks + 1))
        corpora.append(jnp.asarray(
            rng.integers(0, 256, (k * block_symbols, lanes, *shape)),
            jnp.int32))
    total_bytes = sum(int(d.size) for d in corpora)   # 8-bit symbols

    # Warmup per client (trace/codec registration and first-call JIT
    # compile out of the measurement): every client's corpus takes one
    # synchronous streaming pass over its first block, so the measured
    # p50/p99 are steady-state write latencies, not compile time.
    for d in corpora:
        eng.compress_stream(d[:block_symbols],
                            block_symbols=block_symbols)
    t0 = time.perf_counter()
    base_wires = [eng.compress_stream(d, block_symbols=block_symbols)
                  for d in corpora]
    base_s = time.perf_counter() - t0

    latencies_ms = []
    wires = [b""] * clients
    rejected_retries = 0

    async def client(gw: Gateway, i: int):
        nonlocal rejected_retries
        data = corpora[i]
        while True:
            try:
                sess = await gw.open_stream(
                    shape, lanes=lanes, session_id=f"load-{i}",
                    tenant=f"tenant-{i % 3}",
                    block_symbols=block_symbols)
                break
            except Backpressure as e:   # bounded queue: back off, retry
                rejected_retries += 1
                await asyncio.sleep(e.retry_after)
        wire = b""
        for start in range(0, int(data.shape[0]), block_symbols):
            t = time.perf_counter()
            wire += await sess.write(data[start:start + block_symbols])
            latencies_ms.append((time.perf_counter() - t) * 1e3)
        wire += await sess.close()
        wires[i] = wire

    async def drive():
        async with Gateway(eng, queue_depth=clients,
                           max_workers=max_workers) as gw:
            await asyncio.gather(*(client(gw, i)
                                   for i in range(clients)))
            return gw.stats()

    t0 = time.perf_counter()
    stats = asyncio.run(drive())
    gw_s = time.perf_counter() - t0

    for i, (w, b) in enumerate(zip(wires, base_wires)):
        assert w == b, f"client {i}: gateway wire != synchronous wire"

    goodput = total_bytes / 1e6 / gw_s
    baseline = total_bytes / 1e6 / base_s
    return [{
        "bench": "loadgen", "workload": "ragged-stream",
        "clients": clients, "lanes": lanes,
        "blocks": sum(int(d.shape[0]) // block_symbols
                      for d in corpora),
        "payload_mb": total_bytes / 1e6,
        "goodput_mb_per_s": goodput,
        "baseline_mb_per_s": baseline,
        "goodput_ratio": goodput / baseline,
        "p50_ms": _percentile(latencies_ms, 50),
        "p99_ms": _percentile(latencies_ms, 99),
        "backpressure_retries": rejected_retries,
        "deadline_exceeded": stats["deadline_exceeded"],
        "lane_leak": stats["inflight_lanes"],   # must be 0
    }]


def run_cluster(hosts: int = 2, clients: int = 6, lanes: int = 2,
                block_symbols: int = 16, shape=(8, 8),
                min_blocks: int = 2, max_blocks: int = 5,
                seed: int = 0, max_workers: int = 1):
    """Ragged clients across a multi-host cluster with one injected
    mid-run host kill; returns one ``workload="cluster-stream"`` row."""
    import tempfile

    from repro.gateway import GatewayCluster, TenantQuota

    rng = np.random.default_rng(seed)
    budget = max(2, clients // 2) * lanes
    ref = CodecEngine(_family(), seed=seed, init_chunks=0,
                      max_inflight_lanes=budget)
    host_engines = [CodecEngine(_family(), seed=seed, init_chunks=0,
                                max_inflight_lanes=budget)
                    for _ in range(hosts)]
    corpora = []
    for _ in range(clients):
        k = int(rng.integers(min_blocks, max_blocks + 1))
        corpora.append(jnp.asarray(
            rng.integers(0, 256, (k * block_symbols, lanes, *shape)),
            jnp.int32))
    total_bytes = sum(int(d.size) for d in corpora)

    # Warmup every engine (host engines each JIT their own programs)
    # so the measured window is steady-state scheduling, not compiles.
    for eng in [ref] + host_engines:
        eng.compress_stream(corpora[0][:block_symbols],
                            block_symbols=block_symbols)
    t0 = time.perf_counter()
    base_wires = [ref.compress_stream(d, block_symbols=block_symbols)
                  for d in corpora]
    base_s = time.perf_counter() - t0

    latencies_ms = []
    wires = [b""] * clients
    rejected_retries = 0
    killed = [None]

    async def client(cluster, i: int):
        nonlocal rejected_retries
        data = corpora[i]
        while True:
            try:
                sess = await cluster.open_stream(
                    shape, lanes=lanes, session_id=f"load-{i}",
                    tenant=f"tenant-{i % 3}",
                    block_symbols=block_symbols)
                break
            except Backpressure as e:
                rejected_retries += 1
                await asyncio.sleep(e.retry_after)
        wire = b""
        for start in range(0, int(data.shape[0]), block_symbols):
            t = time.perf_counter()
            wire += await sess.write(data[start:start + block_symbols])
            latencies_ms.append((time.perf_counter() - t) * 1e3)
            if i == 0 and start == 0 and killed[0] is None:
                # The injected fault: whichever host serves client 0
                # dies after its first block; its streams fail over.
                killed[0] = sess.host
                await cluster.kill_host(sess.host)
        wire += await sess.close()
        wires[i] = wire

    async def drive(tmp: str):
        cluster = GatewayCluster(
            host_engines, loop_per_host=True, recovery_root=tmp,
            queue_depth=clients,
            default_quota=TenantQuota(max_lanes=budget,
                                      max_queued=clients),
            max_workers=max_workers)
        async with cluster:
            await asyncio.gather(*(client(cluster, i)
                                   for i in range(clients)))
            return cluster.stats()

    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory() as tmp:
        stats = asyncio.run(drive(tmp))
    gw_s = time.perf_counter() - t0

    for i, (w, b) in enumerate(zip(wires, base_wires)):
        assert w == b, (f"client {i}: cluster wire != synchronous wire "
                        f"(killed {killed[0]})")
    assert stats["failovers"] >= 1, "the injected kill failed over "\
        "no streams"

    goodput = total_bytes / 1e6 / gw_s
    baseline = total_bytes / 1e6 / base_s
    return [{
        "bench": "loadgen", "workload": "cluster-stream",
        "hosts": hosts, "clients": clients, "lanes": lanes,
        "blocks": sum(int(d.shape[0]) // block_symbols
                      for d in corpora),
        "payload_mb": total_bytes / 1e6,
        "goodput_mb_per_s": goodput,
        "baseline_mb_per_s": baseline,
        "goodput_ratio": goodput / baseline,
        "p50_ms": _percentile(latencies_ms, 50),
        "p99_ms": _percentile(latencies_ms, 99),
        "backpressure_retries": rejected_retries,
        "failovers": stats["failovers"],
        "lane_leak": stats["cluster_held_lanes"]
        + stats["inflight_lanes"],   # must be 0
    }]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer clients / smaller corpora (CI smoke)")
    ap.add_argument("--cluster", action="store_true",
                    help="also run the multi-host cluster loadgen "
                         "(one injected host kill)")
    ap.add_argument("--hosts", type=int, default=2)
    ap.add_argument("--json-dir", default=".",
                    help="directory for BENCH_loadgen.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    t0 = time.time()
    rows = run(clients=4 if args.quick else 8,
               block_symbols=8 if args.quick else 16,
               max_blocks=3 if args.quick else 5,
               seed=args.seed)
    if args.cluster:
        rows += run_cluster(hosts=args.hosts,
                            clients=4 if args.quick else 6,
                            block_symbols=8 if args.quick else 16,
                            max_blocks=3 if args.quick else 5,
                            seed=args.seed)
    payload = {"bench": "loadgen", "quick": args.quick,
               "elapsed_s": time.time() - t0, "rows": rows}
    path = os.path.join(args.json_dir, "BENCH_loadgen.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    for r in rows:
        print(",".join(f"{k}={v:.4f}" if isinstance(v, float) else
                       f"{k}={v}" for k, v in r.items()))
    print(f"loadgen,json,{path}")


if __name__ == "__main__":
    main()
