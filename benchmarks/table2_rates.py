"""Paper Table 2: BB-ANS compression rates vs -ELBO and generic codecs.

Binarized + full synthetic-MNIST (real MNIST unavailable offline -
DESIGN.md section 6; the paper's own numbers are printed alongside for
reference). For each dataset: train the paper's VAE, chain-compress the
test set with BB-ANS, verify exact decompression, report bits/dim.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro import codecs
from repro.data import synthetic_mnist
from repro.models import vae as vae_lib

PAPER = {  # (VAE ELBO, BB-ANS, bz2, gzip) from the paper's Table 2
    "binarized": (0.19, 0.19, 0.25, 0.33),
    "full": (1.39, 1.41, 1.42, 1.64),
}


def run(n_images: int = 512, lanes: int = 32, train_steps: int = 1500,
        seed: int = 0):
    rows = []
    for name, likelihood in (("binarized", "bernoulli"),
                             ("full", "beta_binomial")):
        cfg = vae_lib.paper_config(likelihood)
        params, neg_elbo = common.train_vae(cfg, steps=train_steps,
                                            seed=seed)

        test_imgs, _ = synthetic_mnist.load("test", n_images, seed)
        if likelihood == "bernoulli":
            test_imgs = synthetic_mnist.binarize(test_imgs, seed + 1)
        n_chain = n_images // lanes
        data = jnp.asarray(
            test_imgs[:n_chain * lanes].reshape(n_chain, lanes, -1),
            jnp.int32)

        codec = codecs.Chained(vae_lib.make_bb_codec(params, cfg), n_chain)
        bits_per_img = 4096 if likelihood == "bernoulli" else 16384
        cap = int(n_chain * bits_per_img / 16) + 256

        t0 = time.perf_counter()
        blob, info = codecs.compress(codec, data, lanes=lanes, seed=9,
                                     capacity=cap, with_info=True)
        enc_s = time.perf_counter() - t0
        rate = info["net_bits"] / data.size

        # verify losslessness on the chain
        t1 = time.perf_counter()
        decoded = codecs.decompress(codec, blob)
        dec_s = time.perf_counter() - t1
        exact = bool(jnp.array_equal(decoded, data))

        base = common.baseline_rates(
            np.asarray(test_imgs[:n_chain * lanes]),
            binary=(likelihood == "bernoulli"))
        flush_overhead = 32.0 * lanes / data.size

        p_elbo, p_bbans, p_bz2, p_gzip = PAPER[name]
        rows.append({
            "dataset": name, "neg_elbo_bpd": neg_elbo,
            "bbans_bpd": rate, "lossless": exact,
            "flush_overhead_bpd": flush_overhead,
            **{f"{k}_bpd": v for k, v in base.items()},
            "paper_elbo": p_elbo, "paper_bbans": p_bbans,
            "paper_bz2": p_bz2, "paper_gzip": p_gzip,
            "encode_s": enc_s, "decode_s": dec_s,
            "images": n_chain * lanes,
        })
    return rows


def main():
    for r in run():
        print(f"table2,{r['dataset']},bbans={r['bbans_bpd']:.4f},"
              f"elbo={r['neg_elbo_bpd']:.4f},"
              f"gzip={r.get('gzip_bpd', 0):.4f},"
              f"bz2={r.get('bz2_bpd', 0):.4f},"
              f"lzma={r.get('lzma_bpd', 0):.4f},"
              f"zstd={r.get('zstd_bpd', 0):.4f},"
              f"lossless={r['lossless']},"
              f"paper_bbans={r['paper_bbans']}")


if __name__ == "__main__":
    main()
