"""End-to-end driver (the paper's kind: compression): fault-tolerant
training of the paper's model with checkpoint/restart, then deploy the
trained model as a compression service over a fresh test stream.

This is the production loop shape at container scale; the same trainer,
checkpointing and codec run on the pod meshes via launch/train.py and
launch/dryrun.py.

Run: PYTHONPATH=src:. python examples/train_and_compress.py
"""

import tempfile

import jax
import jax.numpy as jnp

from benchmarks.common import train_vae
from repro import codecs
from repro.data import synthetic_mnist
from repro.models import vae as vae_lib
from repro.optim import adamw
from repro.train import checkpoint, fault

def main():
    cfg = vae_lib.paper_config("bernoulli")
    opt = adamw.AdamW(learning_rate=adamw.cosine_lr(1e-3, 50, 400))
    imgs, _ = synthetic_mnist.load("train", 4000, 0)
    imgs = synthetic_mnist.binarize(imgs, 0)

    def init_fn():
        params = vae_lib.init(jax.random.PRNGKey(0), cfg)
        return {"params": params, "opt": opt.init(params)}

    @jax.jit
    def step_fn(state, batch):
        loss, grads = jax.value_and_grad(vae_lib.loss)(
            state["params"], cfg, batch["key"], batch["images"])
        params, ostate = opt.update(grads, state["opt"], state["params"])
        return {"params": params, "opt": ostate}, {"loss": loss}

    import numpy as np
    def batch_fn(step):
        rng = np.random.default_rng(1000 + step)
        idx = rng.integers(0, len(imgs), 128)
        return {"images": jnp.asarray(imgs[idx], jnp.int32),
                "key": jax.random.PRNGKey(step)}

    fail_at = {37, 181}  # simulated node losses mid-run
    def injector(s):
        if s in fail_at:
            fail_at.discard(s)
            raise fault.SimulatedNodeFailure(f"node lost at step {s}")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        wd = fault.StepWatchdog()
        state, restarts = fault.run_training(
            init_fn=init_fn, step_fn=step_fn, batch_fn=batch_fn,
            n_steps=400, ckpt_dir=ckpt_dir, save_every=50,
            watchdog=wd, failure_injector=injector,
            on_metrics=lambda s, m: print(
                f"  step {s}: loss {float(m['loss']):.1f}")
            if s % 100 == 0 else None)
        print(f"trained 400 steps with {restarts} simulated node failures"
              f" (restart/restore exercised)")

    # Deploy: compress a fresh stream through the one-call container.
    test, _ = synthetic_mnist.load("test", 64, 0)
    test = synthetic_mnist.binarize(test, 1)
    data = jnp.asarray(test.reshape(4, 16, -1), jnp.int32)
    codec = codecs.Chained(vae_lib.make_bb_codec(state["params"], cfg), 4)
    blob, info = codecs.compress(codec, data, lanes=16, seed=2,
                                 with_info=True)
    rate = info["net_bits"] / data.size
    out = codecs.decompress(codec, blob)
    assert bool(jnp.array_equal(out, data))
    print(f"deployed codec: {rate:.4f} bits/dim "
          f"({len(blob)} wire bytes), lossless verified")

if __name__ == "__main__":
    main()
