"""Streaming quickstart: chunked BB-ANS over the BBX2 wire format.

Trains the paper's VAE briefly, then compresses a stream of images
*incrementally*: datapoints go in as they "arrive", wire bytes come out
as blocks complete, clean bits are carried across block boundaries so
the streamed rate tracks the one-shot rate, and any block boundary is
a valid resume point - the consumer decodes the tail of the stream
without touching earlier bytes.

Run: PYTHONPATH=src:. python examples/stream_quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro import codecs, stream
from repro.data import synthetic_mnist
from repro.models import vae as vae_lib
from benchmarks.common import train_vae


def main():
    cfg = vae_lib.paper_config("bernoulli")
    print("training the paper's VAE (hidden 100, latent 40)...")
    params, neg_elbo = train_vae(cfg, steps=400, seed=0)
    print(f"  test -ELBO: {neg_elbo:.4f} bits/dim")

    lanes, n_stream, block = 16, 12, 4
    imgs, _ = synthetic_mnist.load("test", lanes * n_stream, 0)
    imgs = synthetic_mnist.binarize(imgs, 1)
    data = jnp.asarray(imgs.reshape(n_stream, lanes, -1), jnp.int32)

    codec = vae_lib.make_bb_codec(params, cfg)
    enc = stream.StreamEncoder(codec, lanes=lanes, block_symbols=block,
                               seed=0, init_chunks=32)
    wire = b""
    blocks_seen = 0
    for t in range(n_stream):     # datapoints arrive one at a time
        out = enc.write(jnp.expand_dims(data[t], 0))
        if enc.n_blocks > blocks_seen:
            print(f"  t={t}: block {enc.n_blocks - 1} flushed "
                  f"({len(out)} wire bytes out)")
            blocks_seen = enc.n_blocks
        wire += out
    wire += enc.flush()
    rate = enc.net_bits / data.size
    print(f"  streamed BB-ANS rate: {rate:.4f} bits/dim over "
          f"{enc.n_blocks} blocks ({len(wire)} bytes total)")

    # one-shot reference - head carry keeps the streamed rate close
    _, info = codecs.compress(codecs.Chained(codec, n_stream), data,
                              lanes=lanes, seed=0, with_info=True)
    one = info["net_bits"] / data.size
    print(f"  one-shot rate       : {one:.4f} bits/dim "
          f"(streamed/one-shot = {rate / one:.4f})")

    decoded = stream.decode_stream(codec, wire)
    assert bool(jnp.array_equal(decoded, data))
    print("  full decode: exact (bit-for-bit)")

    header, offsets, trailer = stream.format.scan(wire)
    tail = stream.decode_from_offset(codec, wire, offsets[-1])
    assert bool(jnp.array_equal(tail, data[(len(offsets) - 1) * block:]))
    print(f"  resumed at byte {offsets[-1]} (block {len(offsets) - 1}): "
          "tail decode exact - no earlier bytes touched")


if __name__ == "__main__":
    main()
