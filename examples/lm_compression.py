"""Neural lossless compression of token streams with an assigned LM arch.

Trains a reduced qwen2-style backbone on a Markov corpus with known
entropy, then uses the serving engine's compression service: ANS-code the
stream with the LM as probability model, decompress, verify, and compare
against the entropy floor and gzip.

Run: PYTHONPATH=src:. python examples/lm_compression.py
"""

from benchmarks import lm_compression

def main():
    rows = lm_compression.run(train_steps=150)
    r = rows[0]
    print(f"entropy floor        : {r['entropy_floor_bpt']:.3f} bits/token")
    print(f"model cross-entropy  : {r['model_ce_bpt']:.3f} bits/token")
    print(f"LM-ANS achieved      : {r['achieved_bpt']:.3f} bits/token "
          f"(incl. {r['flush_overhead_bpt']:.3f} flush overhead)")
    print(f"gzip -9              : {r['gzip_bpt']:.3f} bits/token")
    print(f"bz2 -9               : {r['bz2_bpt']:.3f} bits/token")
    print("roundtrip: exact - lossless verified (asserted inside)")

if __name__ == "__main__":
    main()
