"""Quickstart: the paper in ~50 lines, through the one-call codecs API.

Trains the paper's VAE on (synthetic) binarized MNIST for a few hundred
steps, chain-compresses a batch of images with BB-ANS via
``codecs.compress`` (which owns stack sizing, clean-bit seeding, and
framing), decompresses with ``codecs.decompress``, verifies
bit-exactness and prints the achieved rate vs the ELBO bound and gzip.

Run: PYTHONPATH=src:. python examples/quickstart.py
"""

import gzip

import jax.numpy as jnp
import numpy as np

from repro import codecs
from repro.data import synthetic_mnist
from repro.models import vae as vae_lib
from benchmarks.common import train_vae

def main():
    cfg = vae_lib.paper_config("bernoulli")
    print("training the paper's VAE (hidden 100, latent 40)...")
    params, neg_elbo = train_vae(cfg, steps=600, seed=0)
    print(f"  test -ELBO: {neg_elbo:.4f} bits/dim")

    lanes, n_chain = 16, 8
    imgs, _ = synthetic_mnist.load("test", lanes * n_chain, 0)
    imgs = synthetic_mnist.binarize(imgs, 1)
    data = jnp.asarray(imgs.reshape(n_chain, lanes, -1), jnp.int32)

    # The whole coding pipeline is two calls: a codec and the container.
    codec = codecs.Chained(vae_lib.make_bb_codec(params, cfg), n_chain)
    blob, info = codecs.compress(codec, data, lanes=lanes, seed=0,
                                 with_info=True)
    rate = info["net_bits"] / data.size
    print(f"  BB-ANS rate: {rate:.4f} bits/dim "
          f"(gap to ELBO {(rate - neg_elbo) / neg_elbo * 100:+.2f}%); "
          f"blob {len(blob)} bytes")

    gz = len(gzip.compress(np.packbits(imgs).tobytes(), 9)) * 8 / imgs.size
    print(f"  gzip -9    : {gz:.4f} bits/dim")

    decoded = codecs.decompress(codec, blob)
    assert bool(jnp.array_equal(decoded, data))
    print("  decompression: exact (bit-for-bit) - lossless verified")

if __name__ == "__main__":
    main()
