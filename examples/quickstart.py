"""Quickstart: the paper in ~60 lines.

Trains the paper's VAE on (synthetic) binarized MNIST for a few hundred
steps, chain-compresses a batch of images with BB-ANS, decompresses them,
verifies bit-exactness and prints the achieved rate vs the ELBO bound and
gzip.

Run: PYTHONPATH=src:. python examples/quickstart.py
"""

import gzip

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ans, bbans
from repro.data import synthetic_mnist
from repro.models import vae as vae_lib
from benchmarks.common import train_vae

def main():
    cfg = vae_lib.paper_config("bernoulli")
    print("training the paper's VAE (hidden 100, latent 40)...")
    params, neg_elbo = train_vae(cfg, steps=600, seed=0)
    print(f"  test -ELBO: {neg_elbo:.4f} bits/dim")

    lanes, n_chain = 16, 8
    imgs, _ = synthetic_mnist.load("test", lanes * n_chain, 0)
    imgs = synthetic_mnist.binarize(imgs, 1)
    data = jnp.asarray(imgs.reshape(n_chain, lanes, -1), jnp.int32)

    codec = vae_lib.make_codec(params, cfg)
    stack = ans.make_stack(lanes, 4096, key=jax.random.PRNGKey(0))
    stack = ans.seed_stack(stack, jax.random.PRNGKey(1), 32)

    bits0 = float(ans.stack_content_bits(stack))
    stack = bbans.append_batch(codec, stack, data)
    bits1 = float(ans.stack_content_bits(stack))
    rate = (bits1 - bits0) / data.size
    print(f"  BB-ANS rate: {rate:.4f} bits/dim "
          f"(gap to ELBO {(rate - neg_elbo) / neg_elbo * 100:+.2f}%)")

    gz = len(gzip.compress(np.packbits(imgs).tobytes(), 9)) * 8 / imgs.size
    print(f"  gzip -9    : {gz:.4f} bits/dim")

    stack, decoded = bbans.pop_batch(codec, stack, n_chain)
    assert bool(jnp.array_equal(decoded, data))
    print("  decompression: exact (bit-for-bit) - lossless verified")

if __name__ == "__main__":
    main()
