"""repro.stream: BBX2 framing, chunked coding, resume, dynamic batching.

Edge cases the streaming layer must nail: block-boundary roundtrips,
ragged final blocks, decoder resume from a mid-stream byte offset,
double flush, arbitrary byte-split incremental feeding, kernel-vs-
python block coder byte identity, and the dynamic batcher packing many
ragged streams through one stack.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import codecs, stream
from repro.core import ans
from repro.core.distributions import Categorical
from repro.models import vae as vae_lib


def _categorical(lanes, alphabet=7, precision=14, seed=0):
    """Lane-tiled categorical (same table every lane, any lane count)."""
    rng = np.random.default_rng(seed)
    logits = np.tile(rng.normal(0.0, 1.0, (1, alphabet)), (lanes, 1))
    return Categorical(jnp.asarray(logits, jnp.float32), precision)


def _symbols(n, lanes, alphabet=7, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, alphabet, (n, lanes)), jnp.int32)


# ---------------------------------------------------------------------------
# StreamEncoder / StreamDecoder
# ---------------------------------------------------------------------------

def test_roundtrip_across_block_boundaries():
    """>= 3 block boundaries, exact roundtrip, natural symbol order."""
    lanes, n, block = 4, 26, 6   # 4 full blocks + ragged final of 2
    codec = _categorical(lanes)
    data = _symbols(n, lanes)
    blob = stream.encode_stream(codec, data, lanes=lanes,
                                block_symbols=block, seed=None)
    header, offsets, trailer = stream.format.scan(blob)
    assert len(offsets) == 5 and trailer.total_symbols == n
    out = stream.decode_stream(codec, blob)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))


def test_ragged_final_block():
    lanes, block = 3, 8
    codec = _categorical(lanes)
    for n in (1, 7, 8, 9, 17):
        data = _symbols(n, lanes, seed=n)
        blob = stream.encode_stream(codec, data, lanes=lanes,
                                    block_symbols=block, seed=None)
        _, offsets, trailer = stream.format.scan(blob)
        assert len(offsets) == -(-n // block)
        assert trailer.n_blocks == len(offsets)
        out = stream.decode_stream(codec, blob)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(data))


def test_incremental_write_and_byte_split_read():
    """Symbols dribble in, bytes dribble out, decoder fed 5B at a time."""
    lanes, block = 2, 4
    codec = _categorical(lanes)
    data = _symbols(11, lanes)
    enc = stream.StreamEncoder(codec, lanes=lanes, block_symbols=block,
                               seed=None)
    wire = b""
    for t in range(11):   # one datapoint at a time
        wire += enc.write(jax.tree_util.tree_map(
            lambda a: a[t:t + 1], data))
    wire += enc.flush()

    dec = stream.StreamDecoder(codec)
    blocks = []
    for i in range(0, len(wire), 5):
        blocks.extend(dec.read(wire[i:i + 5]))
    assert dec.finished
    out = jnp.concatenate(blocks, axis=0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))


def test_flush_twice_and_write_after_flush():
    lanes = 2
    codec = _categorical(lanes)
    data = _symbols(5, lanes)
    enc = stream.StreamEncoder(codec, lanes=lanes, block_symbols=4,
                               seed=None)
    wire = enc.write(data) + enc.flush()
    assert enc.flush() == b""           # idempotent
    with pytest.raises(RuntimeError, match="write after flush"):
        enc.write(data)
    out = stream.decode_stream(codec, wire)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))


def test_empty_stream_flush():
    codec = _categorical(2)
    enc = stream.StreamEncoder(codec, lanes=2, block_symbols=4, seed=None)
    wire = enc.flush()
    assert len(wire) == (stream.format.HEADER_SIZE
                         + stream.format.TRAILER_SIZE)
    dec = stream.StreamDecoder(codec)
    assert dec.read(wire) == [] and dec.finished


def test_resume_from_mid_stream_offset():
    """Seek to any block boundary and decode only the tail."""
    lanes, n, block = 3, 20, 4
    codec = _categorical(lanes)
    data = _symbols(n, lanes)
    blob = stream.encode_stream(codec, data, lanes=lanes,
                                block_symbols=block, seed=None)
    _, offsets, _ = stream.format.scan(blob)
    assert len(offsets) == 5
    for b, off in enumerate(offsets):
        tail = stream.decode_from_offset(codec, blob, off)
        np.testing.assert_array_equal(np.asarray(tail),
                                      np.asarray(data)[b * block:])


def test_truncated_and_corrupt_streams_raise():
    lanes = 2
    codec = _categorical(lanes)
    blob = stream.encode_stream(codec, _symbols(9, lanes), lanes=lanes,
                                block_symbols=4, seed=None)
    with pytest.raises(ValueError, match="truncated"):
        stream.decode_stream(codec, blob[:-20])   # trailer cut off
    bad = b"XXX2" + blob[4:]
    with pytest.raises(ValueError, match="magic"):
        stream.decode_stream(codec, bad)
    # flipping a marker byte breaks the frame walk
    _, offsets, _ = stream.format.scan(blob)
    mangled = bytearray(blob)
    mangled[offsets[1]] ^= 0xFF
    with pytest.raises(ValueError, match="marker"):
        stream.decode_stream(codec, bytes(mangled))


def test_kernel_and_python_block_coders_byte_identical():
    lanes, n, block = 5, 23, 6
    codec = _categorical(lanes, alphabet=17, precision=12)
    data = _symbols(n, lanes, alphabet=17)
    kw = dict(lanes=lanes, block_symbols=block, seed=None)
    blob_py = stream.encode_stream(codec, data, use_kernel=False, **kw)
    blob_k = stream.encode_stream(codec, data, use_kernel=True, **kw)
    assert blob_py == blob_k
    out = stream.decode_stream(codec, blob_k, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), lanes=st.integers(1, 6),
       block=st.integers(1, 9), n=st.integers(0, 30))
def test_stream_roundtrip_property(seed, lanes, block, n):
    """decode(encode(xs)) is bit-exact for random block sizes, lane
    counts, and stream lengths (including empty)."""
    codec = _categorical(lanes, seed=seed % 97)
    data = _symbols(n, lanes, seed=seed)
    blob = stream.encode_stream(codec, data, lanes=lanes,
                                block_symbols=block, seed=None)
    out = stream.decode_stream(codec, blob)
    if n == 0:
        assert out is None
    else:
        np.testing.assert_array_equal(np.asarray(out), np.asarray(data))


# ---------------------------------------------------------------------------
# Bits-back streaming: head carry + rate parity
# ---------------------------------------------------------------------------

def _tiny_vae(input_dim=48, latent=8):
    cfg = vae_lib.VAEConfig(input_dim=input_dim, hidden=24, latent=latent,
                            likelihood="bernoulli")
    return vae_lib.init(jax.random.PRNGKey(0), cfg), cfg


@pytest.mark.slow
def test_bbans_streamed_roundtrip_and_head_carry():
    """BB-ANS streams across blocks: exact roundtrip, and block b+1's
    initial head (recovered by the decoder as its pop residue) equals
    block b's transmitted final head - the carried clean bits."""
    params, cfg = _tiny_vae()
    codec = vae_lib.make_bb_codec(params, cfg)
    rng = np.random.default_rng(3)
    lanes, n, block = 3, 8, 3
    data = jnp.asarray(rng.integers(0, 2, (n, lanes, cfg.input_dim)),
                       jnp.int32)
    enc = stream.StreamEncoder(codec, lanes=lanes, block_symbols=block,
                               seed=5, init_chunks=32)
    wire = enc.write(data) + enc.flush()
    assert enc.n_blocks == 3
    out = stream.decode_stream(codec, wire)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))

    # head carry: decode block b+1 by hand; after popping all its
    # datapoints the stack head must sit at block b's wire head.
    _, offsets, _ = stream.format.scan(wire)
    frames = [stream.format.decode_next(wire, off, lanes)[0]
              for off in offsets]
    for b in range(1, len(frames)):
        blk = frames[b]
        stack = ans.unflatten(jnp.asarray(blk.msg),
                              jnp.asarray(blk.lengths))
        chain = stream.BlockChain(codec, blk.n_symbols)
        stack, _ = chain.pop(stack)
        prev_head = (frames[b - 1].msg[:, 0].astype(np.uint32) << 16) \
            | frames[b - 1].msg[:, 1]
        np.testing.assert_array_equal(np.asarray(stack.head), prev_head)


@pytest.mark.slow
def test_bbans_streamed_rate_tracks_oneshot():
    """Streamed net rate ~ one-shot net rate (the head-carry payoff)."""
    params, cfg = _tiny_vae(input_dim=96, latent=8)
    codec = vae_lib.make_bb_codec(params, cfg)
    rng = np.random.default_rng(4)
    lanes, n = 8, 16
    data = jnp.asarray(rng.integers(0, 2, (n, lanes, cfg.input_dim)),
                       jnp.int32)
    _, info = codecs.compress(codecs.Chained(codec, n), data,
                              lanes=lanes, seed=9, with_info=True)
    enc = stream.StreamEncoder(codec, lanes=lanes, block_symbols=4,
                               seed=9, init_chunks=32)
    wire = enc.write(data) + enc.flush()
    assert enc.n_blocks == 4
    out = stream.decode_stream(codec, wire)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))
    ratio = enc.net_bits / info["net_bits"]
    # Untrained VAE on random bits -> per-image dither variance is high;
    # the trained table2 parity (<1%) is asserted by the stream bench.
    assert 0.9 < ratio < 1.1, ratio


# ---------------------------------------------------------------------------
# Dynamic batcher
# ---------------------------------------------------------------------------

def test_batcher_eight_concurrent_ragged_streams():
    """>= 8 concurrent streams of different lengths through one stack,
    each blob decoding exactly - per-stream and batched."""
    max_lanes, block = 8, 5
    codec = _categorical(max_lanes, alphabet=9)
    rng = np.random.default_rng(7)
    bat = stream.StreamBatcher(codec, max_lanes=max_lanes,
                               block_symbols=block, seed=None)
    datas = {}
    for i in range(8):
        n = int(rng.integers(1, 23))
        datas[i] = jnp.asarray(rng.integers(0, 9, (n,)), jnp.int32)
        bat.submit(i, datas[i])
    blobs = bat.run()
    assert set(blobs) == set(datas)

    codec1 = _categorical(1, alphabet=9)
    for i, blob in blobs.items():
        header, _, trailer = stream.format.scan(blob)
        assert header.lanes == 1
        assert trailer.total_symbols == datas[i].shape[0]
        out = stream.decode_stream(codec1, blob)
        np.testing.assert_array_equal(np.asarray(out)[:, 0],
                                      np.asarray(datas[i]))

    outs = stream.decode_batched(codec, blobs, max_lanes=max_lanes,
                                 block_symbols=block)
    for i in datas:
        np.testing.assert_array_equal(np.asarray(outs[i]),
                                      np.asarray(datas[i]))


def test_batcher_admits_and_retires_over_queue():
    """More streams than lanes: lanes free up and requeue mid-run."""
    max_lanes, block = 3, 4
    codec = _categorical(max_lanes, alphabet=5)
    rng = np.random.default_rng(8)
    bat = stream.StreamBatcher(codec, max_lanes=max_lanes,
                               block_symbols=block, seed=None)
    datas = {}
    for i in range(10):
        n = int(rng.integers(0, 14))
        datas[i] = jnp.asarray(rng.integers(0, 5, (n,)), jnp.int32)
        bat.submit(i, datas[i])
    blobs = bat.run()
    assert set(blobs) == set(datas)
    codec1 = _categorical(1, alphabet=5)
    for i, blob in blobs.items():
        out = stream.decode_stream(codec1, blob)
        if datas[i].shape[0] == 0:
            assert out is None
        else:
            np.testing.assert_array_equal(np.asarray(out)[:, 0],
                                          np.asarray(datas[i]))


def test_batcher_bbans_streams():
    """Bits-back clients through the batcher (per-block clean bits via
    seed), decoded per-stream at lane width 1."""
    params, cfg = _tiny_vae(input_dim=20, latent=4)
    codec = vae_lib.make_bb_codec(params, cfg)
    rng = np.random.default_rng(9)
    bat = stream.StreamBatcher(codec, max_lanes=4, block_symbols=2,
                               seed=11, init_chunks=32)
    datas = {}
    for i in range(5):
        n = int(rng.integers(1, 6))
        datas[i] = jnp.asarray(rng.integers(0, 2, (n, cfg.input_dim)),
                               jnp.int32)
        bat.submit(i, datas[i])
    blobs = bat.run()
    for i, blob in blobs.items():
        out = stream.decode_stream(codec, blob)
        np.testing.assert_array_equal(np.asarray(out)[:, 0],
                                      np.asarray(datas[i]))


def test_select_lanes_freezes_masked_state():
    lanes = 4
    codec = _categorical(lanes)
    stack = codecs.fresh_stack(lanes, 16, seed=3)
    sym = jnp.asarray([1, 2, 3, 4], jnp.int32)
    pushed = codec.push(stack, sym)
    mask = jnp.asarray([True, False, True, False])
    merged = ans.select_lanes(mask, pushed, stack)
    np.testing.assert_array_equal(
        np.asarray(merged.head),
        np.where(np.asarray(mask), np.asarray(pushed.head),
                 np.asarray(stack.head)))
    np.testing.assert_array_equal(np.asarray(merged.ptr[1::2]),
                                  np.asarray(stack.ptr[1::2]))
    # masked lanes decode nothing; unmasked decode their symbol
    popped, out = codec.pop(merged)
    np.testing.assert_array_equal(np.asarray(out)[::2],
                                  np.asarray(sym)[::2])


# ---------------------------------------------------------------------------
# Corruption matrix: BBX2 scan / BBX3 scan_corpus raise ContainerError
# with the byte offset and block index of the damage (satellite of the
# gateway PR; mirrors the BBX1 matrix in test_codecs.py)
# ---------------------------------------------------------------------------

def _bbx2_blob(lanes=2, n=13, block=4):
    codec = _categorical(lanes)
    return stream.encode_stream(codec, _symbols(n, lanes), lanes=lanes,
                                block_symbols=block, seed=None)


def _set_u32(blob: bytes, offset: int, value: int) -> bytes:
    b = bytearray(blob)
    b[offset:offset + 4] = int(value).to_bytes(4, "little")
    return bytes(b)


def _mut_header_truncated(blob, offs):
    return blob[:8], "truncated .*no header"


def _mut_bad_magic(blob, offs):
    return b"XXX2" + blob[4:], r"bad magic .*at byte 0"


def _mut_bad_version(blob, offs):
    b = bytearray(blob); b[4] = 9
    return bytes(b), "unsupported BBX2 version 9 at byte 0"


def _mut_zero_lanes(blob, offs):
    return _set_u32(blob, 8, 0), "corrupt header at byte 0"


def _mut_marker_flip(blob, offs):
    b = bytearray(blob); b[offs[1]] ^= 0xFF
    return bytes(b), (rf"scan failed at block 1 \(byte offset "
                      rf"{offs[1]}\).*marker")


def _mut_lane_len_lt2(blob, offs):
    return _set_u32(blob, offs[1] + stream.format.BLOCK_HEADER_SIZE, 1), \
        rf"block 1 \(byte offset {offs[1]}\).*lane length < 2"


def _mut_len_sum_mismatch(blob, offs):
    total = int.from_bytes(blob[offs[0] + 8:offs[0] + 12], "little")
    return _set_u32(blob, offs[0] + 8, total + 3), \
        rf"block 0 \(byte offset {offs[0]}\).*length sum mismatch"


@pytest.mark.parametrize("mutate", [
    _mut_header_truncated, _mut_bad_magic, _mut_bad_version,
    _mut_zero_lanes, _mut_marker_flip, _mut_lane_len_lt2,
    _mut_len_sum_mismatch,
], ids=lambda f: f.__name__[5:])
def test_bbx2_scan_corruption_matrix(mutate):
    """Every corruption class surfaces as codecs.ContainerError naming
    where (byte offset / block index) the frame walk failed."""
    blob = _bbx2_blob()
    _, offs, _ = stream.format.scan(blob)
    bad, pattern = mutate(blob, offs)
    with pytest.raises(codecs.ContainerError, match=pattern):
        stream.format.scan(bad)
    # ContainerError subclasses ValueError: pre-existing callers that
    # caught ValueError keep working.
    assert issubclass(codecs.ContainerError, ValueError)


def _bbx3_blob():
    segs = [_bbx2_blob(lanes=1, n=5, block=2),
            _bbx2_blob(lanes=1, n=7, block=2)]
    return stream.encode_corpus(segs, [5, 7], lanes_per_shard=1), segs


def _cmut_truncated(blob):
    return blob[:10], "truncated .*no header"


def _cmut_bad_magic(blob):
    return b"XXX3" + blob[4:], r"bad magic .*at byte 0"


def _cmut_bad_version(blob):
    b = bytearray(blob); b[4] = 7
    return bytes(b), "unsupported BBX3 version 7"


def _cmut_zero_shards(blob):
    return _set_u32(blob, 8, 0), "n_shards/lanes < 1"


def _cmut_huge_shards(blob):
    return _set_u32(blob, 8, 10_000_000), \
        r"n_shards=10000000 needs a larger index"


def _cmut_segment_truncated(blob):
    return blob[:-4], r"shard 1 segment at byte \d+ extends past"


@pytest.mark.parametrize("mutate", [
    _cmut_truncated, _cmut_bad_magic, _cmut_bad_version,
    _cmut_zero_shards, _cmut_huge_shards, _cmut_segment_truncated,
], ids=lambda f: f.__name__[6:])
def test_bbx3_scan_corpus_corruption_matrix(mutate):
    blob, _ = _bbx3_blob()
    bad, pattern = mutate(blob)
    with pytest.raises(codecs.ContainerError, match=pattern):
        stream.format.scan_corpus(bad)


def test_corpus_segment_out_of_range():
    blob, segs = _bbx3_blob()
    assert stream.corpus_segment(blob, 1) == segs[1]
    with pytest.raises(codecs.ContainerError,
                       match=r"shard 2 out of range \[0, 2\)"):
        stream.corpus_segment(blob, 2)


# ---------------------------------------------------------------------------
# Batcher under adversarial schedules (satellite of the gateway PR):
# disconnect mid-stream, admit-while-full, retire-then-readmit,
# timeout eviction - FIFO fairness and no lane leak throughout
# ---------------------------------------------------------------------------

def test_batcher_cancel_midstream_frees_lane_and_yields_valid_prefix():
    """A client disconnect (cancel) releases its lane to the FIFO queue
    and finalizes a *valid* partial blob decoding to a prefix."""
    max_lanes, block = 2, 3
    codec = _categorical(max_lanes, alphabet=5)
    rng = np.random.default_rng(21)
    bat = stream.StreamBatcher(codec, max_lanes=max_lanes,
                               block_symbols=block, seed=None)
    datas = {i: jnp.asarray(rng.integers(0, 5, (9,)), jnp.int32)
             for i in range(3)}
    for i, d in datas.items():
        bat.submit(i, d)
    bat.step()                        # 0 and 1 hold lanes, 2 queued
    assert bat.active_ids == [0, 1] and bat.queued_ids == [2]
    lane = bat.lane_of(0)
    part = bat.cancel(0)              # disconnect mid-stream
    assert 0 in bat.evicted and bat.lane_of(0) is None
    codec1 = _categorical(1, alphabet=5)
    out = stream.decode_stream(codec1, part)   # valid prefix blob
    np.testing.assert_array_equal(np.asarray(out)[:, 0],
                                  np.asarray(datas[0])[:block])
    blobs = bat.run()                 # queued client takes the lane
    assert bat.lane_of(2) is None and bat.idle   # no lane leak
    assert set(blobs) == {0, 1, 2}
    for i in (1, 2):
        out = stream.decode_stream(codec1, blobs[i])
        np.testing.assert_array_equal(np.asarray(out)[:, 0],
                                      np.asarray(datas[i]))
    assert lane is not None   # it did hold a lane before the cancel


def test_batcher_admit_while_full_is_fifo():
    """Submissions beyond max_lanes wait in FIFO order; admission order
    equals submission order (fairness), finish frees lanes in turn."""
    max_lanes, block = 1, 2
    codec = _categorical(max_lanes, alphabet=5)
    rng = np.random.default_rng(22)
    bat = stream.StreamBatcher(codec, max_lanes=max_lanes,
                               block_symbols=block, seed=None)
    admitted = []
    for i in range(4):
        bat.submit(i, jnp.asarray(rng.integers(0, 5, (2,)), jnp.int32))
    while not bat.idle:
        before = set(bat.active_ids)
        bat.step()
        admitted.extend(i for i in bat.active_ids if i not in before)
    # Single lane, 1-block streams: each round admits the next id in
    # submission order - strict FIFO, nobody starves or overtakes.
    assert bat.queued_ids == [] and bat.active_ids == []
    done = bat.run()
    assert set(done) == {0, 1, 2, 3}


def test_batcher_retire_then_readmit_same_lane():
    """A finished id is released and resubmitted: the same lane serves
    it again with fresh state; duplicate ids without release raise."""
    codec = _categorical(1, alphabet=5)
    rng = np.random.default_rng(23)
    bat = stream.StreamBatcher(codec, max_lanes=1, block_symbols=4,
                               seed=None)
    d1 = jnp.asarray(rng.integers(0, 5, (6,)), jnp.int32)
    bat.submit("u", d1)
    blob1 = bat.run()["u"]
    with pytest.raises(ValueError, match="duplicate stream id"):
        bat.submit("u", d1)
    bat.release("u")
    d2 = jnp.asarray(rng.integers(0, 5, (3,)), jnp.int32)
    bat.submit("u", d2)
    blob2 = bat.run()["u"]
    codec1 = _categorical(1, alphabet=5)
    np.testing.assert_array_equal(
        np.asarray(stream.decode_stream(codec1, blob1))[:, 0],
        np.asarray(d1))
    np.testing.assert_array_equal(
        np.asarray(stream.decode_stream(codec1, blob2))[:, 0],
        np.asarray(d2))
    assert bat.idle     # no lane leak across the readmit


def test_batcher_timeout_evicts_at_round_boundary():
    """An expired lane lease is evicted: partial blob valid, lane freed
    for the queue, eviction reported by step()."""
    clock = [0.0]
    codec = _categorical(2, alphabet=5)
    rng = np.random.default_rng(24)
    bat = stream.StreamBatcher(codec, max_lanes=2, block_symbols=2,
                               seed=None, clock=lambda: clock[0])
    slow = jnp.asarray(rng.integers(0, 5, (8,)), jnp.int32)
    fast = jnp.asarray(rng.integers(0, 5, (8,)), jnp.int32)
    queued = jnp.asarray(rng.integers(0, 5, (2,)), jnp.int32)
    bat.submit("slow", slow, timeout=1.0)
    bat.submit("fast", fast)
    bat.submit("queued", queued)
    bat.step()                      # round 0: both code a block
    assert bat.lane_of("slow") is not None
    clock[0] = 2.0                  # lease expires
    finished = bat.step()
    assert "slow" in finished and "slow" in bat.evicted
    assert bat.lane_of("slow") is None
    # The freed lane was re-leased to the queued stream in the same
    # round - short enough (1 block) that it finished there too.
    assert "queued" in finished
    codec1 = _categorical(1, alphabet=5)
    out = stream.decode_stream(codec1, finished["slow"])
    np.testing.assert_array_equal(np.asarray(out)[:, 0],
                                  np.asarray(slow)[:2])  # 1-block prefix
    blobs = bat.run()
    assert bat.idle and set(blobs) == {"slow", "fast", "queued"}
    np.testing.assert_array_equal(
        np.asarray(stream.decode_stream(codec1, blobs["fast"]))[:, 0],
        np.asarray(fast))


def test_batcher_queued_timeout_evicts_without_admission():
    """A stream that times out while still queued never gets a lane;
    its blob is a valid empty/header-only stream."""
    clock = [0.0]
    codec = _categorical(1, alphabet=5)
    bat = stream.StreamBatcher(codec, max_lanes=1, block_symbols=2,
                               seed=None, clock=lambda: clock[0])
    bat.submit("a", jnp.asarray([1, 2, 3, 4], jnp.int32))
    bat.submit("b", jnp.asarray([1, 2], jnp.int32), timeout=0.5)
    bat.step()
    clock[0] = 1.0
    bat.step()
    assert "b" in bat.evicted
    blobs = bat.run()
    codec1 = _categorical(1, alphabet=5)
    assert stream.decode_stream(codec1, blobs["b"]) is None  # empty
    np.testing.assert_array_equal(
        np.asarray(stream.decode_stream(codec1, blobs["a"]))[:, 0],
        np.asarray([1, 2, 3, 4]))
