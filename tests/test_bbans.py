"""BB-ANS end-to-end: exact roundtrip and rate ~= -ELBO (paper's key claims)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs
from repro.core import ans, discretize
from repro.models import vae as vae_lib


@pytest.fixture(scope="module")
def small_cfg():
    return vae_lib.VAEConfig(input_dim=36, hidden=24, latent=6,
                             likelihood="bernoulli", lat_bits=10)


@pytest.fixture(scope="module")
def small_params(small_cfg):
    return vae_lib.init(jax.random.PRNGKey(0), small_cfg)


def test_discretize_prior_roundtrip():
    lanes, lat_bits, prec = 8, 10, 16
    stack = ans.make_stack(lanes, 64, key=jax.random.PRNGKey(1))
    idx = jnp.asarray(
        np.random.default_rng(0).integers(0, 1 << lat_bits, lanes), jnp.int32)
    h0 = stack.head
    s2 = discretize.push_prior(stack, idx, lat_bits, prec)
    s3, out = discretize.pop_prior(s2, lat_bits, prec)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(idx))
    np.testing.assert_array_equal(np.asarray(s3.head), np.asarray(h0))


def test_discretize_posterior_roundtrip():
    lanes, lat_bits, prec = 8, 12, 16
    rng = np.random.default_rng(1)
    mu = jnp.asarray(rng.normal(0, 1, lanes), jnp.float32)
    sigma = jnp.asarray(rng.uniform(0.05, 2.0, lanes), jnp.float32)
    stack = ans.make_stack(lanes, 64, key=jax.random.PRNGKey(2))
    stack = ans.seed_stack(stack, jax.random.PRNGKey(3), 8)
    # Pop (sample) then push must restore the stack exactly.
    h0, p0 = np.asarray(stack.head), np.asarray(stack.ptr)
    s2, idx = discretize.pop_posterior(stack, mu, sigma, lat_bits, prec)
    assert (np.asarray(idx) >= 0).all()
    assert (np.asarray(idx) < (1 << lat_bits)).all()
    s3 = discretize.push_posterior(s2, idx, mu, sigma, lat_bits, prec)
    np.testing.assert_array_equal(np.asarray(s3.head), h0)
    np.testing.assert_array_equal(np.asarray(s3.ptr), p0)
    assert int(jnp.sum(s3.underflows)) == 0


def test_posterior_sampling_statistics():
    """Popping clean bits through Q must produce samples distributed ~Q'.

    The fixed-point CDF codes Q' = (1-eps) Q + eps P with eps =
    2^(lat_bits - precision) (see discretize.py docstring), so the expected
    sample std is sqrt((1-eps) sigma^2 + eps * 1) for a N(0,1) prior.
    """
    lanes, lat_bits, prec = 512, 10, 16
    eps = 2.0 ** (lat_bits - prec)
    mu_v, sig_v = 0.7, 0.31
    mu = jnp.full((lanes,), mu_v, jnp.float32)
    sigma = jnp.full((lanes,), sig_v, jnp.float32)
    stack = ans.make_stack(lanes, 16, key=jax.random.PRNGKey(4))
    stack = ans.seed_stack(stack, jax.random.PRNGKey(5), 8)
    _, idx = discretize.pop_posterior(stack, mu, sigma, lat_bits, prec)
    y = discretize.bucket_centre(idx, lat_bits)
    exp_mean = (1 - eps) * mu_v
    exp_std = float(np.sqrt((1 - eps) * sig_v ** 2 + eps *
                            (1 + (1 - eps) * mu_v ** 2 - exp_mean ** 2)))
    assert float(jnp.mean(y)) == pytest.approx(exp_mean, abs=0.06)
    assert float(jnp.std(y)) == pytest.approx(exp_std, abs=0.05)


def test_bbans_single_roundtrip(small_cfg, small_params):
    lanes = 4
    codec = vae_lib.make_bb_codec(small_params, small_cfg)
    rng = np.random.default_rng(2)
    s = jnp.asarray(rng.integers(0, 2, (lanes, small_cfg.input_dim)),
                    jnp.int32)
    stack = ans.make_stack(lanes, 512, key=jax.random.PRNGKey(6))
    stack = ans.seed_stack(stack, jax.random.PRNGKey(7), 64)
    h0, p0 = np.asarray(stack.head), np.asarray(stack.ptr)
    buf0 = np.asarray(stack.buf)

    stack2 = codec.push(stack, s)
    stack3, s_out = codec.pop(stack2)

    np.testing.assert_array_equal(np.asarray(s_out), np.asarray(s))
    # Full stack restoration (head, depth, and content below the watermark).
    np.testing.assert_array_equal(np.asarray(stack3.head), h0)
    np.testing.assert_array_equal(np.asarray(stack3.ptr), p0)
    for l in range(lanes):
        np.testing.assert_array_equal(np.asarray(stack3.buf)[l, :p0[l]],
                                      buf0[l, :p0[l]])
    assert int(jnp.sum(stack3.underflows)) == 0


def test_bbans_chain_roundtrip(small_cfg, small_params):
    """Chained encode of N datapoints then chained decode recovers all."""
    lanes, n = 3, 5
    chained = codecs.Chained(
        vae_lib.make_bb_codec(small_params, small_cfg), n)
    rng = np.random.default_rng(3)
    data = jnp.asarray(rng.integers(0, 2, (n, lanes, small_cfg.input_dim)),
                       jnp.int32)
    stack = ans.make_stack(lanes, 2048, key=jax.random.PRNGKey(8))
    stack = ans.seed_stack(stack, jax.random.PRNGKey(9), 64)

    stack2 = chained.push(stack, data)
    assert int(jnp.sum(stack2.underflows)) == 0
    assert int(jnp.sum(stack2.overflows)) == 0
    stack3, out = chained.pop(stack2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))


def _analytic_append_bits(cfg, params, s, y):
    """Exact fixed-point cost of appending s given the sampled buckets y:
    -log2 Q'(y|s) recovered, log2 P(y) + log2 p'(s|y) paid."""
    mu, sigma = vae_lib.encode(params, cfg, s)
    post_bits = 0.0
    for d in range(cfg.latent):
        f = discretize.posterior_starts_fn(
            mu[:, d], sigma[:, d], cfg.lat_bits, cfg.precision)
        freq = np.asarray(f(y[:, d] + 1) - f(y[:, d])).astype(np.float64)
        post_bits += float(np.sum(cfg.precision - np.log2(freq)))
    lik_bits = 0.0
    yv = discretize.bucket_centre(y, cfg.lat_bits)
    obs = vae_lib.decode(params, cfg, yv)
    from repro.core.distributions import Bernoulli
    total = 1 << cfg.obs_precision
    for d in range(cfg.input_dim):
        f1 = np.asarray(Bernoulli(obs[:, d], cfg.obs_precision)._freq1(),
                        np.float64)
        sd = np.asarray(s[:, d])
        freq = np.where(sd == 1, f1, total - f1)
        lik_bits += float(np.sum(cfg.obs_precision - np.log2(freq)))
    prior_bits = s.shape[0] * cfg.latent * cfg.lat_bits
    return lik_bits + prior_bits - post_bits


def test_bbans_rate_matches_analytic_exactly(small_cfg, small_params):
    """The coder's achieved length equals the fixed-point information
    content to within ~1 bit/lane (ANS redundancy). This is the precise
    form of the paper's 'rate ~= -ELBO' claim; the statistical form (over a
    trained model + many images) is exercised by benchmarks/table2_rates."""
    cfg, params = small_cfg, small_params
    lanes = 8
    codec = vae_lib.make_bb_codec(params, cfg)
    rng = np.random.default_rng(4)
    s = jnp.asarray(rng.integers(0, 2, (lanes, cfg.input_dim)), jnp.int32)
    stack = ans.make_stack(lanes, 4096, key=jax.random.PRNGKey(10))
    stack = ans.seed_stack(stack, jax.random.PRNGKey(11), 64)

    b0 = float(ans.stack_content_bits(stack))
    st, y = codec.posterior(s).pop(stack)
    st = codec.likelihood(y).push(st, s)
    st = codec.prior.push(st, y)
    achieved = float(ans.stack_content_bits(st)) - b0
    expected = _analytic_append_bits(cfg, params, s, np.asarray(y))
    assert achieved == pytest.approx(expected, abs=1.0 * lanes)


@pytest.mark.slow
def test_bbans_chain_rate_near_elbo(small_cfg, small_params):
    """Chained rate lands near the continuous -ELBO (loose: untrained
    model, finite chain; the trained-model ~1% check lives in benchmarks)."""
    cfg, params = small_cfg, small_params
    lanes, n = 8, 24
    chained = codecs.Chained(vae_lib.make_bb_codec(params, cfg), n)
    rng = np.random.default_rng(4)
    data = jnp.asarray(rng.integers(0, 2, (n, lanes, cfg.input_dim)),
                       jnp.int32)
    stack = ans.make_stack(lanes, 8192, key=jax.random.PRNGKey(10))
    stack = ans.seed_stack(stack, jax.random.PRNGKey(11), 64)
    bits_before = float(ans.stack_content_bits(stack))
    stack2 = chained.push(stack, data)
    bits_after = float(ans.stack_content_bits(stack2))
    rate = (bits_after - bits_before) / (n * lanes * cfg.input_dim)

    keys = jax.random.split(jax.random.PRNGKey(12), 16)
    elbos = jnp.stack([
        vae_lib.elbo_bits_per_dim(params, cfg, k,
                                  data.reshape(-1, cfg.input_dim))
        for k in keys])
    neg_elbo = float(jnp.mean(elbos))
    assert rate == pytest.approx(neg_elbo, rel=0.15), (rate, neg_elbo)
