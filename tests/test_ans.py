"""Property and unit tests for the lane-vectorized rANS coder."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ans


def _random_starts_table(rng, lanes, alphabet, precision):
    """Random valid fixed-point CDF tables (freq >= 1, total = 2^p)."""
    probs = rng.dirichlet(np.ones(alphabet) * 0.5, size=lanes)
    return ans.probs_to_starts(jnp.asarray(probs, jnp.float32), precision)


def test_make_stack_heads_uniform_over_normalized_interval():
    """Random heads must cover the whole normalized interval [2^16,
    2^32) - not just its top half (the old seeding OR'd in bit 31,
    halving the clean-bit supply's support)."""
    heads = np.asarray(ans.make_stack(
        4096, 1, key=jax.random.PRNGKey(0)).head, np.uint64)
    assert (heads >= (1 << 16)).all()
    assert (heads < (1 << 32)).all()
    # With 4096 uniform draws, each quarter of the log-range is hit.
    assert (heads < (1 << 30)).any(), "no heads below 2^30: biased draw"
    assert (heads >= (1 << 31)).any()
    # ~log2(head) - 16 clean bits/lane, ~14.56 expected under uniform.
    mean_bits = float(np.mean(np.log2(heads.astype(np.float64)))) - 16
    assert 14.0 < mean_bits < 15.1, mean_bits


def test_push_pop_single_symbol_roundtrip():
    lanes = 8
    stack = ans.make_stack(lanes, capacity=16,
                           key=jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    table = _random_starts_table(rng, lanes, alphabet=5, precision=12)
    sym = jnp.asarray(rng.integers(0, 5, size=lanes), jnp.int32)
    h0 = stack.head
    stack2 = ans.push_with_table(stack, table, sym, precision=12)
    stack3, sym_out = ans.pop_with_table(stack2, table, precision=12)
    np.testing.assert_array_equal(np.asarray(sym_out), np.asarray(sym))
    np.testing.assert_array_equal(np.asarray(stack3.head), np.asarray(h0))
    np.testing.assert_array_equal(np.asarray(stack3.ptr), np.asarray(stack.ptr))


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    alphabet=st.integers(2, 40),
    precision=st.integers(6, 16),
    n_symbols=st.integers(1, 60),
    lanes=st.integers(1, 9),
)
def test_sequence_roundtrip_property(seed, alphabet, precision, n_symbols,
                                     lanes):
    """LIFO invertibility: pushing N symbols then popping N recovers them
    in reverse, restoring the stack exactly."""
    if alphabet >= (1 << precision) - alphabet:
        alphabet = max(2, (1 << precision) // 4)
    rng = np.random.default_rng(seed)
    stack = ans.make_stack(lanes, capacity=n_symbols + 8,
                           key=jax.random.PRNGKey(seed))
    tables = [
        _random_starts_table(rng, lanes, alphabet, precision)
        for _ in range(n_symbols)
    ]
    syms = [jnp.asarray(rng.integers(0, alphabet, size=lanes), jnp.int32)
            for _ in range(n_symbols)]

    h0, p0 = np.asarray(stack.head), np.asarray(stack.ptr)
    s = stack
    for t in range(n_symbols):
        s = ans.push_with_table(s, tables[t], syms[t], precision)
    for t in reversed(range(n_symbols)):
        s, out = ans.pop_with_table(s, tables[t], precision)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(syms[t]))
    np.testing.assert_array_equal(np.asarray(s.head), h0)
    np.testing.assert_array_equal(np.asarray(s.ptr), p0)
    assert int(jnp.sum(s.underflows)) == 0


def test_rate_matches_entropy():
    """Coding i.i.d. symbols approaches the source entropy (within ~1%)."""
    lanes, n, precision = 4, 4000, 14
    rng = np.random.default_rng(1)
    probs = np.array([0.5, 0.25, 0.125, 0.0625, 0.0625], np.float32)
    entropy = -np.sum(probs * np.log2(probs))
    table = ans.probs_to_starts(
        jnp.tile(jnp.asarray(probs), (lanes, 1)), precision)
    syms = rng.choice(len(probs), size=(n, lanes), p=probs)

    stack = ans.make_stack(lanes, capacity=n + 8)
    bits0 = int(ans.stack_bits(stack))

    def body(i, s):
        return ans.push_with_table(s, table, syms_j[i], precision)

    syms_j = jnp.asarray(syms, jnp.int32)
    stack = jax.lax.fori_loop(0, n, body, stack)
    bits = int(ans.stack_bits(stack)) - bits0
    rate = bits / (n * lanes)
    assert rate == pytest.approx(entropy, rel=0.02), (rate, entropy)


def test_flatten_unflatten_roundtrip():
    lanes = 3
    rng = np.random.default_rng(2)
    stack = ans.make_stack(lanes, capacity=32, key=jax.random.PRNGKey(3))
    table = _random_starts_table(rng, lanes, 17, 12)
    for _ in range(20):
        sym = jnp.asarray(rng.integers(0, 17, lanes), jnp.int32)
        stack = ans.push_with_table(stack, table, sym, 12)
    msg, lengths = ans.flatten(stack)
    stack2 = ans.unflatten(msg, lengths, capacity=32)
    np.testing.assert_array_equal(np.asarray(stack2.head),
                                  np.asarray(stack.head))
    np.testing.assert_array_equal(np.asarray(stack2.ptr),
                                  np.asarray(stack.ptr))
    np.testing.assert_array_equal(np.asarray(stack2.buf),
                                  np.asarray(stack.buf))


def test_flatten_unflatten_ragged_lengths():
    """Lanes renormalize at different rates -> ragged ptrs; the wire
    format must carry each lane's true length and restore it."""
    lanes, precision, n = 2, 14, 40
    # Lane 0 codes a near-certain symbol (~0 bits), lane 1 a rare one
    # (~7 bits): their chunk stacks diverge.
    probs = jnp.asarray([[0.99, 0.01], [0.01, 0.99]], jnp.float32)
    table = ans.probs_to_starts(probs, precision)
    stack = ans.make_stack(lanes, capacity=64, key=jax.random.PRNGKey(11))
    for _ in range(n):
        stack = ans.push_with_table(
            stack, table, jnp.zeros((lanes,), jnp.int32), precision)
    ptrs = np.asarray(stack.ptr)
    assert ptrs[0] != ptrs[1], "expected ragged stacks"

    msg, lengths = ans.flatten(stack)
    np.testing.assert_array_equal(np.asarray(lengths), ptrs + 2)
    stack2 = ans.unflatten(msg, lengths, capacity=64)
    s = stack2
    for _ in range(n):
        s, out = ans.pop_with_table(s, table, precision)
        np.testing.assert_array_equal(np.asarray(out), [0, 0])
    assert int(jnp.sum(s.underflows)) == 0


def test_unflatten_capacity_reexpansion():
    """A message narrower than the requested capacity must re-expand to
    a working stack (pushes beyond the wire width succeed)."""
    lanes, precision = 3, 12
    rng = np.random.default_rng(12)
    stack = ans.make_stack(lanes, capacity=8, key=jax.random.PRNGKey(13))
    table = _random_starts_table(rng, lanes, 17, precision)
    syms = [jnp.asarray(rng.integers(0, 17, lanes), jnp.int32)
            for _ in range(5)]
    for sym in syms:
        stack = ans.push_with_table(stack, table, sym, precision)
    msg, lengths = ans.flatten(stack)
    assert msg.shape[1] == 8 + 2

    big = ans.unflatten(msg, lengths, capacity=64)
    assert big.capacity == 64
    np.testing.assert_array_equal(np.asarray(big.head),
                                  np.asarray(stack.head))
    np.testing.assert_array_equal(np.asarray(big.ptr),
                                  np.asarray(stack.ptr))
    # Keep coding in the re-expanded stack, then drain everything.
    more = [jnp.asarray(rng.integers(0, 17, lanes), jnp.int32)
            for _ in range(30)]
    s = big
    for sym in more:
        s = ans.push_with_table(s, table, sym, precision)
    assert int(jnp.sum(s.overflows)) == 0
    for sym in reversed(syms + more):
        s, out = ans.pop_with_table(s, table, precision)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(sym))
    assert int(jnp.sum(s.underflows)) == 0


def test_pop_underflow_is_counted():
    stack = ans.make_stack(2, capacity=4)  # head == L, empty buffer
    table = ans.probs_to_starts(
        jnp.tile(jnp.asarray([0.5, 0.5], jnp.float32), (2, 1)), 8)
    stack2, _ = ans.pop_with_table(stack, table, 8)
    assert int(jnp.sum(stack2.underflows)) >= 0  # may or may not renorm
    # Pop enough times to force underflow.
    s = stack2
    for _ in range(8):
        s, _ = ans.pop_with_table(s, table, 8)
    assert int(jnp.sum(s.underflows)) > 0


def test_starts_table_invariants():
    rng = np.random.default_rng(4)
    for precision in (8, 12, 16):
        for alphabet in (2, 3, 100, 257):
            if alphabet >= (1 << precision) - alphabet:
                continue
            t = np.asarray(_random_starts_table(rng, 5, alphabet, precision))
            assert (t[:, 0] == 0).all()
            assert (t[:, -1] == (1 << precision)).all()
            assert (np.diff(t.astype(np.int64), axis=1) >= 1).all()


def test_jit_push_pop():
    """The coder must be jittable end to end."""
    lanes, precision = 4, 12
    table = ans.probs_to_starts(
        jnp.tile(jnp.asarray([0.7, 0.2, 0.1], jnp.float32), (lanes, 1)),
        precision)

    @jax.jit
    def roundtrip(stack, sym):
        s = ans.push_with_table(stack, table, sym, precision)
        s, out = ans.pop_with_table(s, table, precision)
        return s, out

    stack = ans.make_stack(lanes, 8, key=jax.random.PRNGKey(7))
    sym = jnp.asarray([0, 1, 2, 0], jnp.int32)
    _, out = roundtrip(stack, sym)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(sym))
