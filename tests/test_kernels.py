"""Pallas kernels vs pure-jnp oracles: shape/dtype/precision sweeps in
interpret mode (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ans, discretize
from repro.kernels.ans import ops as ans_ops, ref as ans_ref
from repro.kernels.bucketize import ops as bk_ops, ref as bk_ref
from repro.kernels.flash import ops as fl_ops, ref as fl_ref


# ---------------------------------------------------------------------------
# ANS push kernel
# ---------------------------------------------------------------------------

def _rand_symbol_stream(rng, steps, lanes, alphabet, precision):
    starts = np.zeros((steps, lanes), np.uint32)
    freqs = np.zeros((steps, lanes), np.uint32)
    for t in range(steps):
        probs = rng.dirichlet(np.ones(alphabet), size=lanes)
        table = np.asarray(ans.probs_to_starts(
            jnp.asarray(probs, jnp.float32), precision))
        sym = rng.integers(0, alphabet, lanes)
        starts[t] = table[np.arange(lanes), sym]
        freqs[t] = table[np.arange(lanes), sym + 1] - starts[t]
    return jnp.asarray(starts), jnp.asarray(freqs)


@pytest.mark.parametrize("steps,lanes,alphabet,precision", [
    (4, 8, 4, 12),
    (16, 64, 17, 16),
    (9, 130, 3, 8),     # lanes not a multiple of the tile
    (32, 128, 256, 16),
])
def test_ans_push_kernel_matches_core(steps, lanes, alphabet, precision):
    rng = np.random.default_rng(steps * 1000 + lanes)
    starts, freqs = _rand_symbol_stream(rng, steps, lanes, alphabet,
                                        precision)
    stack = ans.make_stack(lanes, capacity=steps + 8,
                           key=jax.random.PRNGKey(lanes))
    out_kernel = ans_ops.push_many(stack, starts, freqs, precision)
    out_ref = ans_ref.push_many_ref(stack, starts, freqs, precision)
    np.testing.assert_array_equal(np.asarray(out_kernel.head),
                                  np.asarray(out_ref.head))
    np.testing.assert_array_equal(np.asarray(out_kernel.ptr),
                                  np.asarray(out_ref.ptr))
    np.testing.assert_array_equal(np.asarray(out_kernel.buf),
                                  np.asarray(out_ref.buf))


@pytest.mark.parametrize("steps,lanes,alphabet,precision", [
    (4, 8, 4, 12),
    (16, 64, 17, 16),
    (9, 130, 3, 8),     # lanes not a multiple of the tile
    (32, 128, 200, 16),
])
def test_ans_pop_kernel_matches_core(steps, lanes, alphabet, precision):
    """Table-driven pop_many == sequential ans.pop_with_table, bit for
    bit (head, ptr, symbols, underflow counters)."""
    rng = np.random.default_rng(steps * 977 + lanes)
    probs = rng.dirichlet(np.ones(alphabet), size=lanes)
    table = ans.probs_to_starts(jnp.asarray(probs, jnp.float32), precision)
    syms = jnp.asarray(rng.integers(0, alphabet, (steps, lanes)),
                       jnp.int32)
    stack = ans.make_stack(lanes, steps + 8, key=jax.random.PRNGKey(7))
    stack = ans_ops.push_many_table(stack, table, syms, precision)
    ref_stack = ans_ref.push_many_table_ref(stack, table, syms, precision)

    out_k, syms_k = ans_ops.pop_many(stack, table, steps, precision)
    out_r, syms_r = ans_ref.pop_many_ref(stack, table, steps, precision)
    np.testing.assert_array_equal(np.asarray(syms_k), np.asarray(syms_r))
    np.testing.assert_array_equal(np.asarray(out_k.head),
                                  np.asarray(out_r.head))
    np.testing.assert_array_equal(np.asarray(out_k.ptr),
                                  np.asarray(out_r.ptr))
    np.testing.assert_array_equal(np.asarray(out_k.underflows),
                                  np.asarray(out_r.underflows))
    # and the pushed symbols come back reversed (LIFO)
    np.testing.assert_array_equal(np.asarray(syms_k),
                                  np.asarray(syms)[::-1])


def test_ans_pop_kernel_underflow_matches_core():
    """Pops past the stack bottom must count underflows and mangle the
    head exactly as the core does (bottom chunk re-served)."""
    rng = np.random.default_rng(3)
    lanes, precision = 6, 10
    probs = rng.dirichlet(np.ones(4), size=lanes)
    table = ans.probs_to_starts(jnp.asarray(probs, jnp.float32), precision)
    stack = ans.make_stack(lanes, 4)   # cold head, empty buffer
    out_k, syms_k = ans_ops.pop_many(stack, table, 12, precision)
    out_r, syms_r = ans_ref.pop_many_ref(stack, table, 12, precision)
    np.testing.assert_array_equal(np.asarray(syms_k), np.asarray(syms_r))
    np.testing.assert_array_equal(np.asarray(out_k.head),
                                  np.asarray(out_r.head))
    np.testing.assert_array_equal(np.asarray(out_k.underflows),
                                  np.asarray(out_r.underflows))
    assert int(jnp.sum(out_k.underflows)) > 0


def test_peek_kernel_matches_core_peek():
    """pop_slots is the honest single-step peek: slot = head mod 2^p."""
    rng = np.random.default_rng(5)
    lanes = 256
    head = jnp.asarray(
        rng.integers(1 << 16, 1 << 32, lanes, dtype=np.uint64)
        .astype(np.uint32))
    from repro.kernels.ans import kernel as ans_kernel
    for precision in (8, 12, 16):
        slots = ans_kernel.pop_slots(head, precision)
        expect = ans.peek(
            ans.make_stack(lanes, 1)._replace(head=head), precision)
        np.testing.assert_array_equal(np.asarray(slots),
                                      np.asarray(expect))


def test_ans_push_kernel_then_core_pop_roundtrip():
    """Kernel-encoded stream decodes with the core library."""
    rng = np.random.default_rng(7)
    lanes, steps, alphabet, precision = 8, 12, 5, 12
    probs = rng.dirichlet(np.ones(alphabet), size=lanes)
    table = ans.probs_to_starts(jnp.asarray(probs, jnp.float32), precision)
    syms = rng.integers(0, alphabet, (steps, lanes))
    tab_np = np.asarray(table)
    starts = jnp.asarray(tab_np[np.arange(lanes)[None], syms], jnp.uint32)
    freqs = jnp.asarray(
        tab_np[np.arange(lanes)[None], syms + 1] -
        tab_np[np.arange(lanes)[None], syms], jnp.uint32)

    stack = ans.make_stack(lanes, 32, key=jax.random.PRNGKey(3))
    stack = ans_ops.push_many(stack, starts, freqs, precision)
    for t in reversed(range(steps)):
        stack, out = ans.pop_with_table(stack, table, precision)
        np.testing.assert_array_equal(np.asarray(out), syms[t])


# ---------------------------------------------------------------------------
# Dynamic-table pop kernel (per-step tables)
# ---------------------------------------------------------------------------

def _dyn_tables(rng, steps, lanes, alphabet, precision):
    tabs = []
    for _ in range(steps):
        probs = rng.dirichlet(np.ones(alphabet), size=lanes)
        tabs.append(np.asarray(ans.probs_to_starts(
            jnp.asarray(probs, jnp.float32), precision)))
    return jnp.asarray(np.stack(tabs), jnp.uint32)


@pytest.mark.parametrize("steps,lanes,alphabet,precision", [
    (4, 8, 4, 12),
    (16, 64, 17, 16),
    (9, 130, 3, 8),     # lanes not a multiple of the tile
    (12, 128, 100, 16),
])
def test_ans_pop_dyn_kernel_matches_ref(steps, lanes, alphabet, precision):
    """pop_many_dyn == sequential ans.pop_with_table against the
    per-step tables, bit for bit."""
    rng = np.random.default_rng(steps * 31 + lanes)
    tables = _dyn_tables(rng, steps, lanes, alphabet, precision)
    stack = ans.make_stack(lanes, steps + 8, key=jax.random.PRNGKey(11))
    stack = ans.seed_stack(stack, jax.random.PRNGKey(12), steps)
    out_k, syms_k = ans_ops.pop_many_dyn(stack, tables, precision)
    out_r, syms_r = ans_ref.pop_many_dyn_ref(stack, tables, precision)
    np.testing.assert_array_equal(np.asarray(syms_k), np.asarray(syms_r))
    np.testing.assert_array_equal(np.asarray(out_k.head),
                                  np.asarray(out_r.head))
    np.testing.assert_array_equal(np.asarray(out_k.ptr),
                                  np.asarray(out_r.ptr))
    np.testing.assert_array_equal(np.asarray(out_k.underflows),
                                  np.asarray(out_r.underflows))


def test_ans_pop_dyn_roundtrips_dynamic_push():
    """Dynamic push (push_many) then dynamic pop (pop_many_dyn) against
    the same per-step tables recovers the symbols reversed (LIFO)."""
    rng = np.random.default_rng(21)
    steps, lanes, alphabet, precision = 10, 6, 7, 14
    tables = _dyn_tables(rng, steps, lanes, alphabet, precision)
    syms = jnp.asarray(rng.integers(0, alphabet, (steps, lanes)),
                       jnp.int32)
    tab_np = np.asarray(tables)
    rows = np.arange(lanes)[None, :]
    starts = jnp.asarray(
        tab_np[np.arange(steps)[:, None], rows, np.asarray(syms)],
        jnp.uint32)
    freqs = jnp.asarray(
        tab_np[np.arange(steps)[:, None], rows, np.asarray(syms) + 1],
        jnp.uint32) - starts
    stack = ans.make_stack(lanes, steps + 8, key=jax.random.PRNGKey(13))
    stack = ans_ops.push_many(stack, starts, freqs, precision)
    # pop order reverses push order, so tables are consumed flipped
    out, decoded = ans_ops.pop_many_dyn(stack, tables[::-1], precision)
    np.testing.assert_array_equal(np.asarray(decoded),
                                  np.asarray(syms)[::-1])


def test_ans_pop_dyn_underflow_matches_ref():
    """Underflow edge: pops past the stack bottom count and mangle the
    head exactly as the sequential core does."""
    rng = np.random.default_rng(22)
    steps, lanes, precision = 12, 6, 10
    tables = _dyn_tables(rng, steps, lanes, 4, precision)
    stack = ans.make_stack(lanes, 4)   # cold head, empty buffer
    out_k, syms_k = ans_ops.pop_many_dyn(stack, tables, precision)
    out_r, syms_r = ans_ref.pop_many_dyn_ref(stack, tables, precision)
    np.testing.assert_array_equal(np.asarray(syms_k), np.asarray(syms_r))
    np.testing.assert_array_equal(np.asarray(out_k.head),
                                  np.asarray(out_r.head))
    np.testing.assert_array_equal(np.asarray(out_k.underflows),
                                  np.asarray(out_r.underflows))
    assert int(jnp.sum(out_k.underflows)) > 0


def test_ans_push_kernel_overflow_edge_matches_ref():
    """Overflow edge: chunks dropped past capacity are counted
    identically by the kernel path and the sequential core."""
    rng = np.random.default_rng(23)
    steps, lanes, precision = 24, 6, 12
    starts, freqs = _rand_symbol_stream(rng, steps, lanes, 4, precision)
    stack = ans.make_stack(lanes, 4, key=jax.random.PRNGKey(24))  # tiny
    out_k = ans_ops.push_many(stack, starts, freqs, precision)
    out_r = ans_ref.push_many_ref(stack, starts, freqs, precision)
    np.testing.assert_array_equal(np.asarray(out_k.head),
                                  np.asarray(out_r.head))
    np.testing.assert_array_equal(np.asarray(out_k.overflows),
                                  np.asarray(out_r.overflows))
    assert int(jnp.sum(out_k.overflows)) > 0


# ---------------------------------------------------------------------------
# Fused bucketize+pop grid kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["gaussian", "logistic", "uniform"])
@pytest.mark.parametrize("steps,lanes,lat_bits,precision", [
    (5, 8, 8, 16),
    (16, 64, 10, 16),
    (7, 130, 6, 12),    # lanes not a multiple of the tile
])
def test_ans_pop_grid_kernel_matches_ref(kind, steps, lanes, lat_bits,
                                         precision):
    """pop_many_grid == sequential per-position leaf pops (the fused
    CDF-inversion-in-renorm-chain kernel vs the core library)."""
    rng = np.random.default_rng(steps * 13 + lanes + lat_bits)
    mu = jnp.asarray(rng.normal(0, 1.2, (steps, lanes)), jnp.float32)
    sigma = jnp.asarray(rng.uniform(0.05, 2.0, (steps, lanes)),
                        jnp.float32)
    stack = ans.make_stack(lanes, steps + 8, key=jax.random.PRNGKey(31))
    stack = ans.seed_stack(stack, jax.random.PRNGKey(32), steps)
    out_k, idx_k = ans_ops.pop_many_grid(stack, kind, mu, sigma, steps,
                                         lat_bits, precision)
    out_r, idx_r = ans_ref.pop_many_grid_ref(stack, kind, mu, sigma,
                                             steps, lat_bits, precision)
    np.testing.assert_array_equal(np.asarray(idx_k), np.asarray(idx_r))
    np.testing.assert_array_equal(np.asarray(out_k.head),
                                  np.asarray(out_r.head))
    np.testing.assert_array_equal(np.asarray(out_k.ptr),
                                  np.asarray(out_r.ptr))
    np.testing.assert_array_equal(np.asarray(out_k.underflows),
                                  np.asarray(out_r.underflows))


def test_ans_pop_grid_underflow_matches_ref():
    rng = np.random.default_rng(33)
    steps, lanes, lat_bits, precision = 10, 6, 8, 16
    mu = jnp.asarray(rng.normal(0, 1, (steps, lanes)), jnp.float32)
    sigma = jnp.asarray(rng.uniform(0.1, 1.5, (steps, lanes)),
                        jnp.float32)
    stack = ans.make_stack(lanes, 4)   # cold head, empty buffer
    out_k, idx_k = ans_ops.pop_many_grid(stack, "gaussian", mu, sigma,
                                         steps, lat_bits, precision)
    out_r, idx_r = ans_ref.pop_many_grid_ref(stack, "gaussian", mu,
                                             sigma, steps, lat_bits,
                                             precision)
    np.testing.assert_array_equal(np.asarray(idx_k), np.asarray(idx_r))
    np.testing.assert_array_equal(np.asarray(out_k.head),
                                  np.asarray(out_r.head))
    np.testing.assert_array_equal(np.asarray(out_k.underflows),
                                  np.asarray(out_r.underflows))
    assert int(jnp.sum(out_k.underflows)) > 0


# ---------------------------------------------------------------------------
# Bucketize kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lanes,lat_bits,precision", [
    (8, 8, 16), (64, 10, 16), (200, 12, 16), (128, 6, 12),
])
def test_bucketize_kernel_matches_ref(lanes, lat_bits, precision):
    rng = np.random.default_rng(lanes)
    slot = jnp.asarray(rng.integers(0, 1 << precision, lanes), jnp.uint32)
    mu = jnp.asarray(rng.normal(0, 1.2, lanes), jnp.float32)
    sigma = jnp.asarray(rng.uniform(0.05, 2.0, lanes), jnp.float32)
    idx_k, st_k, fr_k = bk_ops.bucketize(slot, mu, sigma, lat_bits,
                                         precision)
    idx_r, st_r, fr_r = bk_ref.bucketize_ref(slot, mu, sigma, lat_bits,
                                             precision)
    np.testing.assert_array_equal(np.asarray(idx_k), np.asarray(idx_r))
    np.testing.assert_array_equal(np.asarray(st_k), np.asarray(st_r))
    np.testing.assert_array_equal(np.asarray(fr_k), np.asarray(fr_r))


def test_bucketize_kernel_matches_discretize_pop():
    """Kernel output == what core.discretize.pop_posterior decodes."""
    lanes, lat_bits, prec = 16, 10, 16
    rng = np.random.default_rng(5)
    mu = jnp.asarray(rng.normal(0, 1, lanes), jnp.float32)
    sigma = jnp.asarray(rng.uniform(0.1, 1.5, lanes), jnp.float32)
    stack = ans.make_stack(lanes, 16, key=jax.random.PRNGKey(9))
    slot = ans.peek(stack, prec)
    idx_k, _, _ = bk_ops.bucketize(slot, mu, sigma, lat_bits, prec)
    _, idx_core = discretize.pop_posterior(stack, mu, sigma, lat_bits,
                                           prec)
    np.testing.assert_array_equal(np.asarray(idx_k), np.asarray(idx_core))


# ---------------------------------------------------------------------------
# Flash attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq,sk,d,causal,window,dtype", [
    (64, 64, 16, True, 0, jnp.float32),
    (128, 128, 32, True, 40, jnp.float32),
    (96, 160, 16, False, 0, jnp.float32),
    (100, 84, 8, True, 0, jnp.float32),     # non-multiples of block
    (64, 64, 16, True, 0, jnp.bfloat16),
])
def test_flash_kernel_matches_sdpa(sq, sk, d, causal, window, dtype):
    rng = np.random.default_rng(sq + sk)
    bh = 3
    q = jnp.asarray(rng.normal(0, 1, (bh, sq, d)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (bh, sk, d)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (bh, sk, d)), dtype)
    from repro.kernels.flash import kernel as K
    out = K.flash_fwd(q, k, v, causal=causal, window=window,
                      block_q=32, block_k=32)
    ref = fl_ref.flash_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_ops_gqa_layout():
    """Model-layout wrapper (GQA expand) vs the model's exact sdpa."""
    from repro.models import attention
    rng = np.random.default_rng(11)
    b, s, hq, hkv, dh = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(0, 1, (b, s, hq, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, hkv, dh)), jnp.float32)
    out = fl_ops.flash_attention(q, k, v, causal=True, block_q=32,
                                 block_k=32)
    mask = attention._mask(s, s, True, None)
    ref = attention.sdpa(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
