"""The docs are executable and *complete*: every ``python`` fenced
block in ``docs/API.md``, ``docs/SCALING.md``, ``docs/ANALYSIS.md``,
``docs/SERVING.md`` and ``docs/PERF.md`` runs (each in a fresh
namespace), every
relative markdown link/anchor in README.md + docs/ resolves, and - the
coverage gate - every public name exported by ``repro.codecs``,
``repro.stream``, ``repro.serve``, ``repro.analysis``,
``repro.gateway`` and ``repro.kernels`` must appear in ``docs/API.md``
(the failure message lists the missing names).

This is the tier-1 backing of the CI "docs" step: the API examples are
the living spec of the public surface, so a signature change that
would silently rot the docs - or a new export that ships without
documentation - fails here instead.
"""

import os
import re

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_FILES = ["README.md", "docs/ARCHITECTURE.md", "docs/FORMATS.md",
             "docs/API.md", "docs/PERF.md", "docs/SCALING.md",
             "docs/ANALYSIS.md", "docs/SERVING.md"]

#: modules whose whole ``__all__`` must be documented in docs/API.md.
COVERED_MODULES = ("repro.codecs", "repro.stream", "repro.serve",
                   "repro.analysis", "repro.gateway",
                   "repro.gateway.cluster", "repro.kernels")

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)
_LINK = re.compile(r"(?<!!)\[[^\]]+\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^(#{1,6})\s+(.*)$", re.MULTILINE)


def _read(rel):
    with open(os.path.join(ROOT, rel), encoding="utf-8") as f:
        return f.read()


def _python_blocks(rel):
    return _FENCE.findall(_read(rel))


def _slugify(heading: str) -> str:
    """GitHub-style anchor slug (the subset our headings use)."""
    text = heading.strip().replace("`", "")
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return text.replace(" ", "-")


def _anchors(rel):
    return {_slugify(m.group(2)) for m in _HEADING.finditer(_read(rel))}


# ---------------------------------------------------------------------------
# runnable API + scaling examples
# ---------------------------------------------------------------------------

_API_BLOCKS = _python_blocks("docs/API.md")
_SCALING_BLOCKS = _python_blocks("docs/SCALING.md")
_ANALYSIS_BLOCKS = _python_blocks("docs/ANALYSIS.md")
_SERVING_BLOCKS = _python_blocks("docs/SERVING.md")
_PERF_BLOCKS = _python_blocks("docs/PERF.md")


def test_api_md_has_examples():
    assert len(_API_BLOCKS) >= 10


def test_scaling_md_has_examples():
    assert len(_SCALING_BLOCKS) >= 3


def test_analysis_md_has_examples():
    assert len(_ANALYSIS_BLOCKS) >= 10


@pytest.mark.parametrize("i", range(len(_API_BLOCKS)))
def test_api_md_block_runs(i):
    code = _API_BLOCKS[i]
    exec(compile(code, f"docs/API.md[block {i}]", "exec"), {})


@pytest.mark.parametrize("i", range(len(_SCALING_BLOCKS)))
def test_scaling_md_block_runs(i):
    code = _SCALING_BLOCKS[i]
    exec(compile(code, f"docs/SCALING.md[block {i}]", "exec"), {})


@pytest.mark.parametrize("i", range(len(_ANALYSIS_BLOCKS)))
def test_analysis_md_block_runs(i):
    code = _ANALYSIS_BLOCKS[i]
    exec(compile(code, f"docs/ANALYSIS.md[block {i}]", "exec"), {})


def test_serving_md_has_examples():
    assert len(_SERVING_BLOCKS) >= 2


def test_perf_md_has_examples():
    assert len(_PERF_BLOCKS) >= 1


@pytest.mark.parametrize("i", range(len(_PERF_BLOCKS)))
def test_perf_md_block_runs(i):
    code = _PERF_BLOCKS[i]
    exec(compile(code, f"docs/PERF.md[block {i}]", "exec"), {})


@pytest.mark.parametrize("i", range(len(_SERVING_BLOCKS)))
def test_serving_md_block_runs(i):
    code = _SERVING_BLOCKS[i]
    exec(compile(code, f"docs/SERVING.md[block {i}]", "exec"), {})


def test_api_md_covers_every_export():
    """The coverage gate: every ``__all__`` name of the modules in
    ``COVERED_MODULES`` appears in docs/API.md, in at least one
    runnable example or inline-code mention. Fails with the full
    missing-name list so the fix is one read away."""
    import importlib
    text = _read("docs/API.md")
    missing = {}
    for modname in COVERED_MODULES:
        mod = importlib.import_module(modname)
        assert mod.__all__, f"{modname} must define a public __all__"
        absent = [n for n in mod.__all__ if n not in text]
        if absent:
            missing[modname] = absent
    assert not missing, (
        f"docs/API.md misses exports (add a runnable example per "
        f"name): {missing}")


# ---------------------------------------------------------------------------
# link + anchor checker
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rel", DOC_FILES)
def test_markdown_links_resolve(rel):
    base = os.path.dirname(os.path.join(ROOT, rel))
    bad = []
    for target in _LINK.findall(_read(rel)):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path, _, anchor = target.partition("#")
        if path:
            full = os.path.normpath(os.path.join(base, path))
            if not os.path.exists(full):
                bad.append(f"{target}: file missing")
                continue
            rel_target = os.path.relpath(full, ROOT)
        else:
            rel_target = rel
        if anchor and rel_target.endswith(".md") and \
                anchor not in _anchors(rel_target):
            bad.append(f"{target}: anchor #{anchor} not found")
    assert not bad, f"{rel}: broken links: {bad}"


def test_readme_links_to_docs():
    text = _read("README.md")
    for doc in ("docs/ARCHITECTURE.md", "docs/FORMATS.md", "docs/API.md"):
        assert doc in text, f"README.md should link {doc}"
