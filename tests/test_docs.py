"""The docs are executable: every ``python`` fenced block in
``docs/API.md`` runs (each in a fresh namespace), and every relative
markdown link/anchor in README.md + docs/ resolves.

This is the tier-1 backing of the CI "docs" step: the API examples are
the living spec of the public ``repro.codecs``/``repro.stream``
surface, so a signature change that would silently rot the docs fails
here instead.
"""

import os
import re

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_FILES = ["README.md", "docs/ARCHITECTURE.md", "docs/FORMATS.md",
             "docs/API.md", "docs/PERF.md"]

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)
_LINK = re.compile(r"(?<!!)\[[^\]]+\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^(#{1,6})\s+(.*)$", re.MULTILINE)


def _read(rel):
    with open(os.path.join(ROOT, rel), encoding="utf-8") as f:
        return f.read()


def _python_blocks(rel):
    return _FENCE.findall(_read(rel))


def _slugify(heading: str) -> str:
    """GitHub-style anchor slug (the subset our headings use)."""
    text = heading.strip().replace("`", "")
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return text.replace(" ", "-")


def _anchors(rel):
    return {_slugify(m.group(2)) for m in _HEADING.finditer(_read(rel))}


# ---------------------------------------------------------------------------
# runnable API examples
# ---------------------------------------------------------------------------

_API_BLOCKS = _python_blocks("docs/API.md")


def test_api_md_has_examples():
    assert len(_API_BLOCKS) >= 10


@pytest.mark.parametrize("i", range(len(_API_BLOCKS)))
def test_api_md_block_runs(i):
    code = _API_BLOCKS[i]
    exec(compile(code, f"docs/API.md[block {i}]", "exec"), {})


def test_api_md_covers_every_export():
    """Every ``__all__`` name of repro.codecs and repro.stream appears
    in at least one runnable example (or inline-code mention)."""
    from repro import codecs, stream
    text = _read("docs/API.md")
    missing = [name for mod in (codecs, stream) for name in mod.__all__
               if name not in text]
    assert not missing, f"docs/API.md misses exports: {missing}"


# ---------------------------------------------------------------------------
# link + anchor checker
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rel", DOC_FILES)
def test_markdown_links_resolve(rel):
    base = os.path.dirname(os.path.join(ROOT, rel))
    bad = []
    for target in _LINK.findall(_read(rel)):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path, _, anchor = target.partition("#")
        if path:
            full = os.path.normpath(os.path.join(base, path))
            if not os.path.exists(full):
                bad.append(f"{target}: file missing")
                continue
            rel_target = os.path.relpath(full, ROOT)
        else:
            rel_target = rel
        if anchor and rel_target.endswith(".md") and \
                anchor not in _anchors(rel_target):
            bad.append(f"{target}: anchor #{anchor} not found")
    assert not bad, f"{rel}: broken links: {bad}"


def test_readme_links_to_docs():
    text = _read("README.md")
    for doc in ("docs/ARCHITECTURE.md", "docs/FORMATS.md", "docs/API.md"):
        assert doc in text, f"README.md should link {doc}"
