"""Coder tests for observation-model distributions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ans
from repro.core.distributions import (Bernoulli, BetaBinomial, Categorical,
                                      FactoredCategorical,
                                      beta_binomial_log_pmf)


def _fresh(lanes, cap=64, seed=0):
    s = ans.make_stack(lanes, cap, key=jax.random.PRNGKey(seed))
    return ans.seed_stack(s, jax.random.PRNGKey(seed + 1), 8)


def test_bernoulli_roundtrip():
    lanes = 16
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(0, 3, lanes), jnp.float32)
    sym = jnp.asarray(rng.integers(0, 2, lanes), jnp.int32)
    d = Bernoulli(logits)
    st0 = _fresh(lanes)
    st1 = d.push(st0, sym)
    st2, out = d.pop(st1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(sym))
    np.testing.assert_array_equal(np.asarray(st2.head), np.asarray(st0.head))


def test_beta_binomial_roundtrip_and_pmf():
    lanes = 8
    rng = np.random.default_rng(1)
    alpha = jnp.asarray(rng.uniform(0.3, 5.0, lanes), jnp.float32)
    beta = jnp.asarray(rng.uniform(0.3, 5.0, lanes), jnp.float32)
    d = BetaBinomial(alpha, beta, n=255)
    sym = jnp.asarray(rng.integers(0, 256, lanes), jnp.int32)
    st0 = _fresh(lanes)
    st1 = d.push(st0, sym)
    st2, out = d.pop(st1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(sym))
    np.testing.assert_array_equal(np.asarray(st2.head), np.asarray(st0.head))
    # pmf sums to 1
    ks = jnp.arange(256, dtype=jnp.float32)
    lp = beta_binomial_log_pmf(ks[None], 255, alpha[:, None], beta[:, None])
    total = jnp.exp(lp).sum(-1)
    np.testing.assert_allclose(np.asarray(total), 1.0, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), vocab=st.integers(300, 4000))
def test_factored_categorical_roundtrip(seed, vocab):
    """Large-vocab token coder: exact roundtrip through (chunk, offset)."""
    lanes = 4
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(0, 2, (lanes, vocab)), jnp.float32)
    sym = jnp.asarray(rng.integers(0, vocab, lanes), jnp.int32)
    d = FactoredCategorical(logits, chunk_size=256)
    st0 = _fresh(lanes, cap=64, seed=seed % 97)
    st1 = d.push(st0, sym)
    st2, out = d.pop(st1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(sym))
    np.testing.assert_array_equal(np.asarray(st2.head), np.asarray(st0.head))
    np.testing.assert_array_equal(np.asarray(st2.ptr), np.asarray(st0.ptr))


def test_factored_categorical_rate_matches_entropy():
    """Factoring costs ~nothing: coded length ~ -log2 p(token)."""
    lanes, vocab, n = 8, 1000, 150
    rng = np.random.default_rng(3)
    logits_np = rng.normal(0, 1.5, (lanes, vocab)).astype(np.float32)
    logits = jnp.asarray(logits_np)
    d = FactoredCategorical(logits, chunk_size=256)
    logp = jax.nn.log_softmax(logits, -1)
    st = _fresh(lanes, cap=n * 4 + 16, seed=5)
    bits0 = float(ans.stack_content_bits(st))
    expected = 0.0
    for t in range(n):
        sym_np = np.array([rng.choice(vocab, p=np.exp(np.asarray(logp)[l]))
                           for l in range(lanes)])
        sym = jnp.asarray(sym_np, jnp.int32)
        expected += float(-jnp.sum(
            jnp.take_along_axis(logp, sym[:, None], 1)) / jnp.log(2.0))
        st = d.push(st, sym)
    achieved = float(ans.stack_content_bits(st)) - bits0
    assert achieved == pytest.approx(expected, rel=0.02), (achieved, expected)


def test_categorical_large_alphabet_guard():
    """Alphabets beyond the fixed-point budget must hard-fail (the
    FactoredCategorical is the supported path)."""
    lanes = 2
    logits = jnp.zeros((lanes, 70000), jnp.float32)
    d = Categorical(logits, precision=16)
    with pytest.raises(ValueError):
        d.push(_fresh(lanes), jnp.zeros(lanes, jnp.int32))
