"""Lane-sharded dataset coding: split/merge, BBX3 framing, shard
independence, SPMD coder parity - and the PR-5 determinism contract:
multi-device wire bytes are identical to single-device bytes, proved
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in a
subprocess (the in-process backend is already initialized 1-device).
"""

import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs, shard_codec, stream
from repro.core import ans
from repro.sharding import api as shard_api
from repro.stream import format as fmt


def _uniform_data(n=12, lanes=8, bits=6, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 1 << bits, (n, lanes)), jnp.int32)


# ---------------------------------------------------------------------------
# lane split/merge
# ---------------------------------------------------------------------------

def test_split_merge_lanes_roundtrip():
    stack = ans.make_stack(8, 16, key=jax.random.PRNGKey(0))
    stack = ans.seed_stack(stack, jax.random.PRNGKey(1), 4)
    shards = ans.split_lanes(stack, 4)
    assert all(s.lanes == 2 and s.capacity == 16 for s in shards)
    merged = ans.merge_lanes(shards)
    for a, b in zip(merged, stack):
        assert jnp.array_equal(a, b)


def test_split_lanes_coding_is_shard_local():
    """Coding a shard then merging == coding the same lanes unsplit."""
    codec = codecs.Uniform(6)
    xs = _uniform_data(n=1, lanes=8)[0]
    full = codecs.fresh_stack(8, 32, seed=0)
    shards = list(ans.split_lanes(full, 4))
    shards = [codec.push(s, xs[i * 2:(i + 1) * 2])
              for i, s in enumerate(shards)]
    merged = ans.merge_lanes(shards)
    ref = codec.push(full, xs)
    assert jnp.array_equal(merged.head, ref.head)
    assert jnp.array_equal(merged.buf, ref.buf)
    assert jnp.array_equal(merged.ptr, ref.ptr)


def test_split_lanes_rejects_nondivisible():
    stack = ans.make_stack(6, 8)
    with pytest.raises(ValueError):
        ans.split_lanes(stack, 4)
    with pytest.raises(ValueError):
        ans.merge_lanes([])


# ---------------------------------------------------------------------------
# BBX3 framing
# ---------------------------------------------------------------------------

def test_corpus_framing_roundtrip():
    segs = [b"shard-zero", b"s1", b"the-third-shard"]
    blob = fmt.encode_corpus(segs, [10, 2, 7], lanes_per_shard=2)
    header, entries = fmt.scan_corpus(blob)
    assert header.n_shards == 3 and header.lanes_per_shard == 2
    assert [e.n_symbols for e in entries] == [10, 2, 7]
    for s, seg in enumerate(segs):
        assert fmt.corpus_segment(blob, s) == seg
        e = entries[s]
        assert blob[e.offset:e.offset + e.length] == seg


def test_corpus_framing_rejects_corruption():
    blob = fmt.encode_corpus([b"abc"], [1], lanes_per_shard=1)
    with pytest.raises(ValueError):
        fmt.scan_corpus(b"BBQ3" + blob[4:])     # magic
    with pytest.raises(ValueError):
        fmt.scan_corpus(blob[:-2])              # truncated segment
    with pytest.raises(ValueError):
        fmt.corpus_segment(blob, 1)             # shard out of range
    with pytest.raises(ValueError):
        fmt.encode_corpus([], [], lanes_per_shard=1)


# ---------------------------------------------------------------------------
# dataset compress/decompress
# ---------------------------------------------------------------------------

def test_dataset_roundtrip_and_shard_independence():
    xs = _uniform_data(n=10, lanes=8)
    codec = codecs.Uniform(6)
    blob = shard_codec.compress_dataset(codec, xs, n_shards=4,
                                        block_symbols=3, seed=None,
                                        init_chunks=0)
    assert jnp.array_equal(shard_codec.decompress_dataset(codec, blob),
                           xs)
    # every shard decodes alone, from its segment bytes only
    for s in range(4):
        out = shard_codec.decompress_shard(codec, blob, s)
        assert jnp.array_equal(out, xs[:, s * 2:(s + 1) * 2])
    info = shard_codec.corpus_info(blob)
    assert info["n_shards"] == 4 and info["lanes_per_shard"] == 2
    assert info["total_symbols"] == 4 * 10
    assert sum(info["shard_bytes"]) + info["index_bytes"] == len(blob)


def test_dataset_chunked_input_matches_one_shot():
    xs = _uniform_data(n=9, lanes=4, seed=3)
    codec = codecs.Uniform(6)
    kw = dict(n_shards=2, block_symbols=4, seed=0, init_chunks=0)
    one = shard_codec.compress_dataset(codec, xs, **kw)
    chunked = shard_codec.compress_dataset(
        codec, [xs[:2], xs[2:7], xs[7:]], **kw)
    assert chunked == one


def test_dataset_bytes_independent_of_device_placement():
    """Same shard layout, forced single-device placement -> same blob."""
    xs = _uniform_data(n=6, lanes=8, seed=4)
    codec = codecs.Uniform(6)
    kw = dict(n_shards=4, block_symbols=2, seed=1, init_chunks=0)
    auto = shard_codec.compress_dataset(codec, xs, **kw)
    pinned = shard_codec.compress_dataset(
        codec, xs, devices=[jax.devices()[0]] * 4, **kw)
    assert pinned == auto


def test_dataset_bitsback_codec_roundtrip():
    """A BBANS codec (posterior pops -> per-block clean bits) through
    the sharded path."""
    bits = 6
    codec = codecs.BBANS(
        prior=codecs.Uniform(bits),
        likelihood=lambda y: codecs.Bernoulli((y - 32.0) / 8.0),
        posterior=lambda s: codecs.DiscretizedGaussian(
            2.0 * s - 1.0, jnp.full(s.shape, 0.5), bits))
    rng = np.random.default_rng(5)
    xs = jnp.asarray(rng.integers(0, 2, (8, 4)), jnp.int32)
    blob = shard_codec.compress_dataset(codec, xs, n_shards=2,
                                        block_symbols=4, seed=0)
    assert jnp.array_equal(shard_codec.decompress_dataset(codec, blob),
                           xs)


def test_dataset_rejects_bad_layout():
    xs = _uniform_data(n=4, lanes=6)
    with pytest.raises(ValueError):
        shard_codec.compress_dataset(codecs.Uniform(6), xs, n_shards=4,
                                     block_symbols=2, seed=None,
                                     init_chunks=0)
    with pytest.raises(ValueError):
        shard_codec.compress_dataset(codecs.Uniform(6), [], n_shards=2,
                                     block_symbols=2)
    with pytest.raises(ValueError):
        shard_codec.split_lane_tree(xs, 4)


# ---------------------------------------------------------------------------
# SPMD coder programs (lane mesh; 1 device in-process)
# ---------------------------------------------------------------------------

def test_lane_mesh_compiled_codec_byte_parity():
    """Compiled-codec wire under use_lane_mesh == meshless wire."""
    rng = np.random.default_rng(0)
    lanes, n = 4, 16
    mu = jnp.asarray(rng.normal(0, 1, (lanes, n)), jnp.float32)
    sigma = jnp.asarray(rng.uniform(0.2, 1.5, (lanes, n)), jnp.float32)
    codec = codecs.Repeat(
        lambda d: codecs.DiscretizedGaussian(mu[:, d], sigma[:, d], 8),
        n)
    prog = codecs.compile(codec, donate=False)
    stack = codecs.fresh_stack(lanes, 128, seed=0, init_chunks=16)
    s_plain, y_plain = prog.pop(stack)
    with shard_api.use_lane_mesh(shard_api.lane_mesh()):
        s_mesh, y_mesh = prog.pop(stack)
        s_mesh = prog.push(s_mesh, y_mesh)
    s_plain = prog.push(s_plain, y_plain)
    assert jnp.array_equal(y_plain, y_mesh)
    assert jnp.array_equal(s_plain.head, s_mesh.head)
    assert jnp.array_equal(s_plain.buf, s_mesh.buf)


def test_lane_mesh_rejects_bad_shapes():
    with pytest.raises(ValueError):
        shard_api.lane_mesh(len(jax.devices()) + 1)
    from repro.codecs.compile import coder_programs
    with pytest.raises(ValueError):
        coder_programs(jax.sharding.Mesh(
            np.asarray(jax.devices()).reshape(1, 1), ("a", "b")))


# ---------------------------------------------------------------------------
# multi-device determinism (8 simulated host devices, subprocess)
# ---------------------------------------------------------------------------

MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import codecs, serve, shard_codec
    from repro.sharding import api as shard_api

    assert len(jax.devices()) == 8, jax.devices()
    rng = np.random.default_rng(0)
    lanes, n = 8, 6
    xs = jnp.asarray(rng.integers(0, 64, (n, lanes)), jnp.int32)
    codec = codecs.Uniform(6)

    # BBX3 corpus across 8 real (simulated) devices
    blob = shard_codec.compress_dataset(
        codec, xs, n_shards=8, block_symbols=2, seed=0, init_chunks=0)
    ok_rt = bool(jnp.array_equal(
        shard_codec.decompress_dataset(codec, blob), xs))

    # one-shot SPMD path: lane mesh over all 8 devices
    eng = serve.ShardedCodecEngine(
        lambda shape: codecs.Repeat(lambda d: codecs.Uniform(6),
                                    shape[0]),
        seed=0)
    data = xs.reshape(n, lanes, 1)             # [n, lanes, 1]
    one = eng.compress(data)
    ok_spmd = bool(jnp.array_equal(
        eng.decompress(one, n, (1,)), data))

    print(json.dumps({
        "devices": len(jax.devices()),
        "mesh": int(eng.mesh.devices.size),
        "blob": blob.hex(),
        "oneshot": one.hex(),
        "ok_rt": ok_rt, "ok_spmd": ok_spmd,
    }))
""")


@pytest.mark.slow
def test_multi_device_wire_matches_single_device():
    """The acceptance-criterion test: bytes produced on 8 simulated
    devices == bytes produced in this 1-device process, for both the
    BBX3 dataset path and the SPMD one-shot path."""
    out = subprocess.run(
        [sys.executable, "-c", MULTI_DEVICE_SCRIPT],
        capture_output=True, text=True, timeout=420,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["devices"] == 8 and rec["mesh"] == 8
    assert rec["ok_rt"] and rec["ok_spmd"]

    # reproduce both blobs locally (1 device) - bytes must match
    rng = np.random.default_rng(0)
    lanes, n = 8, 6
    xs = jnp.asarray(rng.integers(0, 64, (n, lanes)), jnp.int32)
    codec = codecs.Uniform(6)
    local = shard_codec.compress_dataset(
        codec, xs, n_shards=8, block_symbols=2, seed=0, init_chunks=0)
    assert local.hex() == rec["blob"], \
        "BBX3 corpus bytes differ between 8 devices and 1 device"

    from repro import serve
    eng = serve.ShardedCodecEngine(
        lambda shape: codecs.Repeat(lambda d: codecs.Uniform(6),
                                    shape[0]),
        seed=0)
    data = xs.reshape(n, lanes, 1)
    one = eng.compress(data)
    assert one.hex() == rec["oneshot"], \
        "one-shot SPMD bytes differ between 8 devices and 1 device"


# ---------------------------------------------------------------------------
# ShardedCodecEngine (1 device in-process)
# ---------------------------------------------------------------------------

def test_sharded_engine_matches_codec_engine_and_decodes():
    from repro.serve.engine import CodecEngine, ShardedCodecEngine

    def family(shape):
        return codecs.Repeat(lambda d: codecs.Uniform(4), shape[0])

    rng = np.random.default_rng(7)
    data = jnp.asarray(rng.integers(0, 16, (3, 4, 5)), jnp.int32)
    base = CodecEngine(family, seed=0, compile=True)
    eng = ShardedCodecEngine(family, seed=0, n_shards=2)
    assert eng.compress(data) == base.compress(data)
    assert jnp.array_equal(
        eng.decompress(eng.compress(data), 3, (5,)), data)

    corp = eng.compress_dataset(data, block_symbols=2)
    assert jnp.array_equal(eng.decompress_dataset(corp, (5,)), data)
    assert jnp.array_equal(eng.decompress_shard(corp, 1, (5,)),
                           data[:, 2:])
    # a streaming loader (generator of chunks) produces the same corpus
    corp_gen = eng.compress_dataset(
        (c for c in [data[:1], data[1:]]), block_symbols=2)
    assert corp_gen == corp


def test_sharded_engine_rejects_bad_inputs():
    from repro.serve.engine import ShardedCodecEngine

    def family(shape):
        return codecs.Repeat(lambda d: codecs.Uniform(4), shape[0])

    eng = ShardedCodecEngine(family, seed=0, n_shards=1)
    with pytest.raises(ValueError, match="no data chunks"):
        eng.compress_dataset(iter([]))
    with pytest.raises(ValueError, match="no data chunks"):
        eng.compress_dataset([])
    # lanes not a multiple of the mesh size -> clear up-front error
    eng._check_lanes(4)                        # multiple of 1: fine
    eng2 = ShardedCodecEngine.__new__(ShardedCodecEngine)
    eng2.mesh = type("M", (), {"devices": np.zeros((2,))})()
    eng2._check_lanes(4)                       # 4 % 2 == 0: fine
    with pytest.raises(ValueError, match="multiple"):
        eng2._check_lanes(3)
