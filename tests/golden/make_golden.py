"""Golden-wire fixture builder: one deterministic blob per format tier.

Every fixture is a pure function of pinned seeds - numpy's
``default_rng`` for data and logits, ``jax.random.PRNGKey`` for model
params - so a clean checkout regenerates them byte-for-byte. The
committed blobs freeze the wire formats: ``tests/test_golden.py``
re-encodes each fixture and compares hex-for-hex, then decodes the
*committed* bytes and checks the data comes back losslessly. Any codec
or kernel change that silently moves a single wire byte fails both
directions.

Fixtures:

  * ``bbx1_uniform``       - one-call container, all-integer codec (no
                             float anywhere in table building).
  * ``bbx1_categorical``   - container over a host-built static table.
  * ``bbx1_vae_fixedpoint``- container over the quantized VAE, coded by
                             the FUSED compiled program (wire identical
                             to the eager interpreter by the ISSUE-8
                             parity contract).
  * ``bbx2_stream``        - BBX2 block stream over the quantized VAE,
                             pipelined double-buffered encoder.
  * ``bbx3_corpus``        - BBX3 sharded corpus, 2 lane-shards.
  * ``bbx3_cluster``       - BBX3 corpus driven through a 2-host
                             ``GatewayCluster`` with one host killed
                             mid-stream and its shard streams resumed
                             on the peer (public cluster API only); the
                             committed bytes pin the failover path
                             hex-for-hex to the synchronous wire.

Regenerate after an *intentional* wire change::

    PYTHONPATH=src python tests/golden/make_golden.py
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

GOLDEN_DIR = os.path.dirname(os.path.abspath(__file__))

LANES = 4


def _vae_codec(compiled: bool):
    from repro import codecs
    from repro.models import vae
    cfg = vae.VAEConfig(input_dim=36, hidden=24, latent=6)
    params = vae.init(jax.random.PRNGKey(0), cfg)
    codec = vae.make_bb_codec_q(params, cfg)
    return codecs.compile(codec) if compiled else codec


def _vae_data(n: int) -> jnp.ndarray:
    rng = np.random.default_rng(1234)
    return jnp.asarray(rng.integers(0, 2, (n, LANES, 36)), jnp.int32)


def build() -> dict:
    """name -> (blob bytes, decode fn asserting losslessness)."""
    from repro import codecs, shard_codec
    from repro.stream.coder import StreamEncoder, decode_stream

    out = {}

    # BBX1, integer-only codec: 9 uniform 6-bit symbols per lane.
    rng = np.random.default_rng(42)
    uni = codecs.Shaped(codecs.Repeat(lambda d: codecs.Uniform(6), 9),
                        (9,))
    u_data = jnp.asarray(rng.integers(0, 64, (LANES, 9)), jnp.int32)
    out["bbx1_uniform"] = (
        lambda: codecs.compress(uni, u_data, lanes=LANES, seed=0),
        lambda blob: codecs.decompress(uni, blob), u_data)

    # BBX1, static-table categorical (host-built from seeded logits).
    logits = jnp.asarray(rng.normal(size=(LANES, 12)), jnp.float32)
    cat = codecs.Categorical(logits)
    c_data = jnp.asarray(rng.integers(0, 12, (LANES,)), jnp.int32)
    out["bbx1_categorical"] = (
        lambda: codecs.compress(cat, c_data, lanes=LANES, seed=0),
        lambda blob: codecs.decompress(cat, blob), c_data)

    # BBX1, quantized VAE through the fused compiled program.
    fused = _vae_codec(compiled=True)
    v_data = _vae_data(1)[0]
    kw = dict(lanes=LANES, seed=0, init_chunks=16, capacity=512)
    out["bbx1_vae_fixedpoint"] = (
        lambda: codecs.compress(fused, v_data, **kw),
        lambda blob: codecs.decompress(fused, blob), v_data)

    # BBX2 block stream, pipelined encoder (bytes are asserted equal
    # to the synchronous path in tests/test_stream.py).
    s_codec = _vae_codec(compiled=False)
    s_data = _vae_data(6)

    def _encode_stream() -> bytes:
        enc = StreamEncoder(s_codec, lanes=LANES, block_symbols=2,
                            seed=0, init_chunks=16, capacity=512,
                            compile=True, pipeline=True)
        return enc.write(s_data) + enc.flush()

    out["bbx2_stream"] = (
        _encode_stream,
        lambda blob: decode_stream(s_codec, blob), s_data)

    # BBX3 corpus: 2 lane-shards over the quantized VAE stream.
    d_data = _vae_data(4)
    out["bbx3_corpus"] = (
        lambda: shard_codec.compress_dataset(
            s_codec, d_data, n_shards=2, block_symbols=2, seed=0,
            init_chunks=16, capacity=512),
        lambda blob: shard_codec.decompress_dataset(s_codec, blob),
        d_data)

    # BBX3 corpus through a 2-host cluster with a mid-stream host kill:
    # the determinism contract says the committed bytes are identical
    # to the synchronous sharded path, kill or no kill.
    g_rng = np.random.default_rng(2024)
    g_data = jnp.asarray(g_rng.integers(0, 64, (8, 8, 9)), jnp.int32)
    out["bbx3_cluster"] = (
        lambda: _encode_cluster_corpus(uni, g_data, n_shards=4),
        lambda blob: shard_codec.decompress_dataset(uni, blob),
        g_data)
    return out


def _encode_cluster_corpus(codec, data, n_shards: int) -> bytes:
    """Drive ``data`` shard-by-shard through a 2-host cluster (public
    ``repro.gateway`` API only), killing ``host1`` after the first
    block round so its shard streams fail over mid-stream to ``host0``
    via their replicated recovery records."""
    import asyncio
    import tempfile

    from repro import shard_codec
    from repro.gateway import GatewayCluster, TenantQuota
    from repro.serve import CodecEngine
    from repro.stream import format as fmt

    lanes = int(data.shape[1])
    per = lanes // n_shards
    shards = shard_codec.split_lane_tree(data, n_shards)

    async def scenario(tmp: str) -> bytes:
        cluster = GatewayCluster(
            [CodecEngine(lambda s, _c=codec: _c,
                         max_inflight_lanes=lanes)
             for _ in range(2)],
            recovery_root=tmp,
            default_quota=TenantQuota(max_lanes=lanes, max_queued=8))
        async with cluster:
            sessions, segments = [], [bytearray()
                                      for _ in range(n_shards)]
            for s in range(n_shards):
                sessions.append(await cluster.open_stream(
                    tuple(int(d) for d in data.shape[2:]), lanes=per,
                    session_id=f"golden-shard{s}", block_symbols=2,
                    seed=s, init_chunks=0))
            for s, cs in enumerate(sessions):       # first block round
                segments[s].extend(await cs.write(shards[s][:4]))
            victim = sessions[0].host               # host with streams
            peer, = [h for h in cluster.hosts if h != victim]
            killed = await cluster.kill_host(victim)
            assert killed, "golden: no stream was on the killed host"
            for s, cs in enumerate(sessions):       # failover round
                segments[s].extend(await cs.write(shards[s][4:]))
                segments[s].extend(await cs.close())
            assert all(cs.host == peer for cs in sessions), \
                "golden: a stream survived on the killed host"
            return fmt.encode_corpus(
                [bytes(seg) for seg in segments],
                [int(data.shape[0])] * n_shards, lanes_per_shard=per)

    with tempfile.TemporaryDirectory() as tmp:
        return asyncio.run(scenario(tmp))


def main() -> None:
    for name, (encode, _decode, _data) in build().items():
        blob = encode()
        path = os.path.join(GOLDEN_DIR, f"{name}.bin")
        with open(path, "wb") as f:
            f.write(blob)
        print(f"{name}: {len(blob)} bytes -> {path}")


if __name__ == "__main__":
    main()
