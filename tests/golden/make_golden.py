"""Golden-wire fixture builder: one deterministic blob per format tier.

Every fixture is a pure function of pinned seeds - numpy's
``default_rng`` for data and logits, ``jax.random.PRNGKey`` for model
params - so a clean checkout regenerates them byte-for-byte. The
committed blobs freeze the wire formats: ``tests/test_golden.py``
re-encodes each fixture and compares hex-for-hex, then decodes the
*committed* bytes and checks the data comes back losslessly. Any codec
or kernel change that silently moves a single wire byte fails both
directions.

Fixtures:

  * ``bbx1_uniform``       - one-call container, all-integer codec (no
                             float anywhere in table building).
  * ``bbx1_categorical``   - container over a host-built static table.
  * ``bbx1_vae_fixedpoint``- container over the quantized VAE, coded by
                             the FUSED compiled program (wire identical
                             to the eager interpreter by the ISSUE-8
                             parity contract).
  * ``bbx2_stream``        - BBX2 block stream over the quantized VAE,
                             pipelined double-buffered encoder.
  * ``bbx3_corpus``        - BBX3 sharded corpus, 2 lane-shards.

Regenerate after an *intentional* wire change::

    PYTHONPATH=src python tests/golden/make_golden.py
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

GOLDEN_DIR = os.path.dirname(os.path.abspath(__file__))

LANES = 4


def _vae_codec(compiled: bool):
    from repro import codecs
    from repro.models import vae
    cfg = vae.VAEConfig(input_dim=36, hidden=24, latent=6)
    params = vae.init(jax.random.PRNGKey(0), cfg)
    codec = vae.make_bb_codec_q(params, cfg)
    return codecs.compile(codec) if compiled else codec


def _vae_data(n: int) -> jnp.ndarray:
    rng = np.random.default_rng(1234)
    return jnp.asarray(rng.integers(0, 2, (n, LANES, 36)), jnp.int32)


def build() -> dict:
    """name -> (blob bytes, decode fn asserting losslessness)."""
    from repro import codecs, shard_codec
    from repro.stream.coder import StreamEncoder, decode_stream

    out = {}

    # BBX1, integer-only codec: 9 uniform 6-bit symbols per lane.
    rng = np.random.default_rng(42)
    uni = codecs.Shaped(codecs.Repeat(lambda d: codecs.Uniform(6), 9),
                        (9,))
    u_data = jnp.asarray(rng.integers(0, 64, (LANES, 9)), jnp.int32)
    out["bbx1_uniform"] = (
        lambda: codecs.compress(uni, u_data, lanes=LANES, seed=0),
        lambda blob: codecs.decompress(uni, blob), u_data)

    # BBX1, static-table categorical (host-built from seeded logits).
    logits = jnp.asarray(rng.normal(size=(LANES, 12)), jnp.float32)
    cat = codecs.Categorical(logits)
    c_data = jnp.asarray(rng.integers(0, 12, (LANES,)), jnp.int32)
    out["bbx1_categorical"] = (
        lambda: codecs.compress(cat, c_data, lanes=LANES, seed=0),
        lambda blob: codecs.decompress(cat, blob), c_data)

    # BBX1, quantized VAE through the fused compiled program.
    fused = _vae_codec(compiled=True)
    v_data = _vae_data(1)[0]
    kw = dict(lanes=LANES, seed=0, init_chunks=16, capacity=512)
    out["bbx1_vae_fixedpoint"] = (
        lambda: codecs.compress(fused, v_data, **kw),
        lambda blob: codecs.decompress(fused, blob), v_data)

    # BBX2 block stream, pipelined encoder (bytes are asserted equal
    # to the synchronous path in tests/test_stream.py).
    s_codec = _vae_codec(compiled=False)
    s_data = _vae_data(6)

    def _encode_stream() -> bytes:
        enc = StreamEncoder(s_codec, lanes=LANES, block_symbols=2,
                            seed=0, init_chunks=16, capacity=512,
                            compile=True, pipeline=True)
        return enc.write(s_data) + enc.flush()

    out["bbx2_stream"] = (
        _encode_stream,
        lambda blob: decode_stream(s_codec, blob), s_data)

    # BBX3 corpus: 2 lane-shards over the quantized VAE stream.
    d_data = _vae_data(4)
    out["bbx3_corpus"] = (
        lambda: shard_codec.compress_dataset(
            s_codec, d_data, n_shards=2, block_symbols=2, seed=0,
            init_chunks=16, capacity=512),
        lambda blob: shard_codec.decompress_dataset(s_codec, blob),
        d_data)
    return out


def main() -> None:
    for name, (encode, _decode, _data) in build().items():
        blob = encode()
        path = os.path.join(GOLDEN_DIR, f"{name}.bin")
        with open(path, "wb") as f:
            f.write(blob)
        print(f"{name}: {len(blob)} bytes -> {path}")


if __name__ == "__main__":
    main()
