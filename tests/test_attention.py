"""Flash (blockwise custom-VJP) attention vs exact SDPA oracle."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention


def _rand_qkv(rng, b, sq, sk, hq, hkv, dh, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(0, 1, (b, sq, hq, dh)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (b, sk, hkv, dh)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (b, sk, hkv, dh)), dtype)
    return q, k, v


@pytest.mark.parametrize("causal,window,sq,sk", [
    (True, None, 128, 128),
    (False, None, 96, 160),
    (True, 40, 128, 128),
    (True, None, 100, 100),   # non-multiple of chunk
])
def test_flash_matches_exact_forward(causal, window, sq, sk):
    rng = np.random.default_rng(0)
    q, k, v = _rand_qkv(rng, 2, sq, sk, 4, 2, 16)
    out_flash = attention.sdpa_blockwise(
        q, k, v, causal=causal, window=window, q_chunk=32, kv_chunk=32)
    mask = attention._mask(sq, sk, causal, window)
    out_exact = attention.sdpa(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out_flash), np.asarray(out_exact),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 24),
                                           (False, None)])
def test_flash_matches_exact_grads(causal, window):
    rng = np.random.default_rng(1)
    sq = sk = 96
    q, k, v = _rand_qkv(rng, 1, sq, sk, 4, 2, 8)

    def loss_flash(q, k, v):
        o = attention.sdpa_blockwise(q, k, v, causal=causal, window=window,
                                     q_chunk=32, kv_chunk=32)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    def loss_exact(q, k, v):
        mask = attention._mask(sq, sk, causal, window)
        o = attention.sdpa(q, k, v, mask)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    ge = jax.grad(loss_exact, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, ge):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


def test_flash_traced_window():
    """Per-layer traced windows (hymba) work through jit."""
    rng = np.random.default_rng(2)
    q, k, v = _rand_qkv(rng, 1, 64, 64, 2, 2, 8)

    @jax.jit
    def run(w):
        return attention.sdpa_blockwise(q, k, v, causal=True, window=w,
                                        q_chunk=32, kv_chunk=32)

    o1 = run(jnp.asarray(16.0))
    mask = attention._mask(64, 64, True, 16)
    np.testing.assert_allclose(np.asarray(o1),
                               np.asarray(attention.sdpa(q, k, v, mask)),
                               rtol=2e-5, atol=2e-5)
