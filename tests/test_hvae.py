"""Hierarchical ResNet-VAE + Bit-Swap codec path (the HiLLoC workload).

Covers the PR acceptance criteria: a 2-level HVAE round-trips
losslessly (byte-identically) through both ``codecs.compress`` and the
BBX2 stream path on two distinct image shapes from ONE parameter set
(the fully convolutional "any size" property), plus the 3-level
variant, the ``serve.CodecEngine`` service, the arbitrary-shape data
collation, trainer integration, and bit-parity of the
``kernels/bucketize``-backed posterior decode.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs, stream
from repro.configs import hvae_img
from repro.core import ans
from repro.data import images as img_data
from repro.models import hvae
from repro.serve.engine import CodecEngine


@pytest.fixture(scope="module")
def cfg2():
    return hvae.HVAEConfig(levels=2, ch=8, z_ch=2, n_res=1)


@pytest.fixture(scope="module")
def params2(cfg2):
    return hvae.init(jax.random.PRNGKey(0), cfg2)


def _images(shape, n, lanes, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2, (n, lanes) + shape), jnp.int32)


# ---------------------------------------------------------------------------
# acceptance: lossless round-trips, two shapes, both wire paths
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("shape", [(28, 28), (40, 24)])
def test_container_roundtrip_any_shape(cfg2, params2, shape):
    """One 2-level parameter set codes 28x28 AND 40x24 byte-exactly
    through ``codecs.compress`` (the HiLLoC any-size claim)."""
    n, lanes = 2, 2
    data = _images(shape, n, lanes, seed=shape[0])
    codec = hvae.make_bitswap_codec(params2, cfg2, shape)
    chained = codecs.Chained(codec, n)
    blob, info = codecs.compress(chained, data, lanes=lanes, seed=0,
                                 with_info=True)
    assert info["net_bits"] > 0
    out = codecs.decompress(chained, blob)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))
    # Byte-identical wire on re-encode (deterministic end to end).
    assert codecs.compress(chained, data, lanes=lanes, seed=0) == blob


def test_compiled_codec_byte_identical(cfg2, params2):
    """The HVAE-L2 workload through ``codecs.compile`` (the compiled=
    flag) produces the exact interpreted wire and cross-decodes."""
    shape, n, lanes = (8, 8), 2, 2
    data = _images(shape, n, lanes, seed=3)
    codec = hvae.make_bitswap_codec(params2, cfg2, shape)
    prog = hvae.make_bitswap_codec(params2, cfg2, shape, compiled=True)
    assert isinstance(prog, codecs.CompiledCodec)
    blob_i = codecs.compress(codecs.Chained(codec, n), data, lanes=lanes,
                             seed=0)
    blob_c = codecs.compress(codecs.Chained(prog, n), data, lanes=lanes,
                             seed=0)
    assert blob_i == blob_c
    out = codecs.decompress(codecs.Chained(prog, n), blob_i)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))


@pytest.mark.parametrize("shape", [(12, 8), (8, 10)])
def test_stream_roundtrip_any_shape(cfg2, params2, shape):
    """The same codec family through the BBX2 stream path: ragged final
    block, block-boundary clean-bit carry, lossless."""
    n, lanes = 5, 2
    data = _images(shape, n, lanes, seed=shape[1])
    codec = hvae.make_bitswap_codec(params2, cfg2, shape)
    wire = stream.encode_stream(codec, data, lanes=lanes,
                                block_symbols=2, seed=0, init_chunks=32)
    header, offsets, trailer = stream.format.scan(wire)
    assert trailer is not None and trailer.n_blocks == 3  # 2+2+1
    out = stream.decode_stream(codec, wire)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))


def test_three_level_roundtrip():
    cfg = hvae.HVAEConfig(levels=3, ch=8, z_ch=2)
    params = hvae.init(jax.random.PRNGKey(3), cfg)
    data = _images((8, 8), 1, 3, seed=3)[0]
    codec = hvae.make_bitswap_codec(params, cfg, (8, 8))
    blob = codecs.compress(codec, data, lanes=3, seed=1)
    out = codecs.decompress(codec, blob)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))


def test_odd_shape_rejected(cfg2, params2):
    with pytest.raises(ValueError, match="even"):
        hvae.make_bitswap_codec(params2, cfg2, (7, 8))


# ---------------------------------------------------------------------------
# serve.CodecEngine
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_codec_engine_roundtrip(cfg2, params2):
    eng = CodecEngine(hvae.codec_family(params2, cfg2), seed=0)
    data = _images((8, 6), 3, 2, seed=5)
    blob = eng.compress(data)
    out = eng.decompress(blob, 3, (8, 6))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))
    wire = eng.compress_stream(data, block_symbols=2)
    out2 = eng.decompress_stream(wire, (8, 6))
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(data))
    # Per-shape memoization: the codec object is built once per shape.
    assert eng.codec_for((8, 6)) is eng.codec_for([8, 6])


def test_codec_family_validates_rank(cfg2, params2):
    with pytest.raises(ValueError, match="H, W"):
        hvae.codec_family(params2, cfg2)((8, 6, 1))


# ---------------------------------------------------------------------------
# model + trainer integration
# ---------------------------------------------------------------------------

def test_elbo_finite_and_batched(cfg2, params2):
    x = _images((12, 8), 1, 4, seed=6)[0]
    e = hvae.elbo(params2, cfg2, jax.random.PRNGKey(0), x)
    assert e.shape == (4,)
    assert bool(jnp.all(jnp.isfinite(e)))
    bpd = hvae.elbo_bits_per_dim(params2, cfg2, jax.random.PRNGKey(1), x)
    assert bool(jnp.isfinite(bpd))


def test_trainer_step_updates_params(cfg2):
    from repro.optim import adamw
    from repro.train import trainer

    opt = adamw.AdamW(learning_rate=adamw.cosine_lr(1e-3, 1, 10))
    state = trainer.init_state(jax.random.PRNGKey(1), cfg2, opt,
                               init_params_fn=hvae.init)

    def loss_fn(params, batch):
        l = hvae.loss(params, cfg2, batch["key"], batch["images"])
        return l, {"nats": l}

    step = trainer.make_train_step(cfg2, opt, loss_fn=loss_fn)
    batch = {"images": _images((8, 8), 1, 4, seed=7)[0],
             "key": jax.random.PRNGKey(2)}
    new_state, metrics = step(state, batch)
    assert int(new_state.step) == 1
    assert bool(jnp.isfinite(metrics["loss"]))
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()),
        state.params, new_state.params)
    assert max(jax.tree_util.tree_leaves(diffs)) > 0


# ---------------------------------------------------------------------------
# kernels/bucketize reuse: kernel-backed posterior decode is bit-identical
# ---------------------------------------------------------------------------

def test_kernel_discretized_gaussian_parity():
    lanes, bits, prec = 8, 8, 16
    rng = np.random.default_rng(8)
    mu = jnp.asarray(rng.normal(0, 1, lanes), jnp.float32)
    sigma = jnp.asarray(rng.uniform(0.3, 1.5, lanes), jnp.float32)
    stack = codecs.fresh_stack(lanes, 128, seed=0, init_chunks=16)
    ref = codecs.DiscretizedGaussian(mu, sigma, bits, prec)
    ker = hvae.KernelDiscretizedGaussian(mu, sigma, bits, prec)
    s_ref, idx_ref = ref.pop(stack)
    s_ker, idx_ker = ker.pop(stack)
    np.testing.assert_array_equal(np.asarray(idx_ref),
                                  np.asarray(idx_ker))
    np.testing.assert_array_equal(np.asarray(s_ref.head),
                                  np.asarray(s_ker.head))
    back = ker.push(s_ker, idx_ker)
    np.testing.assert_array_equal(np.asarray(back.head),
                                  np.asarray(stack.head))


def test_kernel_backed_codec_matches_wire(cfg2, params2):
    """A whole Bit-Swap encode with kernel-backed posterior decodes is
    byte-identical to the pure-JAX path (same wire, interoperable)."""
    shape = (6, 6)
    data = _images(shape, 1, 2, seed=9)[0]
    plain = hvae.make_bitswap_codec(params2, cfg2, shape)
    kernel = hvae.make_bitswap_codec(params2, cfg2, shape,
                                     use_bucketize_kernel=True)
    b1 = codecs.compress(plain, data, lanes=2, seed=4)
    b2 = codecs.compress(kernel, data, lanes=2, seed=4)
    assert b1 == b2
    out = codecs.decompress(plain, b2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))


# ---------------------------------------------------------------------------
# data: arbitrary-shape collation
# ---------------------------------------------------------------------------

def test_collate_shapes_and_content():
    rng = np.random.default_rng(10)
    src = rng.integers(0, 256, (5, 28, 28)).astype(np.uint8)
    for hw in [(28, 28), (40, 24), (16, 16), (12, 36)]:
        out = img_data.collate(src, hw, np.random.default_rng(0))
        assert out.shape == (5,) + hw
    # Pure padding preserves total mass (crop can only lose pixels).
    big = img_data.collate(src, (40, 40), np.random.default_rng(1))
    assert big.sum() == src.sum()
    # Flat [n, 784] input is accepted too.
    flat = img_data.collate(src.reshape(5, -1), (14, 14),
                            np.random.default_rng(2))
    assert flat.shape == (5, 14, 14)


def test_pad_to_even():
    imgs = np.ones((2, 7, 9), np.uint8)
    out = img_data.pad_to_even(imgs)
    assert out.shape == (2, 8, 10)
    assert out.sum() == imgs.sum()


def test_image_batch_fn_deterministic():
    imgs = img_data.load("train", 64, seed=0, hw=(28, 28))
    fn = img_data.image_batch_fn(imgs, batch=8, hw=(20, 24))
    a = fn(3, 5, 0, 1)["images"]
    b = fn(3, 5, 0, 1)["images"]
    np.testing.assert_array_equal(a, b)
    assert a.shape == (8, 20, 24)
    c = fn(3, 6, 0, 1)["images"]
    assert not np.array_equal(a, c)


def test_shape_schedule_cycles():
    shapes = [(28, 28), (40, 24), (16, 16)]
    got = [img_data.shape_schedule(shapes, s) for s in range(6)]
    assert got == [(28, 28), (40, 24), (16, 16)] * 2
