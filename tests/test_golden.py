"""Golden-wire regression suite (ISSUE-8 satellite).

Two directions per committed fixture in ``tests/golden/``:

  * **re-encode**: rebuilding the fixture from its pinned seeds must
    reproduce the committed blob hex-for-hex - any codec, kernel,
    compiler, or stream-layer change that moves a wire byte fails here
    before it can silently corrupt archived data;
  * **decode**: the committed bytes (read from disk, never re-derived)
    must decode losslessly back to the fixture's data.

Runs from a clean checkout with only the committed fixtures; regenerate
intentionally with ``python tests/golden/make_golden.py``.
"""

import os

import jax.numpy as jnp
import pytest

from tests.golden.make_golden import GOLDEN_DIR, build

_FIXTURES = sorted(build().keys())


def _read(name: str) -> bytes:
    path = os.path.join(GOLDEN_DIR, f"{name}.bin")
    if not os.path.exists(path):
        pytest.fail(f"golden fixture {name}.bin missing - run "
                    "tests/golden/make_golden.py and commit the blobs")
    with open(path, "rb") as f:
        return f.read()


@pytest.mark.parametrize("name", _FIXTURES)
def test_reencode_matches_committed_bytes(name):
    encode, _decode, _data = build()[name]
    fresh = encode()
    committed = _read(name)
    assert fresh.hex() == committed.hex(), (
        f"{name}: wire bytes drifted from the committed golden blob "
        f"({len(fresh)} vs {len(committed)} bytes) - if the format "
        "change is intentional, regenerate tests/golden/ and say so "
        "in the commit")


@pytest.mark.parametrize("name", _FIXTURES)
def test_committed_bytes_decode_losslessly(name):
    _encode, decode, data = build()[name]
    out = decode(_read(name))
    assert bool(jnp.array_equal(jnp.asarray(out), jnp.asarray(data)))
