"""The contract verifier catches what we break on purpose - and stays
silent on everything we ship.

Gallery layout:

  * deliberately-broken codec fixtures, one per rule: the analyzer must
    flag every one (the PR-4 bug classes - scan-fused ``Chained``,
    shared-divisor division, inline ndtri - are reconstructed here
    exactly as reverting those fixes would);
  * the shipped families (VAE both likelihoods, HVAE BitSwap, LM
    TokenStream, stream block codecs, compiled forms) must report zero
    findings;
  * the wiring: ``CodecEngine`` registration, ``codecs.compile``
    lowering, ``StreamEncoder(verify=True)``, and the BBX1 container's
    named corruption errors;
  * the source lint's AST rules and its escapes.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs
from repro.analysis import (ContractViolation, bits_bound, check_codec,
                            lint_paths, lint_source, verify_codec, RULES)
from repro.core import ans
from repro.core.distributions import Categorical


def rule_set(report):
    return {f.rule for f in report.findings}


# ---------------------------------------------------------------------------
# broken fixtures: every rule must fire
# ---------------------------------------------------------------------------

LOGITS = jnp.asarray(np.linspace(-1.0, 1.0, 16, dtype=np.float32)
                     * np.ones((2, 1), np.float32))


class ZeroFreqTable(Categorical):
    """A symbol whose mass was collapsed to zero (slot 1 == slot 2)."""

    def _table(self):
        t = super()._table()
        return t.at[..., 1].set(t[..., 2])


class ShortTable(Categorical):
    """Table that sums to 2^precision - 4 instead of exactly 2^p."""

    def _table(self):
        t = super()._table()
        return t.at[..., -1].add(-4)


class AsymmetricUniform(codecs.Uniform):
    """push encodes a *shifted* symbol: pop(push(x)) != x."""

    def push(self, stack, x):
        return super().push(stack, (x + 1) % (1 << self.bits))


def test_flags_zero_freq_symbol():
    report = verify_codec(ZeroFreqTable(LOGITS, 16), lanes=2)
    assert "freq-zero" in rule_set(report)
    assert not report.ok


def test_flags_wrong_total():
    report = verify_codec(ShortTable(LOGITS, 16), lanes=2)
    assert "freq-sum" in rule_set(report)


def test_flags_non_monotone_cdf():
    wobble = codecs.PointwiseCDF(
        lambda i: jnp.sin(i.astype(jnp.float32)) * 0.4 + 0.5, bits=4)
    report = verify_codec(wobble, lanes=2)
    assert "starts-monotone" in rule_set(report)


def test_flags_asymmetric_push_pop():
    report = verify_codec(AsymmetricUniform(4), lanes=2)
    assert {"push-pop-mirror", "inverse-probe"} & rule_set(report)


def test_flags_scan_fused_chained():
    """PR-4 bug class 1: lax.scan fusing model floats into the chain
    body. Reverting the Chained(scan=False) fix looks exactly like
    this."""
    inner = codecs.Shaped(codecs.Repeat(
        lambda d: codecs.DiscretizedGaussian(
            jnp.zeros((2,)), jnp.ones((2,)), bits=4, precision=12),
        3), (3,))
    report = verify_codec(codecs.Chained(inner, 2, scan=True), lanes=2)
    assert "scan-chain" in rule_set(report)
    # the same chain without scan is fine
    assert verify_codec(codecs.Chained(inner, 2, scan=False), lanes=2).ok


def test_scan_chained_over_uniform_is_clean():
    """scan=True over a float-free codec is allowed - the rule is about
    model floats in the fused body, not about scan itself."""
    inner = codecs.Shaped(codecs.Repeat(
        lambda d: codecs.Uniform(6), 3), (3,))
    report = verify_codec(codecs.Chained(inner, 2, scan=True), lanes=2)
    assert "scan-chain" not in rule_set(report)


def test_flags_shared_divisor_division():
    """PR-4 bug class 2: (z - mu) / sigma instead of the canonical
    reciprocal-multiply form."""
    sigma = jnp.full((2,), 2.0, jnp.float32)
    shared = codecs.PointwiseCDF(
        lambda i: jax.scipy.stats.norm.cdf(
            (i.astype(jnp.float32) - 8.0) / sigma), bits=4)
    report = verify_codec(shared, lanes=2)
    assert "div-shared" in rule_set(report)


def test_flags_inline_ndtri():
    """PR-4 bug class 3: recomputing bucket geometry inline instead of
    reading the cached concrete tables. jax's ndtri is a rational
    approximation full of non-canonical divisions (div-shared); the
    erfinv spelling traces to the erf_inv primitive (ndtri-coder).
    Either way the verifier refuses it inside a coder program."""
    from jax.scipy.special import erfinv, ndtri
    bad = codecs.PointwiseCDF(
        lambda i: jax.scipy.special.ndtr(
            i.astype(jnp.float32) * 0.1 - ndtri(jnp.full((2,), 0.9))),
        bits=4)
    assert {"div-shared", "ndtri-coder"} & rule_set(
        verify_codec(bad, lanes=2))

    bad2 = codecs.PointwiseCDF(
        lambda i: jax.scipy.special.ndtr(
            i.astype(jnp.float32) * 0.1
            - erfinv(jnp.full((2,), 0.8)) * 1.41421356),
        bits=4)
    assert "ndtri-coder" in rule_set(verify_codec(bad2, lanes=2))


class LeakyCDF(codecs.PointwiseCDF):
    """_starts without the jnp.floor barrier: the float->int truncation
    point becomes fusion-dependent."""

    def _starts(self):
        k = 1 << self.bits
        scale = float((1 << self.precision) - k)
        cdf_fn = self.cdf_fn

        def f(i):
            c = jnp.clip(cdf_fn(i), 0.0, 1.0)
            c = jnp.where(i <= 0, 0.0, c)
            c = jnp.where(i >= k, 1.0, c)
            return (c * scale).astype(jnp.uint32) + i.astype(jnp.uint32)

        return f


def test_flags_float_to_int_without_barrier():
    leaky = LeakyCDF(
        lambda i: jax.nn.sigmoid((i.astype(jnp.float32) - 8.0) * 0.5),
        bits=4)
    assert "float-leak" in rule_set(verify_codec(leaky, lanes=2))


def test_capacity_bound_warns():
    big = codecs.Shaped(
        codecs.Repeat(lambda d: codecs.Uniform(8), 2048), (2048,))
    report = verify_codec(big, lanes=2, capacity=64)
    assert "capacity-bound" in {f.rule for f in report.warnings}
    assert report.ok            # a warning, not an error
    report2 = verify_codec(big, lanes=2, capacity=4096)
    assert not report2.warnings


def test_check_codec_raises_with_report():
    with pytest.raises(ContractViolation) as exc:
        check_codec(ZeroFreqTable(LOGITS, 16), lanes=2)
    assert "freq-zero" in str(exc.value)
    assert exc.value.report.errors


# ---------------------------------------------------------------------------
# bits bound
# ---------------------------------------------------------------------------

def test_bits_bound_composes():
    assert bits_bound(codecs.Uniform(8), lanes=2) == 8.0
    rep = codecs.Shaped(codecs.Repeat(lambda d: codecs.Uniform(8), 5),
                        (5,))
    assert bits_bound(rep, lanes=2) == 40.0
    assert bits_bound(codecs.Chained(rep, 3), lanes=2) == 120.0


# ---------------------------------------------------------------------------
# zero false positives on everything we ship
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def vae_setup():
    from repro.models import vae as vae_lib
    cfg = vae_lib.VAEConfig(input_dim=36, hidden=24, latent=6)
    return vae_lib, cfg, vae_lib.init(jax.random.PRNGKey(0), cfg)


def test_shipped_vae_bernoulli_clean(vae_setup):
    vae_lib, cfg, params = vae_setup
    report = verify_codec(vae_lib.make_bb_codec(params, cfg), lanes=2)
    assert report.ok and not report.findings, str(report)
    assert report.bits_bound is not None


def test_shipped_vae_compiled_clean(vae_setup):
    vae_lib, cfg, params = vae_setup
    codec = vae_lib.make_bb_codec(params, cfg, compiled=True)
    report = verify_codec(codec, lanes=2)
    assert report.ok and not report.findings, str(report)


def test_shipped_vae_beta_binomial_clean(vae_setup):
    vae_lib, cfg, _ = vae_setup
    cfg_bb = dataclasses.replace(cfg, likelihood="beta_binomial")
    params = vae_lib.init(jax.random.PRNGKey(1), cfg_bb)
    report = verify_codec(vae_lib.make_bb_codec(params, cfg_bb), lanes=2)
    assert report.ok and not report.findings, str(report)


@pytest.mark.slow
def test_shipped_hvae_clean():
    from repro.models import hvae
    cfg = hvae.HVAEConfig(levels=2, ch=8, z_ch=2, n_res=1)
    params = hvae.init(jax.random.PRNGKey(0), cfg)
    codec = hvae.make_bitswap_codec(params, cfg, (4, 4))
    report = verify_codec(codec, lanes=2)
    assert report.ok and not report.findings, str(report)


def test_shipped_token_stream_clean():
    from repro.configs import base as cfg_base
    from repro.core import lm_codec
    from repro.models import transformer
    cfg = dataclasses.replace(
        cfg_base.reduced(cfg_base.get("qwen2-0.5b")), vocab=120)
    params = transformer.init(jax.random.PRNGKey(17), cfg)
    report = verify_codec(lm_codec.TokenStream(params, cfg, 4), lanes=2)
    assert report.ok and not report.findings, str(report)
    # opaque driver: no static bound, and no noisy notes either
    # (TokenStream declares itself __analysis_opaque__)
    assert report.bits_bound is None
    assert not report.notes


def test_shipped_stream_codecs_clean():
    from repro.stream import coder as stream_coder
    inner = codecs.Shaped(
        codecs.Repeat(lambda d: codecs.Uniform(8), 4), (4,))
    assert verify_codec(stream_coder.BlockChain(inner, k=3), lanes=2).ok
    table = ans.probs_to_starts(jnp.full((2, 16), 1.0 / 16), 16)
    block = stream_coder.KernelTableBlock(table, k=3, precision=16)
    report = verify_codec(block, lanes=2)
    assert report.ok and not report.findings, str(report)


# ---------------------------------------------------------------------------
# wiring: engine registration, compile lowering, stream opt-in
# ---------------------------------------------------------------------------

def _uniform_family(shape):
    n = int(np.prod(shape))
    return codecs.Shaped(codecs.Repeat(lambda d: codecs.Uniform(6), n),
                         shape)


def _broken_family(shape):
    return ZeroFreqTable(jnp.zeros((2, 8), jnp.float32), 16)


def test_engine_verifies_on_registration():
    from repro.serve import CodecEngine
    eng = CodecEngine(_broken_family, seed=0)
    with pytest.raises(ContractViolation, match="freq-zero"):
        eng.codec_for((4,))
    # opt-out serves the (broken) codec without analysis
    eng2 = CodecEngine(_broken_family, seed=0, verify=False)
    eng2.codec_for((4,))


def test_engine_verifies_once_per_shape():
    from repro.serve import CodecEngine
    calls = []

    def family(shape):
        calls.append(shape)
        return _uniform_family(shape)

    eng = CodecEngine(family, seed=0)
    eng.codec_for((4,))
    eng.codec_for((4,))
    assert calls == [(4,)]      # memo intact; verification ran once


def test_sharded_engine_passes_verify_through():
    from repro.serve import ShardedCodecEngine
    eng = ShardedCodecEngine(_broken_family, n_shards=1, seed=0)
    with pytest.raises(ContractViolation, match="freq-zero"):
        eng._inner.codec_for((4,))


def test_compile_validates_lowered_tables():
    # all -inf logits collapse the softmax to zero mass: the lowered
    # fixed-point table no longer spans 2^precision
    rep = codecs.Repeat(
        lambda d: Categorical(jnp.full((2, 8), -jnp.inf, jnp.float32),
                              16), 4)
    with pytest.raises(ValueError, match=r"freq-sum.*Categorical"):
        codecs.compile(rep)


def test_compile_rejects_non_positive_sigma():
    rep = codecs.Repeat(
        lambda d: codecs.DiscretizedGaussian(
            jnp.zeros((2,)), jnp.zeros((2,)), bits=4, precision=12), 3)
    with pytest.raises(ValueError, match="starts-monotone"):
        codecs.compile(rep)


def test_compile_verify_flag_runs_full_analysis():
    with pytest.raises(ContractViolation):
        codecs.compile(AsymmetricUniform(4), verify=True)
    # clean codec passes with verify on
    codecs.compile(codecs.Repeat(lambda d: codecs.Uniform(6), 4),
                   verify=True)


def test_stream_encoder_verify_opt_in():
    from repro.stream import StreamEncoder
    bad = codecs.Shaped(codecs.Repeat(
        lambda d: ZeroFreqTable(jnp.zeros((2, 8), jnp.float32), 16), 2),
        (2,))
    with pytest.raises(ContractViolation):
        StreamEncoder(bad, lanes=2, block_symbols=4, verify=True)
    StreamEncoder(bad, lanes=2, block_symbols=4)   # default: no check


# ---------------------------------------------------------------------------
# container header validation (satellite: named corruption errors)
# ---------------------------------------------------------------------------

def _blob():
    codec = codecs.Shaped(
        codecs.Repeat(lambda d: codecs.Uniform(8), 6), (6,))
    data = jnp.arange(2 * 6, dtype=jnp.int32).reshape(2, 6) % 256
    return codec, data, codecs.compress(codec, data, lanes=2, seed=None,
                                        init_chunks=0)


def test_container_roundtrip_still_exact():
    codec, data, blob = _blob()
    assert (codecs.decompress(codec, blob) == data).all()


@pytest.mark.parametrize("mutate, msg", [
    (lambda b: b[:4], "no header"),
    (lambda b: b"XXXX" + b[4:], "bad magic"),
    (lambda b: b[:4] + bytes([99]) + b[5:], "version"),
    (lambda b: b[:5] + bytes([61]) + b[6:], "precision"),
    (lambda b: b[:8] + (2 ** 31).to_bytes(4, "little") + b[12:],
     "lane count"),
    (lambda b: b[:14], "lengths block is short"),
    (lambda b: b[:-2], "truncated or trailing garbage"),
    (lambda b: b + b"\x00\x00", "truncated or trailing garbage"),
])
def test_container_rejects_corruption_by_name(mutate, msg):
    codec, _, blob = _blob()
    with pytest.raises(codecs.ContainerError, match=msg):
        codecs.decompress(codec, mutate(blob))


def test_container_error_is_a_value_error():
    assert issubclass(codecs.ContainerError, ValueError)


def test_container_rejects_zero_lane_length():
    codec, _, blob = _blob()
    # lengths block starts at offset 12; zero out lane 0's length
    bad = blob[:12] + b"\x00\x00\x00\x00" + blob[16:]
    with pytest.raises(codecs.ContainerError, match="lane length"):
        codecs.decompress(codec, bad)


# ---------------------------------------------------------------------------
# hot-path invariants raise (satellite: no bare asserts)
# ---------------------------------------------------------------------------

def test_precision_guard_survives_optimization():
    stack = ans.make_stack(2, 8)
    start = jnp.zeros((2,), jnp.uint32)
    freq = jnp.full((2,), 4, jnp.uint32)
    for bad in (0, 17, -1):
        with pytest.raises(ValueError, match="precision"):
            ans.push(stack, start, freq, precision=bad)
        with pytest.raises(ValueError, match="precision"):
            ans.peek(stack, precision=bad)
        with pytest.raises(ValueError, match="precision"):
            ans.pop_update(stack, start, freq, precision=bad)


def test_kernel_lane_guard_raises():
    from repro.kernels.ans import kernel as ans_kernel
    head = jnp.full((3,), 1 << 16, jnp.uint32)   # not a lane-tile multiple
    with pytest.raises(ValueError, match="lane_tile"):
        ans_kernel.pop_slots(head, 16)


# ---------------------------------------------------------------------------
# source lint
# ---------------------------------------------------------------------------

def lint_rules(src, name="src/repro/core/x.py", coder_scope=True):
    return {f.rule for f in lint_source(src, name,
                                        coder_scope=coder_scope)}


def test_lint_bare_assert():
    assert lint_rules("assert precision <= 16") == {"bare-assert"}
    assert lint_rules("if precision > 16:\n    raise ValueError('x')") \
        == set()


def test_lint_div_shared():
    assert lint_rules("y = (z - mu) / sigma") == {"div-shared"}
    assert lint_rules("y = (z - mu) * (1.0 / sigma)") == set()
    assert lint_rules("y = x / 2.0") == set()        # constant divisor
    # build-time divisions under ensure_compile_time_eval are exempt
    src = ("import jax\n"
           "with jax.ensure_compile_time_eval():\n"
           "    t = a / b\n")
    assert lint_rules(src) == set()


def test_lint_ndtri_outside_discretize():
    src = "from jax.scipy.special import ndtri\ny = ndtri(q)"
    assert lint_rules(src) == {"ndtri-coder"}
    assert lint_rules(src, "src/repro/core/discretize.py") == set()


def test_lint_cast_barrier():
    assert lint_rules(
        "f = jax.nn.sigmoid(x).astype(jnp.uint32)") == {"cast-barrier"}
    assert lint_rules(
        "f = jnp.floor(jax.nn.sigmoid(x) * s).astype(jnp.uint32)") == set()


def test_lint_jit_in_table_module():
    src = "import jax\ntable = jax.jit(build)(x)"
    assert lint_rules(src, "src/repro/core/distributions.py") \
        == {"jit-in-table-module"}
    assert lint_rules(src, "src/repro/core/ans.py") == set()


def test_lint_allow_comment_escape():
    src = "y = a / b  # analysis: allow(div-shared)"
    assert lint_rules(src) == set()


def test_lint_scopes_to_coder_dirs():
    # Files outside the coder dirs ARE walked, but only the
    # everywhere-rules apply there: model/serving code evaluates floats
    # and asserts by design, so none of the coder-only rules fire.
    found, n = lint_paths(["src/repro/models"])
    assert n > 0 and found == []


def test_lint_pallas_call_site_rule():
    src = "import jax.experimental.pallas as pl\nout = pl.pallas_call(k)(x)"
    # Outside repro/kernels the rule fires even in non-coder scope...
    assert "pallas-call-site" in lint_rules(src, "src/repro/models/m.py")
    assert lint_rules(src, "src/repro/models/m.py",
                      coder_scope=False) == {"pallas-call-site"}
    # ...inside kernels/ it is the one place pallas_call belongs.
    assert "pallas-call-site" not in lint_rules(
        src, "src/repro/kernels/ans/kernel.py")
    # The escape comment suppresses it like every other rule.
    esc = src.replace("(x)", "(x)  # analysis: allow(pallas-call-site)")
    assert "pallas-call-site" not in lint_rules(
        esc, "src/repro/models/m.py")


def test_lint_shipped_tree_clean():
    findings, n_files = lint_paths(["src/"])
    assert n_files > 10
    assert findings == [], "\n".join(str(f) for f in findings)


def test_rules_catalogue_is_documented():
    for rule, desc in RULES.items():
        assert desc, rule
    # every rule the verifier/lint can emit is in the catalogue
    for emitted in ("freq-sum", "freq-zero", "starts-monotone",
                    "push-pop-mirror", "inverse-probe", "float-leak",
                    "div-shared", "ndtri-coder", "edge-cache",
                    "scan-chain", "capacity-bound", "opaque-probe",
                    "child-build", "bare-assert", "cast-barrier",
                    "jit-in-table-module"):
        assert emitted in RULES
