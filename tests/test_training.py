"""Training substrate: optimizers, trainer, checkpointing, fault
tolerance (restart determinism), gradient compression."""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfg_base
from repro.data import pipeline, tokens as tok_data
from repro.optim import adafactor, adamw, grad_compress
from repro.train import checkpoint, fault, trainer


def _tiny_cfg():
    return dataclasses.replace(
        cfg_base.reduced(cfg_base.get("smollm-360m")), vocab=64)


def _quadratic_params():
    return {"w": jnp.asarray([1.0, -2.0, 3.0]), "b": jnp.asarray(0.5)}


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_optimizers_reduce_quadratic(opt_name):
    if opt_name == "adamw":
        opt = adamw.AdamW(learning_rate=adamw.constant_lr(0.1))
    else:
        opt = adafactor.Adafactor(
            learning_rate=adamw.constant_lr(0.3))
    params = {"w": jnp.asarray([[1.0, -2.0], [3.0, 0.5]]),
              "b": jnp.asarray([0.5, -1.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss(params)) < 0.1 * l0


def test_train_step_reduces_lm_loss():
    cfg = _tiny_cfg()
    opt = trainer.make_optimizer(cfg, lr=3e-3, total_steps=40)
    state = trainer.init_state(jax.random.PRNGKey(0), cfg, opt)
    toks, _ = tok_data.markov_corpus(4000, vocab=cfg.vocab, seed=0)
    batch_fn = pipeline.lm_batch_fn(toks, batch=8, seq=32)
    step = jax.jit(trainer.make_train_step(cfg, opt))
    losses = []
    for i in range(30):
        batch = jax.tree_util.tree_map(jnp.asarray, batch_fn(0, i, 0, 1))
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.9, losses


def test_grad_accumulation_matches_full_batch():
    """Mean of microbatch grads == full-batch grads (up to bf16 reduction
    order). Compared at the gradient level: Adam's first-step
    sign-normalization would amplify sub-ulp sign flips into O(lr) param
    diffs, which is not what this test is about."""
    from repro.models import transformer
    cfg = _tiny_cfg()
    params = transformer.init(jax.random.PRNGKey(1), cfg)
    toks, _ = tok_data.markov_corpus(2000, vocab=cfg.vocab, seed=1)
    batch = jax.tree_util.tree_map(
        jnp.asarray, pipeline.lm_batch_fn(toks, 8, 32)(0, 0, 0, 1))

    def grads_of(b):
        return jax.grad(
            lambda p: transformer.loss_fn(p, cfg, b)[0])(params)

    g_full = grads_of(batch)
    micro = jax.tree_util.tree_map(
        lambda x: x.reshape((4, 2) + x.shape[1:]), batch)
    g_acc = None
    for i in range(4):
        g_i = grads_of(jax.tree_util.tree_map(lambda x: x[i], micro))
        g_acc = g_i if g_acc is None else jax.tree_util.tree_map(
            jnp.add, g_acc, g_i)
    g_acc = jax.tree_util.tree_map(lambda g: g / 4, g_acc)
    for a, b in zip(jax.tree_util.tree_leaves(g_full),
                    jax.tree_util.tree_leaves(g_acc)):
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        scale = max(np.abs(a).max(), 1e-6)
        np.testing.assert_allclose(a, b, atol=3e-2 * scale, rtol=0.1)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}
    checkpoint.save(7, tree, str(tmp_path))
    assert checkpoint.latest_step(str(tmp_path)) == 7
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    out = checkpoint.restore(like, str(tmp_path))
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_pruning(tmp_path):
    tree = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4, 5):
        checkpoint.save(s, tree, str(tmp_path), keep=2)
    assert checkpoint.all_steps(str(tmp_path)) == [4, 5]


def test_restart_determinism(tmp_path):
    """Training with injected node failures must produce bitwise-identical
    results to an uninterrupted run (the core fault-tolerance claim)."""
    cfg = _tiny_cfg()
    opt = adamw.AdamW(learning_rate=adamw.constant_lr(1e-3))
    toks, _ = tok_data.markov_corpus(2000, vocab=cfg.vocab, seed=2)
    raw_batch_fn = pipeline.lm_batch_fn(toks, 4, 16)
    step = jax.jit(trainer.make_train_step(cfg, opt))

    def init_fn():
        return trainer.init_state(jax.random.PRNGKey(3), cfg, opt)

    def batch_fn(s):
        return jax.tree_util.tree_map(jnp.asarray, raw_batch_fn(0, s, 0, 1))

    clean_dir, faulty_dir = str(tmp_path / "clean"), str(tmp_path / "faulty")
    clean, r0 = fault.run_training(
        init_fn=init_fn, step_fn=step, batch_fn=batch_fn, n_steps=12,
        ckpt_dir=clean_dir, save_every=4)
    assert r0 == 0

    fail_at = {3, 9}

    def injector(s):
        if s in fail_at:
            fail_at.discard(s)
            raise fault.SimulatedNodeFailure(f"node lost at {s}")

    faulty, r1 = fault.run_training(
        init_fn=init_fn, step_fn=step, batch_fn=batch_fn, n_steps=12,
        ckpt_dir=faulty_dir, save_every=4, failure_injector=injector)
    assert r1 == 2
    for a, b in zip(jax.tree_util.tree_leaves(clean.params),
                    jax.tree_util.tree_leaves(faulty.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_watchdog_flags_straggler():
    wd = fault.StepWatchdog(z_threshold=3.0, warmup=3)
    for s in range(10):
        wd.observe(s, 0.1 + 0.001 * (s % 2))
    wd.observe(10, 5.0)
    assert 10 in wd.report.stragglers


def test_grad_compression_error_feedback():
    """Compressed-gradient training stays close to exact; wire size well
    under 8 bits/param."""
    rng = np.random.default_rng(5)
    # Heavy-tailed grads (the realistic case: typical |g| << max |g|, so
    # int8 symbols concentrate near zero and entropy-code well).
    w = rng.normal(0, 1e-3, (256, 256))
    outliers = rng.random((256, 256)) < 0.01
    w = np.where(outliers, w * 25, w).astype(np.float32)
    grads = {"w": jnp.asarray(w),
             "b": jnp.asarray(rng.normal(0, 1e-3, (64,)), jnp.float32)}
    cstate = grad_compress.init_state(grads)
    out, cstate = grad_compress.compress_grads(grads, cstate)
    # Error feedback: residual equals g - deq exactly.
    err = np.asarray(cstate.error["w"])
    diff = np.asarray(grads["w"]) - np.asarray(out["w"])
    np.testing.assert_allclose(err, diff, atol=1e-7)
    # Relative error bounded by the int8 step.
    rel = np.abs(diff).max() / np.abs(np.asarray(grads["w"])).max()
    assert rel < 1.2 / 127
    bits_total, bits_pp = grad_compress.measure_wire_bits(grads, cstate)
    assert bits_pp < 8.5, bits_pp


def test_elastic_restore_resharding(tmp_path):
    """A checkpoint written from one topology restores onto another
    (here: host -> explicit single-device sharding)."""
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    checkpoint.save(1, tree, str(tmp_path))
    from repro.launch import mesh as mesh_lib
    mesh = mesh_lib.make_mesh_compat((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    out = checkpoint.restore(jax.tree_util.tree_map(jnp.zeros_like, tree),
                             str(tmp_path), shardings=shardings)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    assert out["w"].sharding == shardings["w"]


def test_adafactor_chunked_matches_unchunked():
    """The two-pass chunked update (big stacked leaves) is bit-for-bit the
    same math as the direct path."""
    rng = np.random.default_rng(9)
    p_small = {"w": jnp.asarray(rng.normal(0, 0.1, (4, 32, 16)),
                                jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(0, 0.01, (4, 32, 16)), jnp.float32)}
    base = adafactor.Adafactor(learning_rate=adamw.constant_lr(0.01))
    # Force the chunked path by monkeypatching the threshold.
    old = adafactor.Adafactor.CHUNK_THRESHOLD
    try:
        s1 = base.init(p_small)
        p1, _ = base.update(g, s1, p_small)
        adafactor.Adafactor.CHUNK_THRESHOLD = 1
        s2 = base.init(p_small)
        p2, _ = base.update(g, s2, p_small)
    finally:
        adafactor.Adafactor.CHUNK_THRESHOLD = old
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=1e-6, atol=1e-7)
