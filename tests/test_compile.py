"""The codec compiler (``codecs.compile``): bit-exact parity of the
compiled (fused kernel) execution vs the interpreted combinators, for
every leaf family and combinator, including ragged shapes, BitSwap with
three layers, container/stream byte-parity, fallback lowering, buffer
donation, and the ``CodecEngine`` LRU + compiled-program cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs, stream
from repro.codecs.compile import _GridRepeat, _TableRepeat
from repro.core import ans, discretize
from repro.models import vae as vae_lib
from repro.serve.engine import CodecEngine


@pytest.fixture(scope="module")
def small_cfg():
    return vae_lib.VAEConfig(input_dim=36, hidden=24, latent=6,
                             likelihood="bernoulli", lat_bits=10)


@pytest.fixture(scope="module")
def small_params(small_cfg):
    return vae_lib.init(jax.random.PRNGKey(0), small_cfg)


def _fresh(lanes, cap=512, seed=0, chunks=32):
    return codecs.fresh_stack(lanes, cap, seed=seed, init_chunks=chunks)


def _assert_stacks_equal(a, b):
    for f in ("head", "ptr", "buf", "underflows", "overflows"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f)


def _assert_parity(codec, prog, stack, x):
    """push and pop must be bit-identical between the two codecs."""
    si = codec.push(stack, x)
    sc = prog.push(stack, x)
    _assert_stacks_equal(si, sc)
    s2i, xi = codec.pop(si)
    s2c, xc = prog.pop(sc)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        xi, xc)
    _assert_stacks_equal(s2i, s2c)
    return xi


# ---------------------------------------------------------------------------
# leaf families inside Repeat (ragged lanes: not a multiple of the tile)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lanes,n", [(5, 9), (8, 1), (130, 3)])
def test_uniform_repeat_parity(lanes, n):
    rng = np.random.default_rng(lanes + n)
    rep = codecs.Repeat(lambda d: codecs.Uniform(7), n)
    prog = codecs.compile(rep, donate=False)
    assert isinstance(prog.lowered, _GridRepeat)
    x = jnp.asarray(rng.integers(0, 128, (lanes, n)), jnp.int32)
    _assert_parity(rep, prog, _fresh(lanes), x)


@pytest.mark.parametrize("lanes,n,bits", [(5, 9, 10), (64, 17, 8)])
def test_gaussian_repeat_parity(lanes, n, bits):
    rng = np.random.default_rng(lanes * 7 + n)
    mu = jnp.asarray(rng.normal(0, 1, (lanes, n)), jnp.float32)
    sg = jnp.asarray(rng.uniform(0.05, 2.0, (lanes, n)), jnp.float32)
    rep = codecs.Repeat(
        lambda d: codecs.DiscretizedGaussian(mu[:, d], sg[:, d], bits), n)
    prog = codecs.compile(rep, donate=False)
    assert isinstance(prog.lowered, _GridRepeat)
    stack = _fresh(lanes)
    si, yi = rep.pop(stack)
    sc, yc = prog.pop(stack)
    np.testing.assert_array_equal(np.asarray(yi), np.asarray(yc))
    _assert_stacks_equal(si, sc)
    _assert_stacks_equal(rep.push(si, yi), prog.push(sc, yc))


def test_logistic_repeat_parity():
    lanes, n, bits = 5, 11, 8
    rng = np.random.default_rng(3)
    mu = jnp.asarray(rng.normal(0, 1, (lanes, n)), jnp.float32)
    sc_ = jnp.asarray(rng.uniform(0.2, 1.5, (lanes, n)), jnp.float32)
    rep = codecs.Repeat(
        lambda d: codecs.DiscretizedLogistic(mu[:, d], sc_[:, d], bits), n)
    prog = codecs.compile(rep, donate=False)
    assert isinstance(prog.lowered, _GridRepeat)
    stack = _fresh(lanes)
    si, yi = rep.pop(stack)
    sc, yc = prog.pop(stack)
    np.testing.assert_array_equal(np.asarray(yi), np.asarray(yc))
    _assert_stacks_equal(rep.push(si, yi), prog.push(sc, yc))


def test_bernoulli_and_categorical_repeat_parity():
    lanes, n = 6, 13
    rng = np.random.default_rng(4)
    blogits = jnp.asarray(rng.normal(0, 2, (lanes, n)), jnp.float32)
    clogits = jnp.asarray(rng.normal(0, 1, (lanes, n, 5)), jnp.float32)
    bern = codecs.Repeat(lambda d: codecs.Bernoulli(blogits[:, d]), n)
    cat = codecs.Repeat(
        lambda d: codecs.Categorical(clogits[:, d]), n)
    pb = codecs.compile(bern, donate=False)
    pc = codecs.compile(cat, donate=False)
    assert isinstance(pb.lowered, _TableRepeat)
    assert isinstance(pc.lowered, _TableRepeat)
    xb = jnp.asarray(rng.integers(0, 2, (lanes, n)), jnp.int32)
    xc = jnp.asarray(rng.integers(0, 5, (lanes, n)), jnp.int32)
    _assert_parity(bern, pb, _fresh(lanes), xb)
    _assert_parity(cat, pc, _fresh(lanes), xc)


def test_betabinomial_repeat_parity():
    lanes, n = 4, 7
    rng = np.random.default_rng(5)
    al = jnp.asarray(rng.uniform(0.5, 3, (lanes, n)), jnp.float32)
    be = jnp.asarray(rng.uniform(0.5, 3, (lanes, n)), jnp.float32)
    rep = codecs.Repeat(
        lambda d: codecs.BetaBinomial(al[:, d], be[:, d], 255), n)
    prog = codecs.compile(rep, donate=False)
    assert isinstance(prog.lowered, _TableRepeat)
    x = jnp.asarray(rng.integers(0, 256, (lanes, n)), jnp.int32)
    _assert_parity(rep, prog, _fresh(lanes, cap=1024), x)


def test_repeat_out_dtype_preserved():
    lanes, n = 4, 5
    rep = codecs.Repeat(lambda d: codecs.Uniform(4), n,
                        out_dtype=jnp.uint8)
    prog = codecs.compile(rep, donate=False)
    stack = _fresh(lanes)
    _, x = prog.pop(stack)
    assert x.dtype == jnp.uint8


# ---------------------------------------------------------------------------
# fallback lowering (unknown / heterogeneous bodies stay interpreted)
# ---------------------------------------------------------------------------

def test_unknown_leaf_falls_back_to_interpreted():
    lanes, n = 4, 6
    inner = codecs.Uniform(5)
    rep = codecs.Repeat(
        lambda d: codecs.FnCodec(inner.push, inner.pop), n, scan=False)
    prog = codecs.compile(rep, donate=False)
    assert isinstance(prog.lowered, codecs.Repeat)   # unchanged
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.integers(0, 32, (lanes, n)), jnp.int32)
    _assert_parity(rep, prog, _fresh(lanes), x)


def test_heterogeneous_repeat_falls_back():
    lanes, n = 4, 6
    rep = codecs.Repeat(
        lambda d: codecs.Uniform(4 if d < 3 else 6), n, scan=False)
    prog = codecs.compile(rep, donate=False)
    assert isinstance(prog.lowered, codecs.Repeat)   # mixed bits: no fuse
    rng = np.random.default_rng(7)
    x = jnp.asarray(
        np.concatenate([rng.integers(0, 16, (lanes, 3)),
                        rng.integers(0, 64, (lanes, 3))], axis=1),
        jnp.int32)
    _assert_parity(rep, prog, _fresh(lanes), x)


def test_nonuniform_position_closure_is_fused_correctly():
    """A closure whose parameters vary per position through arithmetic
    on ``d`` must still fuse bit-exactly (the arange fast-probe)."""
    lanes, n = 5, 8
    rng = np.random.default_rng(8)
    base = jnp.asarray(rng.normal(0, 1, (lanes, n)), jnp.float32)
    rep = codecs.Repeat(
        lambda d: codecs.DiscretizedGaussian(
            base[:, d], jnp.full((lanes,), 0.5, jnp.float32), 10), n)
    prog = codecs.compile(rep, donate=False)
    assert isinstance(prog.lowered, _GridRepeat)
    stack = _fresh(lanes)
    si, yi = rep.pop(stack)
    sc, yc = prog.pop(stack)
    np.testing.assert_array_equal(np.asarray(yi), np.asarray(yc))
    _assert_stacks_equal(si, sc)


# ---------------------------------------------------------------------------
# combinators
# ---------------------------------------------------------------------------

def test_serial_shaped_tree_parity():
    lanes = 5
    rng = np.random.default_rng(9)
    logits = jnp.asarray(rng.normal(0, 1, (lanes, 5)), jnp.float32)
    codec = codecs.Serial([
        codecs.Uniform(6),
        codecs.Categorical(logits),
        codecs.Shaped(
            codecs.Repeat(lambda d: codecs.Uniform(4), 6), (2, 3)),
        codecs.TreeCodec({"a": codecs.Repeat(
            lambda d: codecs.Uniform(3), 2)}),
    ])
    prog = codecs.compile(codec, donate=False)
    x = (jnp.asarray(rng.integers(0, 64, lanes), jnp.int32),
         jnp.asarray(rng.integers(0, 5, lanes), jnp.int32),
         jnp.asarray(rng.integers(0, 16, (lanes, 2, 3)), jnp.int32),
         {"a": jnp.asarray(rng.integers(0, 8, (lanes, 2)), jnp.int32)})
    _assert_parity(codec, prog, _fresh(lanes), x)


def test_chained_parity(small_cfg, small_params):
    lanes, n = 3, 4
    rng = np.random.default_rng(10)
    data = jnp.asarray(rng.integers(0, 2, (n, lanes, small_cfg.input_dim)),
                       jnp.int32)
    chained = codecs.Chained(
        vae_lib.make_bb_codec(small_params, small_cfg), n)
    prog = codecs.compile(chained, donate=False)
    stack = _fresh(lanes, cap=2048, chunks=64)
    _assert_parity(chained, prog, stack, data)


def _toy_bitswap(lanes, seed=7, z_dims=(4, 3, 2), obs_d=10, bits=6):
    """A 3-layer Markov hierarchy over Gaussian grid leaves (mirrors
    tests/test_codecs.py's toy, used here for compiled parity)."""
    rng = np.random.default_rng(seed)
    dims = (obs_d,) + tuple(z_dims)

    def gauss_repeat(mu, sigma_val):
        return codecs.Repeat(
            lambda d: codecs.DiscretizedGaussian(
                mu[:, d], jnp.full_like(mu[:, d], sigma_val), bits),
            mu.shape[1])

    layers = []
    for level in range(1, len(dims)):
        w_post = jnp.asarray(
            rng.normal(0, 0.5, (dims[level - 1], dims[level])), jnp.float32)
        w_lik = jnp.asarray(
            rng.normal(0, 0.8, (dims[level], dims[level - 1])), jnp.float32)
        bottom = level == 1

        def posterior(ctx, _w=w_post, _b=bottom, _s=0.5):
            vals = ctx.astype(jnp.float32) if _b \
                else discretize.bucket_centre(ctx, bits)
            return gauss_repeat(jnp.tanh(vals @ _w), _s)

        def likelihood(z, _w=w_lik, _b=bottom):
            out = jnp.tanh(discretize.bucket_centre(z, bits) @ _w)
            if _b:
                return codecs.Repeat(
                    lambda d: codecs.Bernoulli(out[:, d] * 2.0), obs_d)
            return gauss_repeat(out, 0.7)

        layers.append((posterior, likelihood))

    prior = codecs.Repeat(lambda d: codecs.Uniform(bits), z_dims[-1])
    return codecs.BitSwap(prior=prior, layers=tuple(layers)), obs_d


def test_bitswap_three_layer_parity():
    lanes = 4
    codec, obs_d = _toy_bitswap(lanes)
    prog = codecs.compile(codec, donate=False)
    rng = np.random.default_rng(11)
    s = jnp.asarray(rng.integers(0, 2, (lanes, obs_d)), jnp.int32)
    out = _assert_parity(codec, prog, _fresh(lanes, cap=1024, chunks=64),
                         s)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(s))


# ---------------------------------------------------------------------------
# container / stream byte-parity + cross-decode
# ---------------------------------------------------------------------------

def test_container_blob_byte_identical(small_cfg, small_params):
    lanes, n = 4, 3
    rng = np.random.default_rng(12)
    data = jnp.asarray(rng.integers(0, 2, (n, lanes, small_cfg.input_dim)),
                       jnp.int32)
    chained = codecs.Chained(
        vae_lib.make_bb_codec(small_params, small_cfg), n)
    prog = codecs.compile(chained)      # default donate=True: the
    # container never reuses a pushed stack, so donation is safe here.
    blob_i = codecs.compress(chained, data, lanes=lanes, seed=0)
    blob_c = codecs.compress(prog, data, lanes=lanes, seed=0)
    assert blob_i == blob_c
    # cross-decode: compiled decodes interpreted bytes and vice versa
    np.testing.assert_array_equal(
        np.asarray(codecs.decompress(prog, blob_i)), np.asarray(data))
    np.testing.assert_array_equal(
        np.asarray(codecs.decompress(chained, blob_c)), np.asarray(data))


def test_stream_compiled_byte_identical(small_cfg, small_params):
    lanes, n = 3, 7
    rng = np.random.default_rng(13)
    data = jnp.asarray(rng.integers(0, 2, (n, lanes, small_cfg.input_dim)),
                       jnp.int32)
    codec = vae_lib.make_bb_codec(small_params, small_cfg)
    wire_i = stream.encode_stream(codec, data, lanes=lanes,
                                  block_symbols=3, seed=1, init_chunks=32)
    wire_c = stream.encode_stream(codec, data, lanes=lanes,
                                  block_symbols=3, seed=1, init_chunks=32,
                                  compile=True)
    assert wire_i == wire_c
    out = stream.decode_stream(codec, wire_c, compile=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))


# ---------------------------------------------------------------------------
# determinism at scale (the canonical-evaluation contract)
# ---------------------------------------------------------------------------

def test_grid_roundtrip_restores_at_scale():
    """Fused pop + eager push-back must restore the stack exactly over
    ~50K symbols: the cross-context bit-stability the compiled path's
    losslessness rests on (see compile.py's determinism notes)."""
    from repro.kernels.ans import ops as ans_ops

    lanes, steps, bits, prec = 256, 200, 10, 16
    rng = np.random.default_rng(14)
    mu = jnp.asarray(rng.normal(0, 1.5, (steps, lanes)), jnp.float32)
    sigma = jnp.asarray(rng.uniform(0.05, 3.0, (steps, lanes)),
                        jnp.float32)
    stack = ans.make_stack(lanes, steps + 8, key=jax.random.PRNGKey(14))
    stack = ans.seed_stack(stack, jax.random.PRNGKey(15), steps)

    st, idx = ans_ops.pop_many_grid(stack, "gaussian", mu, sigma, steps,
                                    bits, prec)
    f = discretize.posterior_starts_fn(mu, sigma, bits, prec)
    start = f(idx)
    st_back = ans_ops.push_many(st, start[::-1], (f(idx + 1) - start)[::-1],
                                prec)
    _assert_stacks_equal(st_back, stack)


# ---------------------------------------------------------------------------
# donation
# ---------------------------------------------------------------------------

def test_donation_invalidates_input_stack(small_cfg, small_params):
    """The documented donation contract: a donating program consumes
    its input stack (drivers must use the returned one)."""
    lanes, n = 2, 5
    rep = codecs.Repeat(lambda d: codecs.Uniform(6), n)
    prog = codecs.compile(rep)          # donate=True
    x = jnp.asarray(np.zeros((lanes, n)), jnp.int32)
    stack = _fresh(lanes)
    out = prog.push(stack, x)
    assert out.head is not None
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(stack.head)


# ---------------------------------------------------------------------------
# CodecEngine: LRU cap + compiled program cache
# ---------------------------------------------------------------------------

def _toy_family(bits=6):
    def make(shape):
        n = int(np.prod(shape))
        return codecs.Shaped(
            codecs.Repeat(lambda d: codecs.Uniform(bits), n), tuple(shape))
    return make


def test_codec_engine_lru_eviction():
    calls = []
    base = _toy_family()

    def counting(shape):
        calls.append(shape)
        return base(shape)

    eng = CodecEngine(counting, seed=0, init_chunks=0, max_codecs=2)
    eng.codec_for((2, 2))
    eng.codec_for((2, 3))
    eng.codec_for((2, 2))           # hit: most recently used now (2,2)
    assert len(calls) == 2
    eng.codec_for((2, 4))           # evicts (2,3)
    assert len(calls) == 3
    eng.codec_for((2, 2))           # still cached
    assert len(calls) == 3
    eng.codec_for((2, 3))           # rebuilt after eviction
    assert len(calls) == 4
    assert len(eng._codecs) == 2


def test_codec_engine_lru_rejects_zero():
    with pytest.raises(ValueError, match="max_codecs"):
        CodecEngine(_toy_family(), max_codecs=0)


def test_codec_engine_compiled_byte_identical():
    rng = np.random.default_rng(15)
    data = jnp.asarray(rng.integers(0, 64, (3, 4, 2, 3)), jnp.int32)
    eng_i = CodecEngine(_toy_family(), seed=0, init_chunks=0)
    eng_c = CodecEngine(_toy_family(), seed=0, init_chunks=0,
                        compile=True)
    blob_i = eng_i.compress(data)
    blob_c = eng_c.compress(data)
    assert blob_i == blob_c
    out = eng_c.decompress(blob_i, 3, (2, 3))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))
    # compiled chain programs are cached and LRU-bounded
    assert ((2, 3), 3) in eng_c._programs
    wire_i = eng_i.compress_stream(data, block_symbols=2)
    wire_c = eng_c.compress_stream(data, block_symbols=2)
    assert wire_i == wire_c
    out2 = eng_c.decompress_stream(wire_c, (2, 3))
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(data))


def test_codec_engine_program_cache_evicted_with_shape():
    eng = CodecEngine(_toy_family(), seed=0, init_chunks=0,
                      max_codecs=2, compile=True)
    rng = np.random.default_rng(16)
    for w in (2, 3, 4):   # three shapes through a 2-slot LRU
        data = jnp.asarray(rng.integers(0, 64, (2, 2, 2, w)), jnp.int32)
        eng.compress(data)
    assert len(eng._codecs) == 2
    assert all(key[0] in eng._codecs for key in eng._programs)
