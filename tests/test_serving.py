"""Serving + LM compression: prefill==forward, engine roundtrips,
LM-ANS exact lossless roundtrip, LatentLM bits-back roundtrip."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs
from repro.configs import base as cfg_base
from repro.core import ans, lm_codec
from repro.models import latent_lm, transformer
from repro.serve.engine import Engine


def _cfg(arch="qwen2-0.5b", vocab=300):
    return dataclasses.replace(
        cfg_base.reduced(cfg_base.get(arch)), vocab=vocab)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "rwkv6-3b", "hymba-1.5b"])
def test_prefill_matches_forward_and_decode_continues(arch):
    """prefill logits == forward logits at the last position, and decoding
    after prefill == decoding from scratch."""
    cfg = _cfg(arch)
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    b, s, extra = 2, 6, 3
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + extra)),
                       jnp.int32)

    logits_pre, state = transformer.prefill(
        params, cfg, {"tokens": toks[:, :s]}, max_len=s + extra)
    full, _ = transformer.forward(params, cfg, toks[:, :s])
    np.testing.assert_allclose(np.asarray(logits_pre[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=0.1, atol=0.1)
    assert int(state["cache_len"]) == s

    # Continue decoding; compare against teacher-forced forward.
    fullx, _ = transformer.forward(params, cfg, toks)
    for t in range(s, s + extra):
        logits_t, state = transformer.decode_step(
            params, cfg, toks[:, t:t + 1], state)
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0], np.float32),
            np.asarray(fullx[:, t], np.float32), rtol=0.15, atol=0.15)


def test_engine_generate_deterministic():
    cfg = _cfg()
    params = transformer.init(jax.random.PRNGKey(1), cfg)
    eng = Engine(params, cfg, max_len=32, jit=False)
    prompt = {"tokens": jnp.asarray([[1, 2, 3, 4]], jnp.int32)}
    out1 = eng.generate(prompt, 5)
    out2 = eng.generate(prompt, 5)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (1, 5)


def test_lm_ans_roundtrip_exact():
    """Compress token streams with the LM; decompression is bit-exact."""
    cfg = _cfg(vocab=300)
    params = transformer.init(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    lanes, n = 3, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (lanes, n)), jnp.int32)

    eng = Engine(params, cfg, max_len=n, jit=False)
    blob = eng.compress(toks)
    out = eng.decompress(blob, n)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(toks))
    assert codecs.blob_info(blob)["payload_bits"] > 0


def test_lm_ans_rate_matches_cross_entropy():
    """Achieved bits == model cross-entropy (within ~2% + constant)."""
    cfg = _cfg(vocab=300)
    params = transformer.init(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(3)
    lanes, n = 4, 40
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (lanes, n)), jnp.int32)
    stack = ans.make_stack(lanes, 4 * n + 16, key=jax.random.PRNGKey(4))
    b0 = float(ans.stack_content_bits(stack))
    stack = lm_codec.encode_tokens(params, cfg, toks, stack)
    achieved = float(ans.stack_content_bits(stack)) - b0
    expected = lm_codec.expected_bits(params, cfg, toks)
    assert achieved == pytest.approx(expected, rel=0.02), (achieved,
                                                           expected)


def test_latent_lm_bits_back_roundtrip():
    """BB-ANS over sequences with a transformer backbone: exact roundtrip
    and stack restoration (the paper's scheme on an assigned arch)."""
    bb = _cfg("smollm-360m", vocab=200)
    cfg = latent_lm.LatentLMConfig(backbone=bb, latent_dim=4, n_prefix=1,
                                   lat_bits=8)
    params = latent_lm.init(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(5)
    lanes, n, n_seqs = 2, 10, 3
    data = jnp.asarray(rng.integers(0, bb.vocab, (n_seqs, lanes, n)),
                       jnp.int32)
    chained = codecs.Chained(
        latent_lm.make_bb_codec(params, cfg, seq_len=n), n_seqs,
        scan=False)
    stack = ans.make_stack(lanes, 4096, key=jax.random.PRNGKey(6))
    stack = ans.seed_stack(stack, jax.random.PRNGKey(7), 64)

    stack2 = chained.push(stack, data)
    assert int(jnp.sum(stack2.underflows)) == 0
    assert int(jnp.sum(stack2.overflows)) == 0
    stack3, out = chained.pop(stack2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))
    np.testing.assert_array_equal(np.asarray(stack3.head),
                                  np.asarray(stack.head))


def test_engine_stream_roundtrip_and_resume():
    """Chunked BBX2 LM compression: exact roundtrip across block
    boundaries plus a mid-stream resume from a byte offset."""
    from repro import stream

    cfg = _cfg(vocab=300)
    params = transformer.init(jax.random.PRNGKey(21), cfg)
    rng = np.random.default_rng(21)
    lanes, n, block = 2, 14, 5
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (lanes, n)), jnp.int32)
    eng = Engine(params, cfg, max_len=n, jit=False)

    blob = eng.compress_stream(toks, block_symbols=block)
    header, offsets, trailer = stream.format.scan(blob)
    assert len(offsets) == 3 and trailer.total_symbols == n
    out = eng.decompress_stream(blob)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(toks))

    tail = stream.decode_from_offset(
        None, blob, offsets[1], block_codec_fn=eng._block_codec_fn())
    np.testing.assert_array_equal(np.asarray(tail.T),
                                  np.asarray(toks[:, block:]))


def test_engine_serve_many_ragged_requests():
    """Dynamic batching: ragged requests through one stack (with
    queueing past max_lanes), decoded bit-exactly at the same width."""
    cfg = _cfg(vocab=300)
    params = transformer.init(jax.random.PRNGKey(22), cfg)
    rng = np.random.default_rng(22)
    eng = Engine(params, cfg, max_len=16, jit=False)
    reqs = [jnp.asarray(rng.integers(0, cfg.vocab,
                                     (int(rng.integers(1, 9)),)),
                        jnp.int32) for _ in range(5)]
    blobs = eng.serve_many(reqs, max_lanes=3, block_symbols=4)
    outs = eng.decompress_many(blobs, max_lanes=3, block_symbols=4)
    assert len(outs) == len(reqs)
    for r, o in zip(reqs, outs):
        np.testing.assert_array_equal(np.asarray(o), np.asarray(r))


def test_latent_lm_elbo_finite_and_trainable():
    bb = _cfg("smollm-360m", vocab=64)
    cfg = latent_lm.LatentLMConfig(backbone=bb, latent_dim=4, n_prefix=1)
    params = latent_lm.init(jax.random.PRNGKey(8), cfg)
    toks = jnp.asarray(
        np.random.default_rng(8).integers(0, 64, (4, 12)), jnp.int32)
    l, m = latent_lm.loss(params, cfg, jax.random.PRNGKey(9), toks)
    assert jnp.isfinite(l)
    grads = jax.grad(lambda p: latent_lm.loss(p, cfg,
                                              jax.random.PRNGKey(9),
                                              toks)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g)))
             for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.slow
def test_int8_kv_decode_close_to_bf16():
    """int8 KV cache (hillclimb 3): decode logits within quantization
    tolerance of the bf16 path, exact same control flow."""
    cfg16 = _cfg("qwen2-0.5b")
    cfg8 = dataclasses.replace(cfg16, kv_cache_dtype="int8")
    params = transformer.init(jax.random.PRNGKey(11), cfg16)
    rng = np.random.default_rng(11)
    b, s = 2, 10
    toks = jnp.asarray(rng.integers(0, cfg16.vocab, (b, s)), jnp.int32)

    def run(cfg):
        state = transformer.init_decode_state(cfg, b, max_len=s)
        outs = []
        for t in range(s):
            logits, state = transformer.decode_step(
                params, cfg, toks[:, t:t + 1], state)
            outs.append(logits[:, 0])
        return jnp.stack(outs, 1)

    l16 = np.asarray(run(cfg16), np.float32)
    l8 = np.asarray(run(cfg8), np.float32)
    # int8 KV error is small relative to logit scale
    scale = np.abs(l16).max()
    assert np.abs(l8 - l16).max() < 0.08 * scale, np.abs(l8 - l16).max()


def test_int8_kv_prefill_then_decode():
    """Prefill fills a quantized cache that decode continues from."""
    cfg = dataclasses.replace(_cfg("qwen2-0.5b"), kv_cache_dtype="int8")
    params = transformer.init(jax.random.PRNGKey(12), cfg)
    rng = np.random.default_rng(12)
    b, s = 2, 6
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 2)), jnp.int32)
    logits, state = transformer.prefill(params, cfg,
                                        {"tokens": toks[:, :s]},
                                        max_len=s + 2)
    assert state["k"].dtype == jnp.int8
    for t in range(s, s + 2):
        logits, state = transformer.decode_step(params, cfg,
                                                toks[:, t:t + 1], state)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
