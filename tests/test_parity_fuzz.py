"""Property-based wire-parity fuzzer (ISSUE-8 satellite; clustered
variant from ISSUE 10).

Every example derives a random codec tree, shapes, and coder
precisions from one integer seed (``np.random.default_rng(seed)``, so
the real hypothesis package and the deterministic conftest fallback
both work), then asserts the parity contract:

    eager interpreter == compiled program == fused fixed-point program
    == lane-sharded corpus == multi-host clustered corpus, hex-for-hex
    on the wire - including under a seeded mid-corpus host kill - and
    every path decodes losslessly.

Quick variants (10 examples) run in tier-1; the ``slow``-marked
variants push each property past 100 examples and run in the CI full
suite (zero tolerated divergence).
"""

import asyncio
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import codecs, shard_codec
from repro.gateway import GatewayCluster, TenantQuota
from repro.serve import CodecEngine

LANES = 4


# ---------------------------------------------------------------------------
# generators (all structure flows from one integer seed)
# ---------------------------------------------------------------------------

def _random_leaf(rng: np.random.Generator, param_lanes: int = LANES):
    """(codec, data [LANES]) for one random leaf family.

    ``param_lanes`` sizes the codec's per-lane parameter arrays: the
    full lane count for the unsharded properties, the per-shard lane
    count for the sharded one (a lane-split corpus hands each shard a
    narrower stack, so baked-in parameters must match it; scalar
    Gaussian parameters broadcast and stay lane-agnostic).
    """
    kind = rng.integers(0, 3)
    if kind == 0:
        bits = int(rng.integers(2, 9))
        return (codecs.Uniform(bits),
                jnp.asarray(rng.integers(0, 1 << bits, (LANES,)),
                            jnp.int32))
    if kind == 1:
        alphabet = int(rng.integers(2, 10))
        precision = int(rng.integers(12, 17))
        logits = jnp.asarray(
            np.tile(rng.normal(size=(1, alphabet)), (param_lanes, 1)),
            jnp.float32)
        return (codecs.Categorical(logits, precision=precision),
                jnp.asarray(rng.integers(0, alphabet, (LANES,)),
                            jnp.int32))
    bits = int(rng.integers(4, 9))
    precision = int(rng.integers(max(12, bits + 2), 17))
    if param_lanes == LANES:
        mu = jnp.asarray(rng.normal(size=(LANES,)), jnp.float32)
        sigma = jnp.asarray(np.exp(rng.normal(size=(LANES,)) * 0.5),
                            jnp.float32)
    else:
        mu = jnp.float32(rng.normal())
        sigma = jnp.float32(np.exp(rng.normal() * 0.5))
    return (codecs.DiscretizedGaussian(mu, sigma, bits, precision),
            jnp.asarray(rng.integers(0, 1 << bits, (LANES,)),
                        jnp.int32))


def _random_tree(rng: np.random.Generator, depth: int = 0,
                 param_lanes: int = LANES):
    """(codec, data pytree) - random combinator tree over random leaves."""
    kind = rng.integers(0, 4) if depth < 2 else 3
    if kind == 0:                                   # Serial of 2 subtrees
        (ca, da), (cb, db) = (_random_tree(rng, depth + 1, param_lanes),
                              _random_tree(rng, depth + 1, param_lanes))
        return codecs.Serial((ca, cb)), (da, db)
    if kind == 1:                                   # Shaped Repeat of leaf
        n = int(rng.integers(1, 5))
        leaf, _ = _random_leaf(rng, param_lanes)
        data = jnp.stack([_matching_data(rng, leaf) for _ in range(n)],
                         axis=-1)
        return codecs.Shaped(
            codecs.Repeat(lambda d, _l=leaf: _l, n), (n,)), data
    if kind == 2:                                   # TreeCodec dict
        (ca, da), (cb, db) = (_random_tree(rng, depth + 1, param_lanes),
                              _random_tree(rng, depth + 1, param_lanes))
        return (codecs.TreeCodec({"a": ca, "b": cb}),
                {"a": da, "b": db})
    return _random_leaf(rng, param_lanes)


def _matching_data(rng, leaf):
    if isinstance(leaf, codecs.Uniform):
        return jnp.asarray(rng.integers(0, 1 << leaf.bits, (LANES,)),
                           jnp.int32)
    if isinstance(leaf, codecs.Categorical):
        a = leaf.logits.shape[-1]
        return jnp.asarray(rng.integers(0, a, (LANES,)), jnp.int32)
    return jnp.asarray(rng.integers(0, 1 << leaf.bits, (LANES,)),
                       jnp.int32)


def _random_vae(rng: np.random.Generator):
    """(fixed-point codec pair, data) for a random small VAE."""
    from repro.models import vae
    cfg = vae.VAEConfig(
        input_dim=int(rng.integers(6, 25)),
        hidden=int(rng.integers(8, 17)),
        latent=int(rng.integers(2, 7)),
        lat_bits=int(rng.integers(6, 11)),
        precision=int(rng.integers(14, 17)),
        obs_precision=int(rng.integers(12, 17)))
    params = vae.init(jax.random.PRNGKey(int(rng.integers(0, 2**31))),
                      cfg)
    n_chain = int(rng.integers(1, 4))
    eager = codecs.Chained(vae.make_bb_codec_q(params, cfg), n_chain)
    data = jnp.asarray(
        rng.integers(0, 2, (n_chain, LANES, cfg.input_dim)), jnp.int32)
    return eager, data


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------

def _assert_tree_parity(seed: int) -> None:
    rng = np.random.default_rng(seed)
    codec, data = _random_tree(rng)
    kw = dict(lanes=LANES, seed=int(rng.integers(0, 100)))
    blob = codecs.compress(codec, data, **kw)
    prog = codecs.compile(codec)
    assert codecs.compress(prog, data, **kw).hex() == blob.hex(), \
        f"seed {seed}: compiled wire diverged"
    out = codecs.decompress(prog, blob)
    chk = jax.tree_util.tree_map(
        lambda a, b: bool(jnp.array_equal(a, b)), out, data)
    assert all(jax.tree_util.tree_leaves(chk)), f"seed {seed}: lossy"


def _assert_fused_parity(seed: int) -> None:
    rng = np.random.default_rng(seed)
    eager, data = _random_vae(rng)
    fused = codecs.compile(eager)
    kw = dict(lanes=LANES, seed=int(rng.integers(0, 100)),
              init_chunks=16, capacity=1024)
    blob = codecs.compress(eager, data, **kw)
    assert codecs.compress(fused, data, **kw).hex() == blob.hex(), \
        f"seed {seed}: fused fixed-point wire diverged from eager"
    out = codecs.decompress(fused, blob)
    assert bool(jnp.array_equal(out, data)), f"seed {seed}: lossy"


def _assert_sharded_parity(seed: int) -> None:
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 5))
    n_shards = int(rng.choice([1, 2, 4]))
    codec, one = _random_tree(rng, param_lanes=LANES // n_shards)
    data = jax.tree_util.tree_map(
        lambda a: jnp.stack([a] * n, axis=0), one)
    kw = dict(n_shards=n_shards, block_symbols=int(rng.integers(1, 4)),
              seed=int(rng.integers(0, 100)), init_chunks=0)
    corpus = shard_codec.compress_dataset(codec, data, **kw)
    fused = shard_codec.compress_dataset(codec, data, compile=True,
                                         **kw)
    assert fused.hex() == corpus.hex(), \
        f"seed {seed}: sharded wire depends on execution path"
    out = shard_codec.decompress_dataset(codec, corpus)
    chk = jax.tree_util.tree_map(
        lambda a, b: bool(jnp.array_equal(a, b)), out, data)
    assert all(jax.tree_util.tree_leaves(chk)), f"seed {seed}: lossy"


def _assert_clustered_parity(seed: int) -> None:
    """Random (codec, shard count, host count, fault schedule): the
    clustered corpus must be hex-identical to the synchronous sharded
    path - even when a randomly chosen host is killed mid-corpus and
    its shard streams fail over - and leak no lanes."""
    rng = np.random.default_rng(seed)
    n_shards = int(rng.choice([1, 2, 4]))
    n_hosts = int(rng.integers(1, 4))
    codec, one = _random_tree(rng, param_lanes=LANES // n_shards)
    n = int(rng.integers(2, 5))
    data = jax.tree_util.tree_map(
        lambda a: jnp.stack([a] * n, axis=0), one)
    kw = dict(n_shards=n_shards, block_symbols=int(rng.integers(1, 4)),
              seed=int(rng.integers(0, 100)), init_chunks=0)
    corpus = shard_codec.compress_dataset(codec, data, **kw)
    kill = n_hosts >= 2 and bool(rng.integers(0, 2))
    victim = f"host{int(rng.integers(0, n_hosts))}"

    async def scenario(tmp):
        # verify=False: random trees with lane-width-baked parameters
        # fail the verifier's fixed-lane probes; parity + lossless is
        # asserted below, which is the property under test.
        cluster = GatewayCluster(
            [CodecEngine(lambda s, _c=codec: _c, max_inflight_lanes=64,
                         verify=False)
             for _ in range(n_hosts)],
            recovery_root=tmp,
            default_quota=TenantQuota(max_lanes=64, max_queued=8))
        async with cluster:
            if kill:
                async def killer():
                    await asyncio.sleep(0)
                    await cluster.kill_host(victim)
                blob, _ = await asyncio.gather(
                    cluster.compress_corpus(data, **kw), killer())
            else:
                blob = await cluster.compress_corpus(data, **kw)
            return blob, cluster.stats()

    with tempfile.TemporaryDirectory() as tmp:
        blob, st_ = asyncio.run(scenario(tmp))
    assert blob.hex() == corpus.hex(), (
        f"seed {seed}: clustered wire diverged "
        f"(hosts={n_hosts}, shards={n_shards}, kill={kill})")
    assert st_["cluster_held_lanes"] == 0, f"seed {seed}: lane leak"
    assert st_["inflight_lanes"] == 0, f"seed {seed}: lane leak"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_tree_compiled_parity(seed):
    _assert_tree_parity(seed)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_vae_fused_parity(seed):
    _assert_fused_parity(seed)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_sharded_parity(seed):
    _assert_sharded_parity(seed)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_clustered_parity(seed):
    _assert_clustered_parity(seed)


# -- CI depth: >= 100 examples per property, zero divergence --------------

@pytest.mark.slow
@settings(max_examples=120, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_tree_compiled_parity_deep(seed):
    jax.clear_caches()   # ~100 distinct programs; keep XLA state small
    _assert_tree_parity(seed)


@pytest.mark.slow
@settings(max_examples=100, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_vae_fused_parity_deep(seed):
    jax.clear_caches()
    _assert_fused_parity(seed)


@pytest.mark.slow
@settings(max_examples=100, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_sharded_parity_deep(seed):
    jax.clear_caches()
    _assert_sharded_parity(seed)


@pytest.mark.slow
@settings(max_examples=100, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_clustered_parity_deep(seed):
    jax.clear_caches()
    _assert_clustered_parity(seed)
