"""Multi-host gateway cluster tests (ISSUE 10 acceptance).

(a) cluster wire bytes - streams and BBX3 corpora spread over N hosts -
    are hex-identical to the single-host gateway and the synchronous
    ``shard_codec.compress_dataset`` path;
(b) a killed host's streams fail over to a peer via replicated recovery
    records and finish **byte-identically** (never re-coding committed
    blocks); divergent record/delivery states reject cleanly with
    ``ResumeGap``;
(c) the replicated store write-throughs to >= 2 replicas, skips
    CRC-corrupt copies, and read-repairs divergence;
(d) cluster-wide admission composes with per-host quotas, with zero
    lane leaks after every scenario - including every seeded fault
    schedule in ``tests/chaos.py``.

Plus the PR-7 regression: block commit + recovery-record write are one
transaction, so an abandon racing a write can never leave the record a
block stale.
"""

from __future__ import annotations

import asyncio
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs, shard_codec
from repro.gateway import (Backpressure, ClusterAdmission, Gateway,
                           GatewayCluster, HostDown, RecoveryRecord,
                           RecoveryStore, ReplicatedRecoveryStore,
                           ResumeGap, ShardRouter, TenantQuota, as_store)
from repro.serve import (CodecEngine, EngineHandle, ShardedCodecEngine,
                         engine_from_handle, register_engine_factory)
from tests import chaos


def _family(bits: int = 6):
    def make(shape):
        n = int(np.prod(shape))
        return codecs.Shaped(
            codecs.Repeat(lambda d: codecs.Uniform(bits), n),
            tuple(shape))
    return make


def _data(n=8, lanes=4, shape=(2,), seed=0, bits=6):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 1 << bits, (n, lanes, *shape)),
                       jnp.int32)


def _engine(lanes: int = 64, **kw):
    kw.setdefault("max_inflight_lanes", lanes)
    return CodecEngine(_family(), **kw)


def _cluster(n_hosts: int, tmp_path, **kw):
    kw.setdefault("recovery_root", str(tmp_path / "recovery"))
    # Roomy per-host quota: corpus tests park several shard sessions
    # per host for one tenant (quota pressure gets its own test).
    kw.setdefault("default_quota", TenantQuota(max_lanes=64,
                                               max_queued=8))
    return GatewayCluster([_engine() for _ in range(n_hosts)], **kw)


def _run(coro):
    return asyncio.run(coro)


def _record(sid="sess-1", block=1, offset=64, acked=8):
    return RecoveryRecord(sid, "default", "decode", byte_offset=offset,
                          block_index=block, symbols_acked=acked)


# ---------------------------------------------------------------------------
# router: derived placement + health
# ---------------------------------------------------------------------------

def test_shard_owner_round_robin():
    router = ShardRouter(["h0", "h1", "h2"])
    assert [router.shard_owner(s, 6) for s in range(6)] == \
        ["h0", "h1", "h2", "h0", "h1", "h2"]


def test_shard_route_skips_down_host():
    router = ShardRouter(["h0", "h1"])
    router.mark_down("h1")
    # h1's shards reroute to the healthy peer; h0's stay put.
    assert [router.shard_route(s, 4) for s in range(4)] == ["h0"] * 4
    router.mark_up("h1")
    assert router.shard_route(1, 4) == "h1"


def test_session_placement_deterministic_and_stable():
    router = ShardRouter(["h0", "h1", "h2"])
    placed = {f"sess-{i}": router.session_host(f"sess-{i}")
              for i in range(32)}
    assert len(set(placed.values())) > 1          # actually spreads
    assert placed == {s: router.session_host(s) for s in placed}
    victim = placed["sess-0"]
    router.mark_down(victim)
    # Rendezvous: only the dead host's sessions move.
    for sid, host in placed.items():
        if host != victim:
            assert router.session_host(sid) == host


def test_failover_host_excludes_the_dead_host():
    router = ShardRouter(["h0", "h1"])
    first = router.session_host("cam-1")
    peer = router.failover_host("cam-1", exclude=first)
    assert peer != first
    router.mark_down(peer)
    with pytest.raises(HostDown):
        router.failover_host("cam-1", exclude=first)


def test_router_validates_hosts():
    with pytest.raises(ValueError):
        ShardRouter([])
    with pytest.raises(ValueError):
        ShardRouter(["h0", "h0"])
    with pytest.raises(KeyError):
        ShardRouter(["h0"]).mark_down("nope")


# ---------------------------------------------------------------------------
# replicated recovery store
# ---------------------------------------------------------------------------

def _dirs(tmp_path, n):
    return [str(tmp_path / f"rep{i}") for i in range(n)]


def test_replicated_store_write_through_and_union(tmp_path):
    a, b = _dirs(tmp_path, 2)
    store = ReplicatedRecoveryStore([a, b])
    store.save(_record())
    # Every replica holds the record; either alone can serve it.
    assert RecoveryStore(a).load("sess-1") == _record()
    assert RecoveryStore(b).load("sess-1") == _record()
    assert store.sessions() == ["sess-1"]
    assert store.delete("sess-1") and store.sessions() == []


def test_replicated_store_skips_corrupt_and_read_repairs(tmp_path):
    dirs = _dirs(tmp_path, 2)
    store = ReplicatedRecoveryStore(dirs)
    store.save(_record(block=3, offset=96))
    chaos.corrupt_replica(store, "sess-1", index=0)
    with pytest.raises(ValueError):
        RecoveryStore(dirs[0]).load("sess-1")     # really corrupt
    assert store.load("sess-1") == _record(block=3, offset=96)
    # Read-repair rewrote the corrupt replica from the healthy one.
    assert RecoveryStore(dirs[0]).load("sess-1") == \
        _record(block=3, offset=96)


def test_replicated_store_picks_furthest_and_repairs_stale(tmp_path):
    dirs = _dirs(tmp_path, 3)
    store = ReplicatedRecoveryStore(dirs, min_replicas=2)
    from repro.gateway import save_record
    save_record(dirs[0], _record(block=1, offset=32))
    save_record(dirs[1], _record(block=4, offset=128))
    assert store.load("sess-1").block_index == 4
    for d in dirs:      # divergent + missing replicas converged
        assert RecoveryStore(d).load("sess-1").block_index == 4


def test_replicated_store_min_replicas_enforced(tmp_path):
    store = ReplicatedRecoveryStore(_dirs(tmp_path, 2), min_replicas=2)
    chaos.drop_replica_writes(store, 1)
    with pytest.raises(OSError):
        store.save(_record())
    assert store.dropped_writes == 1


def test_replicated_store_survivable_drop(tmp_path):
    # A window wider than min_replicas tolerates a lost disk.
    dirs = _dirs(tmp_path, 3)
    store = ReplicatedRecoveryStore(dirs, min_replicas=2)
    chaos.drop_replica_writes(store, 1)
    store.save(_record(block=2))
    assert store.dropped_writes == 1
    assert store.load("sess-1").block_index == 2


def test_replicated_store_validation(tmp_path):
    dirs = _dirs(tmp_path, 2)
    with pytest.raises(ValueError):
        ReplicatedRecoveryStore([dirs[0]])
    with pytest.raises(ValueError):
        ReplicatedRecoveryStore([dirs[0], dirs[0]])
    with pytest.raises(ValueError):
        ReplicatedRecoveryStore(dirs, min_replicas=3)
    with pytest.raises(ValueError):
        ReplicatedRecoveryStore(dirs, write_replicas=["elsewhere"])
    with pytest.raises(ValueError):
        ReplicatedRecoveryStore(dirs, min_replicas=2,
                                write_replicas=[dirs[0]])


def test_as_store_normalizes(tmp_path):
    assert as_store(None) is None
    st = as_store(str(tmp_path))
    assert isinstance(st, RecoveryStore)
    assert as_store(st) is st
    with pytest.raises(TypeError):
        as_store(42)


# ---------------------------------------------------------------------------
# PR-7 regression: commit + record are one transaction
# ---------------------------------------------------------------------------

def test_recovery_record_never_one_block_stale(tmp_path):
    """An abandon racing a write must wait for the commit+record
    transaction: with a pause injected in the old snapshot->save gap,
    the surviving record still describes the committed block, and the
    resumed stream is byte-identical."""
    xs = _data(n=8)
    ref = _engine().compress_stream(xs, block_symbols=2)

    async def scenario():
        eng = _engine()
        async with Gateway(eng, recovery_dir=str(tmp_path)) as gw:
            sess = await gw.open_stream(
                (2,), lanes=4, session_id="txn", block_symbols=2)
            in_gap, release = threading.Event(), threading.Event()

            def hook():
                in_gap.set()
                assert release.wait(10)
            sess._gap_hook = hook
            writer = asyncio.create_task(sess.write(xs[:2]))
            assert await asyncio.to_thread(in_gap.wait, 10)
            # The write txn is sitting *between* snapshot and record
            # save. Abandon must block until the record is durable.
            abandoner = asyncio.create_task(
                asyncio.to_thread(sess.abandon))
            await asyncio.sleep(0.1)
            assert not abandoner.done(), \
                "abandon slipped through the txn lock"
            rec_before = gw._store.load("txn")
            release.set()
            prefix = await writer
            await abandoner
            rec = gw._store.load("txn")
            assert rec_before is None or rec_before.block_index == 0
            assert rec is not None and rec.block_index == 1, \
                "record is stale relative to the committed block"
            assert rec.byte_offset == len(prefix)
            # Resume from the record: continuation is hex-identical.
            sess2 = await gw.resume_stream("txn")
            assert sess2.resumed_at == len(prefix)
            rest = await sess2.write(xs[2:])
            rest += await sess2.close()
            return prefix + rest

    assert _run(scenario()) == ref


# ---------------------------------------------------------------------------
# cluster wire identity (corpus + stream), engine handles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_hosts,n_shards", [(1, 2), (2, 4), (3, 4)])
def test_cluster_corpus_hex_identical(tmp_path, n_hosts, n_shards):
    xs = _data(n=8, lanes=8)
    codec = _family()((2,))
    ref = shard_codec.compress_dataset(codec, xs, n_shards=n_shards,
                                       block_symbols=2)

    async def scenario():
        async with _cluster(n_hosts, tmp_path) as cluster:
            blob = await cluster.compress_corpus(
                xs, n_shards=n_shards, block_symbols=2)
            out = await cluster.decompress_corpus(blob, (2,))
            st = cluster.stats()
            return blob, out, st

    blob, out, st = _run(scenario())
    assert blob == ref                     # hex-identical across hosts
    assert (out == xs).all()               # lossless
    assert st["cluster_held_lanes"] == 0 and st["inflight_lanes"] == 0


def test_cluster_corpus_matches_sharded_engine(tmp_path):
    xs = _data(n=8, lanes=8)
    eng = ShardedCodecEngine(_family(), n_shards=4,
                             max_inflight_lanes=64)
    ref = eng.compress_dataset(xs, block_symbols=2)

    async def scenario():
        async with _cluster(2, tmp_path) as cluster:
            return await cluster.compress_corpus(
                xs, n_shards=4, block_symbols=2)

    assert _run(scenario()) == ref


@pytest.mark.parametrize("loop_per_host", [False, True])
def test_cluster_stream_hex_identical(tmp_path, loop_per_host):
    xs = _data(n=8)
    ref = _engine().compress_stream(xs, block_symbols=2)

    async def scenario():
        async with _cluster(2, tmp_path,
                            loop_per_host=loop_per_host) as cluster:
            cs = await cluster.open_stream(
                (2,), lanes=4, session_id="s1", block_symbols=2)
            wire = b""
            for b in range(4):
                wire += await cs.write(xs[2 * b:2 * b + 2])
            wire += await cs.close()
            return wire, cluster.stats()

    wire, st = _run(scenario())
    assert wire == ref
    assert st["cluster_held_lanes"] == 0 and st["inflight_lanes"] == 0


def test_cluster_from_engine_handles(tmp_path):
    register_engine_factory(
        "test-cluster-uniform",
        lambda **kw: CodecEngine(_family(), **kw), overwrite=True)
    handle = EngineHandle("test-cluster-uniform",
                          {"max_inflight_lanes": 64})
    assert isinstance(engine_from_handle(handle), CodecEngine)
    xs = _data(n=4, lanes=8)
    codec = _family()((2,))
    ref = shard_codec.compress_dataset(codec, xs, n_shards=2,
                                       block_symbols=2)

    async def scenario():
        cluster = GatewayCluster(
            [handle, handle], loop_per_host=True,
            recovery_root=str(tmp_path / "recovery"))
        async with cluster:
            return await cluster.compress_corpus(
                xs, n_shards=2, block_symbols=2)

    assert _run(scenario()) == ref


# ---------------------------------------------------------------------------
# failover: kill a host mid-stream / mid-corpus
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("loop_per_host", [False, True])
def test_kill_host_mid_stream_failover_identical(tmp_path,
                                                 loop_per_host):
    xs = _data(n=8)
    ref = _engine().compress_stream(xs, block_symbols=2)

    async def scenario():
        async with _cluster(2, tmp_path,
                            loop_per_host=loop_per_host) as cluster:
            cs = await cluster.open_stream(
                (2,), lanes=4, session_id="s1", block_symbols=2)
            wire = await cs.write(xs[:4])
            victim = cs.host
            assert (await cluster.kill_host(victim)) == ("s1",)
            wire += await cs.write(xs[4:])      # transparent failover
            wire += await cs.close()
            assert cs.host != victim and cs.failovers == 1
            return wire, cluster.stats()

    wire, st = _run(scenario())
    assert wire == ref
    assert st["healthy_hosts"] == ["host0"] or \
        st["healthy_hosts"] == ["host1"]
    assert st["cluster_held_lanes"] == 0 and st["inflight_lanes"] == 0
    assert st["failovers"] == 1


def test_kill_host_mid_corpus_reroutes_and_bytes_hold(tmp_path):
    xs = _data(n=8, lanes=8)
    codec = _family()((2,))
    ref = shard_codec.compress_dataset(codec, xs, n_shards=4,
                                       block_symbols=2)

    async def scenario():
        async with _cluster(2, tmp_path) as cluster:
            chunks = [xs[:4], xs[4:]]

            async def killer():
                await asyncio.sleep(0)
                await cluster.kill_host("host1")
            blob, _ = await asyncio.gather(
                cluster.compress_corpus(iter(chunks), n_shards=4,
                                        block_symbols=2),
                killer())
            return blob, cluster.stats()

    blob, st = _run(scenario())
    assert blob == ref
    assert st["cluster_held_lanes"] == 0 and st["inflight_lanes"] == 0


def test_resume_gap_is_a_clean_reject(tmp_path):
    """A record *ahead* of the delivered bytes (timed-out write whose
    bytes were discarded but whose commit finished) must reject the
    resume - never fabricate or re-code the gap."""
    xs = _data(n=8)
    ref = _engine().compress_stream(xs, block_symbols=2)

    async def scenario():
        async with _cluster(2, tmp_path) as cluster:
            cs = await cluster.open_stream(
                (2,), lanes=4, session_id="s1", block_symbols=2)
            prefix = await cs.write(xs[:2])
            chaos.delay_encoder_writes(cs._sess, 0.25)
            from repro.gateway import DeadlineExceeded
            with pytest.raises(DeadlineExceeded):
                await cs.write(xs[2:4], deadline=0.05)
            await chaos.quiesce(cluster, "s1")
            with pytest.raises(ResumeGap):
                await cs.reattach()
            assert cs.closed
            return prefix, cluster.stats()

    prefix, st = _run(scenario())
    assert ref.startswith(prefix) and prefix    # valid delivered prefix
    assert st["cluster_held_lanes"] == 0 and st["inflight_lanes"] == 0


def test_duplicate_resume_rejected_while_open(tmp_path):
    async def scenario():
        async with _cluster(2, tmp_path) as cluster:
            cs = await cluster.open_stream(
                (2,), lanes=4, session_id="s1", block_symbols=2)
            await cs.write(_data(n=2))
            with pytest.raises(ValueError):
                await cluster.resume_stream("s1")
            with pytest.raises(ValueError):
                await cluster.open_stream((2,), lanes=4,
                                          session_id="s1",
                                          block_symbols=2)
            await cs.close()
            return cluster.stats()

    st = _run(scenario())
    assert st["cluster_held_lanes"] == 0


# ---------------------------------------------------------------------------
# cluster admission + health checks
# ---------------------------------------------------------------------------

def test_cluster_admission_composes_with_host_quota(tmp_path):
    async def scenario():
        cluster = _cluster(
            2, tmp_path,
            default_quota=TenantQuota(max_lanes=8, max_queued=0),
            cluster_default_quota=TenantQuota(max_lanes=6,
                                              max_queued=0))
        async with cluster:
            a = await cluster.open_stream((2,), lanes=4,
                                          session_id="a",
                                          block_symbols=2)
            # Cluster total (6) trips before the per-host quota (8).
            with pytest.raises(Backpressure):
                await cluster.open_stream((2,), lanes=4,
                                          session_id="b",
                                          block_symbols=2)
            assert cluster.admission.held_lanes == 4
            await a.write(_data(n=2))
            await a.close()
            b = await cluster.open_stream((2,), lanes=4,
                                          session_id="b",
                                          block_symbols=2)
            await b.write(_data(n=2))
            await b.close()
            return cluster.stats()

    st = _run(scenario())
    assert st["cluster_rejected"] == 1
    assert st["cluster_held_lanes"] == 0 and st["inflight_lanes"] == 0


def test_cluster_admission_unit():
    adm = ClusterAdmission(default_quota=TenantQuota(max_lanes=4))
    adm.acquire("t", 3)
    with pytest.raises(Backpressure):
        adm.acquire("t", 2)
    adm.release("t", 3)
    assert adm.held_lanes == 0
    with pytest.raises(ValueError):
        adm.release("t", 1)
    with pytest.raises(ValueError):
        adm.acquire("t", 0)


def test_check_health_marks_down_and_reroutes(tmp_path):
    async def scenario():
        async with _cluster(2, tmp_path) as cluster:
            assert await cluster.check_health() == \
                {"host0": True, "host1": True}
            await cluster.kill_host("host1")
            health = await cluster.check_health()
            assert health == {"host0": True, "host1": False}
            # Routing never returns the down host now.
            assert cluster.router.session_host("any") == "host0"
            assert cluster.router.shard_route(1, 4) == "host0"
            xs = _data(n=4, lanes=8)
            blob = await cluster.compress_corpus(xs, n_shards=4,
                                                 block_symbols=2)
            return blob, xs

    blob, xs = _run(scenario())
    codec = _family()((2,))
    assert blob == shard_codec.compress_dataset(
        codec, xs, n_shards=4, block_symbols=2)


# ---------------------------------------------------------------------------
# seeded fault schedules (tests/chaos.py): every ending is clean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", chaos.KINDS)
def test_chaos_each_fault_kind_ends_clean(tmp_path, kind):
    xs = _data(n=8)
    ref = _engine().compress_stream(xs, block_symbols=2)
    schedule = chaos.FaultSchedule(
        seed=0, faults=(chaos.Fault(kind, at_block=2, arg=2),))

    async def scenario():
        async with _cluster(2, tmp_path) as cluster:
            outcome = await chaos.drive_stream(
                cluster, xs, schedule=schedule, session_id="s1",
                block_symbols=2)
            return outcome, cluster.stats()

    outcome, st = _run(scenario())
    chaos.check_outcome(outcome, ref)
    assert st["cluster_held_lanes"] == 0 and st["inflight_lanes"] == 0
    if kind in (chaos.KILL_HOST, chaos.DUP_RESUME):
        # These faults are fully survivable: the wire must finish.
        assert outcome[0] == "wire"
    if kind == chaos.DROP_RECOVERY:
        # 2-host write-through (min 2 replicas) cannot absorb drops.
        assert outcome[0] == "reject" and outcome[1] == "OSError"


@pytest.mark.parametrize("seed", range(6))
def test_chaos_seeded_schedules_end_clean(tmp_path, seed):
    xs = _data(n=8, seed=seed)
    ref = _engine().compress_stream(xs, block_symbols=2)
    schedule = chaos.FaultSchedule.from_seed(seed, n_blocks=4)

    async def scenario():
        async with _cluster(2, tmp_path) as cluster:
            outcome = await chaos.drive_stream(
                cluster, xs, schedule=schedule, session_id="s1",
                block_symbols=2)
            return outcome, cluster.stats()

    outcome, st = _run(scenario())
    chaos.check_outcome(outcome, ref)
    assert st["cluster_held_lanes"] == 0 and st["inflight_lanes"] == 0


def test_golden_cluster_fixture_matches_sync_path():
    """The committed bbx3_cluster blob (2 hosts, 4 shards, one host
    killed mid-stream + failover) is hex-identical to the synchronous
    ``shard_codec.compress_dataset`` wire - the kill left no trace."""
    import os
    from tests.golden.make_golden import GOLDEN_DIR
    with open(os.path.join(GOLDEN_DIR, "bbx3_cluster.bin"), "rb") as f:
        committed = f.read()
    rng = np.random.default_rng(2024)
    data = jnp.asarray(rng.integers(0, 64, (8, 8, 9)), jnp.int32)
    codec = codecs.Shaped(
        codecs.Repeat(lambda d: codecs.Uniform(6), 9), (9,))
    ref = shard_codec.compress_dataset(codec, data, n_shards=4,
                                       block_symbols=2, seed=0,
                                       init_chunks=0)
    assert committed.hex() == ref.hex()


def test_chaos_schedule_is_deterministic():
    for seed in range(16):
        a = chaos.FaultSchedule.from_seed(seed, n_blocks=4)
        b = chaos.FaultSchedule.from_seed(seed, n_blocks=4)
        assert a == b and a.faults[0].kind in chaos.KINDS
