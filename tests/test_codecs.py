"""The composable ``repro.codecs`` API: leaves, combinators, container.

Covers the PR-level acceptance criteria: bit-exact roundtrips through
``codecs.compress``/``decompress`` for the MNIST VAE (via the ``BBANS``
combinator) and a token stream (via the LM codec), equivalence of the
combinator with the legacy six-hook path, the ``BitSwap`` hierarchical
combinator, container header framing, and the overflow/underflow
self-healing of the container.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs
from repro.core import ans, discretize
from repro.core.distributions import Bernoulli, Categorical
from repro.models import vae as vae_lib


@pytest.fixture(scope="module")
def small_cfg():
    return vae_lib.VAEConfig(input_dim=36, hidden=24, latent=6,
                             likelihood="bernoulli", lat_bits=10)


@pytest.fixture(scope="module")
def small_params(small_cfg):
    return vae_lib.init(jax.random.PRNGKey(0), small_cfg)


def _fresh(lanes, cap=256, seed=0, chunks=16):
    return codecs.fresh_stack(lanes, cap, seed=seed, init_chunks=chunks)


# ---------------------------------------------------------------------------
# leaves
# ---------------------------------------------------------------------------

def test_uniform_leaf_roundtrip():
    lanes, bits = 8, 9
    stack = _fresh(lanes)
    x = jnp.asarray(np.random.default_rng(0).integers(0, 1 << bits, lanes),
                    jnp.int32)
    c = codecs.Uniform(bits)
    s2 = c.push(stack, x)
    s3, out = c.pop(s2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(s3.head),
                                  np.asarray(stack.head))


def test_discretized_gaussian_matches_discretize():
    """The leaf must be bit-identical to core.discretize's posterior
    coder (same fixed-point formula, same bisection)."""
    lanes, bits, prec = 8, 10, 16
    rng = np.random.default_rng(1)
    mu = jnp.asarray(rng.normal(0, 1, lanes), jnp.float32)
    sigma = jnp.asarray(rng.uniform(0.05, 2.0, lanes), jnp.float32)
    stack = _fresh(lanes)
    leaf = codecs.DiscretizedGaussian(mu, sigma, bits, prec)

    s_leaf, idx_leaf = leaf.pop(stack)
    s_disc, idx_disc = discretize.pop_posterior(stack, mu, sigma, bits,
                                                prec)
    np.testing.assert_array_equal(np.asarray(idx_leaf),
                                  np.asarray(idx_disc))
    np.testing.assert_array_equal(np.asarray(s_leaf.head),
                                  np.asarray(s_disc.head))

    s_back = leaf.push(s_leaf, idx_leaf)
    np.testing.assert_array_equal(np.asarray(s_back.head),
                                  np.asarray(stack.head))
    np.testing.assert_array_equal(np.asarray(s_back.ptr),
                                  np.asarray(stack.ptr))


def test_discretized_logistic_roundtrip():
    lanes, bits = 8, 8
    rng = np.random.default_rng(2)
    mu = jnp.asarray(rng.normal(0, 1, lanes), jnp.float32)
    scale = jnp.asarray(rng.uniform(0.2, 1.5, lanes), jnp.float32)
    leaf = codecs.DiscretizedLogistic(mu, scale, bits)
    stack = _fresh(lanes)
    s2, idx = leaf.pop(stack)
    assert (np.asarray(idx) >= 0).all()
    assert (np.asarray(idx) < (1 << bits)).all()
    s3 = leaf.push(s2, idx)
    np.testing.assert_array_equal(np.asarray(s3.head),
                                  np.asarray(stack.head))


# ---------------------------------------------------------------------------
# combinators
# ---------------------------------------------------------------------------

def test_serial_and_shaped_roundtrip():
    lanes = 4
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.normal(0, 1, (lanes, 5)), jnp.float32)
    codec = codecs.Serial([
        codecs.Uniform(6),
        Categorical(logits),
        codecs.Shaped(
            codecs.Repeat(lambda d: codecs.Uniform(4), 6), (2, 3)),
    ])
    x = (jnp.asarray(rng.integers(0, 64, lanes), jnp.int32),
         jnp.asarray(rng.integers(0, 5, lanes), jnp.int32),
         jnp.asarray(rng.integers(0, 16, (lanes, 2, 3)), jnp.int32))
    stack = _fresh(lanes)
    s2 = codec.push(stack, x)
    s3, out = codec.pop(s2)
    for a, b in zip(out, x):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(s3.head),
                                  np.asarray(stack.head))


def test_tree_codec_roundtrip():
    lanes = 4
    rng = np.random.default_rng(4)
    tree = {"a": codecs.Uniform(5),
            "b": [codecs.Uniform(3),
                  codecs.Repeat(lambda d: codecs.Uniform(7), 2)]}
    x = {"a": jnp.asarray(rng.integers(0, 32, lanes), jnp.int32),
         "b": [jnp.asarray(rng.integers(0, 8, lanes), jnp.int32),
               jnp.asarray(rng.integers(0, 128, (lanes, 2)), jnp.int32)]}
    codec = codecs.TreeCodec(tree)
    stack = _fresh(lanes)
    s2 = codec.push(stack, x)
    s3, out = codec.pop(s2)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(x["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"][0]),
                                  np.asarray(x["b"][0]))
    np.testing.assert_array_equal(np.asarray(out["b"][1]),
                                  np.asarray(x["b"][1]))
    np.testing.assert_array_equal(np.asarray(s3.head),
                                  np.asarray(stack.head))


def test_repeat_is_jittable():
    lanes, n = 4, 5
    codec = codecs.Repeat(lambda d: codecs.Uniform(6), n)
    x = jnp.asarray(np.random.default_rng(5).integers(0, 64, (lanes, n)),
                    jnp.int32)

    @jax.jit
    def roundtrip(stack, x):
        s = codec.push(stack, x)
        return codec.pop(s)

    _, out = roundtrip(_fresh(lanes), x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_bbans_combinator_matches_compiled(small_cfg, small_params):
    """The interpreted BBANS combinator and its ``codecs.compile``d
    program must produce bit-identical stacks (same pushes in the same
    order - the compiled-path acceptance at stack level)."""
    lanes = 4
    rng = np.random.default_rng(6)
    s = jnp.asarray(rng.integers(0, 2, (lanes, small_cfg.input_dim)),
                    jnp.int32)
    bb = vae_lib.make_bb_codec(small_params, small_cfg)
    # donate=False: this test reuses the input stacks after the calls
    # (donation would invalidate them; drivers never reuse, tests do).
    prog = codecs.compile(bb, donate=False)

    st0 = _fresh(lanes, cap=512, chunks=64)
    st_new = bb.push(st0, s)
    st_old = prog.push(st0, s)
    np.testing.assert_array_equal(np.asarray(st_new.head),
                                  np.asarray(st_old.head))
    np.testing.assert_array_equal(np.asarray(st_new.ptr),
                                  np.asarray(st_old.ptr))
    np.testing.assert_array_equal(np.asarray(st_new.buf),
                                  np.asarray(st_old.buf))

    st_back, s_out = prog.pop(st_new)
    np.testing.assert_array_equal(np.asarray(s_out), np.asarray(s))
    np.testing.assert_array_equal(np.asarray(st_back.head),
                                  np.asarray(st0.head))


def _toy_hierarchy(lanes, seed=7, z_dims=(4, 2), obs_d=8, bits=6):
    """An L-layer Markov latent toy model: s <- z1 <- ... <- zL.

    ``z_dims`` is bottom-up; every conditional is a linear map squashed
    with tanh, every latent leaf a ``DiscretizedGaussian`` over the
    shared max-entropy grid.
    """
    rng = np.random.default_rng(seed)
    dims = (obs_d,) + tuple(z_dims)

    def centre(idx):
        return discretize.bucket_centre(idx, bits)

    def gauss_repeat(mu, sigma_val):
        return codecs.Repeat(
            lambda d: codecs.DiscretizedGaussian(
                mu[:, d], jnp.full_like(mu[:, d], sigma_val), bits),
            mu.shape[1])

    layers = []
    for l in range(1, len(dims)):
        w_post = jnp.asarray(rng.normal(0, 0.5, (dims[l - 1], dims[l])),
                             jnp.float32)
        w_lik = jnp.asarray(rng.normal(0, 0.8, (dims[l], dims[l - 1])),
                            jnp.float32)
        bottom = l == 1

        def posterior(ctx, _w=w_post, _bottom=bottom, _s=0.5 + 0.02 * l):
            vals = ctx.astype(jnp.float32) if _bottom else centre(ctx)
            return gauss_repeat(jnp.tanh(vals @ _w), _s)

        def likelihood(z, _w=w_lik, _bottom=bottom, _s=0.7):
            out = jnp.tanh(centre(z) @ _w)
            if _bottom:
                return codecs.Repeat(
                    lambda d: Bernoulli(out[:, d] * 2.0), obs_d)
            return gauss_repeat(out, _s)

        layers.append((posterior, likelihood))

    prior = codecs.Repeat(lambda d: codecs.Uniform(bits), z_dims[-1])
    return codecs.BitSwap(prior=prior, layers=tuple(layers)), obs_d


def test_bitswap_hierarchical_roundtrip():
    lanes = 4
    codec, obs_d = _toy_hierarchy(lanes)
    rng = np.random.default_rng(8)
    s = jnp.asarray(rng.integers(0, 2, (lanes, obs_d)), jnp.int32)
    st0 = _fresh(lanes, cap=512, chunks=64)
    st1 = codec.push(st0, s)
    assert int(jnp.sum(st1.underflows)) == 0
    st2, out = codec.pop(st1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(s))
    np.testing.assert_array_equal(np.asarray(st2.head),
                                  np.asarray(st0.head))
    np.testing.assert_array_equal(np.asarray(st2.ptr), np.asarray(st0.ptr))


def test_bitswap_three_layer_roundtrip():
    """Exact round-trip with a >= 3-level hierarchy (PR satellite)."""
    lanes = 4
    codec, obs_d = _toy_hierarchy(lanes, z_dims=(6, 4, 3))
    rng = np.random.default_rng(20)
    s = jnp.asarray(rng.integers(0, 2, (lanes, obs_d)), jnp.int32)
    st0 = _fresh(lanes, cap=512, chunks=64)
    st1 = codec.push(st0, s)
    assert int(jnp.sum(st1.underflows)) == 0
    assert int(jnp.sum(st1.overflows)) == 0
    st2, out = codec.pop(st1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(s))
    np.testing.assert_array_equal(np.asarray(st2.head),
                                  np.asarray(st0.head))
    np.testing.assert_array_equal(np.asarray(st2.ptr), np.asarray(st0.ptr))


def _instrumented_push(bitswap: codecs.BitSwap, stack, s):
    """Replay ``BitSwap.push`` step by step, recording the stack content
    after every pop/push. Returns (final stack, content trace in bits,
    per-posterior pop costs in bits)."""
    trace = [float(ans.stack_content_bits(stack))]
    pop_costs = []
    ctx = s
    for posterior_fn, likelihood_fn in bitswap.layers:
        stack, z = posterior_fn(ctx).pop(stack)
        trace.append(float(ans.stack_content_bits(stack)))
        pop_costs.append(trace[-2] - trace[-1])
        stack = likelihood_fn(z).push(stack, ctx)
        trace.append(float(ans.stack_content_bits(stack)))
        ctx = z
    stack = bitswap.prior.push(stack, ctx)
    trace.append(float(ans.stack_content_bits(stack)))
    return stack, trace, pop_costs


def _naive_push(bitswap: codecs.BitSwap, stack, s):
    """The NON-interleaved schedule: pop every posterior first, then do
    all the pushes - transient demand is the sum over layers."""
    trace = [float(ans.stack_content_bits(stack))]
    zs, ctx = [], s
    for posterior_fn, _ in bitswap.layers:
        stack, z = posterior_fn(ctx).pop(stack)
        trace.append(float(ans.stack_content_bits(stack)))
        zs.append(z)
        ctx = z
    ctx = s
    for (_, likelihood_fn), z in zip(bitswap.layers, zs):
        stack = likelihood_fn(z).push(stack, ctx)
        ctx = z
    stack = bitswap.prior.push(stack, zs[-1])
    trace.append(float(ans.stack_content_bits(stack)))
    return stack, trace


def test_bitswap_clean_bit_demand_bounded_by_one_layer():
    """The Bit-Swap advantage, measured: the transient clean-bit demand
    of the interleaved schedule is bounded by (about) ONE layer's
    posterior, while the naive all-posteriors-first schedule needs the
    sum over layers (Kingma, Abbeel & Ho, 2019)."""
    lanes = 4
    # Wide observation layer so each likelihood push re-banks bits
    # before the next posterior pop - the regime Bit-Swap exploits.
    codec, obs_d = _toy_hierarchy(lanes, z_dims=(6, 4, 3), obs_d=32)
    rng = np.random.default_rng(21)
    s = jnp.asarray(rng.integers(0, 2, (lanes, obs_d)), jnp.int32)
    st0 = _fresh(lanes, cap=2048, chunks=96)

    _, trace_swap, pop_costs = _instrumented_push(codec, st0, s)
    _, trace_naive = _naive_push(codec, st0, s)

    start = trace_swap[0]
    demand_swap = start - min(trace_swap)
    demand_naive = trace_naive[0] - min(trace_naive)
    one_layer = max(pop_costs)

    # Interleaving: bounded by one layer's posterior (+ slack for the
    # first likelihood push not fully covering the second pop).
    assert demand_swap <= one_layer + 32.0, \
        (demand_swap, one_layer, pop_costs)
    # Naive: pays every posterior before any bits come back.
    naive_pops = [trace_naive[i] - trace_naive[i + 1]
                  for i in range(len(codec.layers))]
    assert demand_naive >= sum(naive_pops) - 1.0
    # And the advantage is strict with >= 3 layers.
    assert demand_swap < demand_naive - one_layer / 2.0


def test_bitswap_single_layer_equals_bbans(small_cfg, small_params):
    """BitSwap with one layer is definitionally BBANS."""
    lanes = 3
    rng = np.random.default_rng(9)
    s = jnp.asarray(rng.integers(0, 2, (lanes, small_cfg.input_dim)),
                    jnp.int32)
    bb = vae_lib.make_bb_codec(small_params, small_cfg)
    swap = codecs.BitSwap(prior=bb.prior,
                          layers=((bb.posterior, bb.likelihood),))
    st0 = _fresh(lanes, cap=512, chunks=64)
    st_a = bb.push(st0, s)
    st_b = swap.push(st0, s)
    np.testing.assert_array_equal(np.asarray(st_a.head),
                                  np.asarray(st_b.head))
    np.testing.assert_array_equal(np.asarray(st_a.buf),
                                  np.asarray(st_b.buf))


def test_chained_scan_and_python_agree(small_cfg, small_params):
    lanes, n = 3, 4
    rng = np.random.default_rng(10)
    data = jnp.asarray(rng.integers(0, 2, (n, lanes, small_cfg.input_dim)),
                       jnp.int32)
    bb = vae_lib.make_bb_codec(small_params, small_cfg)
    st0 = _fresh(lanes, cap=2048, chunks=64)
    st_scan = codecs.Chained(bb, n, scan=True).push(st0, data)
    st_py = codecs.Chained(bb, n, scan=False).push(st0, data)
    np.testing.assert_array_equal(np.asarray(st_scan.head),
                                  np.asarray(st_py.head))
    np.testing.assert_array_equal(np.asarray(st_scan.ptr),
                                  np.asarray(st_py.ptr))


def test_chained_leading_axis_mismatch_raises(small_cfg, small_params):
    """A chain-length/data mismatch must raise, not silently code the
    wrong number of datapoints."""
    lanes, n = 2, 3
    rng = np.random.default_rng(19)
    data = jnp.asarray(rng.integers(0, 2, (n, lanes, small_cfg.input_dim)),
                       jnp.int32)
    bb = vae_lib.make_bb_codec(small_params, small_cfg)
    with pytest.raises(ValueError, match="leading axis"):
        codecs.Chained(bb, n + 1).push(_fresh(lanes, cap=2048), data)


# ---------------------------------------------------------------------------
# container
# ---------------------------------------------------------------------------

def test_fresh_stack_seedless_chunks_raises():
    with pytest.raises(ValueError, match="seed"):
        codecs.fresh_stack(2, 64, seed=None, init_chunks=8)

def test_container_vae_roundtrip_bit_exact(small_cfg, small_params):
    """Acceptance: the MNIST-style VAE through the one-call API."""
    lanes, n = 4, 5
    rng = np.random.default_rng(11)
    data = jnp.asarray(rng.integers(0, 2, (n, lanes, small_cfg.input_dim)),
                       jnp.int32)
    codec = codecs.Chained(vae_lib.make_bb_codec(small_params, small_cfg),
                           n)
    blob, info = codecs.compress(codec, data, lanes=lanes, seed=0,
                                 with_info=True)
    assert isinstance(blob, bytes)
    assert info["net_bits"] > 0
    out = codecs.decompress(codec, blob)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))


def test_container_header_framing(small_cfg, small_params):
    lanes, n = 4, 2
    rng = np.random.default_rng(12)
    data = jnp.asarray(rng.integers(0, 2, (n, lanes, small_cfg.input_dim)),
                       jnp.int32)
    codec = codecs.Chained(vae_lib.make_bb_codec(small_params, small_cfg),
                           n)
    blob = codecs.compress(codec, data, lanes=lanes, seed=3)

    info = codecs.blob_info(blob)
    assert info["lanes"] == lanes
    assert len(info["lengths"]) == lanes
    assert (info["lengths"] >= 2).all()
    assert info["payload_bits"] == int(info["lengths"].sum()) * 16
    assert info["total_bits"] == len(blob) * 8
    # Header = magic/version/precision/flags/lanes + u32 lengths.
    assert info["header_bits"] == (12 + 4 * lanes) * 8

    with pytest.raises(ValueError, match="magic"):
        codecs.blob_info(b"XXXX" + blob[4:])
    with pytest.raises(ValueError, match="truncated"):
        codecs.blob_info(blob[:8])
    with pytest.raises(ValueError, match="truncated"):
        codecs.blob_info(blob[:-2])


def test_container_determinism(small_cfg, small_params):
    """Same codec, data, and seed -> byte-identical blob."""
    lanes, n = 3, 2
    rng = np.random.default_rng(13)
    data = jnp.asarray(rng.integers(0, 2, (n, lanes, small_cfg.input_dim)),
                       jnp.int32)
    codec = codecs.Chained(vae_lib.make_bb_codec(small_params, small_cfg),
                           n)
    b1 = codecs.compress(codec, data, lanes=lanes, seed=42)
    b2 = codecs.compress(codec, data, lanes=lanes, seed=42)
    assert b1 == b2


def test_container_overflow_grow_and_retry(small_cfg, small_params):
    """A hopelessly undersized capacity must not corrupt the message -
    the container grows the stack and retries."""
    lanes, n = 2, 3
    rng = np.random.default_rng(14)
    data = jnp.asarray(rng.integers(0, 2, (n, lanes, small_cfg.input_dim)),
                       jnp.int32)
    codec = codecs.Chained(vae_lib.make_bb_codec(small_params, small_cfg),
                           n)
    blob, info = codecs.compress(codec, data, lanes=lanes, seed=1,
                                 capacity=40, with_info=True)
    assert info["retries"] > 0
    out = codecs.decompress(codec, blob)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))


def test_container_underflow_grow_and_retry(small_cfg, small_params):
    """Too few clean bits -> dirty pops; the container reseeds with a
    larger supply instead of emitting a corrupt blob."""
    lanes, n = 2, 2
    rng = np.random.default_rng(15)
    data = jnp.asarray(rng.integers(0, 2, (n, lanes, small_cfg.input_dim)),
                       jnp.int32)
    codec = codecs.Chained(vae_lib.make_bb_codec(small_params, small_cfg),
                           n)
    blob, info = codecs.compress(codec, data, lanes=lanes, seed=1,
                                 init_chunks=0, with_info=True)
    assert info["init_chunks"] > 0
    out = codecs.decompress(codec, blob)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))


def test_container_seedless_bitsback_raises(small_cfg, small_params):
    """seed=None (deterministic cold stack) cannot supply clean bits, so
    a bits-back codec that underflows must raise, not corrupt."""
    lanes, n = 2, 2
    rng = np.random.default_rng(16)
    data = jnp.asarray(rng.integers(0, 2, (n, lanes, small_cfg.input_dim)),
                       jnp.int32)
    codec = codecs.Chained(vae_lib.make_bb_codec(small_params, small_cfg),
                           n)
    with pytest.raises(RuntimeError, match="seed"):
        codecs.compress(codec, data, lanes=lanes, seed=None, init_chunks=0)


def test_container_token_stream_roundtrip():
    """Acceptance: a token stream via the LM codec through the same
    public API (reduced backbone for test speed)."""
    from repro.configs import base as cfg_base
    from repro.core import lm_codec
    from repro.models import transformer

    cfg = dataclasses.replace(
        cfg_base.reduced(cfg_base.get("qwen2-0.5b")), vocab=120)
    params = transformer.init(jax.random.PRNGKey(17), cfg)
    rng = np.random.default_rng(17)
    lanes, n = 2, 9
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (lanes, n)), jnp.int32)

    codec = lm_codec.TokenStream(params, cfg, n)
    blob = codecs.compress(codec, toks, lanes=lanes, seed=None,
                           init_chunks=0)
    out = codecs.decompress(codec, blob)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(toks))


# ---------------------------------------------------------------------------
# overflow counter (satellite: no more silent data loss)
# ---------------------------------------------------------------------------

def test_push_overflow_is_counted():
    lanes, cap = 2, 2
    stack = ans.make_stack(lanes, cap)
    table = ans.probs_to_starts(
        jnp.tile(jnp.asarray([0.01, 0.99], jnp.float32), (lanes, 1)), 14)
    # Keep pushing the improbable symbol (~7 bits each, so a 16-bit
    # chunk is emitted roughly every other push) until well past cap.
    s = stack
    for _ in range(4 * cap + 16):
        s = ans.push_with_table(s, table, jnp.zeros((lanes,), jnp.int32),
                                14)
    assert int(jnp.sum(s.overflows)) > 0
    with pytest.raises(RuntimeError, match="overflow"):
        ans.check_clean(s)


def test_seed_stack_overflow_is_counted():
    stack = ans.make_stack(2, capacity=4)
    stack = ans.seed_stack(stack, jax.random.PRNGKey(0), 7)
    np.testing.assert_array_equal(np.asarray(stack.overflows), [3, 3])


def test_chained_overflow_is_counted_and_checked(small_cfg, small_params):
    lanes, n = 2, 3
    rng = np.random.default_rng(18)
    data = jnp.asarray(rng.integers(0, 2, (n, lanes, small_cfg.input_dim)),
                       jnp.int32)
    chained = codecs.Chained(
        vae_lib.make_bb_codec(small_params, small_cfg), n)
    stack = _fresh(lanes, cap=8, chunks=2)  # far too small
    out = chained.push(stack, data)
    assert int(jnp.sum(out.overflows)) > 0
    # The tiny stack both drops chunks (overflow) and runs out of clean
    # bits (underflow); check_clean must refuse either way.
    with pytest.raises(RuntimeError, match="(under|over)flow"):
        ans.check_clean(out)
