"""Gateway serving-tier tests (ISSUE 7 acceptance).

(a) wire bytes through the async gateway are hex-identical to the
    synchronous ``CodecEngine`` / ``ShardedCodecEngine`` paths;
(b) a killed client's session resumes from its recovery record and the
    finished wire still decodes the full corpus losslessly;
(c) saturating the lanes produces backpressure (bounded queue,
    retry-after hints), deadlines are enforced with clean lane
    retirement, and concurrent goodput stays within 10% of the
    single-client streaming baseline (via ``benchmarks.loadgen``).

Plus the satellite regressions: thread-safe per-shape codec memo,
recovery-record CRC integrity, snapshot legality rules, and the
SIGINT flush hook in ``launch/serve.py``.
"""

from __future__ import annotations

import asyncio
import dataclasses
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs, stream
from repro.gateway import (AdmissionController, Backpressure,
                           DeadlineExceeded, Gateway, RecoveryRecord,
                           TenantQuota, delete_record, list_sessions,
                           load_record, save_record)
from repro.serve import CodecEngine, ShardedCodecEngine


def _family(bits: int = 6, delay: float = 0.0, counter=None):
    def make(shape):
        if counter is not None:
            counter[tuple(shape)] = counter.get(tuple(shape), 0) + 1
        if delay:
            time.sleep(delay)
        n = int(np.prod(shape))
        return codecs.Shaped(
            codecs.Repeat(lambda d: codecs.Uniform(bits), n),
            tuple(shape))
    return make


def _data(n=6, lanes=4, shape=(2, 3), seed=0, bits=6):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 1 << bits, (n, lanes, *shape)),
                       jnp.int32)


# ---------------------------------------------------------------------------
# engine admission primitives + thread-safe memo (satellite 1)
# ---------------------------------------------------------------------------

def test_engine_try_admit_retire():
    eng = CodecEngine(_family(), max_inflight_lanes=4)
    a = eng.try_admit(3)
    assert a is not None and eng.inflight_lanes == 3
    assert eng.try_admit(2) is None          # would exceed the cap
    b = eng.try_admit(1)
    assert b is not None and eng.inflight_lanes == 4
    eng.retire(a)
    assert eng.inflight_lanes == 1
    with pytest.raises(ValueError):
        eng.retire(a)                        # double retire
    eng.retire(b)
    assert eng.inflight_lanes == 0


def test_codec_engine_memo_is_thread_safe():
    """Two threads racing ``codec_for`` on the same unseen shape must
    build the codec exactly once (lock-guarded LRU memo)."""
    counter = {}
    eng = CodecEngine(_family(delay=0.05, counter=counter))
    barrier = threading.Barrier(2)
    errs = []

    def hit():
        try:
            barrier.wait(timeout=5)
            eng.codec_for((2, 3))
        except Exception as e:       # pragma: no cover - failure path
            errs.append(e)

    ts = [threading.Thread(target=hit) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs
    assert counter[(2, 3)] == 1, "codec built twice under race"


# ---------------------------------------------------------------------------
# (a) byte identity: gateway wire == synchronous wire
# ---------------------------------------------------------------------------

def test_gateway_byte_identical_to_sync_engines():
    data = _data()
    eng = CodecEngine(_family(), seed=0, init_chunks=0,
                      max_inflight_lanes=8)
    sync_blob = eng.compress(data)
    sync_wire = eng.compress_stream(data, block_symbols=2)

    sharded = ShardedCodecEngine(_family(), n_shards=1, seed=0,
                                 init_chunks=0, max_inflight_lanes=8)
    sync_corpus = sharded.compress(data)

    async def drive():
        async with Gateway(eng, queue_depth=8) as gw:
            blob = await gw.compress(data)
            wire = await gw.compress_stream(data, block_symbols=2)
            out = await gw.decompress(blob, int(data.shape[0]), (2, 3))
            sout = await gw.decompress_stream(wire, (2, 3))
            return blob, wire, out, sout

    blob, wire, out, sout = asyncio.run(drive())
    assert blob.hex() == sync_blob.hex()
    assert wire.hex() == sync_wire.hex()
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))
    np.testing.assert_array_equal(np.asarray(sout), np.asarray(data))

    async def drive_sharded():
        async with Gateway(sharded, queue_depth=8) as gw:
            return await gw.compress(data)

    assert asyncio.run(drive_sharded()).hex() == sync_corpus.hex()
    assert eng.inflight_lanes == 0 and sharded.inflight_lanes == 0


def test_gateway_session_wire_matches_sync(tmp_path):
    data = _data(n=8)
    eng = CodecEngine(_family(), seed=0, init_chunks=0,
                      max_inflight_lanes=8)
    sync_wire = eng.compress_stream(data, block_symbols=2)

    async def drive():
        async with Gateway(eng, queue_depth=4,
                           recovery_dir=str(tmp_path)) as gw:
            sess = await gw.open_stream((2, 3), lanes=4,
                                        session_id="s", block_symbols=2)
            wire = b""
            for i in range(0, 8, 2):
                wire += await sess.write(data[i:i + 2])
            return wire + await sess.close()

    assert asyncio.run(drive()).hex() == sync_wire.hex()
    assert eng.inflight_lanes == 0
    assert list_sessions(str(tmp_path)) == []   # record cleaned on close


# ---------------------------------------------------------------------------
# (b) killed client -> resume from recovery record, lossless end to end
# ---------------------------------------------------------------------------

def test_killed_client_resumes_losslessly(tmp_path):
    data = _data(n=8, seed=3)
    eng = CodecEngine(_family(), seed=0, init_chunks=0,
                      max_inflight_lanes=8)
    sync_wire = eng.compress_stream(data, block_symbols=2)

    async def phase1():
        async with Gateway(eng, queue_depth=4,
                           recovery_dir=str(tmp_path)) as gw:
            sess = await gw.open_stream((2, 3), lanes=4,
                                        session_id="crash",
                                        block_symbols=2)
            w = await sess.write(data[:4])
            sess.abandon()          # client killed; lanes released,
            return w                # record persisted at the boundary

    w1 = asyncio.run(phase1())
    assert eng.inflight_lanes == 0          # abandon retired the lanes
    assert list_sessions(str(tmp_path)) == ["crash"]
    rec = load_record(str(tmp_path), "crash")
    assert rec.byte_offset == len(w1) and rec.block_index == 2

    async def phase2():
        # A *new* gateway (fresh process in real life) picks the
        # session up from the record alone.
        async with Gateway(eng, queue_depth=4,
                           recovery_dir=str(tmp_path)) as gw:
            sess = await gw.resume_stream("crash")
            assert sess.wire_offset == len(w1)
            w = await sess.write(data[4:])
            return w + await sess.close()

    w2 = asyncio.run(phase2())
    wire = w1 + w2
    assert wire.hex() == sync_wire.hex()    # resume is byte-invisible
    out = eng.decompress_stream(wire, (2, 3))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))
    assert list_sessions(str(tmp_path)) == []
    assert eng.inflight_lanes == 0


def test_decode_session_ack_and_resume(tmp_path):
    data = _data(n=6, seed=5)
    eng = CodecEngine(_family(), seed=0, init_chunks=0,
                      max_inflight_lanes=8)
    wire = eng.compress_stream(data, block_symbols=2)

    async def phase1():
        async with Gateway(eng, queue_depth=4,
                           recovery_dir=str(tmp_path)) as gw:
            d = await gw.open_decode(wire, (2, 3), session_id="dec")
            b0 = await d.next_block()
            d.ack()                 # consumer persisted block 0
            d.close()               # dies before finishing: record kept
            return np.asarray(b0)

    b0 = asyncio.run(phase1())
    assert list_sessions(str(tmp_path)) == ["dec"]

    async def phase2():
        async with Gateway(eng, queue_depth=4,
                           recovery_dir=str(tmp_path)) as gw:
            d = await gw.resume_decode(wire, "dec")
            got = []
            while (b := await d.next_block()) is not None:
                got.append(np.asarray(b))
                d.ack()
            d.close()
            return got

    rest = asyncio.run(phase2())
    np.testing.assert_array_equal(np.concatenate([b0, *rest], axis=0),
                                  np.asarray(data))
    assert list_sessions(str(tmp_path)) == []   # fully acked -> deleted
    assert eng.inflight_lanes == 0


# ---------------------------------------------------------------------------
# (c) saturation: backpressure, deadlines, bounded queue, no lane leak
# ---------------------------------------------------------------------------

def test_backpressure_bounded_queue_and_retry_after():
    data = _data()
    eng = CodecEngine(_family(), seed=0, init_chunks=0,
                      max_inflight_lanes=4)
    sync_blob = eng.compress(data)

    async def drive():
        async with Gateway(eng, queue_depth=3) as gw:
            held = eng.try_admit(4)          # saturate the lanes
            assert held is not None
            waiters = [asyncio.create_task(gw.compress(data))
                       for _ in range(3)]
            await asyncio.sleep(0.05)        # queue now full
            with pytest.raises(Backpressure) as ei:
                await gw.compress(data)
            assert ei.value.retry_after > 0
            assert "queue" in ei.value.reason
            assert gw.stats()["rejected"] >= 1
            eng.retire(held)                 # lanes free: queue drains
            gw._pump()
            blobs = await asyncio.gather(*waiters)
            assert all(b == sync_blob for b in blobs)
            return gw.stats()

    stats = asyncio.run(drive())
    assert stats["inflight_lanes"] == 0 and stats["waiting"] == 0


def test_tenant_quota_is_per_tenant():
    data = _data()
    eng = CodecEngine(_family(), seed=0, init_chunks=0,
                      max_inflight_lanes=64)

    async def drive():
        async with Gateway(eng, queue_depth=16,
                           default_quota=TenantQuota(max_lanes=4,
                                                     max_queued=1)) as gw:
            sess = await gw.open_stream((2, 3), lanes=4,
                                        session_id="hog",
                                        tenant="greedy")
            # greedy's 4-lane quota is exhausted (the engine itself has
            # 64 lanes free): its next request queues, and the one
            # after overflows max_queued=1 per-tenant - Backpressure
            # even though the global queue has room.
            t = asyncio.create_task(gw.compress(data, tenant="greedy"))
            await asyncio.sleep(0.05)
            with pytest.raises(Backpressure, match="tenant"):
                await gw.compress(data, tenant="greedy")
            p = asyncio.create_task(gw.compress(data, tenant="polite"))
            await asyncio.sleep(0.02)
            await sess.close()      # frees greedy's quota: FIFO drains
            return await t, await p

    bg, bp = asyncio.run(drive())
    assert bg == eng.compress(data) and bp == bg
    assert eng.inflight_lanes == 0


def test_deadline_while_queued_raises_deadline_exceeded():
    data = _data()
    eng = CodecEngine(_family(), seed=0, init_chunks=0,
                      max_inflight_lanes=4)

    async def drive():
        async with Gateway(eng, queue_depth=4) as gw:
            held = eng.try_admit(4)
            t0 = time.perf_counter()
            with pytest.raises(DeadlineExceeded):
                await gw.compress(data, deadline=0.05)
            waited = time.perf_counter() - t0
            eng.retire(held)
            return waited, gw.stats()

    waited, stats = asyncio.run(drive())
    assert waited < 2.0                      # gave up, didn't hang
    assert stats["deadline_exceeded"] == 1
    assert stats["inflight_lanes"] == 0 and stats["waiting"] == 0


def test_deadline_mid_compute_retires_lane_when_thread_returns():
    """A deadline that fires while the engine is mid-compute cannot
    preempt the thread; the gateway must still retire the lane once the
    abandoned computation returns (no permanent lane leak)."""
    data = _data()
    eng = CodecEngine(_family(), seed=0, init_chunks=0,
                      max_inflight_lanes=4)
    real = eng.compress

    def slow_compress(*a, **k):
        time.sleep(0.3)
        return real(*a, **k)

    eng.compress = slow_compress

    async def drive():
        async with Gateway(eng, queue_depth=4) as gw:
            with pytest.raises(DeadlineExceeded):
                await gw.compress(data, deadline=0.05)
            # lane is still held by the abandoned thread...
            assert eng.inflight_lanes == 4
            for _ in range(100):             # ...until it returns
                if eng.inflight_lanes == 0:
                    break
                await asyncio.sleep(0.02)
            return eng.inflight_lanes

    assert asyncio.run(drive()) == 0


@pytest.mark.slow
def test_goodput_within_10pct_and_p99_bounded():
    """Acceptance (c): concurrent goodput >= 90% of the single-client
    streaming baseline, p99 latency bounded, queue bounded, no lane
    leak. In-process timing is noisy, so the ratio bar gets 3 tries."""
    from benchmarks import loadgen

    row = None
    for attempt in range(3):
        row = loadgen.run(clients=4, lanes=2, block_symbols=8,
                          shape=(4, 4), min_blocks=2, max_blocks=3,
                          seed=attempt)[0]
        assert row["lane_leak"] == 0
        assert row["deadline_exceeded"] == 0
        # p99 bound: no single block write may take longer than coding
        # the *entire* corpus takes synchronously.
        whole_corpus_s = row["payload_mb"] / row["baseline_mb_per_s"]
        assert row["p99_ms"] / 1e3 < whole_corpus_s
        if row["goodput_ratio"] >= 0.9:
            break
    assert row["goodput_ratio"] >= 0.9, row


# ---------------------------------------------------------------------------
# recovery records + snapshot legality (supporting contracts)
# ---------------------------------------------------------------------------

def test_recovery_record_roundtrip_crc_and_corruption(tmp_path):
    rec = RecoveryRecord(session_id="r1", tenant="t", kind="encode",
                         byte_offset=64, block_index=2,
                         symbols_acked=8,
                         snapshot={"heads": [1, 2], "lanes": 2},
                         meta={"shape": [2, 3]})
    path = save_record(str(tmp_path), rec)
    back = load_record(str(tmp_path), "r1")
    assert back.byte_offset == 64 and back.block_index == 2
    assert back.snapshot["heads"] == (1, 2)     # lists -> tuples

    raw = open(path).read()
    with open(path, "w") as f:                  # flip a stored field
        f.write(raw.replace('"byte_offset": 64', '"byte_offset": 65'))
    with pytest.raises(ValueError, match="CRC mismatch"):
        load_record(str(tmp_path), "r1")

    with open(path, "w") as f:
        f.write("not json at all")
    with pytest.raises(ValueError):
        load_record(str(tmp_path), "r1")

    delete_record(str(tmp_path), "r1")
    assert load_record(str(tmp_path), "r1") is None
    assert list_sessions(str(tmp_path)) == []

    with pytest.raises(ValueError, match="session id"):
        RecoveryRecord(session_id="../evil", tenant="t", kind="encode",
                       byte_offset=0, block_index=0, symbols_acked=0)


def test_stream_encoder_snapshot_rules():
    codec = codecs.Shaped(
        codecs.Repeat(lambda d: codecs.Uniform(6), 6), (2, 3))
    enc = stream.StreamEncoder(codec, lanes=4, block_symbols=2,
                               seed=0, init_chunks=0)
    data = _data(n=3)
    enc.write(data[:2])
    assert enc.buffered_symbols == 0
    snap = enc.snapshot()                       # legal at the boundary
    assert snap.n_blocks == 1 and snap.heads is not None
    enc.write(data[2:3])
    assert enc.buffered_symbols == 1
    with pytest.raises(RuntimeError, match="mid-block"):
        enc.snapshot()
    enc.flush()
    with pytest.raises(RuntimeError, match="after flush"):
        enc.snapshot()
    # resume() refuses a codec/lane mismatch
    with pytest.raises(ValueError, match="lanes"):
        stream.StreamEncoder.resume(
            codec, dataclasses.replace(snap, lanes=2))


# ---------------------------------------------------------------------------
# SIGINT flush hook (satellite 2)
# ---------------------------------------------------------------------------

def test_sigint_handler_flushes_open_encoders_to_valid_trailer():
    from repro.launch import serve as launch_serve

    codec = codecs.Shaped(
        codecs.Repeat(lambda d: codecs.Uniform(6), 6), (2, 3))
    enc = stream.StreamEncoder(codec, lanes=4, block_symbols=2,
                               seed=None, init_chunks=0)
    data = _data(n=3)
    wire = enc.write(data)                      # 1 full block + 1 ragged
    tail_seen = []
    orig_flush = enc.flush
    enc.flush = lambda: (tail_seen.append(orig_flush())   # type: ignore
                         or tail_seen[-1])
    launch_serve._OPEN_ENCODERS["t"] = enc
    handler = launch_serve.install_sigint_flush()
    try:
        with pytest.raises(KeyboardInterrupt):
            handler()                           # simulate the signal
    finally:
        import signal as _signal
        _signal.signal(_signal.SIGINT, _signal.default_int_handler)
    assert "t" not in launch_serve._OPEN_ENCODERS
    assert launch_serve.flush_open_encoders() == {}   # idempotent
    # the handler's flush completed the wire: ragged tail + trailer
    wire += tail_seen[0]
    header, offsets, trailer = stream.format.scan(wire)
    assert trailer is not None and trailer.n_blocks == 2
    out = stream.decode_stream(codec, wire)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(data))


# ---------------------------------------------------------------------------
# admission controller unit coverage
# ---------------------------------------------------------------------------

def test_admission_controller_stats_and_quota_accounting():
    eng = CodecEngine(_family(), max_inflight_lanes=8)
    ctl = AdmissionController(eng, queue_depth=2,
                              default_quota=TenantQuota(max_lanes=4))
    a = ctl.try_acquire("t1", 4)
    assert a is not None
    assert ctl.try_acquire("t1", 1) is None     # tenant quota
    b = ctl.try_acquire("t2", 4)                # other tenant fine
    assert b is not None
    ctl.reserve_queue_slot("t1")
    ctl.reserve_queue_slot("t2")
    with pytest.raises(Backpressure, match="queue"):
        ctl.reserve_queue_slot("t3")            # global depth
    ctl.release_queue_slot("t1")
    ctl.release_queue_slot("t2")
    ctl.release("t1", a)
    ctl.release("t2", b)
    s = ctl.stats()
    assert s["rejected"] == 1 and eng.inflight_lanes == 0
