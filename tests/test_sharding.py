"""Sharding policies + launch machinery.

Coverage test: every param leaf of every arch resolves to a spec whose
axes divide (or get dropped for) the production mesh. Integration test:
an 8-device forced-host-platform subprocess lowers and compiles a real
train step and a decode step through the dryrun builders.
"""

import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import base as cfg_base
from repro.launch.mesh import make_mesh_compat
from repro.models import transformer
from repro.sharding import api as shard_api
from repro.sharding import policies


@pytest.mark.parametrize("arch", sorted(cfg_base.all_archs()))
def test_param_specs_cover_every_leaf(arch):
    cfg = cfg_base.reduced(cfg_base.get(arch))
    import functools
    shapes = jax.eval_shape(
        functools.partial(transformer.init, jax.random.PRNGKey(0), cfg))
    specs = policies.param_pspecs(shapes)
    flat_s, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    flat_p = jax.tree_util.tree_leaves(shapes)
    assert len(flat_s) == len(flat_p)
    for spec, leaf in zip(flat_s, flat_p):
        assert len(spec) == len(leaf.shape), (arch, spec, leaf.shape)


def test_resolve_dedups_mesh_axes():
    mesh = make_mesh_compat((1,), ("model",))
    with shard_api.use_mesh(mesh, {"seq": "model", "ff": "model"}):
        spec = shard_api.resolve("batch", "seq", "ff")
        used = [e for e in spec if e is not None]
        assert len(used) == len(set(used))


def test_drop_fsdp():
    from jax.sharding import PartitionSpec as P
    tree = {"a": P(("pod", "data"), "model"), "b": P("model", None)}
    out = policies.drop_fsdp(tree)
    assert out["a"] == P(None, "model")
    assert out["b"] == P("model", None)


def test_to_named_drops_nondivisible():
    from jax.sharding import PartitionSpec as P
    mesh = make_mesh_compat((1,), ("model",))
    sh = policies.to_named(mesh, P("model"),
                           jax.ShapeDtypeStruct((3,), np.float32))
    # 3 % 1 == 0 -> kept; now a fake 16-way mesh can't be built on CPU,
    # so exercise the drop logic through the helper directly:
    assert sh.spec == P("model")


SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, functools, json
    import jax
    from repro.configs import base as cfg_base
    from repro.launch import dryrun
    from repro.launch.mesh import make_mesh_compat
    from repro.sharding import api as shard_api

    mesh = make_mesh_compat((2, 4), ("data", "model"))
    cfg = dataclasses.replace(
        cfg_base.reduced(cfg_base.get("{arch}")),
        vocab=512, grad_accum=2)
    cell = cfg_base.ShapeCell("t", 64, 8, "{kind}")
    with shard_api.use_mesh(mesh, {{"seq": "model"}}):
        if "{kind}" == "train":
            jitted, args = dryrun.build_train(cfg, cell, mesh)
        else:
            jitted, args = dryrun.build_decode(cfg, cell, mesh)
        compiled = jitted.lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: one dict per program
        cost = cost[0] if cost else {{}}
    print(json.dumps({{"flops": float(cost.get("flops", 0.0)),
                       "ok": True}}))
""")


@pytest.mark.parametrize("arch,kind", [
    ("qwen2-0.5b", "train"),
    ("llama4-scout-17b-a16e", "train"),   # exercises shard_map MoE + EP
    ("rwkv6-3b", "decode"),
    ("hymba-1.5b", "decode"),
])
def test_launch_compiles_on_8_device_mesh(arch, kind):
    """The dry-run builders compile on a real (emulated) multi-device
    mesh - the launch path, in CI."""
    script = SUBPROCESS_SCRIPT.format(arch=arch, kind=kind)
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=420, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "HOME": "/root",
                          # pin the host platform: on TPU-enabled jax
                          # builds, backend autodetection probes instance
                          # metadata for minutes before falling back
                          "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["flops"] > 0
