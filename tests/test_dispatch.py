"""Backend-dispatch parity + tuning-cache behaviour (ISSUE-9).

The dispatch contract is that the backend choice is a pure performance
knob: every op produces bit-identical results under every backend that
``available_backends()`` offers, so the wire format can never depend on
which kernel happened to run. Three layers pin that down here:

  * **golden parity** - re-encoding the committed ``tests/golden/``
    fixtures under each pinned backend must reproduce the committed
    blobs hex-for-hex (the strongest end-to-end form of the claim);
  * **op-level fuzz** - seeded random workloads through the dispatched
    ops, each backend against the ``ref.py`` oracle, full stack state
    compared bit-for-bit (a fast subset of the deep sweep in
    ``tests/test_parity_fuzz.py``);
  * **tuning cache** - cold miss -> measured ``autotune_op`` ->
    persisted JSON -> warm ``lookup``/``resolve`` hit, plus the
    corrupt/stale/foreign-backend fallbacks that guarantee tuning
    state can never break coding.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ans
from repro.kernels import dispatch, tuning
from repro.kernels.ans import ops as ans_ops, ref as ans_ref
from repro.kernels.bucketize import ops as bk_ops, ref as bk_ref

BACKENDS = dispatch.available_backends()


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    """Point the tuning cache at a throwaway file for the test body."""
    path = str(tmp_path / "tuning_cache.json")
    monkeypatch.setenv("REPRO_TUNING_CACHE", path)
    tuning.refresh()
    yield path
    tuning.refresh()


# ---------------------------------------------------------------------------
# golden parity: wire bytes are backend-independent, end to end
# ---------------------------------------------------------------------------

def _committed(name: str) -> bytes:
    from tests.golden.make_golden import GOLDEN_DIR
    with open(os.path.join(GOLDEN_DIR, f"{name}.bin"), "rb") as f:
        return f.read()


@pytest.mark.parametrize("name", ["bbx1_uniform", "bbx1_vae_fixedpoint",
                                  "bbx2_stream"])
def test_golden_bytes_identical_under_every_backend(name):
    from tests.golden.make_golden import build
    encode, _decode, _data = build()[name]
    committed = _committed(name)
    for backend in BACKENDS:
        with dispatch.use_backend(backend):
            fresh = encode()
        assert fresh.hex() == committed.hex(), (
            f"{name} under backend={backend}: wire bytes diverged from "
            "the committed golden blob - the backend choice must never "
            "change the format")


def test_golden_decode_under_every_backend():
    from tests.golden.make_golden import build
    name = "bbx1_vae_fixedpoint"
    _encode, decode, data = build()[name]
    blob = _committed(name)
    for backend in BACKENDS:
        with dispatch.use_backend(backend):
            out = decode(blob)
        assert bool(jnp.array_equal(jnp.asarray(out),
                                    jnp.asarray(data))), (
            f"{name} under backend={backend}: lossy decode")


# ---------------------------------------------------------------------------
# op-level fuzz: each backend vs the oracle, bit for bit
# ---------------------------------------------------------------------------

def _assert_stacks_equal(a, b, what):
    for field in ("head", "buf", "ptr", "underflows", "overflows"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, field)), np.asarray(getattr(b, field)),
            err_msg=f"{what}: stack.{field} diverged")


@pytest.mark.parametrize("seed", [0, 1, 7])
def test_push_pop_parity_across_backends(seed):
    rng = np.random.default_rng(seed)
    steps, lanes, alphabet, precision = 6, 16, 11, 12
    probs = rng.dirichlet(np.ones(alphabet), size=lanes)
    table = ans.probs_to_starts(jnp.asarray(probs, jnp.float32),
                                precision)
    syms = jnp.asarray(rng.integers(0, alphabet, (steps, lanes)),
                       jnp.int32)
    stack = ans.make_stack(lanes, steps + 8,
                           key=jax.random.PRNGKey(seed))

    ref_full = ans_ref.push_many_table_ref(stack, table, syms, precision)
    for backend in BACKENDS:
        full = ans_ops.push_many_table(stack, table, syms, precision,
                                       backend=backend)
        _assert_stacks_equal(full, ref_full,
                             f"push_many_table[{backend}]")
        out, popped = ans_ops.pop_many(full, table, steps, precision,
                                       backend=backend)
        out_r, popped_r = ans_ref.pop_many_ref(ref_full, table, steps,
                                               precision)
        np.testing.assert_array_equal(np.asarray(popped),
                                      np.asarray(popped_r))
        _assert_stacks_equal(out, out_r, f"pop_many[{backend}]")


@pytest.mark.parametrize("seed", [3, 11])
def test_grid_pop_and_bucketize_parity_across_backends(seed):
    rng = np.random.default_rng(seed)
    lanes, steps, lat_bits, precision = 8, 5, 6, 12
    stack = ans.seed_stack(
        ans.make_stack(lanes, capacity=4 * steps,
                       key=jax.random.PRNGKey(seed)),
        jax.random.PRNGKey(seed + 1), n_chunks=2 * steps)
    mu = jnp.asarray(rng.normal(0, 1, (steps, lanes)), jnp.float32)
    sigma = jnp.asarray(rng.uniform(0.3, 1.5, (steps, lanes)),
                        jnp.float32)
    ref = ans_ref.pop_many_grid_ref(stack, "gaussian", mu, sigma, steps,
                                    lat_bits, precision)
    slot = jnp.asarray(rng.integers(0, 1 << precision, lanes),
                       jnp.uint32)
    bk_r = bk_ref.bucketize_ref(slot, mu[0], sigma[0], lat_bits,
                                precision)
    for backend in BACKENDS:
        out = ans_ops.pop_many_grid(stack, "gaussian", mu, sigma, steps,
                                    lat_bits, precision, backend=backend)
        np.testing.assert_array_equal(np.asarray(out[1]),
                                      np.asarray(ref[1]),
                                      err_msg=f"grid syms [{backend}]")
        _assert_stacks_equal(out[0], ref[0],
                             f"pop_many_grid[{backend}]")
        bk = bk_ops.bucketize(slot, mu[0], sigma[0], lat_bits,
                              precision, backend=backend)
        np.testing.assert_array_equal(np.asarray(bk), np.asarray(bk_r),
                                      err_msg=f"bucketize [{backend}]")


# ---------------------------------------------------------------------------
# tuning cache: round trip, resolve integration, corruption fallbacks
# ---------------------------------------------------------------------------

def test_tuning_cache_round_trip(tmp_cache):
    plat = dispatch.platform()
    assert tuning.lookup(plat, "push_many", lanes=8) is None   # cold
    decision = tuning.autotune_op("push_many", lanes=8, steps=4, reps=1)
    assert decision.backend in BACKENDS
    assert os.path.exists(tmp_cache)
    with open(tmp_cache) as f:
        raw = json.load(f)
    assert raw["version"] == tuning.CACHE_VERSION
    assert tuning.lookup(plat, "push_many", lanes=8) == decision  # warm
    # Bucketing: any lanes in the same power-of-two class hits too.
    assert tuning.lookup(plat, "push_many", lanes=5) == decision
    # resolve() consults the cache when nothing pins a backend.
    assert dispatch.resolve("push_many", lanes=8) == decision


def test_resolve_precedence_beats_cache(tmp_cache, monkeypatch):
    plat = dispatch.platform()
    tuning.record(plat, "push_many",
                  dispatch.Decision("interpret"), 1.0, lanes=8)
    with dispatch.use_backend("xla"):       # context over cache
        assert dispatch.resolve("push_many", lanes=8).backend == "xla"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "xla")  # env over both
    assert dispatch.resolve("push_many", lanes=8).backend == "xla"
    monkeypatch.delenv("REPRO_KERNEL_BACKEND")
    assert dispatch.resolve("push_many", lanes=8).backend == "interpret"


@pytest.mark.parametrize("content", [
    "not json at all {{{",
    json.dumps({"version": -5, "entries": {"x": {}}}),   # stale version
    json.dumps(["wrong", "shape"]),
])
def test_corrupt_or_stale_cache_reads_as_empty(tmp_cache, content):
    with open(tmp_cache, "w") as f:
        f.write(content)
    tuning.refresh()
    plat = dispatch.platform()
    assert tuning.lookup(plat, "push_many", lanes=8) is None
    # The heuristic still resolves - tuning state can't break coding.
    assert dispatch.resolve("push_many", lanes=8).backend == \
        dispatch.available_backends()[0]
    # record() over the corrupt file leaves a clean, loadable cache.
    tuning.record(plat, "push_many", dispatch.Decision("xla"), 2.5,
                  lanes=8)
    tuning.refresh()
    assert tuning.lookup(plat, "push_many", lanes=8) == \
        dispatch.Decision("xla")


def test_cache_entry_naming_unavailable_backend_is_ignored(tmp_cache):
    plat = dispatch.platform()
    tuning.record(plat, "push_many", dispatch.Decision("xla"), 1.0,
                  lanes=8)
    # Hand-edit the persisted entry to a backend this platform can't
    # run (pallas-compiled on CPU): lookup must skip it, not crash.
    with open(tmp_cache) as f:
        raw = json.load(f)
    for entry in raw["entries"].values():
        entry["backend"] = "pallas"
    with open(tmp_cache, "w") as f:
        json.dump(raw, f)
    tuning.refresh()
    if "pallas" not in dispatch.available_backends():
        assert tuning.lookup(plat, "push_many", lanes=8) is None


def test_cli_multi_lane_sweep(tmp_cache, capsys):
    rc = tuning.main(["--lanes", "4", "8", "--ops", "push_many",
                      "--steps", "2", "--reps", "1"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "lanes=4:" in out and "lanes=8:" in out
    plat = dispatch.platform()
    assert tuning.lookup(plat, "push_many", lanes=4) is not None
    assert tuning.lookup(plat, "push_many", lanes=8) is not None
