"""Per-architecture smoke tests (reduced configs) + cross-path consistency.

Every assigned arch: one forward + one train-style grad step + one decode
step on CPU, asserting shapes and finiteness. Plus the key consistency
checks: chunked-vs-sequential mixers and prefill/decode-vs-forward.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfg_base
from repro.models import transformer

ARCHS = ["whisper-small", "llama4-scout-17b-a16e", "arctic-480b",
         "stablelm-12b", "mistral-nemo-12b", "qwen2-0.5b", "smollm-360m",
         "qwen2-vl-2b", "hymba-1.5b", "rwkv6-3b"]


def _smoke_batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if cfg.enc_dec:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(0, 1, (b, s, cfg.d_model)), jnp.bfloat16)
    if cfg.frontend == "vision_stub":
        batch["embeds"] = jnp.asarray(
            rng.normal(0, 1, (b, s, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_grad(arch):
    cfg = cfg_base.reduced(cfg_base.get(arch))
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg)

    loss, metrics = transformer.loss_fn(params, cfg, batch)
    assert jnp.isfinite(loss), (arch, loss)
    assert float(metrics["bits_per_token"]) > 0

    grads, _ = jax.grad(
        lambda p: transformer.loss_fn(p, cfg, batch), has_aux=True)(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in
                jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_decode_step(arch):
    cfg = cfg_base.reduced(cfg_base.get(arch))
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    b = 2
    enc_out = None
    if cfg.enc_dec:
        enc = jnp.zeros((b, 8, cfg.d_model), jnp.bfloat16)
        enc_out = transformer.encode(params, cfg, enc)
    state = transformer.init_decode_state(cfg, b, max_len=8,
                                          enc_out=enc_out)
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, state = transformer.decode_step(params, cfg, tok, state)
    assert logits.shape == (b, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    assert int(state["cache_len"]) == 1


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "rwkv6-3b", "hymba-1.5b",
                                  "whisper-small"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the full forward logits."""
    cfg = cfg_base.reduced(cfg_base.get(arch))
    params = transformer.init(jax.random.PRNGKey(1), cfg)
    b, s = 2, 8
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)

    enc_out = None
    if cfg.enc_dec:
        enc_embeds = jnp.asarray(
            rng.normal(0, 1, (b, 8, cfg.d_model)), jnp.bfloat16)
        enc_out = transformer.encode(params, cfg, enc_embeds)
    full_logits, _ = transformer.forward(params, cfg, toks,
                                         enc_out=enc_out)

    state = transformer.init_decode_state(cfg, b, max_len=s,
                                          enc_out=enc_out)
    outs = []
    for t in range(s):
        logits_t, state = transformer.decode_step(
            params, cfg, toks[:, t:t + 1], state)
        outs.append(logits_t[:, 0])
    dec_logits = jnp.stack(outs, axis=1)

    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32), rtol=0.15, atol=0.15)


def test_moe_dense_matches_capacity_path():
    """With ample capacity the dispatch path must equal the dense oracle."""
    from repro.models import moe as moe_lib
    cfg = dataclasses.replace(
        cfg_base.reduced(cfg_base.get("llama4-scout-17b-a16e")),
        capacity_factor=8.0)
    p = moe_lib.moe_init(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(np.random.default_rng(2).normal(0, 1, (2, 16, 64)),
                    jnp.bfloat16)
    dense_out, aux_d = moe_lib.moe_apply_dense(p, x, cfg)
    disp_out, aux_c = moe_lib.moe_apply(p, x, cfg)
    np.testing.assert_allclose(np.asarray(dense_out, np.float32),
                               np.asarray(disp_out, np.float32),
                               rtol=0.05, atol=0.02)
    np.testing.assert_allclose(float(aux_d), float(aux_c), rtol=1e-5)


def test_rwkv_chunked_matches_sequential():
    """Chunked WKV6 == step-by-step recurrence."""
    from repro.models import rwkv6 as rw
    cfg = cfg_base.reduced(cfg_base.get("rwkv6-3b"))
    p = rw.rwkv_mixer_init(jax.random.PRNGKey(3), cfg)
    b, s, d = 2, rw.CHUNK * 2 + 7, cfg.d_model
    x = jnp.asarray(np.random.default_rng(3).normal(0, 1, (b, s, d)),
                    jnp.float32)
    full = rw.rwkv_mixer_apply(p, x, cfg, jnp.float32)

    h = cfg.d_model // cfg.head_dim
    state = {"S": jnp.zeros((b, h, cfg.head_dim, cfg.head_dim),
                            jnp.float32),
             "prev_x": jnp.zeros((b, 1, d), jnp.float32)}
    outs = []
    for t in range(s):
        y, state = rw.rwkv_decode_step(p, x[:, t:t + 1], cfg, state,
                                       jnp.float32)
        outs.append(y[:, 0])
    seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_ssm_chunked_matches_sequential():
    from repro.models import ssm as ssm_lib
    cfg = cfg_base.reduced(cfg_base.get("hymba-1.5b"))
    p = ssm_lib.ssm_init(jax.random.PRNGKey(4), cfg)
    b, s, d = 2, ssm_lib.CHUNK + 9, cfg.d_model
    x = jnp.asarray(np.random.default_rng(4).normal(0, 1, (b, s, d)),
                    jnp.float32)
    full = ssm_lib.ssm_apply(p, x, cfg, jnp.float32)

    hh, pp, nn = ssm_lib.ssm_head_dims(cfg)
    state = {"h": jnp.zeros((b, hh, pp, nn), jnp.float32)}
    outs = []
    for t in range(s):
        y, state = ssm_lib.ssm_decode_step(p, x[:, t:t + 1], cfg, state,
                                           jnp.float32)
        outs.append(y[:, 0])
    seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_match_assignment():
    """n_params() sanity: the headline sizes are in the right ballpark."""
    expect = {"smollm-360m": (0.3e9, 0.5e9),
              "qwen2-0.5b": (0.4e9, 0.7e9),
              "mistral-nemo-12b": (11e9, 14e9),
              "stablelm-12b": (11e9, 14e9),
              "rwkv6-3b": (2.5e9, 3.5e9),
              "hymba-1.5b": (1.2e9, 2.0e9),
              "qwen2-vl-2b": (1.5e9, 2.6e9),
              "arctic-480b": (420e9, 520e9)}
    for name, (lo, hi) in expect.items():
        n = cfg_base.get(name).n_params()
        assert lo <= n <= hi, (name, f"{n/1e9:.2f}B not in "
                               f"[{lo/1e9}, {hi/1e9}]")
