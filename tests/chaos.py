"""Deterministic fault injection for the gateway cluster.

A ``FaultSchedule`` is a seeded, reproducible plan of failures - kill
this host after block k, drop that many recovery-replica writes, delay
a write past its deadline, attempt a duplicate resume - and
``drive_stream`` executes a cluster encode stream under it. Every
schedule must end in exactly one of two outcomes (the acceptance
contract for ``repro.gateway.cluster``):

  * ``("wire", blob)`` - the finished stream, which the caller asserts
    **hex-identical** to the single-host / synchronous wire; or
  * ``("reject", exc_name, prefix)`` - a clean typed reject
    (``ResumeGap``, ``OSError``, ``Backpressure``, ``ValueError``)
    whose delivered ``prefix`` is a valid prefix of the reference
    wire. Never a silently divergent blob.

The injectors touch exactly the seams the production code exposes:
``ReplicatedRecoveryStore._save_one`` (replica write drops),
``EncodeSession`` ``_gap_hook`` (the PR-7 snapshot/commit gap),
``cluster.kill_host`` (host death), and an encoder-level write delay
(deadline expiry). Nothing here reaches into coder state - faults
change *scheduling*, the determinism contract says bytes must not.

Shared by ``tests/test_cluster.py`` and the cluster variant in
``tests/test_parity_fuzz.py``; not collected by pytest (no ``test_``
prefix).
"""

from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.gateway import Backpressure, DeadlineExceeded, HostDown, \
    ResumeGap
from repro.gateway.cluster import ClusterSession, GatewayCluster

KILL_HOST = "kill-host"
DROP_RECOVERY = "drop-recovery-write"
DELAY_WRITE = "delay-past-deadline"
DUP_RESUME = "duplicate-resume"
KINDS = (KILL_HOST, DROP_RECOVERY, DELAY_WRITE, DUP_RESUME)

#: rejects that count as *clean* (typed, prefix-preserving)
CLEAN_REJECTS = ("ResumeGap", "OSError", "Backpressure", "ValueError",
                 "DeadlineExceeded", "HostDown")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected failure: ``kind`` fires just before block
    ``at_block`` is written. ``arg`` parameterizes the kind (for
    ``DROP_RECOVERY``: how many replica writes to drop)."""

    kind: str
    at_block: int
    arg: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"chaos: unknown fault kind {self.kind!r}")
        if self.at_block < 0:
            raise ValueError("chaos: at_block must be >= 0")


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A seeded, deterministic set of faults for one stream."""

    seed: int
    faults: Tuple[Fault, ...]

    @classmethod
    def from_seed(cls, seed: int, n_blocks: int,
                  kinds: Tuple[str, ...] = KINDS) -> "FaultSchedule":
        """Derive a schedule from ``seed`` alone: same seed, same
        faults, same blocks - every chaos run is replayable."""
        rng = np.random.default_rng(seed)
        kind = kinds[int(rng.integers(len(kinds)))]
        at = int(rng.integers(1, max(2, n_blocks)))
        arg = int(rng.integers(1, 3)) if kind == DROP_RECOVERY else 0
        return cls(seed=seed, faults=(Fault(kind, at, arg),))

    def at(self, block: int) -> List[Fault]:
        return [f for f in self.faults if f.at_block == block]


# ---------------------------------------------------------------------------
# injectors - each targets one production seam
# ---------------------------------------------------------------------------

def drop_replica_writes(store, count: int) -> None:
    """Make the first ``count`` directories of ``store``'s write window
    silently drop every future record write (the lost-disk fault). The
    store's own ``min_replicas`` arithmetic decides whether saves still
    succeed (write-through survives) or raise ``OSError`` (clean
    reject)."""
    dropped = set(store.write_replicas[:count])
    orig = type(store)._save_one

    def save_one(directory, record):
        if directory in dropped:
            return False
        return orig(store, directory, record)
    store._save_one = save_one


def corrupt_replica(store, session_id: str, index: int = 0) -> None:
    """Flip bytes in one replica's record file (CRC now mismatches):
    ``load`` must skip it and read-repair from a healthy peer."""
    from repro.gateway import recovery
    path = recovery.record_path(store.replicas[index], session_id)
    with open(path, "r+b") as f:
        f.seek(0)
        f.write(b"corrupt!")


def delay_encoder_writes(sess, seconds: float) -> None:
    """Delay the underlying encoder's block commits by ``seconds``
    (inside the write transaction, *after* the commit) - paired with a
    shorter deadline this reproduces the nastiest timeout: the client's
    wait expires and discards the bytes while the worker thread still
    finishes commit + record, leaving the record *ahead* of what the
    client holds."""
    enc = sess.encoder
    orig = enc.write

    def slow(data):
        out = orig(data)
        time.sleep(seconds)
        return out
    enc.write = slow


async def quiesce(cluster: GatewayCluster, session_id: str,
                  timeout: float = 10.0) -> None:
    """Wait until no host still has ``session_id`` open (the timed-out
    worker thread has returned and its abandon ran) - only then is a
    resume's record state deterministic."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if all(session_id not in cluster.host(name).gateway.open_sessions
               for name in cluster.hosts
               if not cluster.host(name).dead):
            return
        await asyncio.sleep(0.01)
    raise TimeoutError(f"chaos: {session_id!r} never quiesced")


# ---------------------------------------------------------------------------
# the chaos driver
# ---------------------------------------------------------------------------

async def _apply(cluster: GatewayCluster, cs: ClusterSession,
                 fault: Fault, notes: List[str]) -> Optional[float]:
    """Inject ``fault`` against the stream's *current* host. Returns a
    deadline to impose on the next write (``DELAY_WRITE``), else
    ``None``."""
    host = cluster.host(cs.host)
    if fault.kind == KILL_HOST:
        await cluster.kill_host(host.name)
        notes.append(f"killed {host.name} before block {fault.at_block}")
        return None
    if fault.kind == DROP_RECOVERY:
        drop_replica_writes(host.gateway._store, fault.arg)
        notes.append(f"dropping {fault.arg} replica writes on "
                     f"{host.name}")
        return None
    if fault.kind == DELAY_WRITE:
        delay_encoder_writes(cs._sess, 0.25)
        notes.append(f"delaying block {fault.at_block} past a 50ms "
                     "deadline")
        return 0.05
    if fault.kind == DUP_RESUME:
        try:
            await cluster.resume_stream(cs.session_id)
        except ValueError:
            notes.append("duplicate resume cleanly rejected")
        else:   # pragma: no cover - would be the silent-fork bug
            raise AssertionError(
                "chaos: duplicate resume was admitted while the "
                "session is open")
        return None
    raise ValueError(fault.kind)   # pragma: no cover


async def drive_stream(cluster: GatewayCluster, data, *,
                       schedule: FaultSchedule, session_id: str,
                       block_symbols: int,
                       tenant: str = "default",
                       **open_kwargs) -> Tuple:
    """Run one cluster encode stream under ``schedule``.

    Feeds ``data`` ([n, lanes, *shape]) block by block; before block
    ``b`` every fault scheduled at ``b`` fires. Outcomes::

        ("wire", blob, notes)            # finished; assert blob == ref
        ("reject", exc_name, prefix, notes)   # clean reject; assert
                                              # ref.startswith(prefix)

    Any other exception propagates - that is a harness bug or a real
    divergence, and the test should fail loudly.
    """
    shape = tuple(int(s) for s in data.shape[2:])
    lanes = int(data.shape[1])
    n_blocks = int(data.shape[0]) // block_symbols
    notes: List[str] = []
    wire = bytearray()
    cs = await cluster.open_stream(
        shape, lanes=lanes, session_id=session_id, tenant=tenant,
        block_symbols=block_symbols, **open_kwargs)
    try:
        for b in range(n_blocks):
            deadline = None
            for fault in schedule.at(b):
                deadline = await _apply(cluster, cs, fault, notes) \
                    or deadline
            chunk = data[b * block_symbols:(b + 1) * block_symbols]
            if deadline is not None:
                # The delayed write must expire, the session quiesce,
                # and the reattach decide: resume or clean ResumeGap.
                try:
                    wire.extend(await cs.write(chunk, deadline=deadline))
                except DeadlineExceeded:
                    notes.append(f"block {b} deadline exceeded")
                    await quiesce(cluster, session_id)
                    await cs.reattach()   # ResumeGap when record ahead
                    wire.extend(await cs.write(chunk))
                else:   # pragma: no cover - delay failed to trip
                    raise AssertionError(
                        "chaos: delayed write beat its deadline")
            else:
                wire.extend(await cs.write(chunk))
        wire.extend(await cs.close())
        return ("wire", bytes(wire), notes)
    except (ResumeGap, Backpressure, OSError, ValueError,
            DeadlineExceeded, HostDown) as e:
        if not cs.closed:
            await cs.abandon()
        notes.append(f"clean reject: {type(e).__name__}: {e}")
        return ("reject", type(e).__name__, bytes(wire), notes)


def check_outcome(outcome: Tuple, reference: bytes) -> None:
    """The acceptance assertion: a finished wire is hex-identical to
    ``reference``; a reject is typed-clean and its delivered prefix is
    a prefix of ``reference``. Anything else fails."""
    kind = outcome[0]
    if kind == "wire":
        _, wire, notes = outcome
        assert wire == reference, (
            f"chaos: wire diverged under faults ({notes}): "
            f"{wire[:32].hex()} != {reference[:32].hex()}")
    elif kind == "reject":
        _, name, prefix, notes = outcome
        assert name in CLEAN_REJECTS, f"chaos: untyped reject {name}"
        assert reference.startswith(prefix), (
            f"chaos: rejected stream delivered a diverging prefix "
            f"({notes})")
    else:   # pragma: no cover
        raise AssertionError(f"chaos: unknown outcome {kind!r}")
