"""Test bootstrap: make the suite runnable from a clean checkout.

* Ensures ``src/`` is importable even when pytest is invoked without
  PYTHONPATH (pyproject's ``pythonpath`` handles pytest>=7; this covers
  direct ``python tests/...`` runs too).
* Gates the ``hypothesis`` dependency: if the real package is missing
  (it is an optional dev extra and may not be baked into minimal
  images), installs a tiny deterministic fallback into ``sys.modules``
  that supports the subset used here (``given``/``settings`` +
  ``strategies.integers``) by enumerating a fixed number of seeded
  pseudo-random examples. Property coverage is strictly better with the
  real hypothesis (``pip install hypothesis``); the fallback keeps the
  tier-1 suite green without it.
"""

import os
import random
import sys

import pytest

_SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


@pytest.fixture(autouse=True, scope="module")
def _release_xla_state_between_modules():
    # The suite compiles thousands of distinct XLA programs; on jaxlib
    # 0.4.x CPU the accumulated backend state eventually segfaults
    # inside backend_compile (deterministically, ~180 tests into a full
    # run). Modules share almost no compiled programs, so dropping the
    # caches at module boundaries keeps the run alive at negligible
    # recompile cost.
    yield
    try:
        import jax

        jax.clear_caches()
    except Exception:
        pass

try:
    import hypothesis  # noqa: F401  (real package wins when available)
except ImportError:
    import functools
    import types

    class _Integers:
        def __init__(self, min_value, max_value):
            self.min_value = min_value
            self.max_value = max_value

        def example(self, rng):
            return rng.randint(self.min_value, self.max_value)

    def _integers(min_value, max_value):
        return _Integers(min_value, max_value)

    def _settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn

        return deco

    def _given(**strategies_kw):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # Read at call time: @settings is usually applied *above*
                # @given, so the attribute lands on this wrapper.
                max_examples = getattr(wrapper, "_fallback_max_examples",
                                       20)
                rng = random.Random(0xB1757)
                for i in range(max_examples):
                    drawn = {k: s.example(rng)
                             for k, s in strategies_kw.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception as e:
                        raise AssertionError(
                            f"fallback-hypothesis example {i} failed "
                            f"with {drawn!r}") from e

            # Drop the strategy params from the signature pytest sees
            # (functools.wraps points __wrapped__ at fn, whose params
            # would otherwise look like missing fixtures).
            del wrapper.__wrapped__
            return wrapper

        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.__version__ = "0.0-fallback"
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
