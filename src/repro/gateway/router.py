"""Deterministic placement + health tracking for the gateway cluster.

Two routing questions, both answered by pure functions so nothing
about placement ever needs serializing into the wire:

  * **Corpus shards** - ``stream.format.shard_host``: shard ``s`` of an
    ``n_shards`` BBX3 corpus belongs to host ``s % n_hosts`` in the
    cluster's configured host order. Shard *bytes* never depend on the
    assignment (each shard's segment is a function of (codec, data,
    seed + s) only - ``repro.shard_codec``), so a down host's shards
    reroute to any healthy peer with zero wire change.
  * **Tenant streams** - rendezvous (highest-random-weight) hashing of
    the session id over the *healthy* host set: stable placement while
    the cluster is calm, deterministic failover order when a host goes
    down, and no reshuffling of unrelated sessions either way.

Health is tracked as a simple up/down flag per host, flipped by
``mark_down``/``mark_up`` (the cluster flips it on kill, on a failed
call, or from its health-check probe). Routing never returns a down
host; when every host is down the router raises ``HostDown`` rather
than inventing a placement.
"""

from __future__ import annotations

import zlib
from typing import Dict, List, Sequence

from repro.stream import format as fmt


class HostDown(RuntimeError):
    """The targeted gateway host is marked down (killed, failed a
    health probe, or stopped answering). In-flight streams fail over to
    a peer via their replicated recovery records - committed blocks are
    never re-coded (``GatewayCluster``, docs/SERVING.md)."""

    def __init__(self, host: str, reason: str = "marked down"):
        super().__init__(f"gateway: host {host!r} {reason}")
        self.host = host


class ShardRouter:
    """Derived shard->host and session->host placement over a fixed,
    ordered host list.

    Example::

        router = ShardRouter(["h0", "h1"])
        assert router.shard_owner(3, n_shards=4) == "h1"
        first = router.session_host("cam-1")
        router.mark_down(first)
        assert router.session_host("cam-1") != first   # failover peer
    """

    def __init__(self, hosts: Sequence[str]):
        names = list(hosts)
        if not names:
            raise ValueError("gateway: ShardRouter needs >= 1 host")
        if len(set(names)) != len(names):
            raise ValueError("gateway: duplicate host names")
        self.hosts = names
        self._healthy: Dict[str, bool] = {h: True for h in names}

    # -- health --------------------------------------------------------------

    def mark_down(self, host: str) -> None:
        self._check_known(host)
        self._healthy[host] = False

    def mark_up(self, host: str) -> None:
        self._check_known(host)
        self._healthy[host] = True

    def is_healthy(self, host: str) -> bool:
        self._check_known(host)
        return self._healthy[host]

    def healthy_hosts(self) -> List[str]:
        return [h for h in self.hosts if self._healthy[h]]

    def _check_known(self, host: str) -> None:
        if host not in self._healthy:
            raise KeyError(f"gateway: unknown host {host!r}")

    # -- corpus shards -------------------------------------------------------

    def shard_owner(self, shard: int, n_shards: int) -> str:
        """The host shard ``shard`` is *assigned* to (health-blind -
        the derived placement; bytes never depend on it)."""
        return self.hosts[fmt.shard_host(shard, n_shards, len(self.hosts))]

    def shard_route(self, shard: int, n_shards: int) -> str:
        """The host shard ``shard`` is *served* by right now: its owner
        when healthy, else the next healthy host in cluster order."""
        owner = self.shard_owner(shard, n_shards)
        if self._healthy[owner]:
            return owner
        up = self.healthy_hosts()
        if not up:
            raise HostDown(owner, "down with no healthy peer")
        return up[shard % len(up)]

    # -- tenant streams ------------------------------------------------------

    @staticmethod
    def _weight(session_id: str, host: str) -> int:
        return zlib.crc32(f"{session_id}@{host}".encode())

    def session_host(self, session_id: str) -> str:
        """Rendezvous-hash placement of a stream over the healthy host
        set; deterministic, and stable under unrelated host changes."""
        up = self.healthy_hosts()
        if not up:
            raise HostDown(self.hosts[0], "no healthy host in cluster")
        return max(up, key=lambda h: self._weight(session_id, h))

    def failover_host(self, session_id: str, exclude: str) -> str:
        """Where ``session_id`` resumes after ``exclude`` died: the
        rendezvous winner among the remaining healthy hosts."""
        up = [h for h in self.healthy_hosts() if h != exclude]
        if not up:
            raise HostDown(exclude, "down with no healthy peer")
        return max(up, key=lambda h: self._weight(session_id, h))
