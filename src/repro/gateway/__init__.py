"""repro.gateway: the async serving tier over the compression engines.

Admission control with per-tenant lane quotas, bounded-queue
backpressure (reject with ``retry_after``, never unbounded buffering),
deadline enforcement that retires lanes cleanly, and mid-stream
checkpoint/resume via durable recovery records. The gateway schedules;
it never recodes - wire bytes are byte-identical to the synchronous
engine paths. See docs/SERVING.md.
"""

from repro.gateway.frontend import DeadlineExceeded, Gateway
from repro.gateway.quota import AdmissionController, Backpressure, \
    TenantQuota
from repro.gateway.recovery import RecoveryRecord, delete_record, \
    list_sessions, load_record, save_record
from repro.gateway.session import DecodeSession, EncodeSession

__all__ = [
    "Gateway",
    "DeadlineExceeded",
    "Backpressure",
    "TenantQuota",
    "AdmissionController",
    "EncodeSession",
    "DecodeSession",
    "RecoveryRecord",
    "save_record",
    "load_record",
    "delete_record",
    "list_sessions",
]
