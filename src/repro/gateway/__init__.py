"""repro.gateway: the async serving tier over the compression engines.

Admission control with per-tenant lane quotas, bounded-queue
backpressure (reject with ``retry_after``, never unbounded buffering),
deadline enforcement that retires lanes cleanly, and mid-stream
checkpoint/resume via durable recovery records. The gateway schedules;
it never recodes - wire bytes are byte-identical to the synchronous
engine paths. ``repro.gateway.cluster`` spreads shards and streams
across N gateways with replicated recovery and health-checked
failover, same bytes. See docs/SERVING.md.
"""

from repro.gateway.cluster import ClusterHost, ClusterSession, \
    GatewayCluster, ResumeGap
from repro.gateway.frontend import DeadlineExceeded, Gateway
from repro.gateway.quota import AdmissionController, Backpressure, \
    ClusterAdmission, TenantQuota
from repro.gateway.recovery import RecoveryRecord, RecoveryStore, \
    ReplicatedRecoveryStore, as_store, delete_record, list_sessions, \
    load_record, save_record
from repro.gateway.router import HostDown, ShardRouter
from repro.gateway.session import DecodeSession, EncodeSession

__all__ = [
    "Gateway",
    "DeadlineExceeded",
    "Backpressure",
    "TenantQuota",
    "AdmissionController",
    "EncodeSession",
    "DecodeSession",
    "RecoveryRecord",
    "save_record",
    "load_record",
    "delete_record",
    "list_sessions",
    "RecoveryStore",
    "ReplicatedRecoveryStore",
    "as_store",
    "GatewayCluster",
    "ClusterSession",
    "ClusterHost",
    "ClusterAdmission",
    "ShardRouter",
    "HostDown",
    "ResumeGap",
]
