"""Durable recovery records for gateway stream sessions.

The same discipline as ``train/fault.py``: progress is a pure function
of a small, explicitly persisted state, so a killed client (or a killed
gateway) resumes *bitwise identically* from its last record instead of
restarting the corpus. A record is one JSON file per session id,
written atomically (temp file + ``os.replace``) and integrity-checked
with a CRC32 of the canonical payload, so a crash mid-write can never
leave a readable-but-wrong record.

What gets persisted:

  * encode sessions - the ``stream.EncoderSnapshot`` (carried clean-bit
    heads, block counter that pins the per-block seeding, grow/retry
    state) plus the wire byte offset already emitted;
  * decode sessions - the byte offset of the next undecoded block, the
    index of the last *acknowledged* block, and the symbols acked.

Records are deliberately tiny (no payload bytes): the wire itself is
the source of truth; the record only says where in it the session
stands.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import zlib
from typing import Any, Dict, List, Optional

_SESSION_ID_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]{0,120}$")
_SUFFIX = ".recovery.json"

KIND_ENCODE = "encode"
KIND_DECODE = "decode"


def check_session_id(session_id: str) -> str:
    """Validate a session id (it becomes a filename): alphanumeric plus
    ``. _ -``, at most 121 chars, no leading dot. Returns it."""
    if not isinstance(session_id, str) or not _SESSION_ID_RE.match(
            session_id):
        raise ValueError(
            f"gateway: bad session id {session_id!r} (need "
            "[A-Za-z0-9][A-Za-z0-9._-]*, <= 121 chars)")
    return session_id


@dataclasses.dataclass(frozen=True)
class RecoveryRecord:
    """One session's resumable progress.

    ``byte_offset`` is the wire position the session continues from:
    for encode sessions the number of bytes already emitted, for decode
    sessions the blob offset of the next block to decode.
    ``block_index`` counts blocks fully coded (encode) or acknowledged
    (decode); ``symbols_acked`` the datapoints safely on the client's
    side of the wire. ``snapshot`` holds the ``EncoderSnapshot`` fields
    for encode sessions (``None`` for decode); ``meta`` carries codec
    routing info (shape, lanes, block_symbols) the gateway needs to
    rebuild the session.
    """

    session_id: str
    tenant: str
    kind: str                        # KIND_ENCODE | KIND_DECODE
    byte_offset: int
    block_index: int
    symbols_acked: int
    snapshot: Optional[Dict[str, Any]] = None
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        check_session_id(self.session_id)
        if self.kind not in (KIND_ENCODE, KIND_DECODE):
            raise ValueError(f"gateway: bad record kind {self.kind!r}")
        if self.byte_offset < 0 or self.block_index < 0 \
                or self.symbols_acked < 0:
            raise ValueError("gateway: recovery record fields must be >= 0")


def _canonical(payload: Dict[str, Any]) -> bytes:
    return json.dumps(payload, sort_keys=True,
                      separators=(",", ":")).encode()


def record_path(directory: str, session_id: str) -> str:
    """The file a session's record lives in."""
    return os.path.join(directory, check_session_id(session_id) + _SUFFIX)


def save_record(directory: str, record: RecoveryRecord) -> str:
    """Atomically persist ``record``; returns the file path.

    Example::

        rec = RecoveryRecord("sess-1", "tenant-a", "decode",
                             byte_offset=128, block_index=2,
                             symbols_acked=16)
        path = save_record(tmpdir, rec)
        assert load_record(tmpdir, "sess-1") == rec
    """
    os.makedirs(directory, exist_ok=True)
    payload = dataclasses.asdict(record)
    body = {"record": payload, "crc32": zlib.crc32(_canonical(payload))}
    path = record_path(directory, record.session_id)
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(body, f, indent=1, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def load_record(directory: str,
                session_id: str) -> Optional[RecoveryRecord]:
    """Load a session's record; ``None`` if absent, raises on a corrupt
    (CRC-mismatched or malformed) file - a half-written record must not
    be silently treated as progress."""
    path = record_path(directory, session_id)
    if not os.path.exists(path):
        return None
    with open(path, encoding="utf-8") as f:
        try:
            body = json.load(f)
        except json.JSONDecodeError as e:
            raise ValueError(
                f"gateway: corrupt recovery record {path} "
                f"(bad JSON: {e})") from e
    payload = body.get("record")
    if not isinstance(payload, dict) or "crc32" not in body:
        raise ValueError(
            f"gateway: corrupt recovery record {path} (missing fields)")
    crc = zlib.crc32(_canonical(payload))
    if crc != body["crc32"]:
        raise ValueError(
            f"gateway: corrupt recovery record {path} (CRC mismatch: "
            f"{crc} != {body['crc32']})")
    # Snapshot heads serialize as a JSON list; the dataclass keeps them
    # as a tuple so records round-trip value-equal.
    snap = payload.get("snapshot")
    if isinstance(snap, dict) and isinstance(snap.get("heads"), list):
        snap = dict(snap, heads=tuple(snap["heads"]))
        payload = dict(payload, snapshot=snap)
    return RecoveryRecord(**payload)


def delete_record(directory: str, session_id: str) -> bool:
    """Remove a session's record (e.g. after a clean close); returns
    whether one existed."""
    path = record_path(directory, session_id)
    if os.path.exists(path):
        os.remove(path)
        return True
    return False


def list_sessions(directory: str) -> List[str]:
    """Session ids with a record in ``directory`` (sorted)."""
    if not os.path.isdir(directory):
        return []
    return sorted(name[:-len(_SUFFIX)] for name in os.listdir(directory)
                  if name.endswith(_SUFFIX))


# ---------------------------------------------------------------------------
# record stores - the pluggable persistence surface sessions write to
# ---------------------------------------------------------------------------

class RecoveryStore:
    """One recovery-record directory behind the store interface the
    sessions call (``save``/``load``/``delete``/``sessions``).

    The plain single-host store: each method is the matching module
    function over one directory. ``ReplicatedRecoveryStore`` is the
    multi-replica drop-in; ``as_store`` normalizes either (or a bare
    path) for the gateway.
    """

    def __init__(self, directory: str):
        self.directory = directory

    def save(self, record: RecoveryRecord) -> None:
        save_record(self.directory, record)

    def load(self, session_id: str) -> Optional[RecoveryRecord]:
        return load_record(self.directory, session_id)

    def delete(self, session_id: str) -> bool:
        return delete_record(self.directory, session_id)

    def sessions(self) -> List[str]:
        return list_sessions(self.directory)


class ReplicatedRecoveryStore:
    """Write-through record replication across >= 2 directories with
    CRC-checked read-repair - the cluster's durability layer.

    ``save`` writes the record to every replica (each write is itself
    atomic + CRC-stamped); it fails unless at least ``min_replicas``
    replicas accepted the record, so a committed block is never
    considered durable on a single disk. ``load`` reads *all* replicas,
    discards corrupt ones (CRC mismatch / bad JSON), picks the furthest
    record by ``(block_index, byte_offset)``, and **repairs** every
    stale, corrupt, or missing replica by rewriting the winner - so a
    killed host's peer always resumes from the newest surviving record
    (``GatewayCluster`` failover, docs/SERVING.md).

    Example::

        store = ReplicatedRecoveryStore([dir_a, dir_b])
        store.save(rec)
        assert store.load(rec.session_id) == rec   # from either replica
    """

    def __init__(self, replicas: List[str], *, min_replicas: int = 2,
                 write_replicas: Optional[List[str]] = None):
        dirs = [str(d) for d in replicas]
        if len(set(dirs)) != len(dirs):
            raise ValueError("gateway: replica directories must be distinct")
        if not 1 <= min_replicas <= len(dirs):
            raise ValueError(
                f"gateway: min_replicas {min_replicas} out of range "
                f"[1, {len(dirs)}] for {len(dirs)} replicas")
        if len(dirs) < 2:
            raise ValueError(
                "gateway: replication needs >= 2 replica directories "
                "(use RecoveryStore for a single-host setup)")
        self.replicas = dirs
        # Writes go through this window (a host's own dir + the next
        # replication-1 peers in the cluster case); reads always scan
        # the full replica set, so any peer can resume any session.
        self.write_replicas = dirs if write_replicas is None \
            else [str(d) for d in write_replicas]
        if not set(self.write_replicas) <= set(dirs):
            raise ValueError(
                "gateway: write_replicas must be a subset of replicas")
        if min_replicas > len(self.write_replicas):
            raise ValueError(
                f"gateway: min_replicas {min_replicas} exceeds the "
                f"{len(self.write_replicas)} write replicas")
        self.min_replicas = min_replicas
        #: replica writes dropped by fault injection / IO errors (tests).
        self.dropped_writes = 0

    # The one seam fault-injection hooks (tests/chaos.py): a drop-one-
    # replica fault overrides this method, nothing else.
    def _save_one(self, directory: str, record: RecoveryRecord) -> bool:
        save_record(directory, record)
        return True

    def save(self, record: RecoveryRecord) -> None:
        ok = 0
        errors: List[str] = []
        for directory in self.write_replicas:
            try:
                if self._save_one(directory, record):
                    ok += 1
                else:
                    self.dropped_writes += 1
            except OSError as e:
                errors.append(f"{directory}: {e}")
        if ok < self.min_replicas:
            raise OSError(
                f"gateway: record {record.session_id!r} reached only "
                f"{ok}/{self.min_replicas} required replicas "
                f"({'; '.join(errors) or 'writes dropped'})")

    @staticmethod
    def _progress(record: RecoveryRecord) -> tuple:
        return (record.block_index, record.byte_offset,
                record.symbols_acked)

    def load(self, session_id: str) -> Optional[RecoveryRecord]:
        held: List[tuple] = []      # (directory, record | None)
        for directory in self.replicas:
            try:
                held.append((directory, load_record(directory, session_id)))
            except ValueError:      # corrupt replica: a repair target
                held.append((directory, None))
        candidates = [rec for _, rec in held if rec is not None]
        if not candidates:
            return None
        best = max(candidates, key=self._progress)
        # Read-repair: divergent/corrupt/missing replicas converge on
        # the furthest CRC-valid record.
        for directory, rec in held:
            if rec != best:
                try:
                    save_record(directory, best)
                except OSError:
                    pass   # a dead replica dir must not fail the read
        return best

    def delete(self, session_id: str) -> bool:
        existed = False
        for directory in self.replicas:
            existed = delete_record(directory, session_id) or existed
        return existed

    def sessions(self) -> List[str]:
        out: set = set()
        for directory in self.replicas:
            out.update(list_sessions(directory))
        return sorted(out)


def as_store(dir_or_store):
    """Normalize the gateway's ``recovery_dir=`` argument: a path
    becomes a ``RecoveryStore``; a store (anything with ``save`` /
    ``load`` / ``delete``) passes through; ``None`` stays ``None``."""
    if dir_or_store is None:
        return None
    if isinstance(dir_or_store, (str, os.PathLike)):
        return RecoveryStore(os.fspath(dir_or_store))
    for method in ("save", "load", "delete"):
        if not callable(getattr(dir_or_store, method, None)):
            raise TypeError(
                f"gateway: recovery_dir must be a path or a record "
                f"store (got {type(dir_or_store).__name__} without "
                f"{method!r})")
    return dir_or_store
