"""Admission control: per-tenant lane quotas, a bounded submit queue,
and backpressure.

The controller is the gateway's gatekeeper for the engine's lane axis.
It is deliberately *synchronous and lock-guarded* - a small amount of
integer bookkeeping callable from the event loop and from engine
threads alike - while all waiting happens in the asyncio layer
(``frontend.Gateway``), so nothing here ever blocks.

Three limits compose:

  * the engine's global lane budget (``engine.try_admit`` /
    ``engine.retire``, the non-blocking surface grown in
    ``serve/engine.py``);
  * a per-tenant lane quota (``TenantQuota.max_lanes``) - one tenant
    cannot monopolize the lane axis;
  * bounded queueing (global ``queue_depth`` + per-tenant
    ``TenantQuota.max_queued``) - when the queue is full the submit is
    rejected **immediately** with ``Backpressure`` carrying a
    ``retry_after`` hint. The gateway never buffers unboundedly; load
    it cannot absorb is the client's signal to back off.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, Optional


class Backpressure(RuntimeError):
    """The gateway cannot take this submission *now*; retry after
    ``retry_after`` seconds. Raised instead of queueing when the
    bounded queue (global or per-tenant) is full - the
    reject-with-retry-after contract that keeps buffering bounded."""

    def __init__(self, reason: str, retry_after: float):
        super().__init__(f"{reason} (retry after {retry_after:.3f}s)")
        self.reason = reason
        self.retry_after = float(retry_after)


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Per-tenant admission limits.

    ``max_lanes``: lanes the tenant may hold concurrently across its
    in-flight requests and open sessions. ``max_queued``: submissions
    the tenant may have waiting for lanes at once; beyond it the tenant
    gets ``Backpressure`` even if the global queue has room.
    """

    max_lanes: int = 4
    max_queued: int = 8

    def __post_init__(self):
        if self.max_lanes < 1 or self.max_queued < 0:
            raise ValueError(
                "gateway: TenantQuota needs max_lanes >= 1, "
                "max_queued >= 0")


class AdmissionController:
    """Non-blocking admission over an engine's lane ledger.

    ``try_acquire`` either returns an engine ``LaneLease`` (tenant
    quota and global budget both fit) or ``None``; the caller decides
    whether to queue. Queue *slots* are themselves admission-controlled
    via ``reserve_queue_slot``/``release_queue_slot`` so the waiting
    set stays bounded.

    Example::

        eng = serve.CodecEngine(family, max_inflight_lanes=8)
        ctl = AdmissionController(eng, queue_depth=4)
        lease = ctl.try_acquire("tenant-a", lanes=2)
        if lease is not None:
            ...  # serve the request
            ctl.release("tenant-a", lease)
    """

    def __init__(self, engine: Any, *, queue_depth: int = 16,
                 default_quota: TenantQuota = TenantQuota(),
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 retry_after: Callable[[], float] = lambda: 0.05):
        if queue_depth < 0:
            raise ValueError("gateway: queue_depth must be >= 0")
        self._engine = engine
        self.queue_depth = queue_depth
        self._default_quota = default_quota
        self._quotas = dict(quotas or {})
        self._retry_after = retry_after
        self._lock = threading.Lock()
        self._tenant_lanes: Dict[str, int] = {}
        self._tenant_queued: Dict[str, int] = {}
        self._queued = 0
        self.admitted = 0
        self.rejected = 0

    def quota_for(self, tenant: str) -> TenantQuota:
        return self._quotas.get(tenant, self._default_quota)

    # -- lanes ---------------------------------------------------------------

    def try_acquire(self, tenant: str, lanes: int):
        """A lane lease for ``tenant``, or ``None`` (quota or global
        budget exhausted). Never blocks."""
        quota = self.quota_for(tenant)
        with self._lock:
            held = self._tenant_lanes.get(tenant, 0)
            if held + lanes > quota.max_lanes:
                return None
            lease = self._engine.try_admit(lanes)
            if lease is None:
                return None
            self._tenant_lanes[tenant] = held + lanes
            self.admitted += 1
            return lease

    def release(self, tenant: str, lease) -> None:
        """Retire a lease back to the engine and the tenant's quota."""
        with self._lock:
            held = self._tenant_lanes.get(tenant, 0)
            if held < lease.lanes:
                raise ValueError(
                    f"gateway: tenant {tenant!r} releasing {lease.lanes} "
                    f"lanes but holds {held}")
            self._engine.retire(lease)
            self._tenant_lanes[tenant] = held - lease.lanes

    # -- bounded queue -------------------------------------------------------

    def reserve_queue_slot(self, tenant: str) -> None:
        """Claim a waiting slot or raise ``Backpressure`` (global queue
        full, or tenant over its ``max_queued``)."""
        quota = self.quota_for(tenant)
        with self._lock:
            if self._queued >= self.queue_depth:
                self.rejected += 1
                raise Backpressure(
                    f"gateway: submit queue full ({self.queue_depth} "
                    "waiting)", self._retry_after())
            if self._tenant_queued.get(tenant, 0) >= quota.max_queued:
                self.rejected += 1
                raise Backpressure(
                    f"gateway: tenant {tenant!r} queue quota full "
                    f"({quota.max_queued} waiting)", self._retry_after())
            self._queued += 1
            self._tenant_queued[tenant] = \
                self._tenant_queued.get(tenant, 0) + 1

    def release_queue_slot(self, tenant: str) -> None:
        with self._lock:
            if self._queued < 1 or self._tenant_queued.get(tenant, 0) < 1:
                raise ValueError(
                    f"gateway: queue slot release for {tenant!r} "
                    "without a reservation")
            self._queued -= 1
            self._tenant_queued[tenant] -= 1

    # -- introspection -------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        """A snapshot of the admission state (for logs and tests)."""
        with self._lock:
            return {
                "queued": self._queued,
                "queue_depth": self.queue_depth,
                "admitted": self.admitted,
                "rejected": self.rejected,
                "tenant_lanes": {t: n for t, n in
                                 self._tenant_lanes.items() if n},
                "tenant_queued": {t: n for t, n in
                                  self._tenant_queued.items() if n},
            }


class ClusterAdmission:
    """Cluster-wide per-tenant lane accounting, composed *above* each
    host's ``AdmissionController``.

    A tenant's lanes are bounded twice: across the whole cluster by the
    quota here (``TenantQuota.max_lanes`` read as a cluster total), and
    on each host by that gateway's own controller - so one tenant can
    neither monopolize the cluster nor pile onto a single host past its
    local budget. ``acquire`` raises ``Backpressure`` immediately when
    the cluster total would be exceeded (no cluster-level queue: the
    per-host bounded queues are the only buffering tier).

    Example::

        adm = ClusterAdmission(default_quota=TenantQuota(max_lanes=8))
        adm.acquire("tenant-a", 4)      # cluster-wide hold
        ...                             # then the host gateway admits
        adm.release("tenant-a", 4)
    """

    def __init__(self, *, default_quota: TenantQuota = TenantQuota(),
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 retry_after: Callable[[], float] = lambda: 0.05):
        self._default_quota = default_quota
        self._quotas = dict(quotas or {})
        self._retry_after = retry_after
        self._lock = threading.Lock()
        self._tenant_lanes: Dict[str, int] = {}
        self.admitted = 0
        self.rejected = 0

    def quota_for(self, tenant: str) -> TenantQuota:
        return self._quotas.get(tenant, self._default_quota)

    def acquire(self, tenant: str, lanes: int) -> None:
        """Hold ``lanes`` cluster-wide for ``tenant`` or raise
        ``Backpressure`` (the cluster quota is a hard reject, not a
        queue). Never blocks."""
        if lanes < 1:
            raise ValueError("gateway: ClusterAdmission needs lanes >= 1")
        quota = self.quota_for(tenant)
        with self._lock:
            held = self._tenant_lanes.get(tenant, 0)
            if held + lanes > quota.max_lanes:
                self.rejected += 1
                raise Backpressure(
                    f"gateway: tenant {tenant!r} over cluster lane "
                    f"quota ({held}+{lanes} > {quota.max_lanes})",
                    self._retry_after())
            self._tenant_lanes[tenant] = held + lanes
            self.admitted += 1

    def release(self, tenant: str, lanes: int) -> None:
        with self._lock:
            held = self._tenant_lanes.get(tenant, 0)
            if held < lanes:
                raise ValueError(
                    f"gateway: tenant {tenant!r} releasing {lanes} "
                    f"cluster lanes but holds {held}")
            self._tenant_lanes[tenant] = held - lanes

    @property
    def held_lanes(self) -> int:
        """Total lanes held cluster-wide (0 = no leak)."""
        with self._lock:
            return sum(self._tenant_lanes.values())

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "cluster_admitted": self.admitted,
                "cluster_rejected": self.rejected,
                "cluster_tenant_lanes": {
                    t: n for t, n in self._tenant_lanes.items() if n},
            }
