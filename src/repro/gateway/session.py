"""Gateway stream sessions: resumable encode/decode over BBX2 wires.

A session is a *lane lease plus a position in a wire*. The gateway
admits it once (its lanes stay claimed until close, eviction, or
deadline), and every coding call runs through the gateway's executor so
the event loop never blocks on model math.

Recovery contract (the mid-stream resume protocol, docs/SERVING.md):

  * ``EncodeSession`` checkpoints a ``stream.EncoderSnapshot`` at every
    block boundary - carried clean-bit heads + block counter + wire
    byte offset. A process that dies mid-stream is rebuilt with
    ``StreamEncoder.resume`` and continues the **byte-identical**
    stream from its last checkpoint; bytes emitted after that
    checkpoint are re-emitted, never re-coded differently.
  * ``DecodeSession`` advances a cursor over the blob's block offsets
    and persists it on ``ack()`` - the client's statement that it has
    safely consumed everything up to a block. Reconnecting resumes at
    the first unacknowledged block (``stream.decode_from_offset``
    semantics), so a kill between ack and the next block never loses
    or duplicates data.

Sessions never recode: encode wire bytes equal the synchronous
``CodecEngine.compress_stream`` path, decode consumes the same framing
``StreamDecoder`` does.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Awaitable, Callable, Dict, List, Optional

import jax

from repro import stream
from repro.gateway import recovery
from repro.stream import format as fmt

# async executor hook supplied by the gateway: (fn, deadline) -> result
ExecuteFn = Callable[..., Awaitable[Any]]


class EncodeSession:
    """A resumable streaming-compression session.

    Built by ``Gateway.open_stream`` (fresh) or ``Gateway.resume_stream``
    (from a recovery record). ``write`` returns the wire bytes that
    became final; the caller owns accumulating them (on resume, bytes
    before ``resumed_at`` offset were already delivered).

    Example (through the gateway)::

        sess = await gw.open_stream(shape=(8, 8), lanes=4,
                                    session_id="cam-1")
        wire = await sess.write(xs)       # [n, 4, 8, 8]
        wire += await sess.close()        # ragged tail + trailer
    """

    kind = recovery.KIND_ENCODE

    def __init__(self, session_id: str, tenant: str,
                 encoder: stream.StreamEncoder, *, execute: ExecuteFn,
                 on_close: Callable[["EncodeSession"], None],
                 recovery_dir: Optional[str] = None,
                 meta: Optional[Dict[str, Any]] = None):
        self.session_id = recovery.check_session_id(session_id)
        self.tenant = tenant
        self.encoder = encoder
        self.meta = dict(meta or {})
        self._execute = execute
        self._on_close = on_close
        self._store = recovery.as_store(recovery_dir)
        self.closed = False
        # Block commit and record write are ONE transaction under this
        # lock: a concurrent checkpoint/abandon (deadline reaper,
        # cluster failover) can never observe a committed block whose
        # record is still the previous boundary - the one-block-stale
        # resume race. Fault injection pauses inside the gap via
        # ``_gap_hook`` (tests only).
        self._txn_lock = threading.Lock()
        self._gap_hook: Optional[Callable[[], None]] = None
        #: wire offset this session started at (0 for a fresh session;
        #: the checkpointed byte offset for a resumed one).
        self.resumed_at = encoder.wire_bytes

    @property
    def wire_offset(self) -> int:
        """Bytes of wire emitted across the session's whole lifetime
        (including before a resume)."""
        return self.encoder.wire_bytes

    async def write(self, data: Any,
                    deadline: Optional[float] = None) -> bytes:
        """Feed time-major ``[n, lanes, ...]`` datapoints; returns the
        bytes that became final. Whenever the write ends on a block
        boundary (and a recovery store is set) the recovery record is
        written *in the same transaction* as the block commit, so no
        observer ever resumes one block stale."""
        if self.closed:
            raise RuntimeError("gateway: write on a closed session")

        def txn():
            with self._txn_lock:
                out = self.encoder.write(data)
                if self._store is not None \
                        and self.encoder.buffered_symbols == 0:
                    self._checkpoint_locked()
                return out
        return await self._execute(txn, deadline=deadline)

    def _checkpoint_locked(self) -> recovery.RecoveryRecord:
        snap = self.encoder.snapshot()
        record = recovery.RecoveryRecord(
            session_id=self.session_id, tenant=self.tenant,
            kind=self.kind, byte_offset=snap.wire_bytes,
            block_index=snap.n_blocks, symbols_acked=snap.n_symbols,
            snapshot=dataclasses.asdict(snap), meta=self.meta)
        if self._gap_hook is not None:   # injected pause (tests/chaos)
            self._gap_hook()
        if self._store is not None:
            self._store.save(record)
        return record

    def checkpoint(self) -> recovery.RecoveryRecord:
        """Persist (when a recovery store is configured) and return the
        session's recovery record. Legal only at a block boundary -
        see ``StreamEncoder.snapshot``. Synchronizes with any in-flight
        write transaction."""
        with self._txn_lock:
            return self._checkpoint_locked()

    async def close(self, deadline: Optional[float] = None) -> bytes:
        """Flush the ragged tail + trailer, retire the session's lanes,
        and drop its recovery record (the stream is complete)."""
        if self.closed:
            return b""

        def txn():
            with self._txn_lock:
                return self.encoder.flush()
        tail = await self._execute(txn, deadline=deadline)
        self.closed = True
        if self._store is not None:
            self._store.delete(self.session_id)
        self._on_close(self)
        return tail

    def abandon(self) -> None:
        """Release the session's lanes *without* flushing (client
        vanished, deadline expired, or the host was killed). Waits for
        any in-flight write transaction, so the surviving recovery
        record always matches the last committed block - a peer
        resuming from it continues byte-identically, never one block
        stale."""
        with self._txn_lock:
            if not self.closed:
                self.closed = True
                self._on_close(self)


class DecodeSession:
    """A resumable streaming-decompression session over one BBX2 blob.

    The cursor walks block offsets (from ``stream.format.scan``);
    ``ack()`` persists progress. On reconnect the gateway rebuilds the
    session at the first unacknowledged block.

    Example::

        sess = await gw.open_decode(blob, shape=(8, 8),
                                    session_id="reader-1")
        while (block := await sess.next_block()) is not None:
            consume(block)
            sess.ack()
    """

    kind = recovery.KIND_DECODE

    def __init__(self, session_id: str, tenant: str, blob: bytes,
                 decoder: stream.StreamDecoder, *, execute: ExecuteFn,
                 on_close: Callable[["DecodeSession"], None],
                 recovery_dir: Optional[str] = None,
                 start_block: int = 0,
                 meta: Optional[Dict[str, Any]] = None):
        self.session_id = recovery.check_session_id(session_id)
        self.tenant = tenant
        self.blob = blob
        self.meta = dict(meta or {})
        self._decoder = decoder
        self._execute = execute
        self._on_close = on_close
        self._store = recovery.as_store(recovery_dir)
        self.closed = False
        header, offsets, trailer = fmt.scan(blob)
        if trailer is None:
            raise ValueError("gateway: decode session needs a complete "
                             "stream (no trailer found)")
        self.header = header
        self.trailer = trailer
        self._offsets: List[int] = offsets
        if not 0 <= start_block <= len(offsets):
            raise ValueError(
                f"gateway: resume block {start_block} out of range "
                f"[0, {len(offsets)}]")
        #: next block index to decode / first unacknowledged block.
        self.cursor = start_block
        self.acked = start_block
        self.symbols_acked = 0
        self._pending_symbols = 0

    @property
    def n_blocks(self) -> int:
        return len(self._offsets)

    @property
    def finished(self) -> bool:
        return self.cursor >= len(self._offsets)

    def _block_bytes(self, index: int) -> bytes:
        start = self._offsets[index]
        end = (self._offsets[index + 1]
               if index + 1 < len(self._offsets) else len(self.blob))
        return self.blob[start:end]

    async def next_block(self,
                         deadline: Optional[float] = None) -> Any:
        """Decode and return the next block (time-major ``[k, lanes,
        ...]``), or ``None`` at end of stream. Does NOT advance the
        recovery record - call ``ack()`` once the block is safely
        consumed."""
        if self.closed:
            raise RuntimeError("gateway: next_block on a closed session")
        if self.finished:
            return None
        payload = self._block_bytes(self.cursor)
        blocks = await self._execute(
            lambda: self._decoder.read(payload), deadline=deadline)
        if not blocks:
            raise ValueError(
                f"gateway: block {self.cursor} did not decode "
                "(corrupt slice)")
        self.cursor += 1
        self._pending_symbols += sum(
            jax.tree_util.tree_leaves(b)[0].shape[0] for b in blocks)
        return blocks[0] if len(blocks) == 1 else blocks

    def ack(self) -> recovery.RecoveryRecord:
        """Acknowledge every block decoded so far: persists (when a
        recovery dir is configured) and returns the record pointing at
        the first *unacknowledged* block."""
        self.acked = self.cursor
        self.symbols_acked += self._pending_symbols
        self._pending_symbols = 0
        byte_offset = (self._offsets[self.acked]
                       if self.acked < len(self._offsets)
                       else len(self.blob))
        record = recovery.RecoveryRecord(
            session_id=self.session_id, tenant=self.tenant,
            kind=self.kind, byte_offset=byte_offset,
            block_index=self.acked, symbols_acked=self.symbols_acked,
            meta=self.meta)
        if self._store is not None:
            self._store.save(record)
        return record

    def close(self) -> None:
        """Retire the session's lanes; keeps the recovery record unless
        the stream was fully acknowledged."""
        if self.closed:
            return
        self.closed = True
        if self._store is not None \
                and self.acked >= len(self._offsets):
            self._store.delete(self.session_id)
        self._on_close(self)
