"""The asyncio gateway: admission-controlled serving over the engines.

``Gateway`` fronts a ``serve.CodecEngine`` (or ``ShardedCodecEngine``)
with the three behaviours a serving tier needs and the engines
deliberately do not have:

  * **admission + backpressure** - every request claims lanes through
    the ``AdmissionController``; when the lane axis is full the request
    waits in a *bounded*, strictly-FIFO queue, and when the queue is
    full the submit fails fast with ``Backpressure`` carrying a
    ``retry_after`` hint (EMA of recent service times). The gateway
    never buffers unboundedly.
  * **deadlines** - any call takes ``deadline=`` seconds; on expiry the
    caller gets ``DeadlineExceeded`` immediately, and the lane lease is
    retired the moment the abandoned compute thread returns (JAX work
    cannot be preempted mid-kernel, but the ledger is always cleaned -
    no lane leak).
  * **recovery** - stream sessions checkpoint to
    ``gateway.recovery`` records, so a killed client resumes its exact
    byte stream (``resume_stream`` / ``resume_decode``).

The gateway schedules; it never recodes. Compression runs through the
same engine methods (and the engine's own codec memo) as the
synchronous path, so blobs are **byte-identical** to
``engine.compress``/``compress_stream`` - the acceptance property
``tests/test_gateway.py`` asserts hex-for-hex.

Example::

    async def main():
        eng = serve.CodecEngine(family, max_inflight_lanes=8)
        async with gateway.Gateway(eng, queue_depth=4) as gw:
            blob = await gw.compress(batch, tenant="cam-fleet")
            sess = await gw.open_stream((8, 8), lanes=4,
                                        session_id="cam-1")
            wire = await sess.write(xs)
            wire += await sess.close()
"""

from __future__ import annotations

import asyncio
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Deque, Dict, Optional, Sequence, Tuple

import jax

from repro import codecs
from repro.gateway import recovery
from repro.gateway.quota import AdmissionController, Backpressure, \
    TenantQuota
from repro.gateway.session import DecodeSession, EncodeSession
from repro.stream import format as fmt


class DeadlineExceeded(TimeoutError):
    """The request's ``deadline=`` expired before the gateway could
    finish it. The lane lease (if one was granted) is retired cleanly
    once the abandoned compute returns; a session op that times out
    abandons its session (recovery record kept, lanes freed)."""


class Gateway:
    """Async serving front: admission, backpressure, deadlines, recovery.

    One ``Gateway`` wraps one engine. Lane capacity comes from the
    engine's ``max_inflight_lanes`` budget; per-tenant fairness from
    ``TenantQuota``; queueing is bounded by ``queue_depth`` (globally)
    and ``TenantQuota.max_queued`` (per tenant). ``recovery_dir``
    enables durable session records (otherwise sessions are resumable
    only within the process via the record objects themselves).

    Use as an async context manager (or call ``stop()`` yourself -
    it flushes open encode sessions so their wires end in a valid
    trailer).
    """

    def __init__(self, engine: Any, *, queue_depth: int = 16,
                 default_quota: TenantQuota = TenantQuota(),
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 recovery_dir: Optional[str] = None,
                 max_workers: int = 4,
                 clock: Callable[[], float] = time.monotonic):
        self.engine = engine
        # A directory path or any record store (e.g. the cluster's
        # ReplicatedRecoveryStore) - sessions and resume go through the
        # normalized store interface either way.
        self.recovery_dir = recovery_dir
        self._store = recovery.as_store(recovery_dir)
        self._clock = clock
        self._ctl = AdmissionController(
            engine, queue_depth=queue_depth,
            default_quota=default_quota, quotas=quotas,
            retry_after=self._retry_hint)
        self._executor = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="gateway")
        # Strict-FIFO admission queue: (future-for-lease, tenant, lanes).
        self._waiters: Deque[Tuple[asyncio.Future, str, int]] = deque()
        self._sessions: Dict[str, Any] = {}
        self._ema_s: Optional[float] = None   # EMA of service time
        self.completed = 0
        self.deadline_exceeded = 0
        self._stopped = False

    # -- lifecycle -----------------------------------------------------------

    async def __aenter__(self) -> "Gateway":
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    async def stop(self, flush_sessions: bool = True) -> Dict[str, bytes]:
        """Shut down: flush every open encode session (so each wire
        ends in a valid BBX2 trailer), close decode sessions, stop the
        worker pool. Returns ``{session_id: tail_bytes}`` for the
        flushed encoders - the bytes a client would have lost."""
        tails: Dict[str, bytes] = {}
        for sid, sess in list(self._sessions.items()):
            if isinstance(sess, EncodeSession):
                tails[sid] = await sess.close()
            else:
                sess.close()
        self._stopped = True
        self._executor.shutdown(wait=True)
        return tails

    def abandon_sessions(self) -> Tuple[str, ...]:
        """Abandon every open session *without* flushing - the
        host-kill path: lanes are freed, recovery records stay so a
        peer gateway resumes each stream byte-identically. Encode
        abandons synchronize with any in-flight write transaction, so
        the surviving records are never one block stale."""
        sids = tuple(sorted(self._sessions))
        for sid in list(self._sessions):
            sess = self._sessions.get(sid)
            if sess is None:
                continue
            if isinstance(sess, EncodeSession):
                sess.abandon()
            else:
                sess.close()
        return sids

    # -- admission / execution machinery -------------------------------------

    def _retry_hint(self) -> float:
        # The EMA of recent service times is the best local estimate of
        # when a lane will free up; floor it so clients never hot-spin.
        return max(0.01, self._ema_s if self._ema_s is not None else 0.05)

    def _observe(self, elapsed: float) -> None:
        self._ema_s = (elapsed if self._ema_s is None
                       else 0.8 * self._ema_s + 0.2 * elapsed)

    def _pump(self) -> None:
        """Grant freed lanes to waiters in strict FIFO order (head-of-
        line blocking is the fairness guarantee: a small request cannot
        starve a large one that arrived first)."""
        while self._waiters:
            fut, tenant, lanes = self._waiters[0]
            if fut.done():           # cancelled/timed-out waiter
                self._waiters.popleft()
                continue
            lease = self._ctl.try_acquire(tenant, lanes)
            if lease is None:
                break
            self._waiters.popleft()
            fut.set_result(lease)

    async def _admit(self, tenant: str, lanes: int,
                     deadline: Optional[float]):
        """A lane lease, waiting (bounded, FIFO) if the axis is full.

        Raises ``Backpressure`` when the queue is full and
        ``DeadlineExceeded`` when the wait outlives ``deadline``."""
        if self._stopped:
            raise RuntimeError("gateway: stopped")
        # Fast path only when nobody is already waiting (FIFO fairness).
        if not self._waiters:
            lease = self._ctl.try_acquire(tenant, lanes)
            if lease is not None:
                return lease
        self._ctl.reserve_queue_slot(tenant)   # may raise Backpressure
        loop = asyncio.get_running_loop()
        fut: asyncio.Future = loop.create_future()
        self._waiters.append((fut, tenant, lanes))
        self._pump()   # capacity may have freed since the fast path
        try:
            if deadline is None:
                return await fut
            return await asyncio.wait_for(fut, deadline)
        except asyncio.TimeoutError:
            self.deadline_exceeded += 1
            raise DeadlineExceeded(
                f"gateway: no lanes within {deadline}s "
                f"(tenant {tenant!r}, {lanes} lanes)") from None
        finally:
            # wait_for returns the lease if it was granted in the same
            # loop tick as the timeout, so a granted lease is never
            # dropped here; a cancelled waiter is skipped by _pump.
            self._ctl.release_queue_slot(tenant)
            try:
                self._waiters.remove((fut, tenant, lanes))
            except ValueError:
                pass   # already popped by _pump

    async def _execute(self, fn: Callable[[], Any], *,
                       deadline: Optional[float] = None,
                       on_timeout: Optional[Callable[[], None]] = None):
        """Run ``fn`` on the worker pool; enforce ``deadline``.

        JAX compute cannot be preempted, so on expiry the result is
        abandoned and ``on_timeout`` runs once the thread returns -
        that is where lane retirement happens, keeping the ledger
        exact."""
        loop = asyncio.get_running_loop()
        start = self._clock()
        fut = loop.run_in_executor(self._executor, fn)
        try:
            if deadline is None:
                result = await fut
            else:
                result = await asyncio.wait_for(
                    asyncio.shield(fut), deadline)
        except asyncio.TimeoutError:
            self.deadline_exceeded += 1

            def _reap(f):
                f.exception()        # retrieve, don't warn
                if on_timeout is not None:
                    on_timeout()
            fut.add_done_callback(_reap)
            raise DeadlineExceeded(
                f"gateway: compute exceeded deadline {deadline}s "
                "(lane retires when the thread returns)") from None
        self._observe(self._clock() - start)
        return result

    async def _run(self, fn: Callable[[], Any], *, tenant: str,
                   lanes: int, deadline: Optional[float]):
        """Admit, execute, retire: the one-shot request path."""
        t0 = self._clock()
        lease = await self._admit(tenant, lanes, deadline)
        remaining = None if deadline is None \
            else max(0.001, deadline - (self._clock() - t0))
        released = []

        def _release():
            if not released:
                released.append(True)
                self._ctl.release(tenant, lease)
                self._pump()
        try:
            result = await self._execute(fn, deadline=remaining,
                                         on_timeout=_release)
        except DeadlineExceeded:
            raise            # _release runs when the thread returns
        except BaseException:
            _release()
            raise
        _release()
        self.completed += 1
        return result

    # -- one-shot requests ---------------------------------------------------

    async def compress(self, data: Any, *, tenant: str = "default",
                       deadline: Optional[float] = None,
                       **kwargs) -> bytes:
        """Admission-controlled ``engine.compress`` (byte-identical
        BBX1 blob). Lanes claimed = the data's lane axis."""
        lanes = int(jax.tree_util.tree_leaves(data)[0].shape[1])
        return await self._run(
            lambda: self.engine.compress(data, **kwargs),
            tenant=tenant, lanes=lanes, deadline=deadline)

    async def decompress(self, blob: bytes, n: int,
                         shape: Sequence[int], *,
                         tenant: str = "default",
                         deadline: Optional[float] = None):
        """Admission-controlled ``engine.decompress`` (bit-exact)."""
        lanes = int(codecs.blob_info(blob)["lanes"])
        return await self._run(
            lambda: self.engine.decompress(blob, n, shape),
            tenant=tenant, lanes=lanes, deadline=deadline)

    async def compress_stream(self, data: Any, *,
                              block_symbols: int = 8,
                              tenant: str = "default",
                              deadline: Optional[float] = None,
                              **kwargs) -> bytes:
        """Admission-controlled ``engine.compress_stream`` (byte-
        identical BBX2 blob)."""
        lanes = int(jax.tree_util.tree_leaves(data)[0].shape[1])
        return await self._run(
            lambda: self.engine.compress_stream(
                data, block_symbols=block_symbols, **kwargs),
            tenant=tenant, lanes=lanes, deadline=deadline)

    async def decompress_stream(self, blob: bytes, shape: Sequence[int],
                                *, tenant: str = "default",
                                deadline: Optional[float] = None):
        """Admission-controlled ``engine.decompress_stream``."""
        parsed = fmt.decode_header(blob)
        if parsed is None:
            raise ValueError("gateway: truncated stream (no header)")
        return await self._run(
            lambda: self.engine.decompress_stream(blob, shape),
            tenant=tenant, lanes=parsed[0].lanes, deadline=deadline)

    # -- stream sessions -----------------------------------------------------

    def _register(self, sess: Any, tenant: str, lease) -> Any:
        self._sessions[sess.session_id] = sess
        orig_on_close = sess._on_close

        def on_close(s):
            self._sessions.pop(s.session_id, None)
            self._ctl.release(tenant, lease)
            self._pump()
            orig_on_close(s)
        sess._on_close = on_close
        return sess

    def _session_execute(self, session_box: list) -> Any:
        """The executor hook handed to sessions: deadline expiry
        abandons the session (lanes freed when the thread returns,
        recovery record kept)."""
        async def execute(fn, deadline=None):
            sess = session_box[0]
            return await self._execute(
                fn, deadline=deadline,
                on_timeout=lambda: sess.abandon()
                if hasattr(sess, "abandon") else sess.close())
        return execute

    async def open_stream(self, shape: Sequence[int], *, lanes: int,
                          session_id: str, tenant: str = "default",
                          block_symbols: int = 8,
                          deadline: Optional[float] = None,
                          **kwargs) -> EncodeSession:
        """Open a resumable encode session (claims ``lanes`` until
        close/abandon/timeout). The wire it produces is byte-identical
        to ``engine.compress_stream`` on the same data."""
        recovery.check_session_id(session_id)
        if session_id in self._sessions:
            raise ValueError(
                f"gateway: session id {session_id!r} already open")
        lease = await self._admit(tenant, lanes, deadline)
        try:
            enc = self.engine.stream_encoder(
                tuple(int(s) for s in shape), lanes=lanes,
                block_symbols=block_symbols, **kwargs)
        except BaseException:
            self._ctl.release(tenant, lease)
            self._pump()
            raise
        box: list = [None]
        sess = EncodeSession(
            session_id, tenant, enc,
            execute=self._session_execute(box),
            on_close=lambda s: None,
            recovery_dir=self._store,
            meta={"shape": [int(s) for s in shape], "lanes": int(lanes),
                  "block_symbols": int(block_symbols)})
        box[0] = sess
        if self._store is not None:
            # Initial block-0 record: the session is resumable on a
            # peer even if this host dies before its first commit.
            sess.checkpoint()
        return self._register(sess, tenant, lease)

    async def resume_stream(self, session_id: str, *,
                            tenant: Optional[str] = None,
                            deadline: Optional[float] = None
                            ) -> EncodeSession:
        """Rebuild a killed client's encode session from its recovery
        record; the continued wire is byte-identical to an
        uninterrupted stream. Bytes before ``sess.resumed_at`` were
        already delivered."""
        if self._store is None:
            raise RuntimeError("gateway: no recovery_dir configured")
        record = self._store.load(session_id)
        if record is None:
            raise KeyError(
                f"gateway: no recovery record for {session_id!r}")
        if record.kind != recovery.KIND_ENCODE or record.snapshot is None:
            raise ValueError(
                f"gateway: record {session_id!r} is not an encode "
                "session")
        if session_id in self._sessions:
            raise ValueError(
                f"gateway: session id {session_id!r} already open")
        tenant = tenant if tenant is not None else record.tenant
        from repro.stream import EncoderSnapshot
        snap_dict = dict(record.snapshot)
        if isinstance(snap_dict.get("heads"), list):
            snap_dict["heads"] = tuple(snap_dict["heads"])
        snap = EncoderSnapshot(**snap_dict)
        shape = tuple(record.meta["shape"])
        lease = await self._admit(tenant, snap.lanes, deadline)
        try:
            enc = self.engine.resume_encoder(shape, snap)
        except BaseException:
            self._ctl.release(tenant, lease)
            self._pump()
            raise
        box: list = [None]
        sess = EncodeSession(
            session_id, tenant, enc,
            execute=self._session_execute(box),
            on_close=lambda s: None,
            recovery_dir=self._store, meta=dict(record.meta))
        box[0] = sess
        return self._register(sess, tenant, lease)

    async def open_decode(self, blob: bytes, shape: Sequence[int], *,
                          session_id: str, tenant: str = "default",
                          start_block: int = 0,
                          deadline: Optional[float] = None
                          ) -> DecodeSession:
        """Open a resumable decode session over a complete BBX2 blob;
        ``ack()`` persists progress for ``resume_decode``."""
        recovery.check_session_id(session_id)
        if session_id in self._sessions:
            raise ValueError(
                f"gateway: session id {session_id!r} already open")
        parsed = fmt.decode_header(blob)
        if parsed is None:
            raise ValueError("gateway: truncated stream (no header)")
        header = parsed[0]
        lease = await self._admit(tenant, header.lanes, deadline)
        try:
            dec = self.engine.stream_decoder(
                tuple(int(s) for s in shape), header=header,
                verify_trailer=False)
        except BaseException:
            self._ctl.release(tenant, lease)
            self._pump()
            raise
        box: list = [None]
        sess = DecodeSession(
            session_id, tenant, blob, dec,
            execute=self._session_execute(box),
            on_close=lambda s: None,
            recovery_dir=self._store, start_block=start_block,
            meta={"shape": [int(s) for s in shape]})
        box[0] = sess
        return self._register(sess, tenant, lease)

    async def resume_decode(self, blob: bytes, session_id: str, *,
                            tenant: Optional[str] = None,
                            deadline: Optional[float] = None
                            ) -> DecodeSession:
        """Reopen a decode session at its first unacknowledged block."""
        if self._store is None:
            raise RuntimeError("gateway: no recovery_dir configured")
        record = self._store.load(session_id)
        if record is None:
            raise KeyError(
                f"gateway: no recovery record for {session_id!r}")
        if record.kind != recovery.KIND_DECODE:
            raise ValueError(
                f"gateway: record {session_id!r} is not a decode "
                "session")
        sess = await self.open_decode(
            blob, tuple(record.meta["shape"]), session_id=session_id,
            tenant=tenant if tenant is not None else record.tenant,
            start_block=record.block_index, deadline=deadline)
        sess.symbols_acked = record.symbols_acked
        return sess

    # -- introspection -------------------------------------------------------

    @property
    def open_sessions(self) -> Tuple[str, ...]:
        return tuple(sorted(self._sessions))

    def stats(self) -> Dict[str, Any]:
        """One merged snapshot: admission state + gateway counters +
        the engine's lane ledger."""
        out = self._ctl.stats()
        out.update(completed=self.completed,
                   deadline_exceeded=self.deadline_exceeded,
                   open_sessions=len(self._sessions),
                   waiting=len(self._waiters),
                   inflight_lanes=self.engine.inflight_lanes,
                   retry_after_hint=self._retry_hint())
        return out
