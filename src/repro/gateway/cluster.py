"""``repro.gateway.cluster`` - multi-host serving over N gateways.

The paper's parallelization claim, taken to its serving conclusion:
BBX3 shards and BBX2 streams are *independent* coders, so a corpus (or
a fleet of tenant streams) spreads across N ``Gateway`` instances -
each with its own engine, admission domain, and (optionally) its own
event loop - **without changing a single wire byte**. Three invariants
carry the whole design (proved by ``tests/test_cluster.py`` +
``tests/chaos.py``):

  * **Placement is derived, never serialized.** Corpus shard ``s``
    routes to host ``s % n_hosts`` (``stream.format.shard_host``);
    streams rendezvous-hash their session id (``router.ShardRouter``).
    Nothing about the assignment enters the blob, so cluster bytes are
    hex-identical to the single-host gateway - and to the synchronous
    ``shard_codec.compress_dataset`` - by construction.
  * **Recovery records are replicated, write-through.** Each host's
    gateway persists session records through a
    ``recovery.ReplicatedRecoveryStore``: every checkpoint lands on
    >= ``replication`` replica directories in the same transaction as
    the block commit, reads scan all replicas with CRC-checked
    read-repair. A killed host's streams resume **byte-identically**
    from any peer.
  * **Failover re-emits, it never re-codes.** When a host stops
    answering, an in-flight stream resumes from its replicated record
    on the rendezvous-next peer; committed blocks are never coded
    again. If the record and the client's delivered bytes disagree
    (e.g. a timed-out write whose bytes were discarded), the resume
    raises ``ResumeGap`` - a clean reject, never silent divergence.

Cluster-wide admission (``quota.ClusterAdmission``) composes above the
per-host controllers: a tenant's lanes are bounded across the cluster
*and* on each host.

Example (2 hosts, one corpus, byte-identical to single-host)::

    cluster = GatewayCluster([eng0, eng1], recovery_root=tmp)
    async with cluster:
        blob = await cluster.compress_corpus(xs, n_shards=4)
        assert blob == shard_codec.compress_dataset(codec, xs,
                                                    n_shards=4)

See docs/SERVING.md ("Cluster") for routing, replication, and failover
semantics.
"""

from __future__ import annotations

import asyncio
import os
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax

from repro.core import ans
from repro.gateway import recovery
from repro.gateway.frontend import Gateway
from repro.gateway.quota import ClusterAdmission, TenantQuota
from repro.gateway.router import HostDown, ShardRouter
from repro.stream import format as fmt

__all__ = [
    "GatewayCluster", "ClusterHost", "ClusterSession",
    "ShardRouter", "HostDown", "ResumeGap",
]


class ResumeGap(RuntimeError):
    """A failover/resume found the replicated record pointing at a wire
    offset different from what the client actually holds (e.g. a block
    committed by a timed-out write whose bytes were never delivered).
    The bytes in the gap exist nowhere the client can reach, so the
    resume is **cleanly rejected** instead of silently producing a
    divergent blob - the client keeps its valid prefix."""

    def __init__(self, session_id: str, record_offset: int,
                 delivered: int):
        super().__init__(
            f"gateway: session {session_id!r} record is at byte "
            f"{record_offset} but the client holds {delivered} - "
            "resume rejected (clean prefix kept, never silent "
            "divergence)")
        self.session_id = session_id
        self.record_offset = record_offset
        self.delivered = delivered


class _LoopThread:
    """One host's private event loop on a daemon thread ("separate
    event loops" in the issue's sense): the cluster submits coroutines
    via ``run_coroutine_threadsafe`` and awaits them from its own
    loop."""

    def __init__(self, name: str):
        self._loop = asyncio.new_event_loop()
        self._thread = threading.Thread(
            target=self._loop.run_forever, name=f"gateway-host-{name}",
            daemon=True)
        self._thread.start()

    def submit(self, coro):
        return asyncio.wrap_future(
            asyncio.run_coroutine_threadsafe(coro, self._loop))

    def stop(self) -> None:
        self._loop.call_soon_threadsafe(self._loop.stop)
        self._thread.join(timeout=5)
        if not self._loop.is_running():
            self._loop.close()


class ClusterHost:
    """One member of the cluster: a name, an engine, a ``Gateway``, and
    (in ``loop_per_host`` mode) a private event loop. ``call`` is the
    only way traffic reaches the host; a killed host raises
    ``HostDown`` from it - "stops answering", deterministically."""

    def __init__(self, name: str, engine: Any, gateway: Gateway,
                 loop: Optional[_LoopThread] = None):
        self.name = name
        self.engine = engine
        self.gateway = gateway
        self._loop = loop
        self.dead = False

    async def call(self, fn):
        """Run ``fn() -> coroutine`` on this host (its own loop when
        one exists). Raises ``HostDown`` once the host was killed."""
        if self.dead:
            raise HostDown(self.name, "killed")
        return await self._submit(fn)

    async def _submit(self, fn):
        # No liveness check: the kill/shutdown paths still need to run
        # cleanup coroutines on the host's loop.
        coro = fn()
        if self._loop is None:
            return await coro
        return await self._loop.submit(coro)

    async def ping(self) -> Dict[str, Any]:
        """Health probe: a trivial round-trip through the host's loop."""
        async def probe():
            return self.gateway.stats()
        return await self.call(lambda: probe())


class ClusterSession:
    """A cluster-routed encode stream: one underlying ``EncodeSession``
    on whichever host currently serves it, plus the failover logic.

    ``delivered`` tracks the wire bytes this client actually received;
    on failover the resumed session's ``resumed_at`` must equal it, or
    the resume is rejected with ``ResumeGap`` (committed blocks are
    re-emitted from the record only when the client is missing them -
    never re-coded, never silently duplicated)."""

    def __init__(self, cluster: "GatewayCluster", sess: Any,
                 host: ClusterHost, tenant: str, lanes: int):
        self._cluster = cluster
        self._sess = sess
        self._host = host
        self.session_id = sess.session_id
        self.tenant = tenant
        self.lanes = lanes
        self.delivered = int(sess.resumed_at)
        self.failovers = 0
        self._released = False

    @property
    def host(self) -> str:
        """The host currently serving this stream."""
        return self._host.name

    @property
    def closed(self) -> bool:
        return self._released

    async def write(self, data: Any,
                    deadline: Optional[float] = None) -> bytes:
        """Feed datapoints; returns the bytes that became final. A dead
        host triggers one transparent failover (resume on the
        rendezvous-next peer from the replicated record), after which
        the write is re-issued - the data's blocks were never committed
        on the dead host past the record."""
        if self._released:
            raise RuntimeError("gateway: write on a closed cluster "
                               "session")
        sess = self._sess
        try:
            out = await self._host.call(
                lambda: sess.write(data, deadline=deadline))
        except HostDown:
            await self._failover()
            sess = self._sess
            out = await self._host.call(
                lambda: sess.write(data, deadline=deadline))
        self.delivered += len(out)
        return out

    async def close(self, deadline: Optional[float] = None) -> bytes:
        """Flush tail + trailer (failing over first if the host died),
        release the cluster-wide lane hold, drop the records."""
        if self._released:
            return b""
        sess = self._sess
        try:
            tail = await self._host.call(
                lambda: sess.close(deadline=deadline))
        except HostDown:
            await self._failover()
            sess = self._sess
            tail = await self._host.call(
                lambda: sess.close(deadline=deadline))
        self.delivered += len(tail)
        self._release()
        return tail

    async def reattach(self) -> None:
        """Re-open the underlying session from its recovery record on a
        healthy host - the client's path back after a deadline abandon
        or a host kill. Raises ``ResumeGap`` when the record does not
        match the delivered bytes (clean reject)."""
        if self._released:
            raise RuntimeError("gateway: reattach on a closed cluster "
                               "session")
        await self._failover(require_dead=False)

    async def abandon(self) -> None:
        """Drop the stream without flushing: underlying session
        abandoned (when its host still answers), records kept,
        cluster-wide lanes released."""
        if self._released:
            return
        sess = self._sess
        if not sess.closed and not self._host.dead:
            async def drop():
                sess.abandon()
            await self._host.call(lambda: drop())
        self._release()

    # -- internals -----------------------------------------------------------

    def _release(self) -> None:
        if not self._released:
            self._released = True
            self._cluster._release_session(self)

    async def _failover(self, require_dead: bool = True) -> None:
        old = self._host
        if old.dead or not self._cluster.router.is_healthy(old.name):
            self._cluster.router.mark_down(old.name)
            try:
                peer = self._cluster.router.failover_host(
                    self.session_id, exclude=old.name)
            except HostDown:
                self._release()   # no healthy peer: lanes must not leak
                raise
        elif require_dead:
            raise HostDown(old.name, "failover without a dead host")
        else:
            peer = old.name          # reattach on the same, live host
        host = self._cluster.host(peer)
        sess = await host.call(
            lambda: host.gateway.resume_stream(self.session_id,
                                               tenant=self.tenant))
        if int(sess.resumed_at) != self.delivered:
            gap = ResumeGap(self.session_id, int(sess.resumed_at),
                            self.delivered)

            async def drop():
                sess.abandon()
            await host.call(lambda: drop())
            self._release()
            raise gap
        self._host, self._sess = host, sess
        self.failovers += 1
        self._cluster.failovers += 1


class GatewayCluster:
    """N ``Gateway`` instances behind one deterministic router.

    ``engines`` is one engine - or one ``serve.EngineHandle`` - per
    host; handles are resolved *on the host* (its own event loop in
    ``loop_per_host`` mode), the remote-attach story. ``recovery_root``
    enables the replicated record store: host ``i`` writes through to
    ``replication`` replica directories starting at its own
    (``recovery_root/<host>``), and every host reads (and read-repairs)
    all of them, so any peer resumes any session.

    Admission composes: ``cluster_default_quota``/``cluster_quotas``
    bound each tenant's lanes across the whole cluster (reject with
    ``Backpressure``, no extra queue) *before* the routed host's own
    ``AdmissionController`` applies its per-host quota + bounded queue.

    Use as an async context manager; ``kill_host`` + ``check_health``
    are the failure-injection/monitoring surface.
    """

    def __init__(self, engines: Sequence[Any], *,
                 host_names: Optional[Sequence[str]] = None,
                 queue_depth: int = 16,
                 default_quota: TenantQuota = TenantQuota(),
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 cluster_default_quota: TenantQuota = TenantQuota(
                     max_lanes=1024, max_queued=0),
                 cluster_quotas: Optional[Dict[str, TenantQuota]] = None,
                 recovery_root: Optional[str] = None,
                 replication: int = 2,
                 loop_per_host: bool = False,
                 max_workers: int = 4):
        engines = list(engines)
        if not engines:
            raise ValueError("gateway: cluster needs >= 1 engine")
        names = ([str(n) for n in host_names] if host_names is not None
                 else [f"host{i}" for i in range(len(engines))])
        if len(names) != len(engines):
            raise ValueError(
                f"gateway: {len(names)} host names for "
                f"{len(engines)} engines")
        self.router = ShardRouter(names)
        self._engines = engines
        self._queue_depth = queue_depth
        self._default_quota = default_quota
        self._quotas = quotas
        self._max_workers = max_workers
        self._recovery_root = recovery_root
        self._replication = replication
        self._loop_per_host = loop_per_host
        self.admission = ClusterAdmission(
            default_quota=cluster_default_quota, quotas=cluster_quotas)
        self._hosts: Dict[str, ClusterHost] = {}
        self._open: Dict[str, ClusterSession] = {}
        self.failovers = 0
        self._started = False
        self._stopped = False

    # -- lifecycle -----------------------------------------------------------

    def _host_store(self, index: int):
        """Host ``index``'s record store: its own dir first, then the
        next ``replication - 1`` peers (write window); reads scan every
        host's dir."""
        if self._recovery_root is None:
            return None
        dirs = [os.path.join(self._recovery_root, name)
                for name in self.router.hosts]
        if len(dirs) == 1:
            return recovery.RecoveryStore(dirs[0])
        repl = min(self._replication, len(dirs))
        window = [dirs[(index + k) % len(dirs)] for k in range(repl)]
        return recovery.ReplicatedRecoveryStore(
            dirs, min_replicas=repl, write_replicas=window)

    async def start(self) -> "GatewayCluster":
        """Attach every host: resolve engine handles (on the host's own
        loop when ``loop_per_host``), build its gateway + replicated
        store."""
        if self._started:
            return self
        from repro import serve
        for i, name in enumerate(self.router.hosts):
            loop = _LoopThread(name) if self._loop_per_host else None
            spec = self._engines[i]

            async def attach(spec=spec):
                return (serve.engine_from_handle(spec)
                        if isinstance(spec, serve.EngineHandle) else spec)
            engine = (await loop.submit(attach())
                      if loop is not None else await attach())
            gw = Gateway(engine, queue_depth=self._queue_depth,
                         default_quota=self._default_quota,
                         quotas=self._quotas,
                         recovery_dir=self._host_store(i),
                         max_workers=self._max_workers)
            self._hosts[name] = ClusterHost(name, engine, gw, loop)
        self._started = True
        return self

    async def __aenter__(self) -> "GatewayCluster":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    async def stop(self) -> None:
        """Flush + stop every live host's gateway, stop the loops."""
        if self._stopped or not self._started:
            self._stopped = True
            return
        for host in self._hosts.values():
            if not host.dead:
                await host._submit(host.gateway.stop)
            if host._loop is not None:
                host._loop.stop()
        self._stopped = True

    # -- topology ------------------------------------------------------------

    @property
    def hosts(self) -> Tuple[str, ...]:
        return tuple(self.router.hosts)

    def host(self, name: str) -> ClusterHost:
        if name not in self._hosts:
            raise KeyError(f"gateway: unknown host {name!r}")
        return self._hosts[name]

    async def kill_host(self, name: str) -> Tuple[str, ...]:
        """Kill a host: mark it down, abandon its open sessions (their
        replicated records survive, current to the last committed
        block), and make every future ``call`` raise ``HostDown``.
        Returns the abandoned session ids - each resumes on a peer."""
        host = self.host(name)
        self.router.mark_down(name)
        if host.dead:
            return ()
        host.dead = True

        async def drop():
            return host.gateway.abandon_sessions()
        return await host._submit(lambda: drop())

    async def check_health(self, timeout: float = 1.0) -> Dict[str, bool]:
        """Probe every host (``timeout`` seconds each); a host that
        raises or stops answering is marked down so the router stops
        placing traffic on it. Returns ``{host: healthy}``."""
        out: Dict[str, bool] = {}
        for name, host in self._hosts.items():
            try:
                await asyncio.wait_for(host.ping(), timeout)
            except (HostDown, asyncio.TimeoutError, RuntimeError):
                self.router.mark_down(name)
                out[name] = False
            else:
                self.router.mark_up(name)
                out[name] = True
        return out

    # -- tenant streams ------------------------------------------------------

    async def open_stream(self, shape: Sequence[int], *, lanes: int,
                          session_id: str, tenant: str = "default",
                          block_symbols: int = 8,
                          deadline: Optional[float] = None,
                          **kwargs) -> ClusterSession:
        """Open a stream on its rendezvous host. Cluster-wide admission
        first (``Backpressure`` on the tenant's cluster quota), then
        the host's own admission - the composed limit."""
        host_name = self.router.session_host(session_id)
        return await self._open_on(
            host_name, shape, lanes=lanes, session_id=session_id,
            tenant=tenant, block_symbols=block_symbols,
            deadline=deadline, **kwargs)

    async def _open_on(self, host_name: str, shape: Sequence[int], *,
                       lanes: int, session_id: str, tenant: str,
                       block_symbols: int,
                       deadline: Optional[float] = None,
                       **kwargs) -> ClusterSession:
        if session_id in self._open:
            raise ValueError(
                f"gateway: session id {session_id!r} already open in "
                "the cluster")
        self.admission.acquire(tenant, lanes)
        host = self.host(host_name)
        try:
            sess = await host.call(
                lambda: host.gateway.open_stream(
                    tuple(int(s) for s in shape), lanes=lanes,
                    session_id=session_id, tenant=tenant,
                    block_symbols=block_symbols, deadline=deadline,
                    **kwargs))
        except BaseException:
            self.admission.release(tenant, lanes)
            raise
        cs = ClusterSession(self, sess, host, tenant, lanes)
        self._open[session_id] = cs
        return cs

    async def resume_stream(self, session_id: str, *,
                            tenant: Optional[str] = None
                            ) -> ClusterSession:
        """Resume a stream (after a kill or abandon) from its
        replicated record, on a healthy host. A session still open in
        the cluster rejects the duplicate resume with ``ValueError`` -
        two writers on one stream would fork the wire."""
        if session_id in self._open:
            raise ValueError(
                f"gateway: session id {session_id!r} already open in "
                "the cluster (duplicate resume rejected)")
        host_name = self.router.session_host(session_id)
        host = self.host(host_name)
        sess = await host.call(
            lambda: host.gateway.resume_stream(session_id,
                                               tenant=tenant))
        lanes = int(sess.encoder.lanes)
        try:
            self.admission.acquire(sess.tenant, lanes)
        except BaseException:
            async def drop():
                sess.abandon()
            await host.call(lambda: drop())
            raise
        cs = ClusterSession(self, sess, host, sess.tenant, lanes)
        self._open[session_id] = cs
        return cs

    def _release_session(self, cs: ClusterSession) -> None:
        self._open.pop(cs.session_id, None)
        self.admission.release(cs.tenant, cs.lanes)

    # -- corpora (BBX3 across hosts; bytes == single-host) -------------------

    async def compress_corpus(self, data: Any, *, n_shards: int,
                              block_symbols: int = 8,
                              seed: Optional[int] = 0,
                              init_chunks: int = 32,
                              precision: int = ans.DEFAULT_PRECISION,
                              tenant: str = "default",
                              tag: str = "corpus",
                              **encoder_kwargs) -> bytes:
        """Compress ``[n, lanes, ...]`` data (or an iterable of chunks)
        to one BBX3 corpus, shards spread across hosts by the derived
        assignment. Shard ``s`` streams through a gateway session on
        host ``shard_host(s)`` with seed ``seed + s`` - exactly the
        ``shard_codec.compress_dataset`` recipe - so the blob is
        **hex-identical** to the single-host (and the synchronous)
        path, even when a host dies mid-corpus and its shards fail
        over."""
        from repro import shard_codec
        first, chunks = shard_codec.peek_chunks(data)
        leaf = jax.tree_util.tree_leaves(first)[0]
        lanes = int(leaf.shape[1])
        if n_shards < 1 or lanes % n_shards:
            raise ValueError(
                f"gateway: {lanes} lanes do not divide into "
                f"{n_shards} equal shards")
        shape = tuple(int(s) for s in leaf.shape[2:])
        # Cluster-level lanes are held per shard session (via _open_on);
        # the per-host tenant quota must fit the shards a host serves,
        # or the open queues behind this corpus's own sessions.
        sessions: List[ClusterSession] = []
        symbols = [0] * n_shards
        segments = [bytearray() for _ in range(n_shards)]
        try:
            for s in range(n_shards):
                open_kw = dict(
                    lanes=lanes // n_shards,
                    session_id=f"{tag}-shard{s}", tenant=tenant,
                    block_symbols=block_symbols,
                    seed=None if seed is None else seed + s,
                    init_chunks=init_chunks, precision=precision,
                    **encoder_kwargs)
                try:
                    cs = await self._open_on(
                        self.router.shard_route(s, n_shards), shape,
                        **open_kw)
                except HostDown as e:
                    # The routed host died between routing and open:
                    # mark it and re-route (bytes are host-blind).
                    self.router.mark_down(e.host)
                    cs = await self._open_on(
                        self.router.shard_route(s, n_shards), shape,
                        **open_kw)
                sessions.append(cs)
            for chunk in chunks:
                shards = shard_codec.split_lane_tree(chunk, n_shards)
                outs = await asyncio.gather(
                    *(cs.write(part)
                      for cs, part in zip(sessions, shards)))
                for s, out in enumerate(outs):
                    segments[s].extend(out)
                    symbols[s] += int(jax.tree_util.tree_leaves(
                        shards[s])[0].shape[0])
            tails = await asyncio.gather(
                *(cs.close() for cs in sessions))
            for s, tail in enumerate(tails):
                segments[s].extend(tail)
        except BaseException:
            for cs in sessions:
                if not cs.closed:
                    await cs.abandon()
            raise
        return fmt.encode_corpus(
            [bytes(seg) for seg in segments], symbols,
            lanes_per_shard=lanes // n_shards, precision=precision)

    async def decompress_corpus(self, blob: bytes,
                                shape: Sequence[int], *,
                                tenant: str = "default") -> Any:
        """Decode a BBX3 corpus, each shard on its routed host (down
        hosts' shards reroute to healthy peers - decode is stateless,
        bytes unaffected). Bit-exact."""
        from repro import shard_codec
        header, entries = fmt.scan_corpus(blob)
        lanes = header.lanes_per_shard * header.n_shards
        self.admission.acquire(tenant, lanes)
        try:
            async def one(s: int, e) -> Any:
                seg = blob[e.offset:e.offset + e.length]
                host = self.host(
                    self.router.shard_route(s, header.n_shards))
                try:
                    return await host.call(
                        lambda: host.gateway.decompress_stream(
                            seg, tuple(int(d) for d in shape),
                            tenant=tenant))
                except HostDown:
                    self.router.mark_down(host.name)
                    peer = self.host(
                        self.router.shard_route(s, header.n_shards))
                    return await peer.call(
                        lambda: peer.gateway.decompress_stream(
                            seg, tuple(int(d) for d in shape),
                            tenant=tenant))
            outs = await asyncio.gather(
                *(one(s, e) for s, e in enumerate(entries)))
        finally:
            self.admission.release(tenant, lanes)
        return shard_codec.merge_lane_tree(outs)

    # -- introspection -------------------------------------------------------

    @property
    def open_sessions(self) -> Tuple[str, ...]:
        return tuple(sorted(self._open))

    def stats(self) -> Dict[str, Any]:
        """Cluster admission + router health + per-host gateway stats
        (``inflight_lanes`` summed: 0 after drain = no leak anywhere)."""
        out = self.admission.stats()
        hosts = {name: host.gateway.stats()
                 for name, host in self._hosts.items() if not host.dead}
        out.update(
            hosts=hosts,
            healthy_hosts=self.router.healthy_hosts(),
            failovers=self.failovers,
            open_sessions=len(self._open),
            cluster_held_lanes=self.admission.held_lanes,
            inflight_lanes=sum(h["inflight_lanes"]
                               for h in hosts.values()),
        )
        return out
