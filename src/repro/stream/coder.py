"""Incremental ``StreamEncoder``/``StreamDecoder`` over the BBX2 format.

The encoder accepts arbitrary-length, time-major symbol arrays
(``[n, lanes, ...]`` pytrees, the ``Chained`` layout), buffers them, and
cuts the stream into fixed-size blocks of ``block_symbols`` datapoints.
Each block is coded on a *fresh* ``ANSStack`` and flushed independently
- that is what makes blocks separately decodable and mid-stream resume
possible - but the stack is **not** seeded with fresh randomness:

  * the initial heads of block ``b+1`` are the *final* heads of block
    ``b`` (carried encoder-side only; the decoder recovers them as the
    residue of block ``b+1``'s pops and simply discards them), so the
    per-block head churn telescopes away and the streamed rate tracks
    the one-shot ``codecs.compress`` rate;
  * bits-back codecs still need a per-block clean-bit supply for their
    first posterior pop (the carried head holds at most ~16 bits);
    ``init_chunks`` seeds it deterministically per block and grows
    automatically on underflow, exactly like the one-shot container.

Within a block, datapoints are pushed in *reverse* so the decoder pops
them in natural order - a streaming decoder yields datapoint ``t``
before it has looked at datapoint ``t+1``.

Fast paths: when the per-datapoint codec is a static-table
``Categorical``, whole blocks go through the Pallas-kernel batch coder
(``kernels.ans.ops.push_many_table``/``pop_many``) instead of ``k``
sequential pushes; with ``compile=True`` every block body is lowered by
the codec compiler (``codecs.compile``) into one fused jit program per
block size (dynamic-leaf codecs included - see docs/PERF.md). All paths
are bit-identical (tested), so the wire format does not know which one
produced a block.

``pipeline=True`` double-buffers blocks: block ``b+1``'s fused push is
dispatched against the *lazy* final heads of block ``b`` before block
``b`` is synced, so model compute for the next block overlaps coder
host work (flatten/framing) for the current one. The overflow/underflow
check of a block is deferred to the moment the next block is dispatched
(or to ``flush``); on a retry the optimistic dispatch is discarded and
both blocks are redone from the corrected heads - wire bytes are
asserted identical to the synchronous path (tests/test_stream.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ans
from repro.core.codec import Codec
from repro.core.distributions import Categorical
from repro.codecs.compile import compile as compile_codec
from repro.codecs.compile import register_lowering
from repro.kernels.ans import ops as ans_ops
from repro.stream import format as fmt

BlockCodecFn = Callable[[int], Codec]


@dataclasses.dataclass(frozen=True)
class _PendingBlock:
    """An encoded-but-unsynced block in the ``pipeline=True`` path.

    ``stack`` is the lazy result of the block's push (device work may
    still be in flight); ``bits_before`` is the lazy content-bit count
    of the stack it started from. ``xs``/``k``/``cap``/``chunks`` are
    kept so the block can be redone synchronously if the deferred
    overflow/underflow check fails.
    """

    xs: Any
    k: int
    stack: ans.ANSStack
    bits_before: jnp.ndarray
    cap: int
    chunks: int


@dataclasses.dataclass(frozen=True)
class EncoderSnapshot:
    """Resumable ``StreamEncoder`` state, captured at a block boundary.

    Everything a fresh process needs to *continue the exact byte
    stream*: the carried clean-bit heads, the block counter (per-block
    seeding is ``fold_in(PRNGKey(seed), n_blocks)``, so the counter
    pins the clean-bit supply), the grow-and-retry state
    (``capacity``/``init_chunks``), and the wire byte offset already
    emitted. All fields are plain Python values, so a snapshot JSON-
    serializes into a ``repro.gateway.recovery`` record as-is.
    """

    lanes: int
    block_symbols: int
    precision: int
    seed: Optional[int]
    init_chunks: int
    capacity: Optional[int]
    n_blocks: int
    n_symbols: int
    wire_bytes: int
    net_bits: float
    started: bool
    heads: Optional[Tuple[int, ...]]   # carried per-lane heads, or None


@dataclasses.dataclass(frozen=True)
class BlockChain(Codec):
    """Chain ``inner`` over a leading time axis ``[k, lanes, ...]``.

    Pushes datapoints in reverse so pops stream in natural order (the
    streaming mirror of ``codecs.Chained``). Python-driven, so inner
    codecs may drive jit-compiled network steps (the ``lm_codec``
    determinism contract).

    Example::

        block = BlockChain(codecs.Uniform(8), k=4)
        stack = block.push(stack, xs)          # xs int[4, lanes]
        stack, xs2 = block.pop(stack)
    """

    inner: Codec
    k: int

    def push(self, stack: ans.ANSStack, xs: Any) -> ans.ANSStack:
        for t in reversed(range(self.k)):
            x_t = jax.tree_util.tree_map(lambda a: a[t], xs)
            stack = self.inner.push(stack, x_t)
        return stack

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, Any]:
        outs = []
        for _ in range(self.k):
            stack, x = self.inner.pop(stack)
            outs.append(x)
        return stack, jax.tree_util.tree_map(
            lambda *ls: jnp.stack(ls, axis=0), *outs)


@dataclasses.dataclass(frozen=True)
class KernelTableBlock(Codec):
    """Kernel fast path for static-table categorical block coding.

    Symbols are int[k, lanes] (time-major); push/pop are bit-identical
    to ``BlockChain(Categorical(...), k)`` but run the whole block
    through one ``push_many_table``/``pop_many`` kernel call, on
    whichever backend ``kernels.dispatch`` resolves (``backend=None``
    here means auto: env var / ``use_backend`` context / tuning cache /
    platform heuristic - set it to pin one).

    Example::

        cat = Categorical(logits)
        fast = KernelTableBlock(cat._table(), k)   # same wire bytes as
        stack = fast.push(stack, xs)               # BlockChain(cat, k)
    """

    table: jnp.ndarray   # uint32[lanes, A+1]
    k: int
    precision: int = ans.DEFAULT_PRECISION
    backend: Optional[str] = None

    def push(self, stack: ans.ANSStack, xs: jnp.ndarray) -> ans.ANSStack:
        return ans_ops.push_many_table(stack, self.table, xs[::-1],
                                       self.precision,
                                       backend=self.backend)

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, jnp.ndarray]:
        return ans_ops.pop_many(stack, self.table, self.k, self.precision,
                                backend=self.backend)


# The compiler lowers a BlockChain by lowering its inner codec; block
# structure (reversed pushes, natural pops) is preserved bit-exactly.
register_lowering(BlockChain,
                  lambda c, rec: BlockChain(rec(c.inner), c.k))


def _resolve_block_codec(codec: Optional[Codec],
                         block_codec_fn: Optional[BlockCodecFn],
                         use_kernel: bool,
                         compile: bool = False) -> BlockCodecFn:
    if block_codec_fn is None:
        if codec is None:
            raise ValueError("stream: pass a per-datapoint codec or a "
                             "block_codec_fn")
        if use_kernel and isinstance(codec, Categorical):
            table = codec._table()
            prec = codec.precision
            block_codec_fn = lambda k: KernelTableBlock(table, k, prec)
        else:
            block_codec_fn = lambda k: BlockChain(codec, k)
    if not compile:
        return block_codec_fn
    # One fused jit program per block size (full blocks share one entry;
    # the ragged final block compiles its own).
    base, programs = block_codec_fn, {}

    def compiled_fn(k: int) -> Codec:
        if k not in programs:
            programs[k] = compile_codec(base(k))
        return programs[k]

    return compiled_fn


class StreamEncoder:
    """Chunked streaming encoder: feed datapoints, collect wire bytes.

    ``write`` returns the bytes that became final since the last call
    (the header on first emission, then completed blocks); ``flush``
    emits any buffered ragged final block plus the end-of-stream
    trailer. Flushing twice is a no-op; writing after a flush raises.

    ``seed=None`` starts the first block cold (deterministic, right for
    direct coding); an integer seed enables random first heads and the
    per-block clean-bit supply for bits-back codecs.

    Example::

        enc = StreamEncoder(codec, lanes=16, block_symbols=64, seed=0)
        wire = enc.write(xs)      # xs [n, 16, ...]; bytes as blocks fill
        wire += enc.flush()       # ragged final block + trailer
    """

    def __init__(self, codec: Optional[Codec] = None, *, lanes: int,
                 block_symbols: int,
                 block_codec_fn: Optional[BlockCodecFn] = None,
                 seed: Optional[int] = 0, init_chunks: int = 0,
                 precision: int = ans.DEFAULT_PRECISION,
                 capacity: Optional[int] = None, max_retries: int = 6,
                 use_kernel: bool = True, compile: bool = False,
                 verify: bool = False, pipeline: bool = False):
        if lanes < 1 or block_symbols < 1:
            raise ValueError("stream: lanes and block_symbols must be >= 1")
        if seed is None and init_chunks:
            raise ValueError("stream: init_chunks requires a seed (clean "
                             "bits are derived from it)")
        self._block_codec_fn = _resolve_block_codec(codec, block_codec_fn,
                                                    use_kernel, compile)
        if verify and codec is not None:
            # Opt-in (streams are often built per connection; engines
            # verify at registration instead): check the per-symbol
            # codec's contract before any bytes hit the wire.
            from repro.analysis import check_codec
            check_codec(codec, lanes=min(lanes, 4),
                        context="StreamEncoder")
        self.lanes = lanes
        self.block_symbols = block_symbols
        self.precision = precision
        self._seed = seed
        self._init_chunks = init_chunks
        self._capacity = capacity
        self._max_retries = max_retries
        self._buffer: List[Any] = []       # pending datapoint pytrees
        self._heads: Optional[jnp.ndarray] = None   # carried across blocks
        self._pipeline = pipeline
        self._pending: Optional[_PendingBlock] = None   # in-flight block
        self._started = False
        self._finished = False
        self.n_blocks = 0
        self.n_symbols = 0
        self.net_bits = 0.0   # content added, the -ELBO-comparable rate
        self.wire_bytes = 0

    # -- input ---------------------------------------------------------------

    def write(self, data: Any) -> bytes:
        """Append time-major ``[n, lanes, ...]`` datapoints; returns any
        bytes that became final (b"" if no block completed)."""
        if self._finished:
            raise RuntimeError("stream: write after flush")
        leaves = jax.tree_util.tree_leaves(data)
        if not leaves:
            return b""
        n = leaves[0].shape[0]
        for leaf in leaves:
            if (leaf.ndim < 2 or leaf.shape[0] != n
                    or leaf.shape[1] != self.lanes):
                raise ValueError(
                    f"stream: data leaves must be [n, lanes={self.lanes}, "
                    f"...]; got {leaf.shape}")
        for t in range(n):
            self._buffer.append(
                jax.tree_util.tree_map(lambda a: a[t], data))
        out = [self._header_bytes()]
        while len(self._buffer) >= self.block_symbols:
            block, self._buffer = (self._buffer[:self.block_symbols],
                                   self._buffer[self.block_symbols:])
            if self._pipeline:
                out.append(self._encode_block_pipelined(block))
            else:
                out.append(self._encode_block(block))
        return self._emit(b"".join(out))

    def flush(self) -> bytes:
        """Emit the ragged final block (if any) and the trailer."""
        if self._finished:
            return b""
        out = [self._header_bytes()]
        if self._pending is not None:
            done, _ = self._finalize_pending()
            out.append(done)
        if self._buffer:
            block, self._buffer = self._buffer, []
            out.append(self._encode_block(block))
        out.append(fmt.encode_trailer(
            fmt.Trailer(self.n_blocks, self.n_symbols)))
        self._finished = True
        return self._emit(b"".join(out))

    def drain(self) -> bytes:
        """Finalize the in-flight block of a ``pipeline=True`` encoder.

        Returns its wire bytes (b"" when nothing is in flight). Call
        before ``snapshot`` - a pending block is not yet on the wire,
        so snapshotting over it would drop its bytes.
        """
        if self._pending is None:
            return b""
        done, _ = self._finalize_pending()
        return self._emit(done)

    @property
    def buffered_symbols(self) -> int:
        """Datapoints accepted by ``write`` but not yet on the wire
        (zero exactly at block boundaries, where ``snapshot`` is legal)."""
        return len(self._buffer)

    # -- checkpoint / resume -------------------------------------------------

    def snapshot(self) -> EncoderSnapshot:
        """Capture resumable state at the current block boundary.

        Only legal with an empty symbol buffer (buffered datapoints are
        not yet on the wire, so a snapshot here would silently drop
        them) and before ``flush``. A ``StreamEncoder.resume``\\ d
        encoder continues the byte stream **identically** to one that
        was never interrupted - asserted by ``tests/test_gateway.py``.

        Example::

            enc = StreamEncoder(codec, lanes=4, block_symbols=8, seed=0)
            wire = enc.write(xs)              # multiple of 8 datapoints
            snap = enc.snapshot()             # ... process dies here ...
            enc2 = StreamEncoder.resume(codec, snap)
            wire += enc2.write(more) + enc2.flush()   # same bytes
        """
        if self._finished:
            raise RuntimeError("stream: snapshot after flush")
        if self._pending is not None:
            raise RuntimeError(
                "stream: snapshot with a pipelined block in flight - "
                "call drain() first (its bytes belong on the wire)")
        if self._buffer:
            raise RuntimeError(
                f"stream: snapshot mid-block ({len(self._buffer)} "
                "datapoints buffered) - write a multiple of "
                "block_symbols, or flush instead")
        heads = (tuple(int(h) for h in np.asarray(self._heads))
                 if self._heads is not None else None)
        return EncoderSnapshot(
            lanes=self.lanes, block_symbols=self.block_symbols,
            precision=self.precision, seed=self._seed,
            init_chunks=self._init_chunks, capacity=self._capacity,
            n_blocks=self.n_blocks, n_symbols=self.n_symbols,
            wire_bytes=self.wire_bytes, net_bits=self.net_bits,
            started=self._started, heads=heads)

    @classmethod
    def resume(cls, codec: Optional[Codec], snap: EncoderSnapshot,
               **kwargs) -> "StreamEncoder":
        """Rebuild an encoder from a ``snapshot()``; continuing bytes
        are identical to the uninterrupted stream. ``kwargs`` pass
        execution choices (``block_codec_fn``, ``use_kernel``,
        ``compile``) - wire bytes do not depend on them."""
        enc = cls(codec, lanes=snap.lanes,
                  block_symbols=snap.block_symbols,
                  precision=snap.precision, seed=snap.seed,
                  init_chunks=snap.init_chunks, capacity=snap.capacity,
                  **kwargs)
        enc._started = snap.started
        enc.n_blocks = snap.n_blocks
        enc.n_symbols = snap.n_symbols
        enc.wire_bytes = snap.wire_bytes
        enc.net_bits = snap.net_bits
        if snap.heads is not None:
            if len(snap.heads) != snap.lanes:
                raise ValueError(
                    f"stream: snapshot heads have {len(snap.heads)} "
                    f"lanes, expected {snap.lanes}")
            enc._heads = jnp.asarray(
                np.asarray(snap.heads, np.uint32))
        return enc

    # -- internals -----------------------------------------------------------

    def _emit(self, payload: bytes) -> bytes:
        self.wire_bytes += len(payload)
        return payload

    def _header_bytes(self) -> bytes:
        if self._started:
            return b""
        self._started = True
        return fmt.encode_header(fmt.StreamHeader(
            lanes=self.lanes, block_symbols=self.block_symbols,
            precision=self.precision))

    def _default_capacity(self, block: List[Any]) -> int:
        per_lane = sum(
            int(np.prod(leaf.shape[1:]))
            for leaf in jax.tree_util.tree_leaves(block[0]))
        return max(256, self.block_symbols * per_lane
                   + self._init_chunks + 64)

    def _block_stack(self, capacity: int, chunks: int,
                     block_index: Optional[int] = None,
                     heads: Optional[jnp.ndarray] = None) -> ans.ANSStack:
        if block_index is None:
            block_index = self.n_blocks
        if heads is None:
            heads = self._heads
        key = (jax.random.fold_in(jax.random.PRNGKey(self._seed),
                                  block_index)
               if self._seed is not None else None)
        if heads is not None:
            stack = ans.make_stack(self.lanes, capacity)
            # Copy: a compiled block codec donates the stack it is
            # handed, which would delete the carried-heads buffer and
            # break the grow-and-retry path (and the next block) on
            # donation-honoring backends.
            stack = stack._replace(head=jnp.copy(heads))
        elif key is not None:
            k_head, _ = jax.random.split(key)
            stack = ans.make_stack(self.lanes, capacity, key=k_head)
        else:
            stack = ans.make_stack(self.lanes, capacity)
        if chunks:
            _, k_bits = jax.random.split(key)
            stack = ans.seed_stack(stack, k_bits, chunks)
        return stack

    def _push_once(self, xs: Any, k: int, cap: int, chunks: int,
                   heads: Optional[jnp.ndarray],
                   block_index: int) -> Tuple[ans.ANSStack, jnp.ndarray]:
        """Dispatch one block push; nothing here syncs with the device."""
        codec = self._block_codec_fn(k)
        stack0 = self._block_stack(cap, chunks, block_index, heads)
        # Dispatch before the push: compiled codecs donate stack0.
        bits_before = ans.stack_content_bits(stack0)
        return codec.push(stack0, xs), bits_before

    def _grow(self, over: int, under: int, cap: int,
              chunks: int) -> Tuple[int, int]:
        if over:
            cap *= 2
        if under:
            if self._seed is None:
                raise RuntimeError(
                    "stream: stack underflow with seed=None - this "
                    "codec pops initial bits (bits-back); pass a seed "
                    "so per-block clean bits can be supplied")
            chunks = max(32, chunks * 4)
        return cap, chunks

    def _commit(self, stack: ans.ANSStack, bits_before: jnp.ndarray,
                k: int, cap: int, chunks: int) -> bytes:
        self.net_bits += float(ans.stack_content_bits(stack)) \
            - float(bits_before)
        self._heads = stack.head   # carry clean bits forward
        self._capacity, self._init_chunks = cap, chunks
        msg, lengths = ans.flatten(stack)
        self.n_blocks += 1
        self.n_symbols += k
        return fmt.encode_block(k, np.asarray(msg), np.asarray(lengths))

    def _encode_sync(self, xs: Any, k: int, cap: int, chunks: int,
                     retries: int) -> bytes:
        for _ in range(retries):
            stack, bits_before = self._push_once(
                xs, k, cap, chunks, self._heads, self.n_blocks)
            over = int(jnp.sum(stack.overflows))
            under = int(jnp.sum(stack.underflows))
            if not over and not under:
                return self._commit(stack, bits_before, k, cap, chunks)
            cap, chunks = self._grow(over, under, cap, chunks)
        raise RuntimeError(
            f"stream: could not encode block cleanly after "
            f"{self._max_retries} attempts (capacity={cap}, "
            f"init_chunks={chunks})")

    def _encode_block(self, block: List[Any]) -> bytes:
        k = len(block)
        xs = jax.tree_util.tree_map(
            lambda *ls: jnp.stack(ls, axis=0), *block)
        cap = self._capacity or self._default_capacity(block)
        return self._encode_sync(xs, k, cap, self._init_chunks,
                                 self._max_retries)

    def _finalize_pending(self) -> Tuple[bytes, bool]:
        """Sync the in-flight block; returns (wire bytes, retried?).

        On a clean check the lazily-pushed stack is committed as-is; on
        overflow/underflow the block is redone synchronously from the
        still-valid carried heads with grown capacity/chunks, so the
        bytes are identical to what the synchronous path would emit.
        """
        pend = self._pending
        if pend is None:
            raise RuntimeError("stream: no block in flight to finalize")
        self._pending = None
        over = int(jnp.sum(pend.stack.overflows))
        under = int(jnp.sum(pend.stack.underflows))
        if not over and not under:
            return self._commit(pend.stack, pend.bits_before, pend.k,
                                pend.cap, pend.chunks), False
        cap, chunks = self._grow(over, under, pend.cap, pend.chunks)
        return self._encode_sync(pend.xs, pend.k, cap, chunks,
                                 self._max_retries - 1), True

    def _encode_block_pipelined(self, block: List[Any]) -> bytes:
        """Double-buffered block encode: dispatch block ``b+1`` against
        the lazy final heads of in-flight block ``b``, *then* pay block
        ``b``'s device sync - the new block's model compute overlaps
        it. Returns block ``b``'s bytes (b"" on the very first block).
        """
        k = len(block)
        xs = jax.tree_util.tree_map(
            lambda *ls: jnp.stack(ls, axis=0), *block)
        cap = self._capacity or self._default_capacity(block)
        chunks = self._init_chunks
        if self._pending is None:
            stack, bits = self._push_once(xs, k, cap, chunks,
                                          self._heads, self.n_blocks)
            self._pending = _PendingBlock(xs, k, stack, bits, cap, chunks)
            return b""
        # Optimistic dispatch: assume the in-flight block lands cleanly
        # and chain this block off its lazy heads.
        stack, bits = self._push_once(xs, k, cap, chunks,
                                      self._pending.stack.head,
                                      self.n_blocks + 1)
        done, retried = self._finalize_pending()
        if retried:
            # The in-flight block grew and re-encoded; the optimistic
            # dispatch chained off stale heads. Discard it (never
            # synced, so it cannot have left the device) and redo from
            # the corrected carried heads.
            cap = self._capacity or cap
            chunks = self._init_chunks
            stack, bits = self._push_once(xs, k, cap, chunks,
                                          self._heads, self.n_blocks)
        self._pending = _PendingBlock(xs, k, stack, bits, cap, chunks)
        return done


class StreamDecoder:
    """Incremental BBX2 decoder: feed bytes in arbitrary pieces, collect
    decoded blocks (time-major ``[k, lanes, ...]`` pytrees) as they
    complete.

    Construct with ``header=`` (e.g. from ``format.scan``) to resume
    mid-stream: the byte feed may then start at any block boundary
    instead of the stream header.

    Example::

        dec = StreamDecoder(codec)
        for piece in network_chunks:
            for block in dec.read(piece):      # [k, lanes, ...] each
                consume(block)
        assert dec.finished
    """

    def __init__(self, codec: Optional[Codec] = None, *,
                 block_codec_fn: Optional[BlockCodecFn] = None,
                 header: Optional[fmt.StreamHeader] = None,
                 use_kernel: bool = True, verify_trailer: bool = True,
                 compile: bool = False, verify: bool = False):
        self._block_codec_fn = _resolve_block_codec(codec, block_codec_fn,
                                                    use_kernel, compile)
        if verify and codec is not None:
            from repro.analysis import check_codec   # opt-in, as encoder
            check_codec(codec, lanes=4, context="StreamDecoder")
        self._header = header
        self._verify_trailer = verify_trailer
        self._buf = bytearray()
        self._finished = False
        self.n_blocks = 0
        self.n_symbols = 0
        self.trailer: Optional[fmt.Trailer] = None

    @property
    def header(self) -> Optional[fmt.StreamHeader]:
        return self._header

    @property
    def finished(self) -> bool:
        return self._finished

    def read(self, chunk: bytes = b"") -> List[Any]:
        """Feed bytes; returns the list of blocks completed by them."""
        self._buf.extend(chunk)
        out: List[Any] = []
        if self._header is None:
            parsed = fmt.decode_header(bytes(self._buf))
            if parsed is None:
                return out
            self._header, off = parsed
            del self._buf[:off]
        while not self._finished:
            res = fmt.decode_next(bytes(self._buf), 0, self._header.lanes)
            if res is None:
                break
            frame, off = res
            del self._buf[:off]
            if isinstance(frame, fmt.Trailer):
                self.trailer = frame
                self._finished = True
                if self._verify_trailer and (
                        frame.n_blocks != self.n_blocks
                        or frame.total_symbols != self.n_symbols):
                    raise ValueError(
                        f"stream: trailer mismatch (saw {self.n_blocks} "
                        f"blocks/{self.n_symbols} symbols, trailer says "
                        f"{frame.n_blocks}/{frame.total_symbols}) - "
                        "stream truncated or resumed mid-way")
                break
            out.append(self._decode_block(frame))
        return out

    def _decode_block(self, block: fmt.Block) -> Any:
        # Width-2 rows mean a chunk-less block; keep a few buffer slots
        # so bits-back decode transients (posterior re-pushes) fit.
        stack = ans.unflatten(jnp.asarray(block.msg),
                              jnp.asarray(block.lengths),
                              capacity=max(block.msg.shape[1] - 2, 8))
        codec = self._block_codec_fn(block.n_symbols)
        stack, xs = codec.pop(stack)
        under = int(jnp.sum(stack.underflows))
        over = int(jnp.sum(stack.overflows))
        if under or over:
            raise ValueError(
                f"stream: corrupt block {self.n_blocks} "
                f"({under} underflows, {over} overflows during decode)")
        self.n_blocks += 1
        self.n_symbols += block.n_symbols
        return xs


# ---------------------------------------------------------------------------
# One-call conveniences
# ---------------------------------------------------------------------------

def encode_stream(codec: Optional[Codec], data: Any, *, lanes: int,
                  block_symbols: int, **kwargs) -> bytes:
    """One-shot helper: the whole of ``data`` through a StreamEncoder.

    Example::

        wire = encode_stream(codec, xs, lanes=16, block_symbols=64)
        assert (decode_stream(codec, wire) == xs).all()
    """
    enc = StreamEncoder(codec, lanes=lanes, block_symbols=block_symbols,
                        **kwargs)
    return enc.write(data) + enc.flush()


def _concat_blocks(blocks: List[Any]) -> Any:
    if not blocks:
        return None
    return jax.tree_util.tree_map(
        lambda *ls: jnp.concatenate(ls, axis=0), *blocks)


def decode_stream(codec: Optional[Codec], blob: bytes,
                  **kwargs) -> Any:
    """Decode a complete BBX2 stream to time-major ``[n, lanes, ...]``.

    Example::

        xs = decode_stream(codec, wire)        # raises if truncated
    """
    dec = StreamDecoder(codec, **kwargs)
    blocks = dec.read(blob)
    if not dec.finished:
        raise ValueError("stream: truncated (no trailer)")
    return _concat_blocks(blocks)


def decode_from_offset(codec: Optional[Codec], blob: bytes, offset: int,
                       **kwargs) -> Any:
    """Resume decoding at a block boundary byte ``offset``.

    The stream header is read from the front of ``blob`` (it is 16
    bytes and static), then decoding starts directly at ``offset`` -
    no earlier payload byte is touched. Offsets come from
    ``format.scan`` or from bookkeeping at encode time. The trailer
    count check is skipped (a resumed decode legitimately sees fewer
    blocks than the whole stream).

    Example::

        header, offsets, trailer = stream.format.scan(wire)
        tail = decode_from_offset(codec, wire, offsets[2])  # block 2 on
    """
    parsed = fmt.decode_header(blob)
    if parsed is None:
        raise ValueError("stream: truncated (no header)")
    header, _ = parsed
    dec = StreamDecoder(codec, header=header, verify_trailer=False,
                        **kwargs)
    return _concat_blocks(dec.read(blob[offset:]))
