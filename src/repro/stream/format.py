"""``BBX2`` - the chunked streaming wire format.

A BBX2 stream is a framed sequence of *independent* BBX1-style blocks:
each block carries a complete flattened ``ANSStack`` message (per-lane
``[head_hi, head_lo, chunks...]`` rows, exactly the BBX1 payload from
``codecs/container.py``) plus the number of datapoints it codes. Any
block can be decoded knowing only the stream header and the codec -
this is what buys mid-stream resume and bounded decode latency; the
price is one head flush (32 bits/lane) plus the per-lane length frame
per block.

Wire layout (little-endian):

    Stream header (16 bytes)
    offset  size    field
    0       4       magic  b"BBX2"
    4       1       version (=1)
    5       1       precision (informational)
    6       2       flags (reserved, 0)
    8       4       lanes (u32)
    12      4       block_symbols (u32) - nominal datapoints per block
                    (the final block may carry fewer; a block never
                    carries more)

    Block (repeated; 12 + 4*lanes + 2*sum(len) bytes each)
    0       2       marker 0xB10C (u16)
    2       2       flags (reserved, 0)
    4       4       n_symbols coded by this block (u32)
    8       4       total chunks = sum(lengths) (u32)
    12      4*lanes lengths (u32 each, in 16-bit chunks, >= 2)
    ...     2*total payload: lane l's [head_hi, head_lo, chunks...]

    Trailer (16 bytes)
    0       2       marker 0xE05D (u16)
    2       2       flags (reserved, 0)
    4       4       n_blocks (u32)
    8       8       total_symbols (u64)

Framing is byte-precise: ``scan`` recovers every block boundary from
the length fields alone, so a decoder can seek to any block offset and
resume without touching earlier payload bytes.

The canonical spec (field tables for BBX1 + BBX2, invariants, and a
worked scan example) is docs/FORMATS.md; this docstring is the
implementation-side summary.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import List, Optional, Tuple

import numpy as np

from repro.codecs.container import pack_lane_rows, unpack_lane_rows

MAGIC = b"BBX2"
VERSION = 1
BLOCK_MARKER = 0xB10C
END_MARKER = 0xE05D

_HEADER = struct.Struct("<4sBBHII")
_BLOCK = struct.Struct("<HHII")
_TRAILER = struct.Struct("<HHIQ")

HEADER_SIZE = _HEADER.size     # 16
BLOCK_HEADER_SIZE = _BLOCK.size   # 12
TRAILER_SIZE = _TRAILER.size   # 16


@dataclasses.dataclass(frozen=True)
class StreamHeader:
    lanes: int
    block_symbols: int
    precision: int
    version: int = VERSION


@dataclasses.dataclass(frozen=True)
class Block:
    """One parsed block: ``msg``/``lengths`` feed ``ans.unflatten``."""
    n_symbols: int
    msg: np.ndarray       # uint16[lanes, width]
    lengths: np.ndarray   # int32[lanes]


@dataclasses.dataclass(frozen=True)
class Trailer:
    n_blocks: int
    total_symbols: int


def encode_header(header: StreamHeader) -> bytes:
    return _HEADER.pack(MAGIC, header.version, header.precision, 0,
                        header.lanes, header.block_symbols)


def decode_header(buf: bytes, offset: int = 0
                  ) -> Optional[Tuple[StreamHeader, int]]:
    """Parse a stream header at ``offset``; None if more bytes needed."""
    if len(buf) - offset < HEADER_SIZE:
        return None
    magic, version, precision, _flags, lanes, block_symbols = \
        _HEADER.unpack_from(buf, offset)
    if magic != MAGIC:
        raise ValueError(f"stream: bad magic {magic!r} (not a BBX2 stream)")
    if version != VERSION:
        raise ValueError(f"stream: unsupported BBX2 version {version}")
    if lanes < 1 or block_symbols < 1:
        raise ValueError("stream: corrupt header (lanes/block_symbols < 1)")
    return StreamHeader(lanes=lanes, block_symbols=block_symbols,
                        precision=precision, version=version), \
        offset + HEADER_SIZE


def encode_block(n_symbols: int, msg: np.ndarray,
                 lengths: np.ndarray) -> bytes:
    """Frame one flattened stack message as a BBX2 block."""
    lengths = np.asarray(lengths)
    return b"".join([
        _BLOCK.pack(BLOCK_MARKER, 0, n_symbols, int(lengths.sum())),
        lengths.astype("<u4").tobytes(),
        pack_lane_rows(np.asarray(msg), lengths),
    ])


def encode_trailer(trailer: Trailer) -> bytes:
    return _TRAILER.pack(END_MARKER, 0, trailer.n_blocks,
                         trailer.total_symbols)


def decode_next(buf: bytes, offset: int, lanes: int):
    """Parse the next frame at ``offset``.

    Returns ``(Block, new_offset)``, ``(Trailer, new_offset)``, or
    ``None`` when the buffer does not yet hold the complete frame
    (incremental feeding). Raises on corrupt markers.
    """
    avail = len(buf) - offset
    if avail < 2:
        return None
    (marker,) = struct.unpack_from("<H", buf, offset)
    if marker == END_MARKER:
        if avail < TRAILER_SIZE:
            return None
        _m, _flags, n_blocks, total_symbols = _TRAILER.unpack_from(
            buf, offset)
        return Trailer(n_blocks, total_symbols), offset + TRAILER_SIZE
    if marker != BLOCK_MARKER:
        raise ValueError(
            f"stream: bad frame marker 0x{marker:04X} at offset {offset} "
            "(not a block boundary)")
    if avail < BLOCK_HEADER_SIZE + 4 * lanes:
        return None
    _m, _flags, n_symbols, total = _BLOCK.unpack_from(buf, offset)
    lengths = np.frombuffer(buf, dtype="<u4", count=lanes,
                            offset=offset + BLOCK_HEADER_SIZE
                            ).astype(np.int32)
    if (lengths < 2).any():
        raise ValueError("stream: corrupt block (lane length < 2)")
    if int(lengths.sum()) != total:
        raise ValueError("stream: corrupt block (length sum mismatch)")
    payload_off = offset + BLOCK_HEADER_SIZE + 4 * lanes
    end = payload_off + 2 * total
    if len(buf) < end:
        return None
    msg = unpack_lane_rows(buf, payload_off, lengths)
    return Block(n_symbols=n_symbols, msg=msg, lengths=lengths), end


def scan(blob: bytes) -> Tuple[StreamHeader, List[int], Optional[Trailer]]:
    """Walk a complete stream: (header, block byte offsets, trailer).

    The offsets index the first byte of each block's marker - exactly
    what ``StreamDecoder.from_header`` + ``blob[offset:]`` needs for a
    mid-stream resume.
    """
    parsed = decode_header(blob)
    if parsed is None:
        raise ValueError("stream: truncated (no header)")
    header, off = parsed
    offsets: List[int] = []
    trailer: Optional[Trailer] = None
    while True:
        out = decode_next(blob, off, header.lanes)
        if out is None:
            break
        frame, new_off = out
        if isinstance(frame, Trailer):
            trailer = frame
            break
        offsets.append(off)
        off = new_off
    return header, offsets, trailer
