"""``BBX2`` - the chunked streaming wire format - and ``BBX3``, the
sharded corpus container framed on top of it.

A BBX2 stream is a framed sequence of *independent* BBX1-style blocks:
each block carries a complete flattened ``ANSStack`` message (per-lane
``[head_hi, head_lo, chunks...]`` rows, exactly the BBX1 payload from
``codecs/container.py``) plus the number of datapoints it codes. Any
block can be decoded knowing only the stream header and the codec -
this is what buys mid-stream resume and bounded decode latency; the
price is one head flush (32 bits/lane) plus the per-lane length frame
per block.

Wire layout (little-endian):

    Stream header (16 bytes)
    offset  size    field
    0       4       magic  b"BBX2"
    4       1       version (=1)
    5       1       precision (informational)
    6       2       flags (reserved, 0)
    8       4       lanes (u32)
    12      4       block_symbols (u32) - nominal datapoints per block
                    (the final block may carry fewer; a block never
                    carries more)

    Block (repeated; 12 + 4*lanes + 2*sum(len) bytes each)
    0       2       marker 0xB10C (u16)
    2       2       flags (reserved, 0)
    4       4       n_symbols coded by this block (u32)
    8       4       total chunks = sum(lengths) (u32)
    12      4*lanes lengths (u32 each, in 16-bit chunks, >= 2)
    ...     2*total payload: lane l's [head_hi, head_lo, chunks...]

    Trailer (16 bytes)
    0       2       marker 0xE05D (u16)
    2       2       flags (reserved, 0)
    4       4       n_blocks (u32)
    8       8       total_symbols (u64)

Framing is byte-precise: ``scan`` recovers every block boundary from
the length fields alone, so a decoder can seek to any block offset and
resume without touching earlier payload bytes.

A ``BBX3`` corpus is the dataset-scale container produced by
``repro.shard_codec``: a 16-byte corpus header, an up-front index of
``n_shards`` fixed-size entries, then ``n_shards`` complete BBX2
streams ("segments") concatenated. Each segment carries one lane
shard's blocks, so any shard decodes independently of every other -
the unit of data-parallel decode is the segment, and a reader seeks
straight to shard ``s`` via the index without touching other shards'
bytes:

    Corpus header (16 bytes)
    offset  size    field
    0       4       magic  b"BBX3"
    4       1       version (=1)
    5       1       precision (informational)
    6       2       flags (reserved, 0)
    8       4       n_shards (u32)
    12      4       lanes_per_shard (u32)

    Index (n_shards entries, 24 bytes each)
    0       8       segment byte offset, relative to index end (u64)
    8       8       segment byte length (u64)
    16      8       n_symbols coded by the segment (u64)

    Segments: n_shards complete BBX2 streams, concatenated.

The canonical spec (field tables for BBX1 + BBX2 + BBX3, invariants,
and a worked scan example) is docs/FORMATS.md; this docstring is the
implementation-side summary. The lane-sharding execution model that
writes BBX3 is docs/SCALING.md.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.codecs.container import (ContainerError, pack_lane_rows,
                                    unpack_lane_rows)

MAGIC = b"BBX2"
VERSION = 1
BLOCK_MARKER = 0xB10C
END_MARKER = 0xE05D

_HEADER = struct.Struct("<4sBBHII")
_BLOCK = struct.Struct("<HHII")
_TRAILER = struct.Struct("<HHIQ")

HEADER_SIZE = _HEADER.size     # 16
BLOCK_HEADER_SIZE = _BLOCK.size   # 12
TRAILER_SIZE = _TRAILER.size   # 16

CORPUS_MAGIC = b"BBX3"
CORPUS_VERSION = 1
_CORPUS_HEADER = struct.Struct("<4sBBHII")
_CORPUS_ENTRY = struct.Struct("<QQQ")

CORPUS_HEADER_SIZE = _CORPUS_HEADER.size   # 16
CORPUS_ENTRY_SIZE = _CORPUS_ENTRY.size     # 24


@dataclasses.dataclass(frozen=True)
class StreamHeader:
    lanes: int
    block_symbols: int
    precision: int
    version: int = VERSION


@dataclasses.dataclass(frozen=True)
class Block:
    """One parsed block: ``msg``/``lengths`` feed ``ans.unflatten``."""
    n_symbols: int
    msg: np.ndarray       # uint16[lanes, width]
    lengths: np.ndarray   # int32[lanes]


@dataclasses.dataclass(frozen=True)
class Trailer:
    n_blocks: int
    total_symbols: int


def encode_header(header: StreamHeader) -> bytes:
    return _HEADER.pack(MAGIC, header.version, header.precision, 0,
                        header.lanes, header.block_symbols)


def decode_header(buf: bytes, offset: int = 0
                  ) -> Optional[Tuple[StreamHeader, int]]:
    """Parse a stream header at ``offset``; None if more bytes needed."""
    if len(buf) - offset < HEADER_SIZE:
        return None
    magic, version, precision, _flags, lanes, block_symbols = \
        _HEADER.unpack_from(buf, offset)
    if magic != MAGIC:
        raise ContainerError(
            f"stream: bad magic {magic!r} at byte {offset} "
            "(not a BBX2 stream)")
    if version != VERSION:
        raise ContainerError(
            f"stream: unsupported BBX2 version {version} at byte {offset}")
    if lanes < 1 or block_symbols < 1:
        raise ContainerError(
            f"stream: corrupt header at byte {offset} "
            "(lanes/block_symbols < 1)")
    return StreamHeader(lanes=lanes, block_symbols=block_symbols,
                        precision=precision, version=version), \
        offset + HEADER_SIZE


def encode_block(n_symbols: int, msg: np.ndarray,
                 lengths: np.ndarray) -> bytes:
    """Frame one flattened stack message as a BBX2 block."""
    lengths = np.asarray(lengths)
    return b"".join([
        _BLOCK.pack(BLOCK_MARKER, 0, n_symbols, int(lengths.sum())),
        lengths.astype("<u4").tobytes(),
        pack_lane_rows(np.asarray(msg), lengths),
    ])


def encode_trailer(trailer: Trailer) -> bytes:
    return _TRAILER.pack(END_MARKER, 0, trailer.n_blocks,
                         trailer.total_symbols)


def decode_next(buf: bytes, offset: int, lanes: int):
    """Parse the next frame at ``offset``.

    Returns ``(Block, new_offset)``, ``(Trailer, new_offset)``, or
    ``None`` when the buffer does not yet hold the complete frame
    (incremental feeding). Raises on corrupt markers.
    """
    avail = len(buf) - offset
    if avail < 2:
        return None
    (marker,) = struct.unpack_from("<H", buf, offset)
    if marker == END_MARKER:
        if avail < TRAILER_SIZE:
            return None
        _m, _flags, n_blocks, total_symbols = _TRAILER.unpack_from(
            buf, offset)
        return Trailer(n_blocks, total_symbols), offset + TRAILER_SIZE
    if marker != BLOCK_MARKER:
        raise ContainerError(
            f"stream: bad frame marker 0x{marker:04X} at offset {offset} "
            "(not a block boundary)")
    if avail < BLOCK_HEADER_SIZE + 4 * lanes:
        return None
    _m, _flags, n_symbols, total = _BLOCK.unpack_from(buf, offset)
    lengths = np.frombuffer(buf, dtype="<u4", count=lanes,
                            offset=offset + BLOCK_HEADER_SIZE
                            ).astype(np.int32)
    if (lengths < 2).any():
        raise ContainerError(
            f"stream: corrupt block at byte {offset} (lane length < 2)")
    if int(lengths.sum()) != total:
        raise ContainerError(
            f"stream: corrupt block at byte {offset} (length sum mismatch)")
    payload_off = offset + BLOCK_HEADER_SIZE + 4 * lanes
    end = payload_off + 2 * total
    if len(buf) < end:
        return None
    msg = unpack_lane_rows(buf, payload_off, lengths)
    return Block(n_symbols=n_symbols, msg=msg, lengths=lengths), end


def scan(blob: bytes) -> Tuple[StreamHeader, List[int], Optional[Trailer]]:
    """Walk a complete stream: (header, block byte offsets, trailer).

    The offsets index the first byte of each block's marker - exactly
    what ``StreamDecoder.from_header`` + ``blob[offset:]`` needs for a
    mid-stream resume.

    Corruption raises ``codecs.ContainerError`` naming the byte offset
    and block index where the frame walk failed, so a bad wire byte is
    reported as *where* in the stream it sits, not as an index error
    deep inside the coder.
    """
    parsed = decode_header(blob)
    if parsed is None:
        raise ContainerError("stream: truncated (no header)")
    header, off = parsed
    offsets: List[int] = []
    trailer: Optional[Trailer] = None
    while True:
        try:
            out = decode_next(blob, off, header.lanes)
        except ContainerError as e:
            raise ContainerError(
                f"stream: scan failed at block {len(offsets)} "
                f"(byte offset {off}): {e}") from e
        if out is None:
            break
        frame, new_off = out
        if isinstance(frame, Trailer):
            trailer = frame
            break
        offsets.append(off)
        off = new_off
    return header, offsets, trailer


# ---------------------------------------------------------------------------
# BBX3 - the sharded corpus container
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CorpusHeader:
    n_shards: int
    lanes_per_shard: int
    precision: int
    version: int = CORPUS_VERSION


@dataclasses.dataclass(frozen=True)
class ShardEntry:
    """One index row: where shard ``s``'s BBX2 segment lives.

    ``offset`` is relative to the end of the index (the first segment
    byte); ``scan_corpus`` returns entries rebased to absolute blob
    offsets, so ``blob[e.offset:e.offset + e.length]`` is the segment.
    """
    offset: int
    length: int
    n_symbols: int


def encode_corpus(segments: Sequence[bytes], n_symbols: Sequence[int],
                  lanes_per_shard: int,
                  precision: int = 16) -> bytes:
    """Frame per-shard BBX2 segments as one BBX3 corpus blob.

    ``segments[s]`` must be a complete BBX2 stream over
    ``lanes_per_shard`` lanes coding ``n_symbols[s]`` datapoints.
    """
    if len(segments) != len(n_symbols) or not segments:
        raise ValueError("corpus: need one n_symbols per segment (>= 1)")
    header = _CORPUS_HEADER.pack(CORPUS_MAGIC, CORPUS_VERSION, precision,
                                 0, len(segments), lanes_per_shard)
    entries, off = [], 0
    for seg, n in zip(segments, n_symbols):
        entries.append(_CORPUS_ENTRY.pack(off, len(seg), n))
        off += len(seg)
    return b"".join([header, *entries, *segments])


def scan_corpus(blob: bytes) -> Tuple[CorpusHeader, List[ShardEntry]]:
    """Parse a BBX3 corpus: (header, index with absolute offsets).

    Touches only the header + index bytes - seeking to one shard of a
    dataset-scale corpus never reads the other shards' payload.

    Example::

        header, entries = scan_corpus(blob)
        seg0 = blob[entries[0].offset:entries[0].offset
                    + entries[0].length]       # a complete BBX2 stream
    """
    if len(blob) < CORPUS_HEADER_SIZE:
        raise ContainerError("corpus: truncated (no header)")
    magic, version, precision, _flags, n_shards, lanes = \
        _CORPUS_HEADER.unpack_from(blob, 0)
    if magic != CORPUS_MAGIC:
        raise ContainerError(
            f"corpus: bad magic {magic!r} at byte 0 (not a BBX3 corpus)")
    if version != CORPUS_VERSION:
        raise ContainerError(f"corpus: unsupported BBX3 version {version}")
    if n_shards < 1 or lanes < 1:
        raise ContainerError("corpus: corrupt header (n_shards/lanes < 1)")
    if n_shards > (len(blob) - CORPUS_HEADER_SIZE) // CORPUS_ENTRY_SIZE:
        raise ContainerError(
            f"corpus: corrupt header (n_shards={n_shards} needs a "
            "larger index than the blob holds)")
    base = CORPUS_HEADER_SIZE + n_shards * CORPUS_ENTRY_SIZE
    if len(blob) < base:
        raise ContainerError("corpus: truncated (index incomplete)")
    entries: List[ShardEntry] = []
    for s in range(n_shards):
        entry_off = CORPUS_HEADER_SIZE + s * CORPUS_ENTRY_SIZE
        off, length, n_sym = _CORPUS_ENTRY.unpack_from(blob, entry_off)
        if base + off + length > len(blob):
            raise ContainerError(
                f"corpus: truncated (shard {s} segment at byte "
                f"{base + off} extends past the blob)")
        entries.append(ShardEntry(base + off, length, n_sym))
    return CorpusHeader(n_shards=n_shards, lanes_per_shard=lanes,
                        precision=precision, version=version), entries


def corpus_segment(blob: bytes, shard: int) -> bytes:
    """Shard ``shard``'s complete BBX2 segment bytes (index-seeked).

    Example::

        seg = corpus_segment(blob, 3)
        xs3 = stream.decode_stream(codec, seg)   # shard 3, independently
    """
    _, entries = scan_corpus(blob)
    if not 0 <= shard < len(entries):
        raise ContainerError(
            f"corpus: shard {shard} out of range [0, {len(entries)})")
    e = entries[shard]
    return blob[e.offset:e.offset + e.length]


# ---------------------------------------------------------------------------
# shard -> host placement (derived, never serialized)
# ---------------------------------------------------------------------------

def shard_host(shard: int, n_shards: int, n_hosts: int) -> int:
    """The host index shard ``shard`` of an ``n_shards`` corpus is
    served by in an ``n_hosts`` cluster: round-robin over the cluster's
    host order.

    The assignment is a pure function of the BBX3 index - it is
    *derived* at routing time and **never serialized into the wire**,
    so corpus bytes stay hex-identical whether one host or N encode or
    decode them (the cluster determinism contract,
    ``tests/test_cluster.py``).

    Example::

        assert shard_host(5, n_shards=8, n_hosts=3) == 5 % 3
    """
    if n_shards < 1 or n_hosts < 1:
        raise ValueError("corpus: shard_host needs n_shards/n_hosts >= 1")
    if not 0 <= shard < n_shards:
        raise ContainerError(
            f"corpus: shard {shard} out of range [0, {n_shards})")
    return shard % n_hosts


def corpus_assignments(blob: bytes, n_hosts: int) -> List[List[int]]:
    """Per-host shard lists for a BBX3 corpus, derived from its index
    alone (``shard_host`` per entry; only header + index bytes are
    read).

    Example::

        plan = corpus_assignments(blob, n_hosts=2)
        assert sorted(s for shards in plan for s in shards) == \\
            list(range(scan_corpus(blob)[0].n_shards))
    """
    header, _ = scan_corpus(blob)
    plan: List[List[int]] = [[] for _ in range(n_hosts)]
    for s in range(header.n_shards):
        plan[shard_host(s, header.n_shards, n_hosts)].append(s)
    return plan
