"""Dynamic batching: many client streams through one ``ANSStack``.

The scheduler packs concurrent streams into the *lane axis* of a single
stack - the axis the whole substrate is vectorized over - so one model
evaluation (VAE decode, LM step, ...) serves every active stream at
once. The batch composition changes **between blocks**: streams are
admitted from a FIFO queue whenever a lane frees up and retired the
round their data runs out. Lanes are fully independent rANS coders, so
each lane's flattened message slices out as a self-contained 1-lane
BBX2 block for that client; a client's blob is an ordinary BBX2 stream
(``lanes=1``) decodable by ``StreamDecoder`` - or, bit-for-bit
identically, by the batched ``decode_batched`` below.

Masking: within a round the active blocks may be ragged (a stream's
final block is shorter) and some lanes may be free. Both cases use
``ans.select_lanes``: the codec runs unmasked over the full lane axis
(vector units don't care) and the lanes that must not advance simply
keep their previous state. No padding symbols are ever coded, so
masked lanes cost zero wire bits.

Head carry works per client exactly as in ``StreamEncoder``: a client's
next block starts from *its own* previous block's final head, whatever
lane either block was scheduled on.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ans
from repro.core.codec import Codec
from repro.stream import format as fmt


class MaskedBlockCodec:
    """Block codec with per-lane valid counts.

    ``push(stack, xs, n_valid)``: ``xs`` is time-major ``[k, lanes,
    ...]``; lane ``l`` codes only its first ``n_valid[l]`` datapoints
    (its state must be byte-identical to never having seen the rest).
    ``pop(stack, k, n_valid)`` is the inverse; values in invalid
    positions of the returned ``xs`` are unspecified.

    Implementations: ``SteppedMaskedBlock`` (any ``Codec``),
    ``serve.engine._LMMaskedBlock`` (LM at fixed batch width).
    """

    def push(self, stack: ans.ANSStack, xs: Any,
             n_valid: jnp.ndarray) -> ans.ANSStack:
        raise NotImplementedError

    def pop(self, stack: ans.ANSStack, k: int,
            n_valid: jnp.ndarray) -> Tuple[ans.ANSStack, Any]:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class SteppedMaskedBlock(MaskedBlockCodec):
    """Any per-datapoint ``Codec`` as a MaskedBlockCodec.

    Steps the inner codec one datapoint at a time (reversed on push so
    pops stream forward) and freezes masked lanes with
    ``ans.select_lanes`` after every step.

    Example::

        block = SteppedMaskedBlock(codecs.Uniform(6))
        stack = block.push(stack, xs, n_valid)   # ragged lanes ok
    """

    inner: Codec

    def push(self, stack: ans.ANSStack, xs: Any,
             n_valid: jnp.ndarray) -> ans.ANSStack:
        k = jax.tree_util.tree_leaves(xs)[0].shape[0]
        for t in reversed(range(k)):
            x_t = jax.tree_util.tree_map(lambda a: a[t], xs)
            pushed = self.inner.push(stack, x_t)
            stack = ans.select_lanes(t < n_valid, pushed, stack)
        return stack

    def pop(self, stack: ans.ANSStack, k: int,
            n_valid: jnp.ndarray) -> Tuple[ans.ANSStack, Any]:
        outs = []
        for t in range(k):
            popped, x = self.inner.pop(stack)
            stack = ans.select_lanes(t < n_valid, popped, stack)
            outs.append(x)
        return stack, jax.tree_util.tree_map(
            lambda *ls: jnp.stack(ls, axis=0), *outs)


class _Client:
    def __init__(self, stream_id: Any, datapoints: List[Any],
                 deadline: Optional[float] = None):
        self.id = stream_id
        self.datapoints = datapoints
        self.pos = 0
        self.head: Optional[jnp.ndarray] = None  # uint32[] carried head
        self.parts: List[bytes] = []
        self.n_blocks = 0
        self.deadline = deadline

    @property
    def remaining(self) -> int:
        return len(self.datapoints) - self.pos


class StreamBatcher:
    """Pack many submitted streams into one ``max_lanes``-wide stack.

    ``codec`` is either a per-datapoint ``Codec`` built for exactly
    ``max_lanes`` lanes (wrapped in ``SteppedMaskedBlock``) or a
    ``MaskedBlockCodec``. Client data has **no** lane axis: leaves are
    ``[n, ...]``; the batcher owns lane placement. ``run()`` drives
    rounds to completion and returns ``{stream_id: blob}`` where each
    blob is a 1-lane BBX2 stream.

    Every codec call runs at the full ``max_lanes`` width (free lanes
    are masked), so each round reuses one compiled executable - the
    property model-backed codecs need for bitwise encode/decode
    symmetry (see ``core.lm_codec``).

    Example::

        bat = StreamBatcher(SteppedMaskedBlock(codec), max_lanes=8,
                            block_symbols=32)
        bat.submit("user-1", xs_a)    # ragged [n_a, ...], no lane axis
        bat.submit("user-2", xs_b)
        blobs = bat.run()             # {"user-1": BBX2 bytes, ...}
    """

    def __init__(self, codec, max_lanes: int, block_symbols: int, *,
                 seed: Optional[int] = None, init_chunks: int = 0,
                 precision: int = ans.DEFAULT_PRECISION,
                 capacity: Optional[int] = None, max_retries: int = 6,
                 clock: Callable[[], float] = time.monotonic):
        if max_lanes < 1 or block_symbols < 1:
            raise ValueError("batcher: max_lanes/block_symbols must be >= 1")
        if seed is None and init_chunks:
            raise ValueError("batcher: init_chunks requires a seed")
        self._block = (codec if isinstance(codec, MaskedBlockCodec)
                       else SteppedMaskedBlock(codec))
        self.max_lanes = max_lanes
        self.block_symbols = block_symbols
        self.precision = precision
        self._seed = seed
        self._init_chunks = init_chunks
        self._capacity = capacity
        self._max_retries = max_retries
        self._clock = clock
        self._queue: List[_Client] = []
        self._lanes: List[Optional[_Client]] = [None] * max_lanes
        self._zero_dp: Optional[Any] = None
        self._round = 0
        self._admitted = 0
        self._done: Dict[Any, bytes] = {}
        #: stream ids whose blob was cut short by cancel()/timeout
        #: eviction (the blob is still a valid BBX2 stream covering the
        #: blocks coded before the cut).
        self.evicted: set = set()

    # -- submission ----------------------------------------------------------

    def submit(self, stream_id: Any, data: Any, *,
               timeout: Optional[float] = None) -> None:
        """Enqueue a client stream; leaves are ``[n, ...]`` (no lanes).

        ``timeout`` (seconds) sets a per-stream deadline: a stream
        still unfinished when it expires is evicted at the next round
        boundary - its lane frees up and its partial blob (a valid
        BBX2 stream covering the blocks coded so far) lands in the
        results with the id recorded in ``evicted``. This is the
        lane-lease discipline: no client may hold a lane forever.
        """
        if stream_id in self._done or any(
                c.id == stream_id
                for c in self._queue + [l for l in self._lanes if l]):
            raise ValueError(f"batcher: duplicate stream id {stream_id!r} "
                             "(release() a finished id to reuse it)")
        leaves = jax.tree_util.tree_leaves(data)
        n = leaves[0].shape[0] if leaves else 0
        datapoints = [jax.tree_util.tree_map(lambda a: a[t], data)
                      for t in range(n)]
        if self._zero_dp is None and datapoints:
            self._zero_dp = jax.tree_util.tree_map(
                jnp.zeros_like, datapoints[0])
        deadline = (self._clock() + timeout) if timeout is not None else None
        self._queue.append(_Client(stream_id, datapoints, deadline))

    # -- lane leases ---------------------------------------------------------

    def lane_of(self, stream_id: Any) -> Optional[int]:
        """The lane a stream currently leases, or None (queued/done)."""
        for l, c in enumerate(self._lanes):
            if c is not None and c.id == stream_id:
                return l
        return None

    @property
    def active_ids(self) -> List[Any]:
        """Stream ids currently holding a lane lease (by lane order)."""
        return [c.id for c in self._lanes if c is not None]

    @property
    def queued_ids(self) -> List[Any]:
        """Stream ids waiting for a lane, in FIFO order."""
        return [c.id for c in self._queue]

    def cancel(self, stream_id: Any) -> bytes:
        """Evict a stream now (client disconnect): its lane lease is
        released and its partial blob - a **valid** BBX2 stream whose
        trailer covers exactly the blocks coded so far - is finalized,
        returned, and recorded in ``evicted``.

        Example::

            bat.submit("u1", xs); bat.step()
            part = bat.cancel("u1")          # decodes to a prefix of xs
        """
        for l, c in enumerate(self._lanes):
            if c is not None and c.id == stream_id:
                self._lanes[l] = None
                return self._finalize_partial(c)
        for i, c in enumerate(self._queue):
            if c.id == stream_id:
                del self._queue[i]
                return self._finalize_partial(c)
        raise KeyError(f"batcher: no in-flight stream {stream_id!r}")

    def release(self, stream_id: Any) -> None:
        """Forget a finished stream's blob so its id can be resubmitted
        (retire-then-readmit)."""
        if stream_id not in self._done:
            raise KeyError(f"batcher: {stream_id!r} has no finished blob")
        del self._done[stream_id]
        self.evicted.discard(stream_id)

    def _finalize_partial(self, client: _Client) -> bytes:
        if not client.parts:   # never admitted: header-only empty stream
            client.parts.append(fmt.encode_header(fmt.StreamHeader(
                lanes=1, block_symbols=self.block_symbols,
                precision=self.precision)))
        client.parts.append(fmt.encode_trailer(
            fmt.Trailer(client.n_blocks, client.pos)))
        blob = b"".join(client.parts)
        self._done[client.id] = blob
        self.evicted.add(client.id)
        return blob

    def _evict_expired(self) -> None:
        now = self._clock()
        for l, c in enumerate(self._lanes):
            if c is not None and c.deadline is not None \
                    and now >= c.deadline:
                self._lanes[l] = None
                self._finalize_partial(c)
        expired = [c for c in self._queue
                   if c.deadline is not None and now >= c.deadline]
        for c in expired:
            self._queue.remove(c)
            self._finalize_partial(c)

    # -- scheduling ----------------------------------------------------------

    def _admit(self) -> None:
        for l in range(self.max_lanes):
            if self._lanes[l] is None and self._queue:
                client = self._queue.pop(0)
                client.parts.append(fmt.encode_header(fmt.StreamHeader(
                    lanes=1, block_symbols=self.block_symbols,
                    precision=self.precision)))
                if self._seed is not None:
                    key = jax.random.fold_in(
                        jax.random.PRNGKey(self._seed), self._admitted)
                    client.head = ans.make_stack(1, 1, key=key).head[0]
                self._admitted += 1
                self._lanes[l] = client

    def _retire(self, lane: int) -> None:
        client = self._lanes[lane]
        client.parts.append(fmt.encode_trailer(
            fmt.Trailer(client.n_blocks, client.pos)))
        self._done[client.id] = b"".join(client.parts)
        self._lanes[lane] = None

    @property
    def idle(self) -> bool:
        return not self._queue and all(c is None for c in self._lanes)

    def step(self) -> Dict[Any, bytes]:
        """One round: admit, code one block per active stream, retire.

        Returns the blobs of streams that *finished* this round
        (including any evicted on timeout - check ``evicted``).
        """
        finished_before = set(self._done)
        self._evict_expired()
        self._admit()
        active = [(l, c) for l, c in enumerate(self._lanes)
                  if c is not None]
        if not active:
            return {sid: blob for sid, blob in self._done.items()
                    if sid not in finished_before}
        counts = {l: min(self.block_symbols, c.remaining)
                  for l, c in active}
        n_steps = max(counts.values())
        if n_steps > 0:
            self._encode_round(active, counts, n_steps)
        for l, c in active:
            if c.remaining == 0:
                self._retire(l)
        self._round += 1
        return {sid: blob for sid, blob in self._done.items()
                if sid not in finished_before}

    def run(self) -> Dict[Any, bytes]:
        """Drive rounds until every submitted stream has its blob."""
        while not self.idle:
            self.step()
        return dict(self._done)

    # -- coding --------------------------------------------------------------

    def _default_capacity(self) -> int:
        per_lane = sum(int(np.prod(leaf.shape)) for leaf in
                       jax.tree_util.tree_leaves(self._zero_dp))
        return max(256, self.block_symbols * per_lane
                   + self._init_chunks + 64)

    def _round_stack(self, heads: jnp.ndarray, mask: jnp.ndarray,
                     capacity: int, chunks: int) -> ans.ANSStack:
        stack = ans.make_stack(self.max_lanes, capacity)
        stack = stack._replace(head=heads)
        if chunks:
            key = jax.random.fold_in(jax.random.PRNGKey(self._seed),
                                     1_000_003 + self._round)
            seeded = ans.seed_stack(stack, key, chunks)
            stack = ans.select_lanes(mask, seeded, stack)
        return stack

    def _encode_round(self, active, counts: Dict[int, int],
                      n_steps: int) -> None:
        # Lanes whose stream has no datapoints this round (freshly
        # admitted empties) stay fully masked and emit no block.
        active = [(l, c) for l, c in active if counts[l] > 0]
        lane_mask = np.zeros((self.max_lanes,), bool)
        n_valid_np = np.zeros((self.max_lanes,), np.int32)
        heads_np = np.full((self.max_lanes,), int(ans.RANS_L), np.uint32)
        for l, c in active:
            lane_mask[l] = True
            n_valid_np[l] = counts[l]
            if c.head is not None:
                heads_np[l] = int(c.head)
        mask = jnp.asarray(lane_mask)
        n_valid = jnp.asarray(n_valid_np)

        xs_steps = []
        by_lane = {l: c for l, c in active}
        for t in range(n_steps):
            per_lane = []
            for l in range(self.max_lanes):
                c = by_lane.get(l)
                if c is not None and t < counts[l]:
                    per_lane.append(c.datapoints[c.pos + t])
                else:
                    per_lane.append(self._zero_dp)
            xs_steps.append(jax.tree_util.tree_map(
                lambda *ls: jnp.stack(ls, axis=0), *per_lane))
        xs = jax.tree_util.tree_map(
            lambda *ls: jnp.stack(ls, axis=0), *xs_steps)

        cap = self._capacity or self._default_capacity()
        chunks = self._init_chunks
        for _ in range(self._max_retries):
            stack0 = self._round_stack(jnp.asarray(heads_np), mask, cap,
                                       chunks)
            stack = self._block.push(stack0, xs, n_valid)
            over = int(jnp.sum(jnp.where(mask, stack.overflows, 0)))
            under = int(jnp.sum(jnp.where(mask, stack.underflows, 0)))
            if not over and not under:
                self._capacity, self._init_chunks = cap, chunks
                msg, lengths = ans.flatten(stack)
                msg_np, lengths_np = np.asarray(msg), np.asarray(lengths)
                head_np = np.asarray(stack.head)
                for l, c in active:
                    c.parts.append(fmt.encode_block(
                        counts[l], msg_np[l:l + 1], lengths_np[l:l + 1]))
                    c.head = jnp.asarray(head_np[l])
                    c.pos += counts[l]
                    c.n_blocks += 1
                return
            if over:
                cap *= 2
            if under:
                if self._seed is None:
                    raise RuntimeError(
                        "batcher: stack underflow with seed=None - this "
                        "codec pops initial bits (bits-back); pass a "
                        "seed so per-block clean bits can be supplied")
                chunks = max(32, chunks * 4)
        raise RuntimeError(
            f"batcher: could not encode round cleanly after "
            f"{self._max_retries} attempts (capacity={cap}, "
            f"init_chunks={chunks})")


def decode_batched(codec, blobs: Dict[Any, bytes], max_lanes: int,
                   block_symbols: int) -> Dict[Any, Any]:
    """Batched decode of ``StreamBatcher`` blobs through one stack.

    Mirrors the encoder's scheduling (FIFO admission in dict order,
    sticky lanes, retire on exhaustion) so every codec call runs at the
    same ``max_lanes`` width as encoding did - the bitwise-determinism
    requirement for model-backed codecs. Pure-math codecs can equally
    decode each blob separately with a 1-lane ``StreamDecoder``.

    Example::

        outs = decode_batched(codec, blobs, max_lanes=8,
                              block_symbols=32)   # {stream_id: [n, ...]}
    """
    block = (codec if isinstance(codec, MaskedBlockCodec)
             else SteppedMaskedBlock(codec))

    class _D:
        def __init__(self, sid, blob):
            self.id = sid
            header, offsets, trailer = fmt.scan(blob)
            if header.lanes != 1:
                raise ValueError("decode_batched expects 1-lane client "
                                 f"blobs; got lanes={header.lanes}")
            if trailer is None:
                raise ValueError(
                    f"stream {sid!r}: truncated (no trailer)")
            self.blocks = []
            for off in offsets:
                frame, _ = fmt.decode_next(blob, off, 1)
                self.blocks.append(frame)
            if trailer.n_blocks != len(self.blocks):
                raise ValueError(f"stream {sid!r}: trailer mismatch")
            self.pos = 0
            self.out: List[Any] = []

    queue = [_D(sid, blob) for sid, blob in blobs.items()]
    lanes: List[Optional[_D]] = [None] * max_lanes
    results: Dict[Any, Any] = {}

    while queue or any(lanes):
        for l in range(max_lanes):
            if lanes[l] is None and queue:
                lanes[l] = queue.pop(0)
                if not lanes[l].blocks:   # empty stream: retire at once
                    results[lanes[l].id] = None
                    lanes[l] = None
        active = [(l, d) for l, d in enumerate(lanes) if d is not None]
        if not active:
            continue
        blocks = {l: d.blocks[d.pos] for l, d in active}
        n_valid_np = np.zeros((max_lanes,), np.int32)
        for l, _ in active:
            n_valid_np[l] = blocks[l].n_symbols
        k = int(n_valid_np.max())
        if k > 0:
            width = max(int(b.lengths.max()) for b in blocks.values())
            msg = np.zeros((max_lanes, width), np.uint16)
            lengths = np.full((max_lanes,), 2, np.int32)
            msg[:, 0] = 1   # free lanes: head = RANS_L, empty buffer
            for l, _ in active:
                b = blocks[l]
                msg[l, :b.msg.shape[1]] = b.msg[0]
                lengths[l] = b.lengths[0]
            stack = ans.unflatten(jnp.asarray(msg), jnp.asarray(lengths),
                                  capacity=max(width - 2, 8))
            n_valid = jnp.asarray(n_valid_np)
            stack, xs = block.pop(stack, k, n_valid)
            under = int(jnp.sum(jnp.where(n_valid > 0,
                                          stack.underflows, 0)))
            over = int(jnp.sum(jnp.where(n_valid > 0,
                                         stack.overflows, 0)))
            if under or over:
                raise ValueError(
                    f"decode_batched: {under} underflow(s), {over} "
                    "overflow(s) on valid lanes - corrupt stream")
            for l, d in active:
                for t in range(int(n_valid_np[l])):
                    d.out.append(jax.tree_util.tree_map(
                        lambda a: a[t][l], xs))
        for l, d in active:
            d.pos += 1
            if d.pos == len(d.blocks):
                results[d.id] = (jax.tree_util.tree_map(
                    lambda *ls: jnp.stack(ls, axis=0), *d.out)
                    if d.out else None)
                lanes[l] = None
    return results
