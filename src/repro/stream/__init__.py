"""``repro.stream`` - chunked streaming codec + dynamic batching.

The layer between the codec algebra (``repro.codecs``) and the serving
engine (``repro.serve``): arbitrary-length symbol streams are cut into
independently-decodable ``BBX2`` blocks (``format``), coded
incrementally with clean bits carried across block boundaries
(``coder``), and many concurrent client streams are packed into the
lane axis of one ``ANSStack`` (``batcher``).

    enc = stream.StreamEncoder(codec, lanes=16, block_symbols=64)
    wire = enc.write(xs)          # bytes out as blocks complete
    wire += enc.flush()           # ragged final block + trailer

    xs2 = stream.decode_stream(codec, wire)             # full decode
    tail = stream.decode_from_offset(codec, wire, off)  # resume

Dataset-scale corpora (``repro.shard_codec``) gather per-shard BBX2
segments into one ``BBX3`` blob; ``scan_corpus``/``corpus_segment``
seek into it by shard index without touching other shards' bytes.

Runnable examples for every exported name: docs/API.md; the BBX2/BBX3
byte layouts: docs/FORMATS.md; lane sharding: docs/SCALING.md.
"""

from repro.stream import format  # noqa: F401  (BBX2 + BBX3 wire formats)
from repro.stream.coder import (BlockChain, EncoderSnapshot,  # noqa: F401
                                KernelTableBlock,
                                StreamDecoder, StreamEncoder,
                                decode_from_offset, decode_stream,
                                encode_stream)
from repro.stream.batcher import (MaskedBlockCodec,  # noqa: F401
                                  SteppedMaskedBlock, StreamBatcher,
                                  decode_batched)
from repro.stream.format import (corpus_assignments,  # noqa: F401
                                 corpus_segment, encode_corpus,
                                 scan_corpus, shard_host)

__all__ = [
    "format",
    "BlockChain", "KernelTableBlock",
    "StreamEncoder", "StreamDecoder", "EncoderSnapshot",
    "encode_stream", "decode_stream", "decode_from_offset",
    "MaskedBlockCodec", "SteppedMaskedBlock", "StreamBatcher",
    "decode_batched",
    "encode_corpus", "scan_corpus", "corpus_segment",
    "shard_host", "corpus_assignments",
]
