"""Parameter / state / batch sharding policies for the production meshes.

2-D sharding (DESIGN.md section 5): tensor-parallel on ``model`` (heads,
ffn inner, vocab, experts), FSDP/ZeRO-3 on ``("pod", "data")`` over a large
remaining dim. Optimizer state follows parameters (AdamW moments share the
param spec; Adafactor row/col stats get the reduced spec). Policies are
*path-based*: they match pytree leaf paths, so any model built from the
shared layers gets covered; a test asserts total coverage per arch.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

FSDP = ("pod", "data")
TP = "model"

# (path-suffix patterns, spec builder by leaf ndim-after-stack)
# Specs are written for the *unstacked* leaf; a leading layer-stack axis
# (blocks/enc_blocks) gets None prepended automatically.


def _param_spec(path: str, ndim: int, stacked: bool) -> P:
    """Spec for one parameter leaf. ``path`` is '/'-joined key names."""
    base_ndim = ndim - (1 if stacked else 0)

    def out(*axes):
        axes = tuple(axes)
        assert len(axes) == base_ndim, (path, axes, base_ndim)
        return P(*(((None,) if stacked else ()) + axes))

    p = path.lower()
    # --- embeddings ---
    if "embed/table" in p or "unembed/table" in p:
        return out(TP, FSDP)
    # --- attention ---
    if "attn/wq/w" in p or "attn/wk/w" in p or "attn/wv/w" in p:
        return out(FSDP, TP, None)          # [D, H, Dh]
    if "attn/wq/b" in p or "attn/wk/b" in p or "attn/wv/b" in p:
        return out(TP, None)                # [H, Dh]
    if "attn/wo/w" in p or "xattn/wo/w" in p:
        return out(TP, FSDP)                # [H*Dh, D]
    if "xattn/wq/w" in p or "xattn/wk/w" in p or "xattn/wv/w" in p:
        return out(FSDP, TP, None)
    if "xattn/wq/b" in p or "xattn/wk/b" in p or "xattn/wv/b" in p:
        return out(TP, None)
    if "wo/b" in p:
        return out(FSDP)
    # --- MoE ---
    if "moe/router/w" in p:
        return out(FSDP, None)              # [D, E]
    if "moe/wi" in p or "moe/wg" in p:
        return out(TP, FSDP, None)          # [E, D, F]
    if "moe/wo" in p:
        return out(TP, None, FSDP)          # [E, F, D]
    if ("shared/" in p or "dense_mlp/" in p or "mlp/" in p
            or "cmix/" in p):
        if p.endswith("wi/w") or p.endswith("wg/w") or p.endswith("wk/w"):
            return out(FSDP, TP)            # [D, F]
        if p.endswith("wo/w") or p.endswith("wv/w"):
            return out(TP, FSDP)            # [F, D]
        if p.endswith("wr/w"):
            return out(FSDP, TP)            # [D, D] (rwkv cmix receptance)
        if p.endswith("/b"):
            return out(None) if base_ndim == 1 else out(*(None,) * base_ndim)
        if base_ndim == 1:
            return out(None)
    # --- RWKV mixer ---
    if "rwkv/" in p:
        if p.endswith(("wr/w", "wk/w", "wv/w", "wg/w")):
            return out(FSDP, TP, None)      # [D, H, Dh]
        if p.endswith("wo/w"):
            return out(TP, FSDP)            # [D, D]
        if "decay_lora_a" in p:
            return out(FSDP, None)          # [D, R]
        if "decay_lora_b" in p:
            return out(None, TP, None)      # [R, H, Dh]
        if "decay_base" in p or "bonus_u" in p:
            return out(TP, None)            # [H, Dh]
        if "mu/" in p or "ln_out" in p:
            return out(*(None,) * base_ndim)
    # --- SSM head (hymba) ---
    if "ssm/" in p:
        if p.endswith(("w_in/w", "w_z/w")):
            return out(FSDP, TP, None)      # [D, H, P]
        if p.endswith(("w_b/w", "w_c/w")):
            return out(FSDP, None)          # [D, N]
        if p.endswith("w_dt/w"):
            return out(FSDP, TP)            # [D, H]
        if p.endswith("w_dt/b") or "a_log" in p:
            return out(TP)                  # [H]
        if p.endswith("/d"):
            return out(TP, None)            # [H, P]
        if p.endswith("w_out/w"):
            return out(TP, FSDP)            # [D, D]
    # --- norms, scalars, small vectors: replicate ---
    return out(*(None,) * base_ndim)


def param_pspecs(params: Any) -> Any:
    """PartitionSpec pytree for a parameter pytree.

    Example::

        specs = param_pspecs(jax.eval_shape(init_fn))
        shardings = to_named(mesh, specs, jax.eval_shape(init_fn))
    """

    def one(path_tuple, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", k)))
                 for k in path_tuple]
        path = "/".join(names)
        stacked = names and names[0] in ("blocks", "enc_blocks")
        return _param_spec(path, np.ndim(leaf), stacked)

    return jax.tree_util.tree_map_with_path(one, params)


def _drop_last(spec: P) -> P:
    return P(*spec[:-1]) if len(spec) else P()


def _drop_second_last(spec: P) -> P:
    if len(spec) < 2:
        return P()
    return P(*(spec[:-2] + (spec[-1],)))


def opt_state_pspecs(opt_state: Any, params: Any, param_specs: Any) -> Any:
    """Shard optimizer state congruently with the params.

    AdamW moments take the parameter spec verbatim; Adafactor row/col
    statistics take the reduced specs (last / second-to-last axis
    dropped).

    Example::

        ospecs = opt_state_pspecs(opt.init(params), params,
                                  param_pspecs(params))
    """
    from repro.optim.adafactor import AdafactorState
    from repro.optim.adamw import AdamWState
    if isinstance(opt_state, AdamWState):
        return AdamWState(step=P(), mu=param_specs, nu=param_specs)
    if isinstance(opt_state, AdafactorState):
        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_s = tdef.flatten_up_to(param_specs)
        vr = tdef.unflatten([
            _drop_last(s) if np.ndim(p) >= 2 else s
            for p, s in zip(flat_p, flat_s)])
        vc = tdef.unflatten([
            _drop_second_last(s) if np.ndim(p) >= 2 else P()
            for p, s in zip(flat_p, flat_s)])
        mu = jax.tree_util.tree_map(lambda _: P(), opt_state.mu)
        return AdafactorState(step=P(), vr=vr, vc=vc, mu=mu)
    raise TypeError(type(opt_state))


def batch_pspecs(batch_shapes: Any) -> Any:
    """Batch inputs: leading axis data-parallel, rest replicated.

    Example::

        in_sh = to_named(mesh, batch_pspecs(batch_shapes), batch_shapes)
    """
    return jax.tree_util.tree_map(
        lambda leaf: P(FSDP, *(None,) * (len(leaf.shape) - 1)),
        batch_shapes)


def decode_state_pspecs(state_shapes: Any) -> Any:
    """Decode state: KV caches [L, B, T, H, Dh] -> batch on data, sequence
    on model (flash-decoding); recurrent states [L, B, H, ...] -> batch on
    data, heads on model; enc_out [B, S, D] -> batch on data."""

    def one(path_tuple, leaf):
        name = str(getattr(path_tuple[-1], "key", path_tuple[-1]))
        nd = len(leaf.shape)
        if name in ("k", "v", "kv_scales"):
            return P(None, FSDP, TP, None, None)
        if name == "S":                      # rwkv [L, B, H, N, N]
            return P(None, FSDP, TP, None, None)
        if name == "ssm_h":                  # [L, B, H, P, N]
            return P(None, FSDP, TP, None, None)
        if name in ("prev_x", "prev_x_ffn"):  # [L, B, 1, D]
            return P(None, FSDP, None, None)
        if name == "enc_out":                # [B, S, D]
            return P(FSDP, None, None)
        return P(*(None,) * nd)

    return jax.tree_util.tree_map_with_path(one, state_shapes)


def drop_fsdp(spec_tree: Any) -> Any:
    """Param specs with the FSDP (data) axes removed - the target layout
    for the regather-once optimization (TP-sharded, data-replicated).

    Example::

        serving_specs = drop_fsdp(param_pspecs(params))
    """
    fsdp_axes = set(FSDP)

    def fix(spec: P) -> P:
        out = []
        for entry in spec:
            if entry is None:
                out.append(None)
            elif isinstance(entry, str):
                out.append(None if entry in fsdp_axes else entry)
            else:
                kept = tuple(a for a in entry if a not in fsdp_axes)
                out.append(kept if kept else None)
        return P(*out)

    return jax.tree_util.tree_map(
        fix, spec_tree, is_leaf=lambda x: isinstance(x, P))


def to_named(mesh: Mesh, spec_tree: Any, shapes_tree: Any = None) -> Any:
    """PartitionSpec pytree -> NamedSharding pytree.

    Two normalizations (both required for `jit(in_shardings=...)`, which
    demands exact divisibility, unlike with_sharding_constraint):
      * mesh axes the mesh doesn't have are dropped (single-pod reuse of
        multi-pod specs);
      * axes that don't divide the dimension are dropped => replicate
        (e.g. GQA kv_heads=8 under 16-way TP). The standard pragmatic
        rule; revisit per-arch in the perf pass.
    """
    names = set(mesh.axis_names)

    def axis_size(entry) -> int:
        if entry is None:
            return 1
        if isinstance(entry, str):
            return mesh.shape[entry]
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n

    def fix(spec: P, shape=None) -> NamedSharding:
        fixed = []
        for i, entry in enumerate(spec):
            if entry is None or isinstance(entry, str):
                keep = entry if (entry is None or entry in names) else None
            else:
                kept = tuple(a for a in entry if a in names)
                keep = kept if kept else None
            if keep is not None and shape is not None:
                if shape[i] % axis_size(keep) != 0:
                    keep = None
            fixed.append(keep)
        return NamedSharding(mesh, P(*fixed))

    if shapes_tree is None:
        return jax.tree_util.tree_map(
            fix, spec_tree, is_leaf=lambda x: isinstance(x, P))
    return jax.tree_util.tree_map(
        lambda s, leaf: fix(s, tuple(leaf.shape)), spec_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, P))
