"""Logical-axis sharding API: models constrain activations by *logical*
names; launch code binds logical names to mesh axes.

Keeps model code mesh-agnostic (the 1000-node posture): the same forward
runs unsharded in unit tests, on a (data, model) pod, or on a
(pod, data, model) multi-pod mesh, with only the rule binding changing.

Two thread-local contexts live here:

  * ``use_mesh``      - the model-sharding context consumed by
    ``constrain`` (training/serving activations and parameters);
  * ``use_lane_mesh`` - the *coder*-sharding context consumed by the
    codec compiler (``codecs.compile``): while active, compiled codecs
    run their integer coder programs SPMD over the ANS lane axis via
    ``shard_map`` (docs/SCALING.md). They are independent on purpose -
    a codec service shards lanes without adopting model-parallel rules.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: The mesh axis name every lane-sharded coder program shards over.
LANE_AXIS = "lanes"

Axes = Union[None, str, Tuple[str, ...]]

#: Default logical->mesh binding for the production meshes (DESIGN.md 5).
DEFAULT_RULES: Dict[str, Axes] = {
    "batch": ("pod", "data"),
    "seq": None,            # sequence usually replicated; SP binds to model
    "kv_seq": None,         # decode KV sequence; SP binds leftover model
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "fsdp": ("pod", "data"),  # parameter dim sharded ZeRO-3 style
    "lanes": ("pod", "data"),  # ANS coder lanes (embarrassingly parallel)
}


class _Env(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Dict[str, Axes]] = None
    lane_mesh: Optional[Mesh] = None


_ENV = _Env()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[Dict[str, Axes]] = None):
    """Bind a mesh + logical rules for ``constrain`` within the context.

    Example::

        mesh = make_mesh_compat((2, 4), ("data", "model"))
        with use_mesh(mesh, {"seq": "model"}):
            y = constrain(x, "batch", "seq")   # sharded inside jit
    """
    prev = (_ENV.mesh, _ENV.rules)
    _ENV.mesh = mesh
    _ENV.rules = dict(DEFAULT_RULES, **(rules or {})) if mesh else None
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _ENV.mesh, _ENV.rules = prev


def current_mesh() -> Optional[Mesh]:
    """The mesh bound by the innermost ``use_mesh`` (None outside)."""
    return _ENV.mesh


# ---------------------------------------------------------------------------
# lane meshes (ANS coder data-parallelism; see docs/SCALING.md)
# ---------------------------------------------------------------------------

def lane_mesh(n_shards: Optional[int] = None) -> Mesh:
    """A 1-D mesh over local devices for lane-axis coder sharding.

    ``n_shards`` defaults to every local device; fewer is allowed (the
    leading devices are used). The single axis is named ``LANE_AXIS`` -
    the name ``shard_map``-wrapped coder programs and the ``lanes``
    entry of ``DEFAULT_RULES`` both resolve against.

    Example::

        mesh = lane_mesh()                     # all local devices
        with use_lane_mesh(mesh):
            blob = codecs.compress(compiled_codec, data, lanes=16)
    """
    devices = jax.devices()
    n = len(devices) if n_shards is None else n_shards
    if not 1 <= n <= len(devices):
        raise ValueError(
            f"sharding.lane_mesh: need 1 <= n_shards <= "
            f"{len(devices)} local devices, got {n_shards}")
    return Mesh(np.asarray(devices[:n]), (LANE_AXIS,))


@contextlib.contextmanager
def use_lane_mesh(mesh: Optional[Mesh]):
    """Bind a lane mesh for compiled-codec coder programs.

    Within the context, ``codecs.compile``'d codecs route their fused
    integer coder calls through ``shard_map`` over ``mesh`` - one SPMD
    program, lanes split across devices, wire bytes identical to the
    meshless path (integer coder ops are exact in any partitioning).
    The stack's lane count must be a multiple of the mesh size.

    Example::

        with use_lane_mesh(lane_mesh()):
            stack = prog.push(stack, xs)       # lanes split over devices
    """
    prev = _ENV.lane_mesh
    _ENV.lane_mesh = mesh
    try:
        yield
    finally:
        _ENV.lane_mesh = prev


def current_lane_mesh() -> Optional[Mesh]:
    """The mesh bound by the innermost ``use_lane_mesh`` (None outside)."""
    return _ENV.lane_mesh


def resolve(*logical: Optional[str]) -> P:
    """Map logical axis names to a PartitionSpec under the active rules.

    Names that are unbound (or when no mesh is active) resolve to None.
    Mesh axes that don't exist on the active mesh are dropped - this is what
    lets the same rules serve the single-pod mesh (no 'pod' axis).

    Example::

        with use_mesh(make_mesh_compat((4,), ("data",))):
            assert resolve("batch", "embed") == P("data", None)
    """
    rules = _ENV.rules or {}
    mesh_axes = set(_ENV.mesh.axis_names) if _ENV.mesh is not None else set()

    def one(name):
        if name is None:
            return None
        ax = rules.get(name)
        if ax is None:
            return None
        if isinstance(ax, str):
            return ax if ax in mesh_axes else None
        kept = tuple(a for a in ax if a in mesh_axes)
        return kept if kept else None

    # A mesh axis may appear at most once in a spec: first logical name
    # wins (e.g. with SP bound, "seq" takes 'model' and later names that
    # also resolve to 'model' fall back to replicated).
    used = set()
    out = []
    for n in logical:
        entry = one(n)
        if isinstance(entry, str):
            entry = None if entry in used else entry
            if entry:
                used.add(entry)
        elif isinstance(entry, tuple):
            kept = tuple(a for a in entry if a not in used)
            used.update(kept)
            entry = kept if kept else None
        out.append(entry)
    return P(*out)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh.

    Example::

        h = constrain(h, "batch", None, "ff")   # inside a jitted step
    """
    mesh = _ENV.mesh
    if mesh is None:
        return x
    spec = resolve(*logical)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def named_sharding(*logical: Optional[str]) -> Optional[NamedSharding]:
    """``NamedSharding`` for the logical names under the active mesh
    (None without one) - the ``jit(in_shardings=...)`` form of
    ``constrain``.

    Example::

        sh = named_sharding("batch")            # place a batch leaf
        batch = jax.device_put(batch, sh) if sh else batch
    """
    mesh = _ENV.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve(*logical))
