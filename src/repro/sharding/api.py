"""Logical-axis sharding API: models constrain activations by *logical*
names; launch code binds logical names to mesh axes.

Keeps model code mesh-agnostic (the 1000-node posture): the same forward
runs unsharded in unit tests, on a (data, model) pod, or on a
(pod, data, model) multi-pod mesh, with only the rule binding changing.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]

#: Default logical->mesh binding for the production meshes (DESIGN.md 5).
DEFAULT_RULES: Dict[str, Axes] = {
    "batch": ("pod", "data"),
    "seq": None,            # sequence usually replicated; SP binds to model
    "kv_seq": None,         # decode KV sequence; SP binds leftover model
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "fsdp": ("pod", "data"),  # parameter dim sharded ZeRO-3 style
    "lanes": ("pod", "data"),  # ANS coder lanes (embarrassingly parallel)
}


class _Env(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Dict[str, Axes]] = None


_ENV = _Env()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[Dict[str, Axes]] = None):
    """Bind a mesh + logical rules for ``constrain`` within the context."""
    prev = (_ENV.mesh, _ENV.rules)
    _ENV.mesh = mesh
    _ENV.rules = dict(DEFAULT_RULES, **(rules or {})) if mesh else None
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _ENV.mesh, _ENV.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _ENV.mesh


def resolve(*logical: Optional[str]) -> P:
    """Map logical axis names to a PartitionSpec under the active rules.

    Names that are unbound (or when no mesh is active) resolve to None.
    Mesh axes that don't exist on the active mesh are dropped - this is what
    lets the same rules serve the single-pod mesh (no 'pod' axis).
    """
    rules = _ENV.rules or {}
    mesh_axes = set(_ENV.mesh.axis_names) if _ENV.mesh is not None else set()

    def one(name):
        if name is None:
            return None
        ax = rules.get(name)
        if ax is None:
            return None
        if isinstance(ax, str):
            return ax if ax in mesh_axes else None
        kept = tuple(a for a in ax if a in mesh_axes)
        return kept if kept else None

    # A mesh axis may appear at most once in a spec: first logical name
    # wins (e.g. with SP bound, "seq" takes 'model' and later names that
    # also resolve to 'model' fall back to replicated).
    used = set()
    out = []
    for n in logical:
        entry = one(n)
        if isinstance(entry, str):
            entry = None if entry in used else entry
            if entry:
                used.add(entry)
        elif isinstance(entry, tuple):
            kept = tuple(a for a in entry if a not in used)
            used.update(kept)
            entry = kept if kept else None
        out.append(entry)
    return P(*out)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = _ENV.mesh
    if mesh is None:
        return x
    spec = resolve(*logical)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def named_sharding(*logical: Optional[str]) -> Optional[NamedSharding]:
    mesh = _ENV.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve(*logical))
