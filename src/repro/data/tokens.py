"""Synthetic token corpora with controllable, *known* entropy.

Offline container: no real text corpora. For LM training and for
LM-compression benchmarks we need token streams whose statistics a model
can actually learn and whose ground-truth entropy rate we can compute, so
achieved ANS rates have an analytic reference.

``markov_corpus`` generates an order-1 Markov chain over the vocabulary
with Zipfian stationary structure and a controllable mixing temperature;
its exact entropy rate is computable from the transition matrix.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def _zipf_probs(vocab: int, alpha: float, rng) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** -alpha
    rng.shuffle(p)
    return p / p.sum()


def make_transition_matrix(vocab: int, alpha: float = 1.2,
                           concentration: float = 40.0,
                           seed: int = 0) -> np.ndarray:
    """Row-stochastic [V, V]: Dirichlet perturbations around a Zipf base."""
    rng = np.random.default_rng(seed)
    base = _zipf_probs(vocab, alpha, rng)
    # Sparse support per row keeps generation + learning tractable.
    k = min(vocab, 64)
    rows = np.zeros((vocab, k))
    cols = np.zeros((vocab, k), np.int64)
    for v in range(vocab):
        sup = rng.choice(vocab, size=k, replace=False, p=base)
        w = rng.dirichlet(concentration * base[sup] /
                          base[sup].sum())
        rows[v], cols[v] = w, sup
    t = np.zeros((vocab, vocab))
    np.put_along_axis(t, cols, rows, axis=1)
    return t


def entropy_rate_bits(trans: np.ndarray, tol: float = 1e-10) -> float:
    """Exact entropy rate of the stationary chain, bits/token."""
    v = trans.shape[0]
    pi = np.full(v, 1.0 / v)
    for _ in range(2000):
        nxt = pi @ trans
        if np.abs(nxt - pi).max() < tol:
            break
        pi = nxt
    with np.errstate(divide="ignore", invalid="ignore"):
        logt = np.where(trans > 0, np.log2(trans), 0.0)
    return float(-(pi[:, None] * trans * logt).sum())


def markov_corpus(n_tokens: int, vocab: int = 256, seed: int = 0,
                  alpha: float = 1.2) -> Tuple[np.ndarray, float]:
    """Returns (tokens int32[n_tokens], exact entropy rate bits/token)."""
    trans = make_transition_matrix(vocab, alpha=alpha, seed=seed)
    rng = np.random.default_rng(seed + 1)
    cdf = np.cumsum(trans, axis=1)
    toks = np.empty(n_tokens, np.int32)
    toks[0] = rng.integers(vocab)
    u = rng.random(n_tokens)
    for i in range(1, n_tokens):
        toks[i] = np.searchsorted(cdf[toks[i - 1]], u[i])
    return toks, entropy_rate_bits(trans)
