"""Generic-compressor baselines for the Table-1 comparison.

The paper's headline artifact (Table 1) pits BB-ANS bits/dim against
off-the-shelf compressors on the full MNIST set. This module computes
those reference rates on any image batch:

  * ``gzip``/``bz2``/``lzma`` - stdlib, whole-corpus (one stream over
    the concatenated images; binarized corpora are bit-packed first);
  * ``png`` - real per-image PNG via PIL, when PIL is installed;
  * ``png_proxy`` - a dependency-free stand-in for PNG used by the CI
    benchmark: per image, PNG's actual pipeline (scanline filtering -
    Paeth for 8-bit, bit-packing for binary - then one zlib stream)
    plus PNG's fixed 57 bytes of per-file structure (signature + IHDR
    + IDAT framing + IEND). It tracks real PNG within a few percent on
    this corpus and keeps the benchmark rows identical with or without
    PIL.

Used by ``launch/compress.py`` (the Table-1 CLI) and
``benchmarks/dataset_rate.py``; ``benchmarks.common.baseline_rates``
delegates here.
"""

from __future__ import annotations

import bz2
import gzip
import lzma
import zlib
from typing import Dict, Optional, Tuple

import numpy as np

#: PNG per-file structural bytes: 8 signature + 25 IHDR + 12 IDAT
#: chunk framing + 12 IEND.
PNG_FIXED_BYTES = 57


def _paeth(a: np.ndarray, b: np.ndarray, c: np.ndarray) -> np.ndarray:
    p = a.astype(np.int32) + b.astype(np.int32) - c.astype(np.int32)
    pa, pb, pc = (np.abs(p - x.astype(np.int32)) for x in (a, b, c))
    return np.where((pa <= pb) & (pa <= pc), a,
                    np.where(pb <= pc, b, c)).astype(np.uint8)


def _filtered_scanlines(img: np.ndarray, binary: bool) -> bytes:
    """One image's IDAT input: filter byte + filtered bytes per row."""
    h, w = img.shape
    if binary:
        rows = [np.packbits(img[y].astype(np.uint8)).tobytes()
                for y in range(h)]
        return b"".join(b"\x00" + r for r in rows)
    out = []
    prev = np.zeros((w,), np.uint8)
    for y in range(h):
        row = img[y].astype(np.uint8)
        left = np.concatenate([[0], row[:-1]]).astype(np.uint8)
        upleft = np.concatenate([[0], prev[:-1]]).astype(np.uint8)
        filt = (row.astype(np.int32)
                - _paeth(left, prev, upleft).astype(np.int32)) % 256
        out.append(b"\x04" + filt.astype(np.uint8).tobytes())
        prev = row
    return b"".join(out)


def png_proxy_bytes(img: np.ndarray, binary: bool) -> int:
    """Size of one image as the dependency-free PNG proxy (see module
    docstring).

    Example::

        n = png_proxy_bytes(np.zeros((28, 28), np.uint8), binary=True)
        assert n > PNG_FIXED_BYTES
    """
    raw = _filtered_scanlines(np.asarray(img), binary)
    return len(zlib.compress(raw, 9)) + PNG_FIXED_BYTES


def png_bytes(img: np.ndarray, binary: bool) -> Optional[int]:
    """Size of one image as a real PNG (PIL); None when PIL is absent."""
    try:
        from PIL import Image
    except ImportError:
        return None
    import io
    arr = np.asarray(img, np.uint8)
    im = Image.fromarray(arr * 255 if binary else arr)
    if binary:
        im = im.convert("1")
    buf = io.BytesIO()
    im.save(buf, format="PNG", optimize=True)
    return buf.getbuffer().nbytes


def baseline_rates(images: np.ndarray, binary: bool,
                   hw: Tuple[int, int] = (28, 28),
                   with_png: bool = False,
                   try_real_png: bool = True) -> Dict[str, float]:
    """bits/dim of the generic compressors on an image batch.

    ``images`` is uint8 ``[n, H*W]`` (or ``[n, H, W]``); binarized
    corpora are bit-packed before the corpus-level compressors.
    ``with_png=True`` adds the per-image ``png_proxy`` row and, when
    PIL is installed, the real ``png`` row - pass
    ``try_real_png=False`` to skip the real-PNG pass (the CI bench
    does: its rows must be identical with or without PIL, so encoding
    every image twice would be wasted work).

    Example::

        rates = baseline_rates(imgs, binary=True, with_png=True)
        assert set(rates) >= {"gzip", "bz2", "lzma", "png_proxy"}
    """
    images = np.asarray(images)
    n_dims = images.size
    payload = np.packbits(images.astype(np.uint8)).tobytes() if binary \
        else images.astype(np.uint8).tobytes()
    out = {
        "gzip": len(gzip.compress(payload, 9)) * 8 / n_dims,
        "bz2": len(bz2.compress(payload, 9)) * 8 / n_dims,
        "lzma": len(lzma.compress(payload, preset=6)) * 8 / n_dims,
    }
    try:
        import zstandard as zstd
        out["zstd"] = len(zstd.ZstdCompressor(level=19).compress(payload)
                          ) * 8 / n_dims
    except ImportError:
        pass
    if with_png:
        imgs2d = images.reshape(-1, *hw)
        out["png_proxy"] = sum(
            png_proxy_bytes(im, binary) for im in imgs2d) * 8 / n_dims
        if try_real_png:
            real = [png_bytes(im, binary) for im in imgs2d]
            if all(r is not None for r in real):
                out["png"] = sum(real) * 8 / n_dims
    return out
