"""Arbitrary-H x W synthetic image batches (random-crop / pad collation).

The HVAE codec path is fully convolutional - one trained model codes
images of any (even) size. This module supplies the matching data side:
the 28 x 28 synthetic digits from ``synthetic_mnist`` are *collated* to
any requested target shape by random cropping (target smaller than
source) and/or zero padding at a random offset (target larger), per
axis independently - so a single source set exercises every shape.

Everything is seeded and step-indexed (pure function of ``(seed,
step)``), matching the restart-safe contract of ``data.pipeline``.
"""

from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np

from repro.data import synthetic_mnist


def collate(images: np.ndarray, hw: Tuple[int, int],
            rng: np.random.Generator) -> np.ndarray:
    """Crop/pad a [n, 28, 28] (or [n, 784]) batch to [n, H, W].

    Per axis: if the target is smaller, a random crop window is taken;
    if larger, the image lands at a random offset inside a zero canvas.
    Offsets are drawn per image, so the collation doubles as the usual
    random-translation augmentation.
    """
    if images.ndim == 2:
        images = images.reshape(-1, synthetic_mnist.H, synthetic_mnist.W)
    n, sh, sw = images.shape
    th, tw = hw
    out = np.zeros((n, th, tw), images.dtype)
    ch, cw = min(sh, th), min(sw, tw)
    src_y = rng.integers(0, sh - ch + 1, n)
    src_x = rng.integers(0, sw - cw + 1, n)
    dst_y = rng.integers(0, th - ch + 1, n)
    dst_x = rng.integers(0, tw - cw + 1, n)
    for i in range(n):
        out[i, dst_y[i]:dst_y[i] + ch, dst_x[i]:dst_x[i] + cw] = \
            images[i, src_y[i]:src_y[i] + ch, src_x[i]:src_x[i] + cw]
    return out


def pad_to_even(images: np.ndarray) -> np.ndarray:
    """Zero-pad [n, H, W] on the bottom/right so H and W are even (the
    only shape constraint of the stride-2 HVAE stem)."""
    n, h, w = images.shape
    return np.pad(images, ((0, 0), (0, h % 2), (0, w % 2)))


def load(split: str = "train", n: int = 8000, seed: int = 0,
         hw: Tuple[int, int] = (28, 28),
         binarized: bool = True) -> np.ndarray:
    """Synthetic digits collated to ``hw``: uint8 [n, H, W] (binary or
    0..255)."""
    imgs, _ = synthetic_mnist.load(split, n, seed)
    if binarized:
        imgs = synthetic_mnist.binarize(imgs, seed)
    salt = {"train": 0x5EED, "test": 0x7E57}[split]
    rng = np.random.default_rng(seed * 7919 + salt)
    return collate(imgs, hw, rng)


def image_batch_fn(images: np.ndarray, batch: int,
                   hw: Tuple[int, int]):
    """Step-indexed image batches at a fixed train shape.

    Returns a ``(seed, step, shard, nshards) -> {"images": [B, H, W]}``
    pure generator (the ``data.pipeline`` contract); collation offsets
    are re-drawn per step, so every step sees fresh crops.
    """
    if images.ndim == 2:
        images = images.reshape(-1, synthetic_mnist.H, synthetic_mnist.W)

    def fn(seed, step, shard, nshards):
        rng = np.random.default_rng((seed * 1_000_003 + step) ^ shard)
        local = batch // nshards
        idx = rng.integers(0, len(images), local)
        return {"images": collate(images[idx], hw, rng).astype(np.int32)}

    return fn


def shape_schedule(shapes: Sequence[Tuple[int, int]], step: int
                   ) -> Tuple[int, int]:
    """Deterministically cycle a set of image shapes across steps - the
    "one model, any size" evaluation schedule."""
    return tuple(shapes[step % len(shapes)])
