"""Deterministic synthetic MNIST-like digits (offline container substitute).

Real MNIST is not available in this offline environment (DESIGN.md section
6). This module procedurally renders 28x28 grayscale digits: per-digit
stroke polylines -> random affine jitter -> soft distance-field rasterization
-> intensity jitter. Statistically digit-like enough for (i) a VAE to learn,
(ii) generic compressors to be meaningfully compared, (iii) all rate
numbers to be reproducible (pure numpy, seeded).

API mirrors common MNIST loaders:
  load(split, n, seed)            -> uint8 [n, 784] in [0, 255]
  binarize(images, seed)          -> uint8 [n, 784] in {0, 1} (stochastic,
                                     as Salakhutdinov & Murray 2008)
"""

from __future__ import annotations

import numpy as np

H = W = 28
DIM = H * W


def _circle(cx, cy, rx, ry, n=14, a0=0.0, a1=2 * np.pi):
    t = np.linspace(a0, a1, n)
    return np.stack([cx + rx * np.cos(t), cy + ry * np.sin(t)], axis=-1)


def _digit_strokes():
    """List (per digit 0-9) of polylines; each polyline is [P, 2] in the
    unit square (x right, y down)."""
    d = {}
    d[0] = [_circle(0.5, 0.5, 0.21, 0.32)]
    d[1] = [np.array([[0.36, 0.28], [0.54, 0.16], [0.54, 0.84]])]
    d[2] = [np.concatenate([
        _circle(0.5, 0.32, 0.2, 0.17, 7, -np.pi, 0.0),
        np.array([[0.68, 0.38], [0.3, 0.84], [0.72, 0.84]])])]
    d[3] = [np.concatenate([
        _circle(0.47, 0.32, 0.2, 0.16, 7, -np.pi * 0.8, np.pi * 0.5),
        _circle(0.47, 0.67, 0.22, 0.18, 7, -np.pi * 0.5, np.pi * 0.82)])]
    d[4] = [np.array([[0.58, 0.14], [0.27, 0.6], [0.76, 0.6]]),
            np.array([[0.6, 0.34], [0.6, 0.86]])]
    d[5] = [np.concatenate([
        np.array([[0.7, 0.16], [0.33, 0.16], [0.31, 0.48]]),
        _circle(0.48, 0.65, 0.22, 0.19, 8, -np.pi * 0.45, np.pi * 0.75)])]
    d[6] = [np.concatenate([
        np.array([[0.64, 0.14], [0.42, 0.36]]),
        _circle(0.47, 0.65, 0.18, 0.2, 10, np.pi * 0.75,
                np.pi * 0.75 + 2 * np.pi)])]
    d[7] = [np.array([[0.3, 0.16], [0.72, 0.16], [0.44, 0.86]])]
    d[8] = [_circle(0.5, 0.33, 0.16, 0.15),
            _circle(0.5, 0.67, 0.2, 0.17)]
    d[9] = [_circle(0.52, 0.35, 0.17, 0.17),
            np.array([[0.69, 0.38], [0.6, 0.86]])]
    return [d[i] for i in range(10)]


def _pack_segments():
    """Pack all digit strokes into [10, S, 2, 2] segments + mask [10, S]."""
    strokes = _digit_strokes()
    segs, masks = [], []
    max_s = 0
    all_segs = []
    for polys in strokes:
        s = []
        for poly in polys:
            for i in range(len(poly) - 1):
                s.append(np.stack([poly[i], poly[i + 1]]))
        all_segs.append(np.array(s))
        max_s = max(max_s, len(s))
    for s in all_segs:
        pad = max_s - len(s)
        masks.append(np.concatenate([np.ones(len(s)), np.zeros(pad)]))
        if pad:
            s = np.concatenate([s, np.zeros((pad, 2, 2))])
        segs.append(s)
    return np.stack(segs), np.stack(masks).astype(bool)


_SEGS, _SEG_MASK = _pack_segments()


def render(labels: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Render a batch of digits. labels int[n] -> uint8 [n, 784]."""
    n = len(labels)
    # Pixel-centre coordinates in the unit square.
    ys, xs = np.meshgrid(np.arange(H), np.arange(W), indexing="ij")
    grid = np.stack([(xs + 0.5) / W, (ys + 0.5) / H], -1).reshape(-1, 2)

    # Per-image random affine (applied to grid coords, i.e. inverse map).
    ang = rng.uniform(-0.18, 0.18, n)
    scale = rng.uniform(0.85, 1.12, (n, 1))
    shear = rng.uniform(-0.12, 0.12, n)
    tx = rng.uniform(-0.07, 0.07, (n, 2))
    ca, sa = np.cos(ang), np.sin(ang)
    rot = np.stack([np.stack([ca, -sa], -1),
                    np.stack([sa, ca], -1)], -2)          # [n, 2, 2]
    shm = np.tile(np.eye(2), (n, 1, 1))
    shm[:, 0, 1] = shear
    amat = np.einsum("nij,njk->nik", rot, shm) / scale[..., None]
    centred = grid[None] - 0.5                           # [n, 784, 2]
    coords = np.einsum("nij,npj->npi", amat, centred) + 0.5 + tx[:, None]

    segs = _SEGS[labels]        # [n, S, 2, 2]
    mask = _SEG_MASK[labels]    # [n, S]
    a = segs[:, :, 0][:, None]  # [n, 1, S, 2]
    b = segs[:, :, 1][:, None]
    p = coords[:, :, None]      # [n, 784, 1, 2]
    ab = b - a
    denom = (ab * ab).sum(-1) + 1e-9
    t = ((p - a) * ab).sum(-1) / denom
    t = np.clip(t, 0.0, 1.0)
    proj = a + t[..., None] * ab
    dist = np.sqrt(((p - proj) ** 2).sum(-1))           # [n, 784, S]
    dist = np.where(mask[:, None], dist, np.inf).min(-1)  # [n, 784]

    width = rng.uniform(0.032, 0.05, (n, 1))
    inten = np.exp(-0.5 * (dist / width) ** 2)
    peak = rng.uniform(0.75, 1.0, (n, 1))
    img = np.clip(inten * peak * 255.0, 0, 255)
    # Faint sensor noise in the background, like MNIST's greyscale fringe.
    img += rng.uniform(0, 6, img.shape)
    return np.clip(img, 0, 255).astype(np.uint8)


def load(split: str = "train", n: int = 10000, seed: int = 0):
    """Deterministic split -> (images uint8 [n, 784], labels int[n])."""
    salt = {"train": 0x5EED, "test": 0x7E57}[split]
    rng = np.random.default_rng(seed * 1000003 + salt)
    labels = rng.integers(0, 10, n)
    return render(labels, rng), labels


def binarize(images: np.ndarray, seed: int = 0) -> np.ndarray:
    """Stochastic binarization (Salakhutdinov & Murray, 2008)."""
    rng = np.random.default_rng(seed + 0xB1A4)
    return (rng.random(images.shape) < images / 255.0).astype(np.uint8)
