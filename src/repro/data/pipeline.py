"""Deterministic, restart-safe host data pipeline.

Design (1000-node posture, DESIGN.md section 5):

  * every batch is a pure function of ``(seed, step)`` - restarts resume
    bitwise-identically from any checkpointed step with no state handoff;
  * each data-parallel host generates only its own shard (shard index and
    count are explicit), so ingestion scales with the fleet;
  * double-buffered background prefetch thread hides host latency.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np


class StepIndexedSource:
    """Wraps a ``(seed, step, shard, nshards) -> batch`` pure generator."""

    def __init__(self, gen_fn: Callable, seed: int,
                 shard: int = 0, nshards: int = 1):
        self.gen_fn = gen_fn
        self.seed = seed
        self.shard = shard
        self.nshards = nshards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        return self.gen_fn(self.seed, step, self.shard, self.nshards)


def lm_batch_fn(tokens: np.ndarray, batch: int, seq: int):
    """Slice a flat corpus into per-step LM batches, step-indexed."""

    def fn(seed, step, shard, nshards):
        rng = np.random.default_rng((seed * 1_000_003 + step) ^ shard)
        span = len(tokens) - seq - 1
        local = batch // nshards
        starts = rng.integers(0, span, local)
        out = np.stack([tokens[s:s + seq] for s in starts])
        return {"tokens": out.astype(np.int32)}

    return fn


def mnist_batch_fn(images: np.ndarray, batch: int):
    def fn(seed, step, shard, nshards):
        rng = np.random.default_rng((seed * 1_000_003 + step) ^ shard)
        local = batch // nshards
        idx = rng.integers(0, len(images), local)
        return {"images": images[idx]}

    return fn


class Prefetcher:
    """Background-thread prefetch of step-indexed batches."""

    def __init__(self, source: StepIndexedSource, start_step: int,
                 depth: int = 2):
        self.source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._next
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
