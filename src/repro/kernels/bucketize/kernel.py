"""Pallas TPU kernel: fused max-entropy Gaussian posterior decode.

Given per-lane (slot, mu, sigma) and the prior's bucket-edge table
``z[i] = ndtri(i / K)`` (computed once outside - it is shared by every
lane, latent dim and datapoint, ~16 KB in VMEM for K = 4096), finds
``idx = max{i : F(i) <= slot}`` for the pointwise fixed-point posterior
CDF ``F(i) = floor(ndtr((z[i]-mu)/sigma) * (2^prec - K)) + i`` and
returns (idx, start, freq) - the per-latent-dim hot loop of BB-ANS
decode. The bisection is ``lat_bits + 1`` fully-vectorized iterations;
ndtr lowers to the erfc VPU primitive.

Bit-exact vs ref.py / core.discretize: the edge table is built by the
same expression the core uses pointwise, and ndtr is the same primitive.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.scipy.special import ndtr

LANE_TILE = 128


def edge_table(lat_bits: int) -> jnp.ndarray:
    """z[i] = Phi^-1(i/K) for i = 0..K - the shared concrete table of
    ``core.discretize.edge_table`` (one source of truth: every coding
    path gathers the same bits, whatever the surrounding compilation
    context)."""
    from repro.core import discretize
    return discretize.edge_table(lat_bits)


def _bucketize_kernel(slot_ref, mu_ref, sigma_ref, edges_ref,
                      idx_ref, start_ref, freq_ref, *,
                      lat_bits: int, precision: int):
    slot = slot_ref[...]
    mu = mu_ref[...]
    sigma = sigma_ref[...]
    k = 1 << lat_bits
    scale = float((1 << precision) - k)

    def f(i):
        z = edges_ref[i]  # gather from the shared edge table
        c = ndtr((z - mu) * (1.0 / sigma))   # canonical form, see core
        c = jnp.where(i <= 0, 0.0, c)
        c = jnp.where(i >= k, 1.0, c)
        return jnp.floor(c * scale).astype(jnp.uint32) + i.astype(jnp.uint32)

    lo = jnp.zeros_like(slot, jnp.int32)
    hi = jnp.full_like(lo, k)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi + 1) // 2
        up = f(mid) <= slot
        return jnp.where(up, mid, lo), jnp.where(up, hi, mid)

    lo, hi = jax.lax.fori_loop(0, lat_bits + 1, body, (lo, hi))
    start = f(lo)
    idx_ref[...] = lo
    start_ref[...] = start
    freq_ref[...] = f(lo + 1) - start


def bucketize(slot: jnp.ndarray, mu: jnp.ndarray, sigma: jnp.ndarray,
              lat_bits: int, precision: int, interpret: bool = True,
              lane_tile: int = LANE_TILE):
    """uint32[lanes], f32[lanes], f32[lanes] -> (idx i32, start u32,
    freq u32). lanes must be a multiple of ``lane_tile`` (ops.py pads)."""
    lanes = slot.shape[0]
    if lanes % lane_tile != 0:
        raise ValueError(
            f"kernels.bucketize: lanes ({lanes}) must be a multiple of "
            f"lane_tile ({lane_tile}); ops.py pads before calling")
    k = 1 << lat_bits
    edges = edge_table(lat_bits)
    kernel = functools.partial(_bucketize_kernel, lat_bits=lat_bits,
                               precision=precision)
    spec = pl.BlockSpec((lane_tile,), lambda i: (i,))
    return pl.pallas_call(
        kernel,
        grid=(lanes // lane_tile,),
        in_specs=[spec, spec, spec,
                  pl.BlockSpec((k + 1,), lambda i: (0,))],
        out_specs=[spec, spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((lanes,), jnp.int32),
            jax.ShapeDtypeStruct((lanes,), jnp.uint32),
            jax.ShapeDtypeStruct((lanes,), jnp.uint32),
        ],
        interpret=interpret,
    )(slot, mu, sigma, edges)
