"""Oracle: the same posterior decode via core.discretize (pure jnp)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import discretize


def bucketize_ref(slot, mu, sigma, lat_bits, precision):
    f = discretize.posterior_starts_fn(mu, sigma, lat_bits, precision)
    lo = jnp.zeros_like(slot, jnp.int32)
    hi = jnp.full_like(lo, 1 << lat_bits)
    import jax
    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi + 1) // 2
        up = f(mid) <= slot
        return jnp.where(up, mid, lo), jnp.where(up, hi, mid)
    lo, hi = jax.lax.fori_loop(0, lat_bits + 1, body, (lo, hi))
    start = f(lo)
    return lo, start, f(lo + 1) - start
