"""Pure-XLA twin of the fused posterior-decode bucketize kernel.

Same contract as ``kernel.bucketize`` - (slot, mu, sigma) per lane plus
the shared edge table -> (idx, start, freq) - but the bisection runs as
straight-line XLA over the caller's lane count: no LANE_TILE padding,
no Pallas interpreter. The CDF chain is expression-identical to
``kernel._bucketize_kernel`` (and ``core.discretize``), so the gathered
bits match bit-for-bit on every backend.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax.scipy.special import ndtr


def bucketize(slot: jnp.ndarray, mu: jnp.ndarray, sigma: jnp.ndarray,
              edges: jnp.ndarray, lat_bits: int, precision: int):
    """uint32[lanes], f32[lanes], f32[lanes], f32[K+1] ->
    (idx i32, start u32, freq u32) - any lane count."""
    k = 1 << lat_bits
    scale = float((1 << precision) - k)

    def f(i):
        z = edges[i]
        c = ndtr((z - mu) * (1.0 / sigma))   # canonical form, see core
        c = jnp.where(i <= 0, 0.0, c)
        c = jnp.where(i >= k, 1.0, c)
        return jnp.floor(c * scale).astype(jnp.uint32) \
            + i.astype(jnp.uint32)

    lo = jnp.zeros_like(slot, jnp.int32)
    hi = jnp.full_like(lo, k)
    for _ in range(lat_bits + 1):            # static-count bisection
        mid = (lo + hi + 1) // 2
        up = f(mid) <= slot
        lo = jnp.where(up, mid, lo)
        hi = jnp.where(up, hi, mid)
    start = f(lo)
    return lo, start, f(lo + 1) - start
