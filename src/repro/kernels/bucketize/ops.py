"""jit wrapper for the fused posterior-decode kernel (pads lane tiles)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.bucketize import kernel as K


def bucketize(slot, mu, sigma, lat_bits, precision, interpret=True):
    lanes = slot.shape[0]
    pad = (-lanes) % K.LANE_TILE
    if pad:
        slot = jnp.pad(slot, (0, pad))
        mu = jnp.pad(mu, (0, pad))
        sigma = jnp.pad(sigma, (0, pad), constant_values=1.0)
    idx, start, freq = K.bucketize(slot, mu, sigma, lat_bits, precision,
                                   interpret=interpret)
    return idx[:lanes], start[:lanes], freq[:lanes]
