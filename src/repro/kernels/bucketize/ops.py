"""Dispatched wrapper for the fused posterior-decode bucketize op.

Backend selection follows ``kernels.dispatch`` (XLA twin on CPU,
compiled Pallas on accelerators, interpreter as oracle); the Pallas
paths pad lanes to the decision's tile width, the XLA path runs the
caller's lane count as-is.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.bucketize import kernel as K
from repro.kernels.bucketize import xla as X


def bucketize(slot, mu, sigma, lat_bits, precision,
              backend: dispatch.BackendLike = None):
    """uint32[lanes], f32[lanes], f32[lanes] -> (idx i32, start u32,
    freq u32): ``idx = max{i : F(i) <= slot}`` under the pointwise
    fixed-point posterior CDF (see kernel.py). Bit-exact on every
    backend."""
    lanes = slot.shape[0]
    d = dispatch.resolve("bucketize", lanes=lanes, backend=backend)
    if d.backend == "xla":
        return X.bucketize(slot, mu.astype(jnp.float32),
                           sigma.astype(jnp.float32),
                           K.edge_table(lat_bits), lat_bits, precision)
    pad = (-lanes) % d.lane_tile
    if pad:
        slot = jnp.pad(slot, (0, pad))
        mu = jnp.pad(mu, (0, pad))
        sigma = jnp.pad(sigma, (0, pad), constant_values=1.0)
    idx, start, freq = K.bucketize(slot, mu, sigma, lat_bits, precision,
                                   interpret=(d.backend == "interpret"),
                                   lane_tile=d.lane_tile)
    return idx[:lanes], start[:lanes], freq[:lanes]
