"""Backend dispatch for the coder kernels: pallas / xla / interpret.

Every hot op in ``kernels/ans/ops.py`` and ``kernels/bucketize/ops.py``
has up to three bit-identical implementations:

  * ``"pallas"``    - ``pl.pallas_call`` compiled through Mosaic (TPU)
                      or Triton (GPU). Only available when an
                      accelerator platform is active.
  * ``"xla"``       - the pure-XLA twins in ``kernels/*/xla.py``: same
                      loop bodies jitted straight through XLA, no lane
                      padding, tunable ``fori_loop`` unroll. The CPU
                      fast path.
  * ``"interpret"`` - ``pl.pallas_call(interpret=True)``: the Pallas
                      interpreter emulating the kernel. Runs anywhere;
                      the last-resort oracle and the historical
                      behaviour of every op before the dispatcher
                      existed.

``resolve(op, ...)`` picks one as a :class:`Decision` (backend +
lane-tile + unroll), with precedence:

  1. an explicit ``backend=`` argument (string or ``Decision``),
  2. the ``REPRO_KERNEL_BACKEND`` environment variable,
  3. an enclosing ``with use_backend(...)`` context,
  4. the persisted tuning cache (``kernels.tuning``, measured once),
  5. the platform heuristic: ``xla`` on CPU, ``pallas`` on TPU/GPU.

Wire bytes never depend on the choice - the parity suite
(``tests/test_dispatch.py``) pins every available backend to the
``ref.py`` oracles and the committed golden fixtures.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
from typing import Iterator, Optional, Tuple, Union

import jax

BACKENDS = ("pallas", "xla", "interpret")

# Default Pallas lane tile; kernels accept other multiples of the VPU
# width via Decision.lane_tile (the autotuner's tiling candidates).
DEFAULT_LANE_TILE = 128

_ENV_BACKEND = "REPRO_KERNEL_BACKEND"


@dataclasses.dataclass(frozen=True)
class Decision:
    """One resolved kernel choice. Frozen + hashable, so a Decision can
    ride through ``jax.jit`` as a static argument - the tuner times
    candidates by passing them straight to the public ops."""

    backend: str
    lane_tile: int = DEFAULT_LANE_TILE   # pallas/interpret tile width
    unroll: int = 1                      # xla fori_loop unroll factor

    def __post_init__(self) -> None:
        if self.backend not in BACKENDS:
            raise ValueError(
                f"kernels.dispatch: unknown backend {self.backend!r} "
                f"(expected one of {BACKENDS})")
        if self.lane_tile < 1 or self.unroll < 1:
            raise ValueError(
                "kernels.dispatch: lane_tile and unroll must be >= 1 "
                f"(got lane_tile={self.lane_tile}, unroll={self.unroll})")


BackendLike = Union[None, str, Decision]

class _ContextStack(threading.local):
    """Per-thread ``use_backend()`` stack (innermost last): the serve
    engines pin backends per request from a thread pool, so one
    request's pin must not leak into a concurrent one."""

    def __init__(self) -> None:
        self.stack: list = []


_CONTEXT = _ContextStack()


def platform() -> str:
    """The active JAX platform ("cpu", "tpu", "gpu", ...)."""
    return jax.default_backend()


def available_backends(plat: Optional[str] = None) -> Tuple[str, ...]:
    """Backends that can actually run on ``plat`` (default: active
    platform), best-first. ``pallas`` compiled mode needs a Mosaic or
    Triton lowering, so it is only offered off-CPU."""
    p = plat if plat is not None else platform()
    if p == "cpu":
        return ("xla", "interpret")
    return ("pallas", "xla", "interpret")


def _normalize(backend: BackendLike) -> Optional[Decision]:
    if backend is None:
        return None
    if isinstance(backend, Decision):
        return backend
    return Decision(backend=backend)


@contextlib.contextmanager
def use_backend(backend: Union[str, Decision]) -> Iterator[Decision]:
    """Force a backend for every dispatched op in the ``with`` body
    (unless a call passes an explicit ``backend=``). Nests; innermost
    wins. The serve engines and benchmark pins use this.

    Example::

        with use_backend("xla"):
            blob = engine.compress(data)
    """
    decision = _normalize(backend)
    _CONTEXT.stack.append(decision)
    try:
        yield decision
    finally:
        _CONTEXT.stack.pop()


def resolve(op: str, lanes: Optional[int] = None,
            table_size: Optional[int] = None,
            backend: BackendLike = None) -> Decision:
    """Resolve ``op`` to a concrete :class:`Decision`.

    ``lanes`` / ``table_size`` describe the workload for the tuning
    cache; they do not change which backends are legal. Resolution is
    pure lookup - it never times anything (measured autotuning is
    explicit: ``kernels.tuning.autotune``).
    """
    explicit = _normalize(backend)
    if explicit is not None:
        return explicit

    env = os.environ.get(_ENV_BACKEND)
    if env:
        return Decision(backend=env)

    if _CONTEXT.stack:
        return _CONTEXT.stack[-1]

    from repro.kernels import tuning
    cached = tuning.lookup(platform(), op, lanes=lanes,
                           table_size=table_size)
    if cached is not None:
        return cached

    return Decision(backend=available_backends()[0])
