"""Dispatched ANSStack coder ops: one public surface, three backends.

``push_many`` is the production batch-encode path: the ALU-bound coder
loop runs in whichever backend ``kernels.dispatch`` resolves - the
pure-XLA twin (``xla.py``, the CPU fast path: no lane padding, tunable
unroll), the compiled Pallas kernel (``kernel.py`` on TPU/GPU), or the
Pallas interpreter as the last-resort oracle - and the irregular
per-lane stack append becomes one vectorized cumsum + scatter.
``pop_many`` is its decode twin: the table search and state updates run
in the selected backend against a pre-gathered chunk feed (each pop
reads at most one chunk, in stack order, so the feed is a dense
[steps, lanes] slice), and the per-lane pointer/underflow bookkeeping
happens outside. All backends are bit-exact equivalents of the
sequential ``repro.core.ans`` calls, validated against the ``ref.py``
oracle and each other (tests/test_dispatch.py); ``repro.stream`` and
``codecs.compile`` use them as the block coder's fast path.

``backend=`` accepts None (resolve via env / context / tuning cache /
platform heuristic), a backend name, or a full ``dispatch.Decision``
(hashable, so compiled programs pass it through ``jax.jit`` statically).
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core import ans
from repro.kernels import dispatch
from repro.kernels.ans import kernel as K
from repro.kernels.ans import xla as X


def push_many(stack: ans.ANSStack, starts: jnp.ndarray, freqs: jnp.ndarray,
              precision: int = ans.DEFAULT_PRECISION,
              backend: dispatch.BackendLike = None) -> ans.ANSStack:
    """Push ``steps`` symbols per lane. starts/freqs uint32[steps, lanes].

    Bit-exact equivalent of ``steps`` sequential ``ans.push`` calls,
    whatever backend resolves.
    """
    steps, lanes = starts.shape
    d = dispatch.resolve("push_many", lanes=lanes, backend=backend)
    if d.backend == "xla":
        new_head, chunks, need = X.push_emit(stack.head, starts, freqs,
                                             precision, unroll=d.unroll)
    else:
        head = stack.head
        pad = (-lanes) % d.lane_tile
        if pad:
            head = jnp.pad(head, (0, pad), constant_values=1 << 16)
            starts = jnp.pad(starts, ((0, 0), (0, pad)))
            freqs = jnp.pad(freqs, ((0, 0), (0, pad)), constant_values=1)
        new_head, chunks, need = K.push_emit(
            head, starts, freqs, precision,
            interpret=(d.backend == "interpret"), lane_tile=d.lane_tile)
        new_head = new_head[:lanes]
        chunks = chunks[:, :lanes]
        need = need[:, :lanes]
    # Compaction: chunk emitted at step t lands at ptr + (#emits before t).
    before = jnp.cumsum(need, axis=0) - need
    pos = stack.ptr[None, :] + before
    cols = jnp.where(need.astype(bool), pos, stack.capacity)  # drop if not
    rows = jnp.broadcast_to(jnp.arange(lanes)[None, :], cols.shape)
    buf = stack.buf.at[rows, cols].set(chunks.astype(jnp.uint16),
                                       mode="drop")
    ptr = stack.ptr + jnp.sum(need, axis=0).astype(jnp.int32)
    over = jnp.sum(need.astype(bool) & (pos >= stack.capacity),
                   axis=0).astype(jnp.int32)
    return stack._replace(head=new_head, buf=buf, ptr=ptr,
                          overflows=stack.overflows + over)


def push_many_table(stack: ans.ANSStack, starts_table: jnp.ndarray,
                    symbols: jnp.ndarray,
                    precision: int = ans.DEFAULT_PRECISION,
                    backend: dispatch.BackendLike = None) -> ans.ANSStack:
    """Push ``steps`` symbols per lane from a static per-lane table.

    ``starts_table``: uint32[lanes, A+1] cumulative starts (as in
    ``ans.push_with_table``); ``symbols``: int[steps, lanes]. Bit-exact
    equivalent of ``steps`` sequential ``ans.push_with_table`` calls.
    """
    sym = symbols.astype(jnp.int32)
    rows = jnp.arange(stack.lanes)[None, :]
    starts = starts_table[rows, sym]
    freqs = starts_table[rows, sym + 1] - starts
    if backend is None:
        backend = dispatch.resolve(
            "push_many_table", lanes=stack.lanes,
            table_size=starts_table.shape[-1] - 1)
    return push_many(stack, starts.astype(jnp.uint32),
                     freqs.astype(jnp.uint32), precision, backend)


def _chunk_feed(stack: ans.ANSStack, steps: int) -> jnp.ndarray:
    """Pre-gather the renormalization chunk feed for a ``steps``-pop.

    ``feed[r, l]`` is the ``r``-th chunk lane ``l``'s stack would serve:
    ``buf[l, ptr-1-r]`` clamped at the bottom (the core re-serves the
    bottom chunk on underflow - replicated here for bit-exactness).
    """
    lanes = stack.lanes
    if not stack.capacity:   # chunk-less stack: every read serves 0
        return jnp.zeros((steps, lanes), jnp.uint32)
    t = jnp.arange(steps)
    cols = jnp.clip(stack.ptr[None, :] - 1 - t[:, None], 0,
                    stack.capacity - 1)
    return stack.buf[jnp.arange(lanes)[None, :], cols].astype(jnp.uint32)


def _finish_pop(stack: ans.ANSStack, new_head: jnp.ndarray,
                syms: jnp.ndarray, reads: jnp.ndarray
                ) -> Tuple[ans.ANSStack, jnp.ndarray]:
    """Apply the kernel's (head, reads) to the stack bookkeeping."""
    lanes = stack.lanes
    new_head = new_head[:lanes]
    syms = syms[:, :lanes].astype(jnp.int32)
    reads = reads[:lanes].astype(jnp.int32)
    under = jnp.maximum(reads - stack.ptr, 0)
    ptr = jnp.maximum(stack.ptr - reads, 0)
    return stack._replace(head=new_head, ptr=ptr,
                          underflows=stack.underflows + under), syms


def pop_many(stack: ans.ANSStack, starts_table: jnp.ndarray, steps: int,
             precision: int = ans.DEFAULT_PRECISION,
             backend: dispatch.BackendLike = None
             ) -> Tuple[ans.ANSStack, jnp.ndarray]:
    """Pop ``steps`` symbols per lane from a static per-lane table.

    Bit-exact equivalent of ``steps`` sequential ``ans.pop_with_table``
    calls, including the underflow accounting (reads past the stack
    bottom re-serve the bottom chunk, exactly as ``ans.pop_update``
    does). Returns ``(stack, symbols int32[steps, lanes])`` with symbols
    in pop order.
    """
    lanes = stack.lanes
    d = dispatch.resolve("pop_many", lanes=lanes,
                         table_size=starts_table.shape[-1] - 1,
                         backend=backend)
    feed = _chunk_feed(stack, steps)
    head, table = stack.head, starts_table.astype(jnp.uint32)
    if d.backend == "xla":
        new_head, syms, reads = X.pop_table_emit(head, table, feed,
                                                 precision,
                                                 unroll=d.unroll)
    else:
        pad = (-lanes) % d.lane_tile
        if pad:
            head = jnp.pad(head, (0, pad), constant_values=1 << 16)
            table = jnp.pad(table, ((0, pad), (0, 0)))
            feed = jnp.pad(feed, ((0, 0), (0, pad)))
        new_head, syms, reads = K.pop_table_emit(
            head, table, feed, precision,
            interpret=(d.backend == "interpret"), lane_tile=d.lane_tile)
    return _finish_pop(stack, new_head, syms, reads)


def pop_many_dyn(stack: ans.ANSStack, tables: jnp.ndarray,
                 precision: int = ans.DEFAULT_PRECISION,
                 backend: dispatch.BackendLike = None
                 ) -> Tuple[ans.ANSStack, jnp.ndarray]:
    """Pop ``steps`` symbols per lane from *per-step* dynamic tables.

    ``tables``: uint32[steps, lanes, A+1] cumulative starts, one table
    per step per lane (the decode twin of the dynamic ``push_many``).
    Bit-exact equivalent of ``steps`` sequential ``ans.pop_with_table``
    calls against ``tables[t]``. Returns ``(stack, symbols int32[steps,
    lanes])`` in pop order.
    """
    steps, lanes = tables.shape[0], stack.lanes
    d = dispatch.resolve("pop_many_dyn", lanes=lanes,
                         table_size=tables.shape[-1] - 1, backend=backend)
    feed = _chunk_feed(stack, steps)
    head, tables = stack.head, tables.astype(jnp.uint32)
    if d.backend == "xla":
        new_head, syms, reads = X.pop_dyntable_emit(head, tables, feed,
                                                    precision,
                                                    unroll=d.unroll)
    else:
        pad = (-lanes) % d.lane_tile
        if pad:
            head = jnp.pad(head, (0, pad), constant_values=1 << 16)
            tables = jnp.pad(tables, ((0, 0), (0, pad), (0, 0)))
            feed = jnp.pad(feed, ((0, 0), (0, pad)))
        new_head, syms, reads = K.pop_dyntable_emit(
            head, tables, feed, precision,
            interpret=(d.backend == "interpret"), lane_tile=d.lane_tile)
    return _finish_pop(stack, new_head, syms, reads)


def pop_many_grid(stack: ans.ANSStack, kind: str, mu: jnp.ndarray,
                  sigma: jnp.ndarray, steps: int, lat_bits: int,
                  precision: int = ans.DEFAULT_PRECISION,
                  backend: dispatch.BackendLike = None
                  ) -> Tuple[ans.ANSStack, jnp.ndarray]:
    """Fused bucketize+pop over the max-entropy N(0,1) bucket grid.

    Decodes ``steps`` bucket indices per lane under per-step
    distributions on the shared grid: ``kind="gaussian"`` is bit-exact
    vs sequential ``discretize.pop_posterior(mu[t], sigma[t])``,
    ``"logistic"`` vs ``codecs.DiscretizedLogistic(mu[t], sigma[t])``
    pops (``sigma`` carries the scale), ``"uniform"`` vs
    ``discretize.pop_prior`` (mu/sigma ignored; pass zeros). The CDF
    bisection of ``kernels/bucketize`` runs inside the pop renorm chain
    - one program for the whole [steps, lanes] grid.
    """
    from repro.kernels.bucketize import kernel as BK

    lanes = stack.lanes
    d = dispatch.resolve("pop_many_grid", lanes=lanes, backend=backend)
    feed = _chunk_feed(stack, steps)
    head = stack.head
    if kind == "uniform":
        mu = jnp.zeros((steps, lanes), jnp.float32)
        sigma = jnp.ones((steps, lanes), jnp.float32)
        edges = jnp.zeros((2,), jnp.float32)
    else:
        mu = mu.astype(jnp.float32)
        sigma = sigma.astype(jnp.float32)
        edges = BK.edge_table(lat_bits)
    if d.backend == "xla":
        new_head, idx, reads = X.pop_grid_emit(head, mu, sigma, feed,
                                               edges, kind, lat_bits,
                                               precision, unroll=d.unroll)
    else:
        pad = (-lanes) % d.lane_tile
        if pad:
            head = jnp.pad(head, (0, pad), constant_values=1 << 16)
            mu = jnp.pad(mu, ((0, 0), (0, pad)))
            sigma = jnp.pad(sigma, ((0, 0), (0, pad)),
                            constant_values=1.0)
            feed = jnp.pad(feed, ((0, 0), (0, pad)))
        new_head, idx, reads = K.pop_grid_emit(
            head, mu, sigma, feed, edges, kind, lat_bits, precision,
            interpret=(d.backend == "interpret"), lane_tile=d.lane_tile)
    return _finish_pop(stack, new_head, idx, reads)
