"""jit wrapper: Pallas emission kernel + XLA compaction -> ANSStack push.

``push_many`` is the production batch-encode path: the ALU-bound coder
loop runs in the Pallas kernel (VPU lanes), the irregular per-lane stack
append becomes one vectorized cumsum + scatter.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ans
from repro.kernels.ans import kernel as K


def push_many(stack: ans.ANSStack, starts: jnp.ndarray, freqs: jnp.ndarray,
              precision: int = ans.DEFAULT_PRECISION,
              interpret: bool = True) -> ans.ANSStack:
    """Push ``steps`` symbols per lane. starts/freqs uint32[steps, lanes].

    Bit-exact equivalent of ``steps`` sequential ``ans.push`` calls.
    """
    steps, lanes = starts.shape
    pad = (-lanes) % K.LANE_TILE
    head = stack.head
    if pad:
        head = jnp.pad(head, (0, pad), constant_values=1 << 16)
        starts = jnp.pad(starts, ((0, 0), (0, pad)))
        freqs = jnp.pad(freqs, ((0, 0), (0, pad)), constant_values=1)
    new_head, chunks, need = K.push_emit(head, starts, freqs, precision,
                                         interpret=interpret)
    new_head = new_head[:lanes]
    chunks = chunks[:, :lanes]
    need = need[:, :lanes]
    # Compaction: chunk emitted at step t lands at ptr + (#emits before t).
    before = jnp.cumsum(need, axis=0) - need
    pos = stack.ptr[None, :] + before
    cols = jnp.where(need.astype(bool), pos, stack.capacity)  # drop if not
    rows = jnp.broadcast_to(jnp.arange(lanes)[None, :], cols.shape)
    buf = stack.buf.at[rows, cols].set(chunks.astype(jnp.uint16),
                                       mode="drop")
    ptr = stack.ptr + jnp.sum(need, axis=0).astype(jnp.int32)
    over = jnp.sum(need.astype(bool) & (pos >= stack.capacity),
                   axis=0).astype(jnp.int32)
    return stack._replace(head=new_head, buf=buf, ptr=ptr,
                          overflows=stack.overflows + over)
