"""Pure-XLA jitted twins of the Pallas coder kernels (the CPU fast path).

Each entry point mirrors the matching ``kernel.py`` wrapper - same
arguments, same outputs, bit-identical results - but lowers the coder
loop straight through XLA instead of ``pl.pallas_call``:

  * no lane-tile constraint: the caller's lane count runs as-is (the
    Pallas paths pad to a ``lane_tile`` multiple, which on a 4-lane
    codec-compile workload does 32x the useful work);
  * the whole lane axis is one vector per step instead of a grid of
    tiles, so there is no interpreter masking/copy overhead when the
    platform has no Mosaic/Triton lowering (CPU);
  * an ``unroll`` knob forwards to ``lax.fori_loop`` - the lane-tiling
    autotuner (``kernels.tuning``) measures candidate unroll factors
    per (op, platform, shape) and persists the winner.

Bit-exactness: the loop bodies are copied expression-for-expression
from ``kernel.py`` (integer renorm arithmetic is exact in any fusion
context; the grid CDF chain is the canonical reciprocal-multiply form
shared with ``core.discretize``, stable under fusion by the PR-4
determinism contract). ``tests/test_dispatch.py`` pins every backend
to the ``ref.py`` oracles and to the committed golden wires.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def push_emit(head: jnp.ndarray, starts: jnp.ndarray, freqs: jnp.ndarray,
              precision: int, unroll: int = 1
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """XLA twin of ``kernel.push_emit``: any lane count, no padding."""
    steps, lanes = starts.shape

    def body(t, carry):
        head, chunks, need = carry
        start = starts[t]
        freq = freqs[t]
        x_max = freq << (32 - precision)
        n = head >= x_max
        chunk = jnp.where(n, head & jnp.uint32(0xFFFF), jnp.uint32(0))
        chunks = chunks.at[t].set(chunk)
        need = need.at[t].set(n.astype(jnp.uint32))
        head = jnp.where(n, head >> 16, head)
        return (((head // freq) << precision) + (head % freq) + start,
                chunks, need)

    zeros = jnp.zeros((steps, lanes), jnp.uint32)
    return jax.lax.fori_loop(0, steps, body, (head, zeros, zeros),
                             unroll=unroll)


def pop_slots(head: jnp.ndarray, precision: int) -> jnp.ndarray:
    """XLA twin of ``kernel.pop_slots``: slot = head mod 2^precision."""
    return head & jnp.uint32((1 << precision) - 1)


def pop_table_emit(head: jnp.ndarray, table: jnp.ndarray,
                   feed: jnp.ndarray, precision: int, unroll: int = 1):
    """XLA twin of ``kernel.pop_table_emit`` (static per-lane table)."""
    steps = feed.shape[0]
    total = jnp.uint32(1 << precision)
    mask = jnp.uint32((1 << precision) - 1)
    table = table.astype(jnp.uint32)

    def body(t, carry):
        head, r, syms = carry
        slot = head & mask
        le = table <= slot[:, None]
        syms = syms.at[t].set(jnp.sum(le, axis=1).astype(jnp.uint32) - 1)
        start = jnp.max(jnp.where(le, table, jnp.uint32(0)), axis=1)
        nxt = jnp.min(jnp.where(le, total, table), axis=1)
        head = (nxt - start) * (head >> precision) + slot - start
        need = head < jnp.uint32(1 << 16)
        chunk = jnp.take_along_axis(feed, r[None, :], axis=0)[0]
        head = jnp.where(need, (head << 16) | chunk, head)
        return head, r + need.astype(jnp.int32), syms

    reads0 = jnp.zeros(head.shape, jnp.int32)
    syms0 = jnp.zeros(feed.shape, jnp.uint32)
    head, reads, syms = jax.lax.fori_loop(
        0, steps, body, (head, reads0, syms0), unroll=unroll)
    return head, syms, reads.astype(jnp.uint32)


def pop_dyntable_emit(head: jnp.ndarray, tables: jnp.ndarray,
                      feed: jnp.ndarray, precision: int, unroll: int = 1):
    """XLA twin of ``kernel.pop_dyntable_emit`` (per-step tables)."""
    steps = feed.shape[0]
    total = jnp.uint32(1 << precision)
    mask = jnp.uint32((1 << precision) - 1)
    tables = tables.astype(jnp.uint32)

    def body(t, carry):
        head, r, syms = carry
        slot = head & mask
        table = tables[t]                        # uint32[lanes, A+1]
        le = table <= slot[:, None]
        syms = syms.at[t].set(jnp.sum(le, axis=1).astype(jnp.uint32) - 1)
        start = jnp.max(jnp.where(le, table, jnp.uint32(0)), axis=1)
        nxt = jnp.min(jnp.where(le, total, table), axis=1)
        head = (nxt - start) * (head >> precision) + slot - start
        need = head < jnp.uint32(1 << 16)
        chunk = jnp.take_along_axis(feed, r[None, :], axis=0)[0]
        head = jnp.where(need, (head << 16) | chunk, head)
        return head, r + need.astype(jnp.int32), syms

    reads0 = jnp.zeros(head.shape, jnp.int32)
    syms0 = jnp.zeros(feed.shape, jnp.uint32)
    head, reads, syms = jax.lax.fori_loop(
        0, steps, body, (head, reads0, syms0), unroll=unroll)
    return head, syms, reads.astype(jnp.uint32)


def _grid_starts_fn(mu_t, sigma_t, edges, kind: str, lat_bits: int,
                    precision: int):
    """The canonical grid CDF chain for one step's (mu, sigma) row -
    expression-identical to ``kernel._pop_grid_kernel``'s ``starts_fn``
    (and to ``core.discretize``), so every backend gathers one set of
    bits."""
    from jax.scipy.special import ndtr

    k = 1 << lat_bits
    scale = float((1 << precision) - k)

    def f(i):
        z = edges[i]
        if kind == "gaussian":
            c = ndtr((z - mu_t) * (1.0 / sigma_t))
        else:  # logistic: sigma carries the scale parameter
            c = jax.nn.sigmoid((z - mu_t) * (1.0 / sigma_t))
            c = jnp.clip(c, 0.0, 1.0)
        c = jnp.where(i <= 0, 0.0, c)
        c = jnp.where(i >= k, 1.0, c)
        return jnp.floor(c * scale).astype(jnp.uint32) \
            + i.astype(jnp.uint32)

    return f


def pop_grid_emit(head: jnp.ndarray, mu: jnp.ndarray, sigma: jnp.ndarray,
                  feed: jnp.ndarray, edges: jnp.ndarray, kind: str,
                  lat_bits: int, precision: int, unroll: int = 1):
    """XLA twin of ``kernel.pop_grid_emit`` (fused bucketize+pop).

    The ``lat_bits + 1``-step CDF bisection has a static trip count, so
    it unrolls at trace time (fewer tiny while-loop dispatches on CPU);
    the sequential pop chain stays a ``fori_loop`` with the tuned
    ``unroll``.
    """
    if kind not in ("gaussian", "logistic", "uniform"):
        raise ValueError(
            f"kernels.ans.xla: unknown grid kind {kind!r} (expected "
            "'gaussian', 'logistic', or 'uniform')")
    steps = feed.shape[0]
    k = 1 << lat_bits
    shift = precision - lat_bits
    mask = jnp.uint32((1 << precision) - 1)

    def body(t, carry):
        head, r, idxs = carry
        slot = head & mask
        if kind == "uniform":
            idx = (slot >> shift).astype(jnp.int32)
            start = idx.astype(jnp.uint32) << shift
            freq = jnp.full_like(start, jnp.uint32(1 << shift))
        else:
            f = _grid_starts_fn(mu[t], sigma[t], edges, kind, lat_bits,
                                precision)
            lo = jnp.zeros(slot.shape, jnp.int32)
            hi = jnp.full(slot.shape, k, jnp.int32)
            for _ in range(lat_bits + 1):     # static-count bisection
                mid = (lo + hi + 1) // 2
                up = f(mid) <= slot
                lo = jnp.where(up, mid, lo)
                hi = jnp.where(up, hi, mid)
            idx = lo
            start = f(idx)
            freq = f(idx + 1) - start
        idxs = idxs.at[t].set(idx.astype(jnp.uint32))
        head = freq * (head >> precision) + slot - start
        need = head < jnp.uint32(1 << 16)
        chunk = jnp.take_along_axis(feed, r[None, :], axis=0)[0]
        head = jnp.where(need, (head << 16) | chunk, head)
        return head, r + need.astype(jnp.int32), idxs

    reads0 = jnp.zeros(head.shape, jnp.int32)
    idxs0 = jnp.zeros(feed.shape, jnp.uint32)
    head, reads, idxs = jax.lax.fori_loop(
        0, steps, body, (head, reads0, idxs0), unroll=unroll)
    return head, idxs, reads.astype(jnp.uint32)
