"""Pallas TPU kernel: lane-vectorized rANS push (the coder's hot loop).

TPU mapping (DESIGN.md section 3): lanes tile onto the VPU's (8, 128)
registers; heads live in VMEM across the whole symbol loop; the
data-dependent "emit" branch of scalar rANS is a masked vector op (the
uint32/16-bit-renorm design guarantees at most one emission per push, so
the loop body is branchless). The kernel emits a dense (chunk, need)
emission list; stack compaction (a cumsum scatter) stays outside in XLA
where the irregular write pattern is handled well.

Validated bit-exactly against the pure-jnp oracle (ref.py) under
interpret=True over shape/precision sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE_TILE = 128


def _push_kernel(head_ref, starts_ref, freqs_ref,
                 out_head_ref, chunks_ref, need_ref, *, precision: int):
    """One lane-tile: sequentially push ``steps`` symbols per lane.

    head_ref: uint32[LANE_TILE]; starts/freqs: uint32[steps, LANE_TILE];
    chunks/need out: uint32[steps, LANE_TILE].
    """
    steps = starts_ref.shape[0]

    def body(t, head):
        start = starts_ref[t, :]
        freq = freqs_ref[t, :]
        x_max = freq << (32 - precision)
        need = head >= x_max
        chunk = jnp.where(need, head & 0xFFFF, 0).astype(jnp.uint32)
        chunks_ref[t, :] = chunk
        need_ref[t, :] = need.astype(jnp.uint32)
        head = jnp.where(need, head >> 16, head)
        return ((head // freq) << precision) + (head % freq) + start

    out_head_ref[...] = jax.lax.fori_loop(0, steps, body, head_ref[...])


def push_emit(head: jnp.ndarray, starts: jnp.ndarray, freqs: jnp.ndarray,
              precision: int, interpret: bool = True):
    """head uint32[lanes]; starts/freqs uint32[steps, lanes] ->
    (new_head, chunks uint32[steps, lanes], need uint32[steps, lanes]).

    lanes must be a multiple of LANE_TILE (ops.py pads).
    """
    steps, lanes = starts.shape
    assert lanes % LANE_TILE == 0, lanes
    grid = (lanes // LANE_TILE,)
    kernel = functools.partial(_push_kernel, precision=precision)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((LANE_TILE,), lambda i: (i,)),
            pl.BlockSpec((steps, LANE_TILE), lambda i: (0, i)),
            pl.BlockSpec((steps, LANE_TILE), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((LANE_TILE,), lambda i: (i,)),
            pl.BlockSpec((steps, LANE_TILE), lambda i: (0, i)),
            pl.BlockSpec((steps, LANE_TILE), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lanes,), jnp.uint32),
            jax.ShapeDtypeStruct((steps, lanes), jnp.uint32),
            jax.ShapeDtypeStruct((steps, lanes), jnp.uint32),
        ],
        interpret=interpret,
    )(head, starts, freqs)


def _pop_kernel(head_ref, slots_out_ref, *, precision: int, steps: int):
    """Decode-side helper: emit the slot stream for ``steps`` pops when the
    per-step (start, freq) is resolved outside (table lookup); included to
    demonstrate the decode loop shape. Used by ops.pop_slots."""
    mask = (1 << precision) - 1
    head = head_ref[...]
    for t in range(steps):
        slots_out_ref[t, :] = (head & mask).astype(jnp.uint32)
        # state update happens outside (needs symbol resolution)
        break  # single-step variant; the multi-step path lives in ops.py


def pop_slots(head: jnp.ndarray, precision: int,
              interpret: bool = True) -> jnp.ndarray:
    """Vector peek: slot = head mod 2^precision per lane."""
    lanes = head.shape[0]
    assert lanes % LANE_TILE == 0
    kernel = functools.partial(_pop_kernel, precision=precision, steps=1)
    out = pl.pallas_call(
        kernel,
        grid=(lanes // LANE_TILE,),
        in_specs=[pl.BlockSpec((LANE_TILE,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, LANE_TILE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, lanes), jnp.uint32),
        interpret=interpret,
    )(head)
    return out[0]
