"""Pallas TPU kernel: lane-vectorized rANS push (the coder's hot loop).

TPU mapping (DESIGN.md section 3): lanes tile onto the VPU's (8, 128)
registers; heads live in VMEM across the whole symbol loop; the
data-dependent "emit" branch of scalar rANS is a masked vector op (the
uint32/16-bit-renorm design guarantees at most one emission per push, so
the loop body is branchless). The kernel emits a dense (chunk, need)
emission list; stack compaction (a cumsum scatter) stays outside in XLA
where the irregular write pattern is handled well.

Validated bit-exactly against the pure-jnp oracle (ref.py) under
interpret=True over shape/precision sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE_TILE = 128


def _check_lanes(lanes: int, lane_tile: int = LANE_TILE) -> None:
    # Explicit raise rather than assert: the invariant must survive
    # python -O (ops.py pads to a lane_tile multiple before calling).
    if lanes % lane_tile != 0:
        raise ValueError(
            f"kernels.ans: lanes ({lanes}) must be a multiple of "
            f"lane_tile ({lane_tile}); ops.py pads before calling")


def _push_kernel(head_ref, starts_ref, freqs_ref,
                 out_head_ref, chunks_ref, need_ref, *, precision: int):
    """One lane-tile: sequentially push ``steps`` symbols per lane.

    head_ref: uint32[LANE_TILE]; starts/freqs: uint32[steps, LANE_TILE];
    chunks/need out: uint32[steps, LANE_TILE].
    """
    steps = starts_ref.shape[0]

    def body(t, head):
        start = starts_ref[t, :]
        freq = freqs_ref[t, :]
        x_max = freq << (32 - precision)
        need = head >= x_max
        chunk = jnp.where(need, head & 0xFFFF, 0).astype(jnp.uint32)
        chunks_ref[t, :] = chunk
        need_ref[t, :] = need.astype(jnp.uint32)
        head = jnp.where(need, head >> 16, head)
        return ((head // freq) << precision) + (head % freq) + start

    out_head_ref[...] = jax.lax.fori_loop(0, steps, body, head_ref[...])


def push_emit(head: jnp.ndarray, starts: jnp.ndarray, freqs: jnp.ndarray,
              precision: int, interpret: bool = True,
              lane_tile: int = LANE_TILE):
    """head uint32[lanes]; starts/freqs uint32[steps, lanes] ->
    (new_head, chunks uint32[steps, lanes], need uint32[steps, lanes]).

    lanes must be a multiple of ``lane_tile`` (ops.py pads).
    """
    steps, lanes = starts.shape
    _check_lanes(lanes, lane_tile)
    grid = (lanes // lane_tile,)
    kernel = functools.partial(_push_kernel, precision=precision)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((lane_tile,), lambda i: (i,)),
            pl.BlockSpec((steps, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((steps, lane_tile), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((lane_tile,), lambda i: (i,)),
            pl.BlockSpec((steps, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((steps, lane_tile), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lanes,), jnp.uint32),
            jax.ShapeDtypeStruct((steps, lanes), jnp.uint32),
            jax.ShapeDtypeStruct((steps, lanes), jnp.uint32),
        ],
        interpret=interpret,
    )(head, starts, freqs)


def _peek_kernel(head_ref, slots_out_ref, *, precision: int):
    """Single-step vector peek: the decode slot per lane.

    The honest single-step kernel: one masked AND per lane, no loop. The
    real multi-step decode path is ``_pop_table_kernel`` below.
    """
    mask = jnp.uint32((1 << precision) - 1)
    slots_out_ref[0, :] = head_ref[...] & mask


def pop_slots(head: jnp.ndarray, precision: int,
              interpret: bool = True,
              lane_tile: int = LANE_TILE) -> jnp.ndarray:
    """Vector peek: slot = head mod 2^precision per lane."""
    lanes = head.shape[0]
    _check_lanes(lanes, lane_tile)
    kernel = functools.partial(_peek_kernel, precision=precision)
    out = pl.pallas_call(
        kernel,
        grid=(lanes // lane_tile,),
        in_specs=[pl.BlockSpec((lane_tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, lane_tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, lanes), jnp.uint32),
        interpret=interpret,
    )(head)
    return out[0]


def _pop_table_kernel(head_ref, table_ref, feed_ref,
                      out_head_ref, syms_ref, reads_ref, *, precision: int):
    """Multi-step table-driven pop for one lane tile.

    Decodes ``steps`` symbols per lane against a static per-lane
    cumulative-starts table (uint32[LANE_TILE, A+1]). The data-dependent
    renormalization *read* is fed from ``feed_ref`` - the next ``steps``
    chunks of each lane's stack pre-gathered outside the kernel in pop
    order (each pop reads at most one chunk, so ``steps`` rows suffice) -
    indexed by a per-lane read counter. The symbol search is branchless:
    ``sym = #(F <= slot) - 1``, ``start = max F <= slot``, ``next = min
    F > slot``, all lane-parallel reductions over the table axis.
    """
    steps = feed_ref.shape[0]
    total = jnp.uint32(1 << precision)
    mask = jnp.uint32((1 << precision) - 1)
    table = table_ref[...]   # uint32[LANE_TILE, A+1]
    feed = feed_ref[...]     # uint32[steps, LANE_TILE]

    def body(t, carry):
        head, r = carry
        slot = head & mask
        le = table <= slot[:, None]
        syms_ref[t, :] = jnp.sum(le, axis=1).astype(jnp.uint32) - 1
        start = jnp.max(jnp.where(le, table, jnp.uint32(0)), axis=1)
        nxt = jnp.min(jnp.where(le, total, table), axis=1)
        head = (nxt - start) * (head >> precision) + slot - start
        need = head < jnp.uint32(1 << 16)
        chunk = jnp.take_along_axis(feed, r[None, :], axis=0)[0]
        head = jnp.where(need, (head << 16) | chunk, head)
        return head, r + need.astype(jnp.int32)

    head0 = head_ref[...]
    reads0 = jnp.zeros(head0.shape, jnp.int32)
    head, reads = jax.lax.fori_loop(0, steps, body, (head0, reads0))
    out_head_ref[...] = head
    reads_ref[...] = reads.astype(jnp.uint32)


def pop_table_emit(head: jnp.ndarray, table: jnp.ndarray,
                   feed: jnp.ndarray, precision: int,
                   interpret: bool = True, lane_tile: int = LANE_TILE):
    """head uint32[lanes]; table uint32[lanes, A+1]; feed uint32[steps,
    lanes] -> (new_head, syms uint32[steps, lanes], reads uint32[lanes]).

    ``feed[r, l]`` must hold the ``r``-th chunk lane ``l``'s stack would
    serve (top first, clamped at the bottom - see ops.pop_many). lanes
    must be a multiple of ``lane_tile`` (ops.py pads).
    """
    steps, lanes = feed.shape
    _check_lanes(lanes, lane_tile)
    grid = (lanes // lane_tile,)
    a1 = table.shape[1]
    kernel = functools.partial(_pop_table_kernel, precision=precision)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((lane_tile,), lambda i: (i,)),
            pl.BlockSpec((lane_tile, a1), lambda i: (i, 0)),
            pl.BlockSpec((steps, lane_tile), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((lane_tile,), lambda i: (i,)),
            pl.BlockSpec((steps, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((lane_tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lanes,), jnp.uint32),
            jax.ShapeDtypeStruct((steps, lanes), jnp.uint32),
            jax.ShapeDtypeStruct((lanes,), jnp.uint32),
        ],
        interpret=interpret,
    )(head, table, feed)


def _pop_dyntable_kernel(head_ref, tables_ref, feed_ref,
                         out_head_ref, syms_ref, reads_ref, *,
                         precision: int):
    """Multi-step pop against *per-step* dynamic tables.

    Like ``_pop_table_kernel`` but the cumulative-starts table changes
    every step (uint32[steps, LANE_TILE, A+1]) - the decode twin of the
    dynamic ``_push_kernel``, used by the codec compiler for per-position
    Bernoulli/Categorical/BetaBinomial leaves whose parameters vary along
    the ``Repeat`` axis.
    """
    steps = feed_ref.shape[0]
    total = jnp.uint32(1 << precision)
    mask = jnp.uint32((1 << precision) - 1)
    feed = feed_ref[...]     # uint32[steps, LANE_TILE]

    def body(t, carry):
        head, r = carry
        slot = head & mask
        table = tables_ref[t]                    # uint32[LANE_TILE, A+1]
        le = table <= slot[:, None]
        syms_ref[t, :] = jnp.sum(le, axis=1).astype(jnp.uint32) - 1
        start = jnp.max(jnp.where(le, table, jnp.uint32(0)), axis=1)
        nxt = jnp.min(jnp.where(le, total, table), axis=1)
        head = (nxt - start) * (head >> precision) + slot - start
        need = head < jnp.uint32(1 << 16)
        chunk = jnp.take_along_axis(feed, r[None, :], axis=0)[0]
        head = jnp.where(need, (head << 16) | chunk, head)
        return head, r + need.astype(jnp.int32)

    head0 = head_ref[...]
    reads0 = jnp.zeros(head0.shape, jnp.int32)
    head, reads = jax.lax.fori_loop(0, steps, body, (head0, reads0))
    out_head_ref[...] = head
    reads_ref[...] = reads.astype(jnp.uint32)


def pop_dyntable_emit(head: jnp.ndarray, tables: jnp.ndarray,
                      feed: jnp.ndarray, precision: int,
                      interpret: bool = True, lane_tile: int = LANE_TILE):
    """head uint32[lanes]; tables uint32[steps, lanes, A+1]; feed
    uint32[steps, lanes] -> (new_head, syms uint32[steps, lanes],
    reads uint32[lanes]). lanes must be a multiple of ``lane_tile``."""
    steps, lanes = feed.shape
    _check_lanes(lanes, lane_tile)
    grid = (lanes // lane_tile,)
    a1 = tables.shape[2]
    kernel = functools.partial(_pop_dyntable_kernel, precision=precision)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((lane_tile,), lambda i: (i,)),
            pl.BlockSpec((steps, lane_tile, a1), lambda i: (0, i, 0)),
            pl.BlockSpec((steps, lane_tile), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((lane_tile,), lambda i: (i,)),
            pl.BlockSpec((steps, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((lane_tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lanes,), jnp.uint32),
            jax.ShapeDtypeStruct((steps, lanes), jnp.uint32),
            jax.ShapeDtypeStruct((lanes,), jnp.uint32),
        ],
        interpret=interpret,
    )(head, tables, feed)


def _pop_grid_kernel(head_ref, mu_ref, sigma_ref, feed_ref, edges_ref,
                     out_head_ref, idx_ref, reads_ref, *, kind: str,
                     lat_bits: int, precision: int):
    """Fused bucketize + pop over the max-entropy N(0,1) bucket grid.

    The CDF inversion of ``DiscretizedGaussian``/``DiscretizedLogistic``
    (the ``kernels/bucketize`` bisection) runs *inside* the ANS pop
    renormalization chain: per step, slot -> bisection over the
    pointwise fixed-point CDF -> state update -> masked chunk read.
    ``kind`` selects the CDF (``gaussian`` via ndtr, ``logistic`` via
    sigmoid, ``uniform`` closed-form, no bisection); the shared bucket
    edge table ``z[i] = ndtri(i/K)`` sits once in VMEM. Bit-exact vs
    the per-position leaves by construction (same edge expression, same
    primitives, same iteration count - tested against ref.py).
    """
    from jax.scipy.special import ndtr

    steps = feed_ref.shape[0]
    k = 1 << lat_bits
    scale = float((1 << precision) - k)
    shift = precision - lat_bits
    mask = jnp.uint32((1 << precision) - 1)
    feed = feed_ref[...]     # uint32[steps, LANE_TILE]

    def starts_fn(t):
        mu = mu_ref[t, :]
        sigma = sigma_ref[t, :]

        def f(i):
            # Reciprocal-multiply standardization: the canonical
            # bit-stable form shared with core.discretize/codecs.leaves.
            z = edges_ref[i]
            if kind == "gaussian":
                c = ndtr((z - mu) * (1.0 / sigma))
            else:  # logistic: sigma carries the scale parameter
                c = jax.nn.sigmoid((z - mu) * (1.0 / sigma))
                c = jnp.clip(c, 0.0, 1.0)
            c = jnp.where(i <= 0, 0.0, c)
            c = jnp.where(i >= k, 1.0, c)
            return jnp.floor(c * scale).astype(jnp.uint32) \
                + i.astype(jnp.uint32)

        return f

    def body(t, carry):
        head, r = carry
        slot = head & mask
        if kind == "uniform":
            idx = (slot >> shift).astype(jnp.int32)
            start = idx.astype(jnp.uint32) << shift
            freq = jnp.full_like(start, jnp.uint32(1 << shift))
        else:
            f = starts_fn(t)
            lo = jnp.zeros(slot.shape, jnp.int32)
            hi = jnp.full(slot.shape, k, jnp.int32)

            def bisect(_, lohi):
                lo, hi = lohi
                mid = (lo + hi + 1) // 2
                up = f(mid) <= slot
                return jnp.where(up, mid, lo), jnp.where(up, hi, mid)

            lo, hi = jax.lax.fori_loop(0, lat_bits + 1, bisect, (lo, hi))
            idx = lo
            start = f(idx)
            freq = f(idx + 1) - start
        idx_ref[t, :] = idx.astype(jnp.uint32)
        head = freq * (head >> precision) + slot - start
        need = head < jnp.uint32(1 << 16)
        chunk = jnp.take_along_axis(feed, r[None, :], axis=0)[0]
        head = jnp.where(need, (head << 16) | chunk, head)
        return head, r + need.astype(jnp.int32)

    head0 = head_ref[...]
    reads0 = jnp.zeros(head0.shape, jnp.int32)
    head, reads = jax.lax.fori_loop(0, steps, body, (head0, reads0))
    out_head_ref[...] = head
    reads_ref[...] = reads.astype(jnp.uint32)


def pop_grid_emit(head: jnp.ndarray, mu: jnp.ndarray, sigma: jnp.ndarray,
                  feed: jnp.ndarray, edges: jnp.ndarray, kind: str,
                  lat_bits: int, precision: int, interpret: bool = True,
                  lane_tile: int = LANE_TILE):
    """head uint32[lanes]; mu/sigma float32[steps, lanes]; feed
    uint32[steps, lanes]; edges float32[K+1] -> (new_head, idx
    uint32[steps, lanes], reads uint32[lanes]).

    ``kind`` in {"gaussian", "logistic", "uniform"}; for uniform the
    mu/sigma/edges contents are ignored (pass zero-size-compatible
    dummies). lanes must be a multiple of ``lane_tile`` (ops.py pads).
    """
    if kind not in ("gaussian", "logistic", "uniform"):
        raise ValueError(
            f"kernels.ans: unknown grid kind {kind!r} (expected "
            "'gaussian', 'logistic', or 'uniform')")
    steps, lanes = feed.shape
    _check_lanes(lanes, lane_tile)
    grid = (lanes // lane_tile,)
    e = edges.shape[0]
    kernel = functools.partial(_pop_grid_kernel, kind=kind,
                               lat_bits=lat_bits, precision=precision)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((lane_tile,), lambda i: (i,)),
            pl.BlockSpec((steps, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((steps, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((steps, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((e,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((lane_tile,), lambda i: (i,)),
            pl.BlockSpec((steps, lane_tile), lambda i: (0, i)),
            pl.BlockSpec((lane_tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lanes,), jnp.uint32),
            jax.ShapeDtypeStruct((steps, lanes), jnp.uint32),
            jax.ShapeDtypeStruct((lanes,), jnp.uint32),
        ],
        interpret=interpret,
    )(head, mu, sigma, feed, edges)
