"""Pallas TPU kernel: lane-vectorized rANS push (the coder's hot loop).

TPU mapping (DESIGN.md section 3): lanes tile onto the VPU's (8, 128)
registers; heads live in VMEM across the whole symbol loop; the
data-dependent "emit" branch of scalar rANS is a masked vector op (the
uint32/16-bit-renorm design guarantees at most one emission per push, so
the loop body is branchless). The kernel emits a dense (chunk, need)
emission list; stack compaction (a cumsum scatter) stays outside in XLA
where the irregular write pattern is handled well.

Validated bit-exactly against the pure-jnp oracle (ref.py) under
interpret=True over shape/precision sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE_TILE = 128


def _push_kernel(head_ref, starts_ref, freqs_ref,
                 out_head_ref, chunks_ref, need_ref, *, precision: int):
    """One lane-tile: sequentially push ``steps`` symbols per lane.

    head_ref: uint32[LANE_TILE]; starts/freqs: uint32[steps, LANE_TILE];
    chunks/need out: uint32[steps, LANE_TILE].
    """
    steps = starts_ref.shape[0]

    def body(t, head):
        start = starts_ref[t, :]
        freq = freqs_ref[t, :]
        x_max = freq << (32 - precision)
        need = head >= x_max
        chunk = jnp.where(need, head & 0xFFFF, 0).astype(jnp.uint32)
        chunks_ref[t, :] = chunk
        need_ref[t, :] = need.astype(jnp.uint32)
        head = jnp.where(need, head >> 16, head)
        return ((head // freq) << precision) + (head % freq) + start

    out_head_ref[...] = jax.lax.fori_loop(0, steps, body, head_ref[...])


def push_emit(head: jnp.ndarray, starts: jnp.ndarray, freqs: jnp.ndarray,
              precision: int, interpret: bool = True):
    """head uint32[lanes]; starts/freqs uint32[steps, lanes] ->
    (new_head, chunks uint32[steps, lanes], need uint32[steps, lanes]).

    lanes must be a multiple of LANE_TILE (ops.py pads).
    """
    steps, lanes = starts.shape
    assert lanes % LANE_TILE == 0, lanes
    grid = (lanes // LANE_TILE,)
    kernel = functools.partial(_push_kernel, precision=precision)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((LANE_TILE,), lambda i: (i,)),
            pl.BlockSpec((steps, LANE_TILE), lambda i: (0, i)),
            pl.BlockSpec((steps, LANE_TILE), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((LANE_TILE,), lambda i: (i,)),
            pl.BlockSpec((steps, LANE_TILE), lambda i: (0, i)),
            pl.BlockSpec((steps, LANE_TILE), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lanes,), jnp.uint32),
            jax.ShapeDtypeStruct((steps, lanes), jnp.uint32),
            jax.ShapeDtypeStruct((steps, lanes), jnp.uint32),
        ],
        interpret=interpret,
    )(head, starts, freqs)


def _peek_kernel(head_ref, slots_out_ref, *, precision: int):
    """Single-step vector peek: the decode slot per lane.

    The honest single-step kernel: one masked AND per lane, no loop. The
    real multi-step decode path is ``_pop_table_kernel`` below.
    """
    mask = jnp.uint32((1 << precision) - 1)
    slots_out_ref[0, :] = head_ref[...] & mask


def pop_slots(head: jnp.ndarray, precision: int,
              interpret: bool = True) -> jnp.ndarray:
    """Vector peek: slot = head mod 2^precision per lane."""
    lanes = head.shape[0]
    assert lanes % LANE_TILE == 0
    kernel = functools.partial(_peek_kernel, precision=precision)
    out = pl.pallas_call(
        kernel,
        grid=(lanes // LANE_TILE,),
        in_specs=[pl.BlockSpec((LANE_TILE,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, LANE_TILE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, lanes), jnp.uint32),
        interpret=interpret,
    )(head)
    return out[0]


def _pop_table_kernel(head_ref, table_ref, feed_ref,
                      out_head_ref, syms_ref, reads_ref, *, precision: int):
    """Multi-step table-driven pop for one lane tile.

    Decodes ``steps`` symbols per lane against a static per-lane
    cumulative-starts table (uint32[LANE_TILE, A+1]). The data-dependent
    renormalization *read* is fed from ``feed_ref`` - the next ``steps``
    chunks of each lane's stack pre-gathered outside the kernel in pop
    order (each pop reads at most one chunk, so ``steps`` rows suffice) -
    indexed by a per-lane read counter. The symbol search is branchless:
    ``sym = #(F <= slot) - 1``, ``start = max F <= slot``, ``next = min
    F > slot``, all lane-parallel reductions over the table axis.
    """
    steps = feed_ref.shape[0]
    total = jnp.uint32(1 << precision)
    mask = jnp.uint32((1 << precision) - 1)
    table = table_ref[...]   # uint32[LANE_TILE, A+1]
    feed = feed_ref[...]     # uint32[steps, LANE_TILE]

    def body(t, carry):
        head, r = carry
        slot = head & mask
        le = table <= slot[:, None]
        syms_ref[t, :] = jnp.sum(le, axis=1).astype(jnp.uint32) - 1
        start = jnp.max(jnp.where(le, table, jnp.uint32(0)), axis=1)
        nxt = jnp.min(jnp.where(le, total, table), axis=1)
        head = (nxt - start) * (head >> precision) + slot - start
        need = head < jnp.uint32(1 << 16)
        chunk = jnp.take_along_axis(feed, r[None, :], axis=0)[0]
        head = jnp.where(need, (head << 16) | chunk, head)
        return head, r + need.astype(jnp.int32)

    head0 = head_ref[...]
    reads0 = jnp.zeros(head0.shape, jnp.int32)
    head, reads = jax.lax.fori_loop(0, steps, body, (head0, reads0))
    out_head_ref[...] = head
    reads_ref[...] = reads.astype(jnp.uint32)


def pop_table_emit(head: jnp.ndarray, table: jnp.ndarray,
                   feed: jnp.ndarray, precision: int,
                   interpret: bool = True):
    """head uint32[lanes]; table uint32[lanes, A+1]; feed uint32[steps,
    lanes] -> (new_head, syms uint32[steps, lanes], reads uint32[lanes]).

    ``feed[r, l]`` must hold the ``r``-th chunk lane ``l``'s stack would
    serve (top first, clamped at the bottom - see ops.pop_many). lanes
    must be a multiple of LANE_TILE (ops.py pads).
    """
    steps, lanes = feed.shape
    assert lanes % LANE_TILE == 0, lanes
    grid = (lanes // LANE_TILE,)
    a1 = table.shape[1]
    kernel = functools.partial(_pop_table_kernel, precision=precision)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((LANE_TILE,), lambda i: (i,)),
            pl.BlockSpec((LANE_TILE, a1), lambda i: (i, 0)),
            pl.BlockSpec((steps, LANE_TILE), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((LANE_TILE,), lambda i: (i,)),
            pl.BlockSpec((steps, LANE_TILE), lambda i: (0, i)),
            pl.BlockSpec((LANE_TILE,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lanes,), jnp.uint32),
            jax.ShapeDtypeStruct((steps, lanes), jnp.uint32),
            jax.ShapeDtypeStruct((lanes,), jnp.uint32),
        ],
        interpret=interpret,
    )(head, table, feed)
