"""Pure-jnp oracle for the ANS push kernel: the core coder, symbol by
symbol, via repro.core.ans (itself exhaustively property-tested)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ans, discretize


def push_emit_ref(head, starts, freqs, precision):
    """Reference for kernel.push_emit: same (new_head, chunks, need)."""
    steps, lanes = starts.shape

    def body(t, carry):
        head, chunks, need = carry
        x_max = freqs[t] << (32 - precision)
        n = head >= x_max
        c = jnp.where(n, head & jnp.uint32(0xFFFF), jnp.uint32(0))
        chunks = chunks.at[t].set(c)
        need = need.at[t].set(n.astype(jnp.uint32))
        head = jnp.where(n, head >> 16, head)
        head = ((head // freqs[t]) << precision) + (head % freqs[t]) \
            + starts[t]
        return head, chunks, need

    chunks0 = jnp.zeros((steps, lanes), jnp.uint32)
    need0 = jnp.zeros((steps, lanes), jnp.uint32)
    return jax.lax.fori_loop(0, steps, body, (head, chunks0, need0))


def push_many_ref(stack: ans.ANSStack, starts, freqs,
                  precision) -> ans.ANSStack:
    """End-to-end reference: sequential core-library pushes."""
    steps = starts.shape[0]

    def body(t, st):
        return ans.push(st, starts[t], freqs[t], precision)

    return jax.lax.fori_loop(0, steps, body, stack)


def push_many_table_ref(stack: ans.ANSStack, starts_table, symbols,
                        precision) -> ans.ANSStack:
    """Reference for ops.push_many_table: sequential table pushes."""
    steps = symbols.shape[0]

    def body(t, st):
        return ans.push_with_table(st, starts_table, symbols[t], precision)

    return jax.lax.fori_loop(0, steps, body, stack)


def pop_many_ref(stack: ans.ANSStack, starts_table, steps: int,
                 precision):
    """Reference for ops.pop_many: sequential core-library table pops.

    Returns (stack, symbols int32[steps, lanes]) in pop order.
    """
    syms0 = jnp.zeros((steps, stack.lanes), jnp.int32)

    def body(t, carry):
        st, syms = carry
        st, sym = ans.pop_with_table(st, starts_table, precision)
        return st, syms.at[t].set(sym)

    return jax.lax.fori_loop(0, steps, body, (stack, syms0))


def pop_many_dyn_ref(stack: ans.ANSStack, tables, precision):
    """Reference for ops.pop_many_dyn: sequential table pops against the
    per-step tables. Returns (stack, symbols int32[steps, lanes])."""
    steps = tables.shape[0]
    syms0 = jnp.zeros((steps, stack.lanes), jnp.int32)

    def body(t, carry):
        st, syms = carry
        st, sym = ans.pop_with_table(st, tables[t], precision)
        return st, syms.at[t].set(sym)

    return jax.lax.fori_loop(0, steps, body, (stack, syms0))


def pop_many_grid_ref(stack: ans.ANSStack, kind: str, mu, sigma,
                      steps: int, lat_bits: int, precision):
    """Reference for ops.pop_many_grid: sequential per-position leaf
    pops via the core library (``discretize.pop_posterior`` /
    ``codecs.DiscretizedLogistic`` / ``discretize.pop_prior``).

    Python-driven (an oracle, not a fast path); returns (stack, symbols
    int32[steps, lanes]) in pop order.
    """
    syms = []
    for t in range(steps):
        if kind == "gaussian":
            stack, idx = discretize.pop_posterior(
                stack, mu[t], sigma[t], lat_bits, precision)
        elif kind == "logistic":
            from repro.codecs.leaves import DiscretizedLogistic
            leaf = DiscretizedLogistic(mu[t], sigma[t], lat_bits,
                                       precision)
            stack, idx = leaf.pop(stack)
        elif kind == "uniform":
            stack, idx = discretize.pop_prior(stack, lat_bits, precision)
        else:
            raise ValueError(kind)
        syms.append(idx)
    return stack, jnp.stack(syms, axis=0).astype(jnp.int32)
