"""Measured lane-tiling/unroll autotuner with a persisted decision cache.

The dispatcher (``kernels.dispatch``) resolves backends by pure lookup;
this module is the part that actually *times* candidates. For one
(op, platform, lane-bucket, table-bucket) signature it builds a
representative workload, runs every legal candidate Decision through
the public op (so the measurement includes padding, compaction and
bookkeeping, not just the kernel), keeps the fastest, and persists it
to a versioned JSON cache:

  * location: ``$REPRO_TUNING_CACHE`` if set, else
    ``~/.cache/repro/tuning_cache.json``;
  * format: ``{"version": 1, "entries": {"<platform>/<op>/lanes<B>/``
    ``table<B>": {"backend": ..., "lane_tile": ..., "unroll": ...,``
    ``"ms": ...}}}`` with lane/table counts bucketed to the next power
    of two so one measurement covers a size class;
  * a corrupt, unreadable, or version-mismatched cache file is treated
    as empty (and overwritten on the next ``record``) - tuning state
    can never break coding.

Nothing here runs implicitly: ``codecs.compile`` only *measures* at
lowering when ``REPRO_AUTOTUNE`` is set (see ``ensure``); otherwise a
cache miss falls back to the dispatch heuristic. Candidates never
include ``interpret`` - it exists as an oracle, not a contender.

CLI: ``python -m repro.kernels.tuning --lanes 64 --steps 256`` warms
the cache for every hot op and prints the winning decisions.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import (DEFAULT_LANE_TILE, Decision,
                                    available_backends, platform)

CACHE_VERSION = 1
_ENV_CACHE = "REPRO_TUNING_CACHE"
_ENV_AUTOTUNE = "REPRO_AUTOTUNE"

# Hot ops the CLI sweep covers, with the workload knobs they use.
OPS = ("push_many", "push_many_table", "pop_many", "pop_many_dyn",
       "pop_many_grid", "bucketize")

_MEM: Optional[Dict[str, dict]] = None
_MEM_PATH: Optional[str] = None


def cache_path() -> str:
    """The tuning-cache file location (env override or XDG default)."""
    env = os.environ.get(_ENV_CACHE)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "tuning_cache.json")


# Public alias under the package namespace (repro.kernels exports it).
def tuning_cache_path() -> str:
    """Alias of :func:`cache_path` for the ``repro.kernels`` surface."""
    return cache_path()


def refresh() -> None:
    """Drop the in-process cache view (tests use this after swapping
    ``$REPRO_TUNING_CACHE``)."""
    global _MEM, _MEM_PATH
    _MEM = None
    _MEM_PATH = None


def _load() -> Dict[str, dict]:
    """The cache's entries dict; corrupt/stale files read as empty."""
    global _MEM, _MEM_PATH
    path = cache_path()
    if _MEM is not None and _MEM_PATH == path:
        return _MEM
    entries: Dict[str, dict] = {}
    try:
        with open(path, "r", encoding="utf-8") as fh:
            raw = json.load(fh)
        if isinstance(raw, dict) and raw.get("version") == CACHE_VERSION \
                and isinstance(raw.get("entries"), dict):
            entries = raw["entries"]
    except (OSError, ValueError):
        pass   # missing or corrupt: start empty, never fail coding
    _MEM, _MEM_PATH = entries, path
    return entries


def _save(entries: Dict[str, dict]) -> None:
    path = cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as fh:
        json.dump({"version": CACHE_VERSION, "entries": entries}, fh,
                  indent=1, sort_keys=True)
    os.replace(tmp, path)


def _bucket(n: Optional[int]) -> int:
    """Next power of two >= n (0 for unknown): one measurement per size
    class instead of per exact shape."""
    if not n or n <= 0:
        return 0
    b = 1
    while b < n:
        b <<= 1
    return b


def _key(plat: str, op: str, lanes: Optional[int],
         table_size: Optional[int]) -> str:
    return f"{plat}/{op}/lanes{_bucket(lanes)}/table{_bucket(table_size)}"


def lookup(plat: str, op: str, lanes: Optional[int] = None,
           table_size: Optional[int] = None) -> Optional[Decision]:
    """The cached Decision for this signature, or None on miss. Entries
    naming a backend unavailable on ``plat`` (or malformed entries) are
    ignored rather than raised."""
    entry = _load().get(_key(plat, op, lanes, table_size))
    if not isinstance(entry, dict):
        return None
    try:
        decision = Decision(
            backend=str(entry["backend"]),
            lane_tile=int(entry.get("lane_tile", DEFAULT_LANE_TILE)),
            unroll=int(entry.get("unroll", 1)))
    except (KeyError, TypeError, ValueError):
        return None
    if decision.backend not in available_backends(plat):
        return None
    return decision


def record(plat: str, op: str, decision: Decision, ms: float,
           lanes: Optional[int] = None,
           table_size: Optional[int] = None) -> None:
    """Persist a measured winner (atomic write, updates the in-process
    view)."""
    entries = _load()
    entries[_key(plat, op, lanes, table_size)] = {
        "backend": decision.backend,
        "lane_tile": decision.lane_tile,
        "unroll": decision.unroll,
        "ms": round(ms, 4),
    }
    _save(entries)


def candidates(plat: Optional[str] = None) -> List[Decision]:
    """The Decisions worth timing on ``plat``: compiled-pallas tilings
    on accelerators, unroll factors for the XLA twins everywhere.
    ``interpret`` is excluded - it is the oracle, never a contender."""
    p = plat if plat is not None else platform()
    out: List[Decision] = []
    if "pallas" in available_backends(p):
        for tile in (DEFAULT_LANE_TILE, 2 * DEFAULT_LANE_TILE):
            out.append(Decision("pallas", lane_tile=tile))
    for unroll in (1, 2, 4):
        out.append(Decision("xla", unroll=unroll))
    return out


def _time_ms(fn, reps: int = 3) -> float:
    """Best-of-``reps`` wall time of ``fn`` in ms, compile excluded."""
    jax.block_until_ready(fn())   # warmup: compile outside the clock
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1000.0


def _workload(op: str, lanes: int, steps: int, table_size: int,
              lat_bits: int, precision: int):
    """A representative closure for ``op``: (callable taking a Decision)
    built once, so every candidate times identical inputs."""
    from repro.core import ans
    from repro.kernels.ans import ops as ans_ops
    from repro.kernels.bucketize import ops as bucketize_ops

    key = jax.random.PRNGKey(0)
    half = jnp.uint32(1 << (precision - 1))

    if op == "push_many":
        stack = ans.make_stack(lanes, capacity=4 * steps)
        starts = jnp.zeros((steps, lanes), jnp.uint32)
        freqs = jnp.full((steps, lanes), half, jnp.uint32)
        return lambda d: ans_ops.push_many(stack, starts, freqs,
                                           precision, backend=d)

    if op == "push_many_table":
        stack = ans.make_stack(lanes, capacity=4 * steps)
        table = _uniform_table(lanes, table_size, precision)
        syms = jax.random.randint(key, (steps, lanes), 0, table_size)
        return lambda d: ans_ops.push_many_table(stack, table, syms,
                                                 precision, backend=d)

    if op in ("pop_many", "pop_many_dyn"):
        stack = ans.seed_stack(ans.make_stack(lanes, capacity=4 * steps),
                               key, n_chunks=2 * steps)
        table = _uniform_table(lanes, table_size, precision)
        if op == "pop_many":
            return lambda d: ans_ops.pop_many(stack, table, steps,
                                              precision, backend=d)
        tables = jnp.broadcast_to(table, (steps,) + table.shape)
        return lambda d: ans_ops.pop_many_dyn(stack, tables, precision,
                                              backend=d)

    if op == "pop_many_grid":
        stack = ans.seed_stack(ans.make_stack(lanes, capacity=4 * steps),
                               key, n_chunks=2 * steps)
        mu = jnp.zeros((steps, lanes), jnp.float32)
        sigma = jnp.ones((steps, lanes), jnp.float32)
        return lambda d: ans_ops.pop_many_grid(
            stack, "gaussian", mu, sigma, steps, lat_bits, precision,
            backend=d)

    if op == "bucketize":
        slot = jax.random.randint(
            key, (lanes,), 0, 1 << precision).astype(jnp.uint32)
        mu = jnp.zeros((lanes,), jnp.float32)
        sigma = jnp.ones((lanes,), jnp.float32)
        return lambda d: bucketize_ops.bucketize(
            slot, mu, sigma, lat_bits, precision, backend=d)

    raise ValueError(f"kernels.tuning: unknown op {op!r} "
                     f"(expected one of {OPS})")


def _uniform_table(lanes: int, table_size: int, precision: int):
    with jax.ensure_compile_time_eval():
        edges = jnp.linspace(0, 1 << precision, table_size + 1)
        table = jnp.round(edges).astype(jnp.uint32)
    return jnp.broadcast_to(table, (lanes, table_size + 1))


def autotune_op(op: str, lanes: int, steps: int = 256,
                table_size: int = 16, lat_bits: int = 6,
                precision: int = 14, reps: int = 3) -> Decision:
    """Time every candidate for ``op`` on a representative workload,
    persist the winner, and return it. Candidates that fail to compile
    (e.g. a Pallas lowering gap) are skipped, not raised."""
    plat = platform()
    tsize = table_size if op in ("push_many_table", "pop_many",
                                 "pop_many_dyn") else None
    fn = _workload(op, lanes, steps, table_size, lat_bits, precision)
    best: Optional[Decision] = None
    best_ms = float("inf")
    for decision in candidates(plat):
        try:
            ms = _time_ms(lambda d=decision: fn(d), reps=reps)
        except Exception:   # noqa: BLE001 - a losing candidate, not a bug
            continue
        if ms < best_ms:
            best, best_ms = decision, ms
    if best is None:       # nothing compiled: fall back to the oracle
        best, best_ms = Decision("interpret"), 0.0
    record(plat, op, best, best_ms, lanes=lanes, table_size=tsize)
    return best


def ensure(op: str, lanes: Optional[int] = None,
           table_size: Optional[int] = None, steps: int = 256,
           lat_bits: int = 6, precision: int = 14) -> Optional[Decision]:
    """The lowering-time hook ``codecs.compile`` calls: a cached
    Decision if one exists; measure-and-cache if ``$REPRO_AUTOTUNE`` is
    set; otherwise None (heuristic applies)."""
    cached = lookup(platform(), op, lanes=lanes, table_size=table_size)
    if cached is not None:
        return cached
    if not os.environ.get(_ENV_AUTOTUNE):
        return None
    return autotune_op(op, lanes=lanes or 16, steps=steps,
                       table_size=table_size or 16, lat_bits=lat_bits,
                       precision=precision)


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    parser = argparse.ArgumentParser(
        description="Warm the kernel tuning cache: time every candidate "
                    "backend per hot op and persist the winners.")
    parser.add_argument("--ops", nargs="*", default=list(OPS),
                        help="ops to tune (default: all hot ops)")
    parser.add_argument("--lanes", type=int, nargs="+", default=[64],
                        help="lane counts to tune (one cache entry per "
                             "power-of-two lane bucket)")
    parser.add_argument("--steps", type=int, default=256)
    parser.add_argument("--table-size", type=int, default=16)
    parser.add_argument("--lat-bits", type=int, default=6)
    parser.add_argument("--precision", type=int, default=14)
    parser.add_argument("--reps", type=int, default=3)
    args = parser.parse_args(argv)

    print(f"platform={platform()}  cache={cache_path()}")
    for lanes in args.lanes:
        if len(args.lanes) > 1:
            print(f"lanes={lanes}:")
        for op in args.ops:
            decision = autotune_op(
                op, lanes=lanes, steps=args.steps,
                table_size=args.table_size, lat_bits=args.lat_bits,
                precision=args.precision, reps=args.reps)
            print(f"  {op:16s} -> {decision.backend}"
                  f"(lane_tile={decision.lane_tile}, "
                  f"unroll={decision.unroll})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
