"""Coder kernels: Pallas/XLA implementations behind one dispatcher.

Subpackages hold one op family each (``ans``, ``bucketize``, ``flash``)
as kernel.py (Pallas) + xla.py (pure-XLA twin) + ops.py (the dispatched
public surface) + ref.py (oracle). ``dispatch`` picks the backend per
(op, platform, workload); ``tuning`` measures candidates once and
persists the winners. See docs/PERF.md ("Kernel backends").
"""

from repro.kernels.dispatch import (Decision, available_backends,
                                    resolve, use_backend)
from repro.kernels.tuning import autotune_op, tuning_cache_path

__all__ = [
    "Decision",
    "available_backends",
    "resolve",
    "use_backend",
    "autotune_op",
    "tuning_cache_path",
]
