"""Oracle: exact SDPA with a materialized mask (repro.models.attention)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models import attention


def flash_ref(q, k, v, *, causal=True, window=0):
    """q/k/v [BH, S, D] -> [BH, Sq, D] via exact softmax attention."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    mask = attention._mask(sq, sk, causal, window if window > 0 else None)
    out = attention.sdpa(q[:, :, None, :], k[:, :, None, :],
                         v[:, :, None, :], mask)
    return out[:, :, 0, :]
