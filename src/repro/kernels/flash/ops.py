"""jit wrapper: model-layout flash attention via the Pallas kernel.

``flash_attention`` takes the model's [B, S, H, Dh] GQA layout, expands
kv heads, folds (B, H) into the kernel grid, and restores the layout.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash import kernel as K


def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=True):
    """q [B, Sq, Hq, Dh]; k/v [B, Sk, Hkv, Dh] -> [B, Sq, Hq, Dh]."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    if g > 1:  # expand GQA kv heads for the kernel's per-head grid
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hq, -1, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hq, -1, dh)
    out = K.flash_fwd(qf, kf, vf, causal=causal, window=window,
                      block_q=block_q, block_k=block_k,
                      interpret=interpret)
    return out.reshape(b, hq, sq, dh).transpose(0, 2, 1, 3)
