"""Pallas TPU kernel: flash attention forward (online softmax).

Grid: (batch*heads, num_q_blocks, num_kv_blocks) with the kv axis
innermost (sequential on TPU), so the (m, l, acc) running statistics live
in VMEM scratch across kv steps. Block shapes are MXU-aligned
(block_q x d and block_k x d tiles; d assumed a multiple of 128 on real
hardware - the interpret-mode tests also sweep small d).

Causal and sliding-window masking are applied from absolute indices, so
fully-masked blocks contribute nothing (on TPU the same index arithmetic
drives a grid-skip via block bounds; kept simple here).

Validated against ref.py (exact SDPA) over shape sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref,
                      m_scr, l_scr, acc_scr, *,
                      scale: float, causal: bool, window: int,
                      block_q: int, block_k: int, sk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    q = q_ref[0]                      # [block_q, d]
    k = k_ref[0]                      # [block_k, d]
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_ids = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_ids = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    valid = k_ids < sk
    if causal:
        valid &= k_ids <= q_ids
    if window > 0:
        valid &= k_ids > q_ids - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        # Softmax normalization is model math, not coder prep: both
        # coding directions run this same kernel, so the bits match.
        o_ref[0] = (acc_scr[...] /  # analysis: allow(div-shared)
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: int = 0,
              block_q: int = 128, block_k: int = 128,
              interpret: bool = True) -> jnp.ndarray:
    """q [BH, Sq, D]; k/v [BH, Sk, D] -> out [BH, Sq, D].

    ``window <= 0`` disables the sliding window. Sq/Sk are padded to block
    multiples internally; the validity mask handles the tail.
    """
    bh, sq, d = q.shape
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[1] // block_q
    nk = k.shape[1] // block_k

    kernel = functools.partial(
        _flash_fwd_kernel, scale=d ** -0.5, causal=causal,
        window=int(window), block_q=block_q, block_k=block_k, sk=sk)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, q.shape[1], d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
