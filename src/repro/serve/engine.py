"""Serving engine: prefill/decode session management, greedy generation,
and the neural-compression service entry points.

The engine is the jit boundary for serving: ``prefill_step`` and
``serve_step`` are the two lowered programs (the dry-run lowers exactly
these for the decode/prefill cells). State is donated across ``serve_step``
calls so KV caches update in place.

Three services live here:

  * ``Engine``      - the LM service (generation + token-stream
    compression, one-shot and BBX2 streaming).
  * ``CodecEngine`` - the shape-polymorphic codec service: any
    ``shape -> Codec`` family (e.g. the fully convolutional HVAE via
    ``models.hvae.codec_family``) served through the same one-shot
    container and BBX2 stream paths, with per-shape codec memoization.
  * ``ShardedCodecEngine`` - ``CodecEngine`` across a device mesh:
    one-shot requests run their compiled coder programs SPMD over the
    ANS lane axis (byte-identical wire to the single-device engine),
    and whole datasets shard into per-device BBX2 segments gathered as
    one BBX3 corpus (``repro.shard_codec``; docs/SCALING.md).
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import itertools
import threading
from collections import OrderedDict
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import codecs, stream
from repro.core import ans, lm_codec
from repro.core.codec import FnCodec
from repro.kernels import dispatch
from repro.models import transformer


@dataclasses.dataclass(frozen=True)
class _LMMaskedBlock(stream.MaskedBlockCodec):
    """LM token coding as a masked block codec for the dynamic batcher.

    The batcher's block symbols are time-major int[k, lanes]; the LM
    codes [lanes, k] with block-local context (the decode state resets
    at block boundaries - the price of independently-decodable blocks).
    """

    params: Any
    cfg: Any
    precision: int = ans.DEFAULT_PRECISION

    def push(self, stack: ans.ANSStack, xs: jnp.ndarray,
             n_valid: jnp.ndarray) -> ans.ANSStack:
        return lm_codec.encode_tokens_masked(
            self.params, self.cfg, xs.T.astype(jnp.int32), n_valid,
            stack, self.precision)

    def pop(self, stack: ans.ANSStack, k: int,
            n_valid: jnp.ndarray) -> Tuple[ans.ANSStack, jnp.ndarray]:
        stack, toks = lm_codec.decode_tokens_masked(
            self.params, self.cfg, stack, k, n_valid, self.precision)
        return stack, toks.T


class LaneLease(NamedTuple):
    """A granted claim on ``lanes`` lanes of an engine's lane budget.

    Returned by ``try_admit``; hand it back via ``retire``. The token
    makes double-retire detectable.
    """
    lanes: int
    token: int


class _LaneLedger:
    """Thread-safe non-blocking lane accounting shared by the engines.

    ``try_admit(lanes)`` either grants a ``LaneLease`` immediately or
    returns ``None`` (budget exhausted) - it never blocks, so an async
    front can turn a ``None`` into backpressure instead of buffering.
    ``max_lanes=None`` means an unbounded budget (leases still count,
    so ``inflight_lanes`` stays meaningful).
    """

    def __init__(self, max_lanes: Optional[int]):
        if max_lanes is not None and max_lanes < 1:
            raise ValueError("engine: max_inflight_lanes must be >= 1")
        self.max_lanes = max_lanes
        self._lock = threading.Lock()
        self._inflight = 0
        self._tokens = itertools.count()
        self._live: set = set()

    def try_admit(self, lanes: int) -> Optional[LaneLease]:
        if lanes < 1:
            raise ValueError("engine: try_admit needs lanes >= 1")
        with self._lock:
            if (self.max_lanes is not None
                    and self._inflight + lanes > self.max_lanes):
                return None
            self._inflight += lanes
            lease = LaneLease(lanes, next(self._tokens))
            self._live.add(lease.token)
            return lease

    def retire(self, lease: LaneLease) -> None:
        with self._lock:
            if lease.token not in self._live:
                raise ValueError(
                    f"engine: retire of unknown/already-retired lease "
                    f"{lease!r}")
            self._live.discard(lease.token)
            self._inflight -= lease.lanes

    @property
    def inflight_lanes(self) -> int:
        with self._lock:
            return self._inflight


class CodecEngine:
    """Shape-polymorphic compression service over any codec family.

    ``make_codec(shape) -> Codec`` builds the per-datapoint codec for
    symbols whose per-lane shape is ``shape`` (for the HVAE: ``(H, W)``
    images; the networks are fully convolutional so every shape shares
    one parameter set). Codecs are memoized per shape - the service
    pays network trace/compile cost once per distinct request shape.

    The memo is LRU-bounded by ``max_codecs`` (default 32): a workload
    cycling through many distinct shapes evicts the least recently used
    codec *and* its compiled programs instead of growing device memory
    without limit.

    ``compile=True`` routes every request through the codec compiler
    (``codecs.compile``): per (shape, chain length) one fused jit
    program is cached alongside the codec memo; wire bytes are
    identical to the interpreted path.

    Example (HVAE image service)::

        eng = CodecEngine(hvae.codec_family(params, cfg), seed=0,
                          compile=True)
        blob = eng.compress(batch)              # [n, lanes, H, W]
        out  = eng.decompress(blob, n, (H, W))  # bit-exact
        wire = eng.compress_stream(batch, block_symbols=8)
        out2 = eng.decompress_stream(wire, (H, W))
    """

    def __init__(self, make_codec, *, seed: Optional[int] = 0,
                 init_chunks: int = 32, max_codecs: int = 32,
                 compile: bool = False, verify: bool = True,
                 max_inflight_lanes: Optional[int] = None,
                 kernel_backend: Optional[str] = None):
        if max_codecs < 1:
            raise ValueError("CodecEngine: max_codecs must be >= 1")
        # Pin every request to one coder backend (None = auto-dispatch:
        # env / tuning cache / platform heuristic picks the fastest
        # bit-exact kernel per op).  Validated eagerly so a typo fails
        # at construction, not mid-request.
        if kernel_backend is not None:
            dispatch.Decision(backend=kernel_backend)
        self._kernel_backend = kernel_backend
        self._make_codec = make_codec
        self._codecs: "OrderedDict[Tuple[int, ...], Any]" = OrderedDict()
        # (shape, n) -> compiled Chained program; evicted with its shape.
        self._programs: "OrderedDict[Tuple, Any]" = OrderedDict()
        # Registration is not naturally thread-safe (LRU mutation +
        # build-then-insert races); the gateway serves requests from a
        # thread pool, so memo and program cache share one lock.
        self._memo_lock = threading.RLock()
        self._ledger = _LaneLedger(max_inflight_lanes)
        self._seed = seed
        self._init_chunks = init_chunks
        self._max_codecs = max_codecs
        self._compile = compile
        # Contract-verify each codec once at registration (on by
        # default): a family bug surfaces as analysis.ContractViolation
        # naming the subtree, before any request bytes are at stake.
        self._verify = verify

    # -- admission (non-blocking; the async gateway's hook) -----------------

    def try_admit(self, lanes: int) -> Optional[LaneLease]:
        """Claim ``lanes`` lanes of the engine's lane budget, or
        ``None`` when the budget (``max_inflight_lanes``) is exhausted.
        Never blocks; thread-safe."""
        return self._ledger.try_admit(lanes)

    def retire(self, lease: LaneLease) -> None:
        """Return a ``try_admit`` lease's lanes to the budget."""
        self._ledger.retire(lease)

    @property
    def inflight_lanes(self) -> int:
        """Lanes currently held by un-retired leases."""
        return self._ledger.inflight_lanes

    def codec_for(self, shape: Sequence[int]):
        """The memoized per-datapoint codec for one symbol shape.

        With ``verify=True`` (the default) a newly built codec is run
        through ``repro.analysis.check_codec`` before it is memoized;
        a contract violation raises instead of serving requests.
        Thread-safe: concurrent registration of the same shape builds
        (and verifies) the codec exactly once."""
        key = tuple(int(s) for s in shape)
        with self._memo_lock:
            if key in self._codecs:
                self._codecs.move_to_end(key)
                return self._codecs[key]
            while len(self._codecs) >= self._max_codecs:
                evicted, _ = self._codecs.popitem(last=False)
                for pkey in [k for k in self._programs if k[0] == evicted]:
                    del self._programs[pkey]
            codec = self._make_codec(key)
            if self._verify:
                from repro.analysis import check_codec   # lazy: avoid cycle
                check_codec(codec, lanes=2,
                            context=f"CodecEngine.codec_for({key})")
            self._codecs[key] = codec
            return self._codecs[key]

    def _chained_for(self, shape: Sequence[int], n: int):
        """A (compiled, when enabled) chain codec for ``n`` datapoints."""
        key = tuple(int(s) for s in shape)
        codec = codecs.Chained(self.codec_for(key), n)
        if not self._compile:
            return codec
        with self._memo_lock:
            pkey = (key, n)
            if pkey not in self._programs:
                while len(self._programs) >= self._max_codecs:
                    self._programs.popitem(last=False)
                self._programs[pkey] = codecs.compile(codec)
            self._programs.move_to_end(pkey)
            return self._programs[pkey]

    @staticmethod
    def _shape_of(data) -> Tuple[int, ...]:
        leaf = jax.tree_util.tree_leaves(data)[0]
        return tuple(leaf.shape[2:])  # [n, lanes, *shape]

    def _backend_ctx(self):
        """Kernel-backend pin for one request (no-op when unset).

        Fused coder programs resolve their ``dispatch.Decision`` at
        call time, so the pin steers even codecs compiled before the
        engine was built - at the cost of one retrace per distinct
        decision."""
        if self._kernel_backend is None:
            return contextlib.nullcontext()
        return dispatch.use_backend(self._kernel_backend)

    def compress(self, data, **kwargs) -> bytes:
        """One-shot compress of ``[n, lanes, *shape]`` data to a BBX1
        blob (``codecs.compress`` semantics: grow-and-retry, never a
        corrupt blob)."""
        leaf = jax.tree_util.tree_leaves(data)[0]
        n, lanes = leaf.shape[0], leaf.shape[1]
        with self._backend_ctx():
            codec = self._chained_for(self._shape_of(data), n)
            kwargs.setdefault("seed", self._seed)
            kwargs.setdefault("init_chunks", self._init_chunks)
            return codecs.compress(codec, data, lanes=lanes, **kwargs)

    def decompress(self, blob: bytes, n: int, shape: Sequence[int]):
        """Decode a ``compress`` blob of ``n`` datapoints of ``shape``."""
        with self._backend_ctx():
            return codecs.decompress(self._chained_for(shape, n), blob)

    def stream_encoder(self, shape: Sequence[int], *, lanes: int,
                       block_symbols: int = 8,
                       **kwargs) -> stream.StreamEncoder:
        """A ``StreamEncoder`` configured exactly as ``compress_stream``
        builds one (same memoized codec, seed, init_chunks, compile
        choice) - the session constructor the gateway uses, so gateway
        wires are byte-identical to the synchronous path by
        construction."""
        kwargs.setdefault("seed", self._seed)
        kwargs.setdefault("init_chunks", self._init_chunks)
        kwargs.setdefault("compile", self._compile)
        return stream.StreamEncoder(
            self.codec_for(shape), lanes=lanes,
            block_symbols=block_symbols, **kwargs)

    def resume_encoder(self, shape: Sequence[int],
                       snap: stream.EncoderSnapshot
                       ) -> stream.StreamEncoder:
        """Rebuild a mid-stream encoder from an ``EncoderSnapshot``;
        continuing bytes are identical to the uninterrupted stream."""
        return stream.StreamEncoder.resume(
            self.codec_for(shape), snap, compile=self._compile)

    def stream_decoder(self, shape: Sequence[int],
                       **kwargs) -> stream.StreamDecoder:
        """A ``StreamDecoder`` matching this engine's execution config
        (pass ``header=`` to start mid-stream)."""
        kwargs.setdefault("compile", self._compile)
        return stream.StreamDecoder(self.codec_for(shape), **kwargs)

    def compress_stream(self, data, *, block_symbols: int = 8,
                        **kwargs) -> bytes:
        """Chunked-streaming compress to a BBX2 blob: blocks become
        independently decodable as they fill (mid-stream resume via
        ``stream.decode_from_offset``)."""
        leaf = jax.tree_util.tree_leaves(data)[0]
        with self._backend_ctx():
            enc = self.stream_encoder(self._shape_of(data),
                                      lanes=leaf.shape[1],
                                      block_symbols=block_symbols, **kwargs)
            return enc.write(data) + enc.flush()

    def decompress_stream(self, blob: bytes, shape: Sequence[int]):
        """Decode a ``compress_stream`` blob back to [n, lanes, *shape]."""
        with self._backend_ctx():
            return stream.decode_stream(self.codec_for(shape), blob,
                                        compile=self._compile)


class ShardedCodecEngine:
    """Lane-sharded compression service over any codec family.

    Wraps a ``CodecEngine`` with a 1-D device mesh over the ANS lane
    axis (``sharding.lane_mesh``), adding data parallelism in both
    request shapes while keeping wire bytes *identical* to the
    single-device engine (the determinism contract across devices;
    proved in ``tests/test_shard_codec.py`` under 8 simulated
    devices):

      * ``compress``/``decompress`` - one-shot BBX1 requests: the
        compiled codec's fused integer coder programs run SPMD over
        the mesh via ``shard_map`` (``sharding.use_lane_mesh``); the
        request's lane count must be a multiple of the mesh size
        (checked up front).
      * ``compress_dataset``/``decompress_dataset``/
        ``decompress_shard`` - dataset-scale BBX3 corpora: the lane
        axis splits into ``n_shards`` independent BBX2 segments, one
        per device (``repro.shard_codec``), so any shard decodes
        alone.

    Example (HVAE image service across all local devices)::

        eng = ShardedCodecEngine(hvae.codec_family(params, cfg), seed=0)
        blob = eng.compress(batch)               # SPMD; bytes == 1-dev
        corp = eng.compress_dataset(batch)       # BBX3, lane-sharded
        out  = eng.decompress_dataset(corp, (H, W))
    """

    def __init__(self, make_codec, *, mesh=None,
                 n_shards: Optional[int] = None, seed: Optional[int] = 0,
                 init_chunks: int = 32, max_codecs: int = 32,
                 compile: bool = True, verify: bool = True,
                 max_inflight_lanes: Optional[int] = None,
                 kernel_backend: Optional[str] = None):
        from repro.sharding import api as shard_api
        self._shard_api = shard_api
        self.mesh = mesh if mesh is not None \
            else shard_api.lane_mesh(min(n_shards, len(jax.devices()))
                                     if n_shards is not None else None)
        self.n_shards = int(n_shards if n_shards is not None
                            else self.mesh.devices.size)
        if self.n_shards < 1:
            raise ValueError("ShardedCodecEngine: n_shards must be >= 1")
        self._inner = CodecEngine(make_codec, seed=seed,
                                  init_chunks=init_chunks,
                                  max_codecs=max_codecs, compile=compile,
                                  verify=verify,
                                  max_inflight_lanes=max_inflight_lanes,
                                  kernel_backend=kernel_backend)
        self._seed = seed
        self._init_chunks = init_chunks
        self._compile = compile

    # -- admission (delegated to the inner engine's ledger) -----------------

    def try_admit(self, lanes: int) -> Optional[LaneLease]:
        """Non-blocking lane claim; see ``CodecEngine.try_admit``."""
        return self._inner.try_admit(lanes)

    def retire(self, lease: LaneLease) -> None:
        self._inner.retire(lease)

    @property
    def inflight_lanes(self) -> int:
        return self._inner.inflight_lanes

    # -- stream sessions (delegated; wire bytes == single-device) -----------

    def stream_encoder(self, shape: Sequence[int], **kwargs):
        return self._inner.stream_encoder(shape, **kwargs)

    def resume_encoder(self, shape: Sequence[int], snap):
        return self._inner.resume_encoder(shape, snap)

    def stream_decoder(self, shape: Sequence[int], **kwargs):
        return self._inner.stream_decoder(shape, **kwargs)

    # -- one-shot path (SPMD coder programs; BBX1 wire) ---------------------

    def _check_lanes(self, lanes: int) -> None:
        mesh_size = int(self.mesh.devices.size)
        if lanes % mesh_size:
            raise ValueError(
                f"ShardedCodecEngine: {lanes} lanes must be a multiple "
                f"of the lane-mesh size {mesh_size} (size the batch's "
                "lane axis to the device count, or build the engine "
                "with a smaller mesh via n_shards=)")

    def compress(self, data, **kwargs) -> bytes:
        """One-shot compress of ``[n, lanes, *shape]`` data; lanes are
        split across the mesh inside the fused coder programs. Bytes
        are identical to ``CodecEngine.compress``."""
        self._check_lanes(jax.tree_util.tree_leaves(data)[0].shape[1])
        with self._shard_api.use_lane_mesh(self.mesh):
            return self._inner.compress(data, **kwargs)

    def decompress(self, blob: bytes, n: int, shape: Sequence[int]):
        """SPMD decode of a ``compress`` blob (bit-exact)."""
        self._check_lanes(codecs.blob_info(blob)["lanes"])
        with self._shard_api.use_lane_mesh(self.mesh):
            return self._inner.decompress(blob, n, shape)

    # -- dataset path (per-shard segments; BBX3 wire) -----------------------

    def compress_dataset(self, data, *, block_symbols: int = 8,
                         **kwargs) -> bytes:
        """Compress ``[n, lanes, *shape]`` data (or an iterable of such
        chunks) into a BBX3 corpus: ``n_shards`` independently
        decodable per-device BBX2 segments plus an index."""
        from repro import shard_codec
        with self._inner._backend_ctx():
            first, data = shard_codec.peek_chunks(data)
            codec = self._inner.codec_for(self._inner._shape_of(first))
            kwargs.setdefault("seed", self._seed)
            kwargs.setdefault("init_chunks", self._init_chunks)
            kwargs.setdefault("compile", self._compile)
            return shard_codec.compress_dataset(
                codec, data, n_shards=self.n_shards,
                block_symbols=block_symbols, **kwargs)

    def decompress_dataset(self, blob: bytes, shape: Sequence[int]):
        """Decode a whole BBX3 corpus back to ``[n, lanes, *shape]``."""
        from repro import shard_codec
        with self._inner._backend_ctx():
            return shard_codec.decompress_dataset(
                self._inner.codec_for(shape), blob, compile=self._compile)

    def decompress_shard(self, blob: bytes, shard: int,
                         shape: Sequence[int]):
        """Decode ONE shard's segment - the distributed-decode unit."""
        from repro import shard_codec
        with self._inner._backend_ctx():
            return shard_codec.decompress_shard(
                self._inner.codec_for(shape), blob, shard,
                compile=self._compile)


# ---------------------------------------------------------------------------
# engine factory handles - the remote-attach surface for the cluster
# ---------------------------------------------------------------------------

#: name -> builder(**kwargs) -> engine. Builders are registered once
#: per process; a handle names one, so it stays JSON-small on the wire.
_ENGINE_FACTORIES: Dict[str, Any] = {}
_FACTORY_LOCK = threading.Lock()


@dataclasses.dataclass(frozen=True)
class EngineHandle:
    """A serializable recipe for attaching an engine on a remote host.

    Engines hold codec closures and device buffers, so they cannot
    cross process boundaries; a handle can - it is just a registered
    ``factory`` name plus JSON-able ``kwargs``. Each cluster host (its
    own event loop or process) resolves the handle *locally* with
    ``engine_from_handle``, building its own engine from the same
    recipe - which is exactly what keeps cluster wire bytes identical
    to single-host: every host derives its coder state from (family,
    seed), never from another host's memory.

    Example::

        register_engine_factory("uniform8", lambda **kw:
            CodecEngine(make_uniform_family(8), **kw))
        handle = EngineHandle("uniform8", {"seed": 0, "init_chunks": 0})
        eng = engine_from_handle(handle)     # on any host
    """

    factory: str
    kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)


def register_engine_factory(name: str, builder: Any, *,
                            overwrite: bool = False) -> None:
    """Register ``builder(**kwargs) -> engine`` under ``name`` so
    ``EngineHandle(name, ...)`` resolves on this host. Re-registering
    an existing name raises unless ``overwrite=True``."""
    if not name or not isinstance(name, str):
        raise ValueError("serve: engine factory name must be a "
                         "non-empty string")
    if not callable(builder):
        raise TypeError(f"serve: engine factory {name!r} must be callable")
    with _FACTORY_LOCK:
        if name in _ENGINE_FACTORIES and not overwrite:
            raise ValueError(
                f"serve: engine factory {name!r} already registered "
                "(pass overwrite=True to replace)")
        _ENGINE_FACTORIES[name] = builder


def engine_from_handle(handle: EngineHandle) -> Any:
    """Build the engine a handle describes, using this host's factory
    registry. Raises ``KeyError`` with the known names when the factory
    was never registered here - the remote host must load the same
    registration module the submitting host did."""
    if not isinstance(handle, EngineHandle):
        raise TypeError(
            f"serve: expected an EngineHandle, got "
            f"{type(handle).__name__}")
    with _FACTORY_LOCK:
        builder = _ENGINE_FACTORIES.get(handle.factory)
        known = sorted(_ENGINE_FACTORIES)
    if builder is None:
        raise KeyError(
            f"serve: no engine factory {handle.factory!r} registered "
            f"on this host (known: {known})")
    return builder(**dict(handle.kwargs))


class Engine:
    """The LM serving engine: sessionful generation plus the token
    compression service (one-shot BBX1, streamed BBX2, dynamic-batched
    multi-request).

    Example::

        eng = Engine(params, cfg, max_len=128)
        toks = eng.generate(batch, n_tokens=16)      # greedy continue
        blob = eng.compress(token_streams)           # lossless LM-ANS
    """

    def __init__(self, params, cfg, max_len: int = 2048,
                 jit: bool = True, max_inflight_lanes: Optional[int] = None):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self._ledger = _LaneLedger(max_inflight_lanes)
        self._prefill = jax.jit(
            functools.partial(transformer.prefill, cfg=self.cfg,
                              max_len=max_len)) if jit else \
            functools.partial(transformer.prefill, cfg=self.cfg,
                              max_len=max_len)
        self._step = jax.jit(
            functools.partial(transformer.decode_step, cfg=self.cfg),
            donate_argnames=("state",)) if jit else \
            functools.partial(transformer.decode_step, cfg=self.cfg)

    # -- admission ----------------------------------------------------------

    def try_admit(self, lanes: int) -> Optional[LaneLease]:
        """Non-blocking lane claim; see ``CodecEngine.try_admit``."""
        return self._ledger.try_admit(lanes)

    def retire(self, lease: LaneLease) -> None:
        self._ledger.retire(lease)

    @property
    def inflight_lanes(self) -> int:
        return self._ledger.inflight_lanes

    # -- session ------------------------------------------------------------
    def start(self, batch: Dict[str, jnp.ndarray]
              ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """Prefill the prompt; returns (last logits [B,1,V], session)."""
        return self._prefill(self.params, batch=batch)

    def step(self, tok: jnp.ndarray, session: Dict[str, Any]):
        return self._step(self.params, tok=tok, state=session)

    def generate(self, batch: Dict[str, jnp.ndarray], n_tokens: int
                 ) -> jnp.ndarray:
        """Greedy continuation of the prompt; [B, n_tokens]."""
        logits, session = self.start(batch)
        toks = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        for _ in range(n_tokens):
            toks.append(tok[:, 0])
            logits, session = self.step(tok, session)
            tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        return jnp.stack(toks, axis=1)

    # -- compression service --------------------------------------------------
    def compress(self, tokens: jnp.ndarray, capacity_factor: float = 1.5
                 ) -> bytes:
        """Losslessly compress token streams [lanes, N] with the LM.

        Returns a self-contained ``repro.codecs`` container blob
        (header + per-lane ANS message); ``codecs.blob_info`` exposes
        the payload size. Direct coding needs no clean bits, so the
        stack starts cold (``seed=None``) and the blob is deterministic.
        """
        lanes, n = tokens.shape
        codec = lm_codec.TokenStream(self.params, self.cfg, n)
        return codecs.compress(
            codec, tokens, lanes=lanes, seed=None, init_chunks=0,
            capacity=int(n * capacity_factor) + 8)

    def decompress(self, blob: bytes, n: int) -> jnp.ndarray:
        codec = lm_codec.TokenStream(self.params, self.cfg, n)
        return codecs.decompress(codec, blob)

    # -- streaming service ----------------------------------------------------

    def _block_codec_fn(self):
        """BBX2 block codec: TokenStream over one block, transposed to
        the stream layer's time-major [k, lanes] layout."""
        def fn(k: int):
            inner = lm_codec.TokenStream(self.params, self.cfg, k)

            def push(stack, xs):
                return inner.push(stack, xs.T.astype(jnp.int32))

            def pop(stack):
                stack, toks = inner.pop(stack)
                return stack, toks.T

            return FnCodec(push, pop)
        return fn

    def compress_stream(self, tokens: jnp.ndarray, *,
                        block_symbols: int = 64,
                        capacity_factor: float = 1.5) -> bytes:
        """Chunked-streaming compress of token streams [lanes, N].

        Returns a ``BBX2`` blob: every ``block_symbols`` tokens/lane
        become an independently-decodable block (clean bits carried
        across boundaries encoder-side), so a consumer can start
        decoding - or resume from a mid-stream byte offset via
        ``stream.decode_from_offset`` - long before the stream ends.
        The LM context is block-local: prediction resets at block
        boundaries, trading a little rate for random access.
        """
        lanes, n = tokens.shape
        enc = stream.StreamEncoder(
            block_codec_fn=self._block_codec_fn(),
            lanes=lanes, block_symbols=block_symbols, seed=None,
            capacity=int(block_symbols * capacity_factor) + 8)
        return enc.write(tokens.T) + enc.flush()

    def decompress_stream(self, blob: bytes) -> jnp.ndarray:
        """Decode a ``compress_stream`` blob back to [lanes, N]."""
        out = stream.decode_stream(None, blob,
                                   block_codec_fn=self._block_codec_fn())
        return out.T if out is not None else out

    def serve_many(self, requests: Sequence[jnp.ndarray], *,
                   max_lanes: int = 8, block_symbols: int = 32,
                   capacity_factor: float = 1.5) -> List[bytes]:
        """Compress many independent token streams of different lengths
        through one ``ANSStack`` (the multi-request service path).

        The dynamic batcher packs up to ``max_lanes`` requests into the
        lane axis per block round, admitting queued requests as lanes
        free up; every network call runs at width ``max_lanes`` (free
        lanes masked) so encode and decode share one compiled
        executable - the ``lm_codec`` determinism contract at batch
        level. Returns one 1-lane BBX2 blob per request, in order.
        """
        bat = stream.StreamBatcher(
            _LMMaskedBlock(self.params, self.cfg),
            max_lanes=max_lanes, block_symbols=block_symbols, seed=None,
            capacity=int(block_symbols * capacity_factor) + 8)
        for i, toks in enumerate(requests):
            bat.submit(i, toks.astype(jnp.int32))
        blobs = bat.run()
        return [blobs[i] for i in range(len(requests))]

    def decompress_many(self, blobs: Sequence[bytes], *,
                        max_lanes: int = 8,
                        block_symbols: int = 32) -> List[jnp.ndarray]:
        """Batched decode of ``serve_many`` blobs.

        ``max_lanes`` must match the encoding call: the decoder drives
        the same width-``max_lanes`` executable so logits are bitwise
        identical to encode time.
        """
        outs = stream.decode_batched(
            _LMMaskedBlock(self.params, self.cfg),
            {i: b for i, b in enumerate(blobs)},
            max_lanes=max_lanes, block_symbols=block_symbols)
        empty = jnp.zeros((0,), jnp.int32)
        return [outs[i] if outs[i] is not None else empty
                for i in range(len(blobs))]
