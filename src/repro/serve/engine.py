"""Serving engine: prefill/decode session management, greedy generation,
and the neural-compression service entry points.

The engine is the jit boundary for serving: ``prefill_step`` and
``serve_step`` are the two lowered programs (the dry-run lowers exactly
these for the decode/prefill cells). State is donated across ``serve_step``
calls so KV caches update in place.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import codecs
from repro.core import lm_codec
from repro.models import transformer


class Engine:
    def __init__(self, params, cfg, max_len: int = 2048,
                 jit: bool = True):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self._prefill = jax.jit(
            functools.partial(transformer.prefill, cfg=self.cfg,
                              max_len=max_len)) if jit else \
            functools.partial(transformer.prefill, cfg=self.cfg,
                              max_len=max_len)
        self._step = jax.jit(
            functools.partial(transformer.decode_step, cfg=self.cfg),
            donate_argnames=("state",)) if jit else \
            functools.partial(transformer.decode_step, cfg=self.cfg)

    # -- session ------------------------------------------------------------
    def start(self, batch: Dict[str, jnp.ndarray]
              ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """Prefill the prompt; returns (last logits [B,1,V], session)."""
        return self._prefill(self.params, batch=batch)

    def step(self, tok: jnp.ndarray, session: Dict[str, Any]):
        return self._step(self.params, tok=tok, state=session)

    def generate(self, batch: Dict[str, jnp.ndarray], n_tokens: int
                 ) -> jnp.ndarray:
        """Greedy continuation of the prompt; [B, n_tokens]."""
        logits, session = self.start(batch)
        toks = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        for _ in range(n_tokens):
            toks.append(tok[:, 0])
            logits, session = self.step(tok, session)
            tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        return jnp.stack(toks, axis=1)

    # -- compression service --------------------------------------------------
    def compress(self, tokens: jnp.ndarray, capacity_factor: float = 1.5
                 ) -> bytes:
        """Losslessly compress token streams [lanes, N] with the LM.

        Returns a self-contained ``repro.codecs`` container blob
        (header + per-lane ANS message); ``codecs.blob_info`` exposes
        the payload size. Direct coding needs no clean bits, so the
        stack starts cold (``seed=None``) and the blob is deterministic.
        """
        lanes, n = tokens.shape
        codec = lm_codec.TokenStream(self.params, self.cfg, n)
        return codecs.compress(
            codec, tokens, lanes=lanes, seed=None, init_chunks=0,
            capacity=int(n * capacity_factor) + 8)

    def decompress(self, blob: bytes, n: int) -> jnp.ndarray:
        codec = lm_codec.TokenStream(self.params, self.cfg, n)
        return codecs.decompress(codec, blob)
