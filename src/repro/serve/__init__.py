"""``repro.serve`` - the serving engines.

``Engine`` is the LM service (generation + token compression),
``CodecEngine`` the shape-polymorphic codec service, and
``ShardedCodecEngine`` its lane-sharded, multi-device form (one-shot
SPMD requests + BBX3 dataset corpora - docs/SCALING.md). Runnable
examples for every exported name: docs/API.md.
"""

from repro.serve.engine import (CodecEngine, Engine,  # noqa: F401
                                EngineHandle, LaneLease,
                                ShardedCodecEngine, engine_from_handle,
                                register_engine_factory)

__all__ = ["Engine", "CodecEngine", "ShardedCodecEngine", "LaneLease",
           "EngineHandle", "register_engine_factory",
           "engine_from_handle"]
