"""qwen2-vl-2b [vlm]: M-RoPE, dynamic-resolution vision stubbed (patch
embeddings provided by input_specs). [arXiv:2409.12191; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936,
    qkv_bias=True, tie_embeddings=True,
    rope_kind="mrope", mrope_sections=(16, 24, 24),
    rope_theta=1000000.0, frontend="vision_stub",
    optimizer="adamw", remat="full", grad_accum=2, fsdp_regather_once=True,
))
