"""Hierarchical image-VAE config family (the Bit-Swap/HiLLoC workload).

Every config is a shape-free ``HVAEConfig``: the networks are fully
convolutional, so the same parameters code any even H x W (the data
side pads odd shapes - ``data.images``). ``small`` is the smoke/CI
scale; ``base`` is the real training scale. Both come in 2- and
3-level variants so the Bit-Swap clean-bit bound can be measured as a
function of depth (``benchmarks/hvae_rate.py``).

    cfg = hvae_img.get("hvae-small2")
    PYTHONPATH=src python -m repro.launch.train --arch hvae-small2
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.hvae import HVAEConfig

SMALL2 = HVAEConfig(levels=2, ch=16, z_ch=2, n_res=1)
SMALL3 = dataclasses.replace(SMALL2, levels=3)
BASE2 = HVAEConfig(levels=2, ch=48, z_ch=4, n_res=2)
BASE3 = dataclasses.replace(BASE2, levels=3)

_REGISTRY: Dict[str, HVAEConfig] = {
    "hvae-small2": SMALL2,
    "hvae-small3": SMALL3,
    "hvae-base2": BASE2,
    "hvae-base3": BASE3,
}


def get(name: str) -> HVAEConfig:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown hvae config {name!r}; choose from "
            f"{sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> Dict[str, HVAEConfig]:
    return dict(_REGISTRY)
