"""mistral-nemo-12b [dense]: 128k ctx, explicit head_dim=128 (!= d/H).
[hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab=131072,
    rope_kind="rope", rope_theta=1000000.0,
    optimizer="adamw", remat="full", grad_accum=4,
))
