"""rwkv6-3b 'Finch' [ssm]: attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=0, n_kv_heads=0, d_head=64,
    d_ff=8960, vocab=65536,
    mixer="rwkv6", rope_kind="none",
    optimizer="adamw", remat="full", grad_accum=8,
))
