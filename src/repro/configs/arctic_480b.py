"""arctic-480b [moe]: 128 experts top-2 in parallel with a dense residual
MLP. [hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000,
    n_experts=128, top_k=2, moe_d_ff=4864, dense_ff_parallel=True,
    rope_kind="rope",
    optimizer="adafactor", remat="full", param_dtype="bfloat16",
    grad_accum=8,
))
