"""llama4-scout-17b-a16e [moe]: 16 experts top-1 + shared expert, early
fusion (text cells exercise the LM backbone).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048,
    n_experts=16, top_k=1, moe_d_ff=8192, shared_expert=True,
    rope_kind="rope", rope_theta=500000.0,
    optimizer="adafactor", remat="full", grad_accum=4,
))
