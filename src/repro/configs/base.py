"""Architecture config schema + registry + input-shape cells.

Every assigned architecture is a frozen ``ArchConfig``; the four assigned
input-shape cells are ``SHAPES``. ``input_specs`` builds the
ShapeDtypeStruct stand-ins used by the multi-pod dry-run (no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None          # explicit head dim (mistral-nemo)
    qkv_bias: bool = False
    rope_kind: str = "rope"               # rope | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # t/h/w for M-RoPE
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    act: str = "silu"                     # silu | gelu
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 1
    moe_d_ff: Optional[int] = None        # expert hidden (defaults d_ff)
    shared_expert: bool = False           # llama4: always-on shared expert
    dense_ff_parallel: bool = False       # arctic: dense MLP residual + MoE
    capacity_factor: float = 1.25
    # --- mixer ---
    mixer: str = "attention"              # attention | rwkv6 | hymba
    ssm_state: int = 16
    sliding_window: Optional[int] = None
    global_attn_every: int = 0            # hymba: full-attn layer stride
    # --- structure ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: Optional[str] = None        # audio_stub | vision_stub
    # --- numerics/training ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    optimizer: str = "adamw"              # adamw | adafactor
    remat: str = "dots"                   # none | dots | full
    loss_chunk: int = 1024                # seq chunking for the vocab loss
    grad_accum: int = 1                   # microbatches per train step
    fsdp_regather_once: bool = False      # gather params once per step
    kv_cache_dtype: str = "bfloat16"      # bfloat16 | int8 (serving)

    @property
    def head_dim(self) -> int:
        if self.d_head is not None:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    @property
    def is_subquadratic(self) -> bool:
        return self.mixer in ("rwkv6", "hymba")

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, dh = self.d_model, self.head_dim
        attn = (self.n_heads * dh + 2 * self.n_kv_heads * dh) * d \
            + self.n_heads * dh * d
        if self.mixer == "rwkv6":
            attn = 4 * d * d  # r/k/v/out (+ small lora terms, ignored)
        dense_mlp = 3 * d * self.d_ff if self.act == "silu" \
            else 2 * d * self.d_ff
        per_layer = attn
        if self.n_experts:
            per_layer += self.n_experts * 3 * d * self.expert_d_ff
            if self.shared_expert:
                per_layer += 3 * d * self.expert_d_ff
            if self.dense_ff_parallel:
                per_layer += dense_mlp
        else:
            per_layer += dense_mlp
        if self.mixer == "hymba":
            per_layer += 2 * d * d  # ssm branch in/out (+ small ssm params)
        n_blocks = self.n_layers + self.n_enc_layers
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return n_blocks * per_layer + embed

    def active_params(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.n_params()
        d = self.d_model
        full = self.n_params()
        all_experts = (self.n_layers *
                       self.n_experts * 3 * d * self.expert_d_ff)
        routed = self.n_layers * self.top_k * 3 * d * self.expert_d_ff
        return full - all_experts + routed


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_is_skipped(cfg: ArchConfig, cell: ShapeCell) -> Optional[str]:
    """Return a skip reason or None. Per the assignment: long_500k only for
    sub-quadratic mixers."""
    if cell.name == "long_500k" and not cfg.is_subquadratic:
        return "full-attention arch: 500k dense-KV decode is out of scope"
    return None


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_archs() -> Dict[str, ArchConfig]:
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


def _load_all():
    # Import for registration side effects.
    from repro.configs import (arctic_480b, hymba_1_5b,  # noqa: F401
                               llama4_scout_17b_a16e, mistral_nemo_12b,
                               qwen2_0_5b, qwen2_vl_2b, rwkv6_3b,
                               smollm_360m, stablelm_12b, vae_mnist,
                               whisper_small)


def input_shapes(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, Tuple]:
    """Abstract input shapes (name -> (shape, dtype)) for a cell.

    Used by the dry-run to build ShapeDtypeStructs (and by the data pipeline
    to size real batches). Frontend stubs follow the assignment spec:
    whisper gets precomputed frame embeddings (seq split 50/50 enc/dec),
    qwen2-vl gets precomputed merged patch+text embeddings.
    """
    b, s = cell.global_batch, cell.seq_len
    if cell.kind in ("train", "prefill"):
        if cfg.enc_dec:
            half = s // 2
            return {
                "enc_embeds": ((b, half, cfg.d_model), jnp.bfloat16),
                "tokens": ((b, half), jnp.int32),
            }
        if cfg.frontend == "vision_stub":
            return {
                "embeds": ((b, s, cfg.d_model), jnp.bfloat16),
                "tokens": ((b, s), jnp.int32),
            }
        return {"tokens": ((b, s), jnp.int32)}
    # decode cells: one new token against a cache of length s.
    return {"tokens": ((b, 1), jnp.int32)}


def reduced(cfg: ArchConfig, layers: int = 2, width: int = 64) -> ArchConfig:
    """Shrink a config to smoke-test scale, preserving family structure."""
    dh = 16
    n_heads = max(2, min(4, cfg.n_heads)) if cfg.n_heads else 0
    # Keep the GQA ratio >= 1 and divisible.
    n_kv = max(1, min(cfg.n_kv_heads, n_heads)) if cfg.n_heads else 0
    if n_heads and n_kv and n_heads % n_kv:
        n_kv = 1
    d_model = width
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=layers,
        n_enc_layers=min(cfg.n_enc_layers, layers) if cfg.enc_dec else 0,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=dh,
        d_ff=width * 2,
        moe_d_ff=width * 2 if cfg.n_experts else None,
        n_experts=min(cfg.n_experts, 4),
        mrope_sections=(dh // 8, dh // 8 + dh // 16, dh // 8 + dh // 16),
        vocab=257,
        sliding_window=min(cfg.sliding_window, 32)
        if cfg.sliding_window else None,
        loss_chunk=16,
        remat="none",
    )
