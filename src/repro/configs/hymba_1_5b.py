"""hymba-1.5b [hybrid]: parallel attention + mamba heads per layer,
sliding-window attention with periodic global layers, ssm_state=16.
[arXiv:2411.13676; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001,
    mixer="hymba", ssm_state=16,
    sliding_window=1024, global_attn_every=16,
    rope_kind="rope", optimizer="adamw", remat="full", grad_accum=2,
))
