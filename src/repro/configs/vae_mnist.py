"""The paper's own model: fully-connected VAE for (binarized) MNIST,
exposed as a config so launch/train drivers treat it uniformly."""
from repro.models.vae import VAEConfig, paper_config

BINARIZED = paper_config("bernoulli")
FULL = paper_config("beta_binomial")
