"""whisper-small [audio]: enc-dec, conv frontend stubbed (frame embeddings
provided by input_specs). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-small", family="encdec",
    n_layers=12, n_enc_layers=12, enc_dec=True,
    d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072, vocab=51865,
    rope_kind="none", norm="layernorm", act="gelu",
    frontend="audio_stub", qkv_bias=True,
    optimizer="adamw", remat="full", grad_accum=2, fsdp_regather_once=True,
))
