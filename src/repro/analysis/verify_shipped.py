"""CI gate: run the contract verifier over every shipped codec family.

Builds one small instance of each codec constructor the repo ships -
VAE BB-ANS (both likelihoods, interpreted and compiled), hierarchical
BitSwap, the LM token stream, and the stream-layer block codecs - and
requires a finding-free report from ``repro.analysis.verify_codec``.

Usage::

    python -m repro.analysis.verify_shipped

Exits 1 and prints rule name, subtree path, and fix hint for any
finding (warnings included: shipped constructors should be beyond
reproach); 0 when every family is clean.
"""

from __future__ import annotations

import dataclasses
import sys

import jax
import jax.numpy as jnp


def _cases():
    from repro import codecs
    from repro.models import vae as vae_lib

    cfg = vae_lib.VAEConfig(input_dim=36, hidden=24, latent=6)
    params = vae_lib.init(jax.random.PRNGKey(0), cfg)
    yield "vae-bernoulli", vae_lib.make_bb_codec(params, cfg)
    yield "vae-bernoulli-compiled", vae_lib.make_bb_codec(
        params, cfg, compiled=True)

    cfg_bb = dataclasses.replace(cfg, likelihood="beta_binomial")
    params_bb = vae_lib.init(jax.random.PRNGKey(1), cfg_bb)
    yield "vae-beta-binomial", vae_lib.make_bb_codec(params_bb, cfg_bb)

    # Fixed-point (quantized) variants: the verifier walks the
    # interpreted twin each FixedPointFn builds; the fused jit program
    # is bit-identical to it by construction (tests/test_parity_fuzz).
    yield "vae-bernoulli-quantized", vae_lib.make_bb_codec_q(params, cfg)
    yield "vae-quantized-compiled", vae_lib.make_bb_codec_q(
        params, cfg, compiled=True)

    from repro.models import hvae
    hcfg = hvae.HVAEConfig(levels=2, ch=8, z_ch=2, n_res=1)
    hparams = hvae.init(jax.random.PRNGKey(2), hcfg)
    yield "hvae-bitswap", hvae.make_bitswap_codec(hparams, hcfg, (4, 4))
    yield "hvae-bitswap-quantized", hvae.make_bitswap_codec_q(
        hparams, hcfg, (4, 4))

    from repro.configs import base as cfg_base
    from repro.core import lm_codec
    from repro.models import transformer
    tcfg = dataclasses.replace(
        cfg_base.reduced(cfg_base.get("qwen2-0.5b")), vocab=120)
    tparams = transformer.init(jax.random.PRNGKey(17), tcfg)
    yield "token-stream", lm_codec.TokenStream(tparams, tcfg, 4)

    from repro.core import ans
    from repro.stream import coder as stream_coder
    inner = codecs.Shaped(
        codecs.Repeat(lambda d: codecs.Uniform(8), 4), (4,))
    yield "stream-block-chain", stream_coder.BlockChain(inner, k=3)
    table = jnp.tile(
        ans.probs_to_starts(jnp.full((2, 16), 1.0 / 16), 16), (1, 1))
    yield "stream-kernel-table", stream_coder.KernelTableBlock(
        table, k=3, precision=16)


def main() -> int:
    from repro.analysis import verify_codec

    bad = 0
    for name, codec in _cases():
        report = verify_codec(codec, lanes=2, context=name)
        if report.findings:
            bad += 1
            print(report)
        else:
            bound = ("unbounded (opaque driver)"
                     if report.bits_bound is None
                     else f"<= {report.bits_bound:.0f} bits/lane")
            print(f"{name}: clean, worst case {bound}")
    if bad:
        print(f"verify_shipped: {bad} codec famil"
              f"{'y' if bad == 1 else 'ies'} with findings")
        return 1
    print("verify_shipped: all shipped codec families clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
