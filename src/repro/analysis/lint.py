"""CLI for the source-level contract lint.

Usage::

    python -m repro.analysis.lint src/ [more paths...]

Prints one block per finding (rule, file:line, message, fix hint) and
exits 1 if anything fired, 0 on a clean tree - suitable as a CI gate.
"""

from __future__ import annotations

import sys

from repro.analysis.source_lint import lint_paths


def main(argv=None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    if not args:
        print("usage: python -m repro.analysis.lint <path> [path...]",
              file=sys.stderr)
        return 2
    findings, n_files = lint_paths(args)
    for f in findings:
        print(f"{f.rule}: {f.path}")
        print(f"    {f.message}")
        if f.hint:
            print(f"    fix: {f.hint}")
    if findings:
        print(f"contract lint: {len(findings)} finding(s) in {n_files} "
              "file(s)")
        return 1
    print(f"contract lint: clean ({n_files} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
