"""``repro.analysis`` - static contract verification for codec trees.

BB-ANS correctness rests on invariants the rest of the repo only checks
by round-tripping data: every codec is an exact LIFO inverse pair
(``pop(push(stack, x)) == (stack, x)`` bit-for-bit, Townsend, Bird &
Barber, ICLR 2019, App. C), every frequency table sums to exactly
``2^precision`` with no zero-mass symbol, and model-float evaluation
stays in canonical eager form so compiled and interpreted wire bytes
match (the determinism contract; docs/PERF.md). This package checks
those invariants *without coding any user data*:

  * ``verify_codec(codec)`` traverses a ``Codec`` tree down to its
    leaves - materializing ``BBANS``/``BitSwap`` function children from
    scratch-stack probes - and proves frequency-table soundness, traces
    push/pop to jaxprs to catch float leaks and non-canonical float
    division, mirror-checks every leaf's (start, freq) events, probes
    the whole tree for bit-exact inversion, and bounds the worst-case
    bits per datapoint against stack capacity. Returns a ``Report``;
    ``check_codec`` raises ``ContractViolation`` instead.
  * ``lint_paths(["src/"])`` / ``python -m repro.analysis.lint src/``
    enforce the same rules at source level (AST) for code the tracer
    cannot see: kernels, oracles, lowering code.

The rule catalogue with a minimal offending example per rule (each one
executed by ``tests/test_docs.py``): docs/ANALYSIS.md. The verifier is
wired into ``serve.CodecEngine`` codec registration (on by default,
``verify=False`` to opt out) and ``codecs.compile`` validates lowered
tables unconditionally - a contract violation fails at build time
naming the offending subtree, not as a hex mismatch three layers later.

Example::

    from repro import analysis, codecs
    report = analysis.verify_codec(codecs.Uniform(8), lanes=2)
    assert report.ok and not report.findings
"""

from repro.analysis.verifier import (ContractViolation, Finding,  # noqa: F401
                                     Report, bits_bound, check_codec,
                                     verify_codec)
from repro.analysis.source_lint import (RULES, lint_paths,  # noqa: F401
                                        lint_source)

__all__ = [
    "Finding", "Report", "ContractViolation",
    "verify_codec", "check_codec", "bits_bound",
    "lint_paths", "lint_source", "RULES",
]
