"""The codec-tree contract verifier (see package docstring).

Two passes over a ``Codec`` tree, both driven by a scratch stack seeded
with deterministic clean bits - no user data is coded:

  1. **Inverse probe**: ``pop`` the whole tree off a fresh stack, push
     the decoded value back, and require the stack to come back
     bit-identical (head, chunk buffer, and depth). This is the paper's
     App.-C contract checked end to end (rule ``inverse-probe``).
  2. **Collection walk**: a decode-ordered traversal of the combinator
     structure. Function children (``BBANS`` likelihood/posterior,
     ``BitSwap`` layers) are materialized by popping representative
     values from the scratch stack, exactly as a decode would; every
     leaf then gets
       - frequency-table soundness checks (``freq-sum``, ``freq-zero``,
         ``starts-monotone``),
       - a mirror probe comparing the (start, freq, precision) events
         of one pop against the push that inverts it
         (``push-pop-mirror``),
       - jaxpr rules over its traced push/pop programs (``float-leak``,
         ``div-shared``, ``ndtri-coder``),
     plus the structural PR-4 rules (``scan-chain``, ``edge-cache``)
     and a worst-case bits-per-datapoint bound (``capacity-bound``).

Opaque leaves - ``FnCodec``, ``core.lm_codec.TokenStream``, any class
marking itself ``__analysis_opaque__ = True`` - are driver codecs whose
float evaluation happens inside jitted network steps they manage
themselves; they are probed for inversion only (the jaxpr rules would
false-positive on network internals like softmax divisions). Unknown
``Codec`` subclasses are treated the same but noted in the report.
"""

from __future__ import annotations

import dataclasses
from contextlib import contextmanager
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ans, discretize
from repro.core.codec import Codec, FnCodec
from repro.core.distributions import (Bernoulli, BetaBinomial, Categorical,
                                      FactoredCategorical)
from repro.codecs import combinators as C
from repro.codecs import leaves as L
from repro.codecs.container import fresh_stack


# ---------------------------------------------------------------------------
# findings and reports
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation (or warning/note) at a tree path.

    ``rule`` is a key of ``analysis.RULES``; ``path`` names the
    offending subtree (e.g. ``codec.likelihood(y).codec_fn(3)``);
    ``hint`` says how to fix it.
    """

    rule: str
    severity: str          # "error" | "warning" | "info"
    path: str
    message: str
    hint: str = ""

    def __str__(self) -> str:
        tail = f"\n      hint: {self.hint}" if self.hint else ""
        return (f"[{self.severity}] {self.rule} at {self.path}: "
                f"{self.message}{tail}")


@dataclasses.dataclass(frozen=True)
class Report:
    """The outcome of ``verify_codec``: findings plus context.

    ``findings`` holds errors and warnings (the things that gate);
    ``notes`` holds info-level observations (opaque leaves probed but
    not traced). ``bits_bound`` is the worst-case bits one datapoint
    can push per lane (``None`` when the tree contains opaque leaves
    whose cost is unknowable statically).
    """

    context: str
    findings: Tuple[Finding, ...]
    notes: Tuple[Finding, ...] = ()
    bits_bound: Optional[float] = None

    @property
    def errors(self) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.severity == "error")

    @property
    def warnings(self) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.severity == "warning")

    @property
    def ok(self) -> bool:
        """True when no *errors* were found (warnings don't gate)."""
        return not self.errors

    def __str__(self) -> str:
        if not self.findings and not self.notes:
            return f"{self.context}: clean"
        lines = [f"{self.context}: {len(self.errors)} error(s), "
                 f"{len(self.warnings)} warning(s)"]
        lines += [f"  {f}" for f in self.findings]
        lines += [f"  {n}" for n in self.notes]
        return "\n".join(lines)


class ContractViolation(RuntimeError):
    """Raised by ``check_codec`` when verification finds errors; the
    full ``Report`` rides along as ``.report``."""

    def __init__(self, report: Report):
        self.report = report
        super().__init__(str(report))


# ---------------------------------------------------------------------------
# walk context
# ---------------------------------------------------------------------------

class _Ctx:
    def __init__(self) -> None:
        self.findings: List[Finding] = []
        self.notes: List[Finding] = []
        self.bound = 0.0
        self.bound_exact = True

    def error(self, rule: str, path: str, msg: str, hint: str = "") -> None:
        self.findings.append(Finding(rule, "error", path, msg, hint))

    def warn(self, rule: str, path: str, msg: str, hint: str = "") -> None:
        self.findings.append(Finding(rule, "warning", path, msg, hint))

    def note(self, rule: str, path: str, msg: str, hint: str = "") -> None:
        self.notes.append(Finding(rule, "info", path, msg, hint))


def _unwrap(codec: Codec) -> Codec:
    """Analyze a ``CompiledCodec`` through its source tree: the lowering
    is bit-exact by construction (and separately validated at lowering
    time), and the source tree is the form the rules understand."""
    src = getattr(codec, "source", None)
    return src if isinstance(src, Codec) else codec


def _stacks_equal(a: ans.ANSStack, b: ans.ANSStack) -> Optional[str]:
    """None when coder state matches bit-for-bit, else a description."""
    ah, bh = np.asarray(a.head), np.asarray(b.head)
    ap, bp = np.asarray(a.ptr), np.asarray(b.ptr)
    ab, bb = np.asarray(a.buf), np.asarray(b.buf)
    if (ah != bh).any():
        lanes = np.nonzero(ah != bh)[0][:4].tolist()
        return f"head differs on lanes {lanes}"
    if (ap != bp).any():
        lanes = np.nonzero(ap != bp)[0][:4].tolist()
        return f"stack depth differs on lanes {lanes}"
    # Only chunks below ptr are live; slots above it are dead scratch
    # that interleaved bits-back pushes legitimately leave behind.
    live = np.arange(ab.shape[1])[None, :] < ap[:, None]
    if ((ab != bb) & live).any():
        lane, col = (int(x[0]) for x in np.nonzero((ab != bb) & live))
        return f"chunk buffer differs first at lane {lane}, slot {col}"
    return None


# ---------------------------------------------------------------------------
# (start, freq) event recording - the push/pop mirror check
# ---------------------------------------------------------------------------

class _Recorder:
    def __init__(self) -> None:
        self.events: List[Tuple[str, np.ndarray, np.ndarray, int]] = []


@contextmanager
def _recording(rec: _Recorder):
    """Temporarily interpose on ``ans.push``/``ans.pop_update`` to log
    every (start, freq, precision) triple the tree hands the coder.
    Works because every caller in the repo resolves them through the
    module attribute at call time."""
    real_push, real_pop = ans.push, ans.pop_update

    def push(stack, start, freq, precision=ans.DEFAULT_PRECISION):
        rec.events.append(("push", np.asarray(start), np.asarray(freq),
                           precision))
        return real_push(stack, start, freq, precision)

    def pop_update(stack, start, freq, precision=ans.DEFAULT_PRECISION):
        rec.events.append(("pop", np.asarray(start), np.asarray(freq),
                           precision))
        return real_pop(stack, start, freq, precision)

    ans.push, ans.pop_update = push, pop_update
    try:
        yield rec
    finally:
        ans.push, ans.pop_update = real_push, real_pop


# ---------------------------------------------------------------------------
# jaxpr rules: float-leak, div-shared, ndtri-coder
# ---------------------------------------------------------------------------

_BARRIERS = frozenset({"floor", "ceil", "round", "round_nearest_even",
                       "sign"})
# Value-preserving ops the barrier search looks through.
_TRANSPARENT = frozenset({"broadcast_in_dim", "reshape", "squeeze",
                          "expand_dims", "transpose", "slice", "rev",
                          "copy", "gather", "dynamic_slice",
                          "concatenate", "pad", "select_n",
                          "convert_element_type", "stop_gradient"})
# Call-like wrappers (jnp.floor/round are jit-wrapped composites).
_WRAPPERS = frozenset({"pjit", "closed_call", "core_call", "remat2",
                       "checkpoint", "custom_jvp_call",
                       "custom_vjp_call"})


def _inner_jaxpr(eqn):
    for key in ("jaxpr", "call_jaxpr"):
        j = eqn.params.get(key)
        if j is not None:
            return getattr(j, "jaxpr", j)
    return None


def _feeds_barrier(var, jaxpr, defs, outer, depth=0) -> bool:
    """True when ``var``'s float value demonstrably passed through an
    explicit floor/round barrier (or is concrete: a literal, a jaxpr
    input, or a constvar). ``outer(i)`` re-runs the check on the
    enclosing frame's i-th call operand."""
    if _is_literal(var) or depth > 32:
        return True
    dtype = getattr(getattr(var, "aval", None), "dtype", None)
    if dtype is not None and not jnp.issubdtype(dtype, jnp.floating):
        return True
    eqn = defs.get(var)
    if eqn is None:
        if outer is not None and var in jaxpr.invars:
            return outer(jaxpr.invars.index(var))
        return True   # top-level input or constvar: concrete bits
    name = eqn.primitive.name
    if name in _BARRIERS:
        return True
    if name in _TRANSPARENT:
        return all(_feeds_barrier(v, jaxpr, defs, outer, depth + 1)
                   for v in eqn.invars)
    if name in _WRAPPERS:
        inner = _inner_jaxpr(eqn)
        if inner is None:
            return False
        try:
            idx = eqn.outvars.index(var)
            target = inner.outvars[idx]
        except (ValueError, IndexError):
            return False
        sub_defs = {}
        for e in inner.eqns:
            for ov in e.outvars:
                sub_defs[ov] = e

        def sub_outer(i, _eqn=eqn):
            if i >= len(_eqn.invars):
                return True
            return _feeds_barrier(_eqn.invars[i], jaxpr, defs, outer,
                                  depth + 1)

        return _feeds_barrier(target, inner, sub_defs, sub_outer,
                              depth + 1)
    return False


def _sub_jaxprs(params):
    out = []
    for v in params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for item in vs:
            j = getattr(item, "jaxpr", None)   # ClosedJaxpr -> Jaxpr
            if j is not None and hasattr(j, "eqns"):
                out.append(j)
            elif hasattr(item, "eqns"):        # bare Jaxpr
                out.append(item)
    return out


def _is_literal(v) -> bool:
    return hasattr(v, "val") and not hasattr(v, "count")


def _scan_jaxpr(jaxpr, ctx: _Ctx, path: str, seen_rules: set) -> None:
    defs = {}
    for eqn in jaxpr.eqns:
        for ov in eqn.outvars:
            defs[ov] = eqn
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "pallas_call":
            # Kernel boundary: bodies are checked at source level by
            # the AST lint (repro.analysis.lint), not here.
            continue
        if name == "erf_inv" and "ndtri-coder" not in seen_rules:
            seen_rules.add("ndtri-coder")
            ctx.error(
                "ndtri-coder", path,
                "ndtri (erf_inv) is evaluated inside a coder program - "
                "its float32 bits vary with the XLA fusion context, so "
                "encode and decode can disagree",
                "gather bucket geometry from the concrete "
                "core.discretize.edge_table/centre_table instead of "
                "recomputing ndtri inline")
        elif name == "div":
            out = eqn.outvars[0]
            dtype = getattr(getattr(out, "aval", None), "dtype", None)
            if (dtype is not None and jnp.issubdtype(dtype, jnp.floating)
                    and not _is_literal(eqn.invars[0])
                    and not _is_literal(eqn.invars[1])
                    and "div-shared" not in seen_rules):
                seen_rules.add("div-shared")
                ctx.error(
                    "div-shared", path,
                    "non-reciprocal float division in a coder program - "
                    "XLA rewrites shared-divisor divisions to "
                    "multiply-by-reciprocal in some fusion contexts and "
                    "not others, flipping fixed-point floors",
                    "write the canonical form x * (1.0 / d) so every "
                    "compilation context produces the same bits")
        elif name == "convert_element_type":
            src = eqn.invars[0]
            if _is_literal(src):
                continue
            src_dtype = getattr(getattr(src, "aval", None), "dtype", None)
            new_dtype = eqn.params.get("new_dtype")
            if (src_dtype is None or new_dtype is None
                    or not jnp.issubdtype(src_dtype, jnp.floating)
                    or not jnp.issubdtype(new_dtype, jnp.integer)):
                continue
            if not _feeds_barrier(src, jaxpr, defs, None) \
                    and "float-leak" not in seen_rules:
                seen_rules.add("float-leak")
                producer = defs.get(src)
                pname = producer.primitive.name if producer else "input"
                ctx.error(
                    "float-leak", path,
                    f"float->int conversion fed by '{pname}' with no "
                    "explicit floor/round barrier - truncation of "
                    "context-dependent float bits leaks into the "
                    "integer coder",
                    "apply jnp.floor/jnp.round before .astype so the "
                    "integer boundary is explicit and canonical")
        for sub in _sub_jaxprs(eqn.params):
            _scan_jaxpr(sub, ctx, path, seen_rules)


def _jaxpr_rules(leaf: Codec, stack: ans.ANSStack, value, ctx: _Ctx,
                 path: str) -> None:
    seen: set = set()
    try:
        closed = jax.make_jaxpr(lambda st: leaf.pop(st))(stack)
    except Exception as e:   # pragma: no cover - trace-hostile leaf
        ctx.note("opaque-probe", path,
                 f"pop is not traceable ({type(e).__name__}); jaxpr "
                 "rules skipped")
        return
    _scan_jaxpr(closed.jaxpr, ctx, path + ".pop", seen)
    if value is None:
        return
    try:
        closed = jax.make_jaxpr(lambda st, v: leaf.push(st, v))(stack, value)
    except Exception:        # pragma: no cover
        return
    _scan_jaxpr(closed.jaxpr, ctx, path + ".push", seen)


# ---------------------------------------------------------------------------
# frequency-table soundness
# ---------------------------------------------------------------------------

def _check_starts(F: np.ndarray, precision: int, ctx: _Ctx, path: str,
                  idx: Optional[np.ndarray] = None) -> float:
    """Check a cumulative-starts array F[..., A+1] (int64); returns the
    worst-case bits one symbol under this table can cost."""
    total = 1 << precision
    gaps = np.diff(idx) if idx is not None \
        else np.ones(F.shape[-1] - 1, np.int64)
    first, last = F[..., 0], F[..., -1]
    if (first != 0).any() or (last != total).any():
        ctx.error(
            "freq-sum", path,
            f"table spans [{int(first.min())}, {int(last.max())}] "
            f"instead of exactly [0, 2^{precision}] - slots outside the "
            "span decode to garbage or crash",
            "build tables with ans.cdf_to_starts/probs_to_starts, which "
            "are exact-total by construction")
    d = np.diff(F, axis=-1)
    if (d < 0).any():
        ctx.error(
            "starts-monotone", path,
            "cumulative starts decrease - the decode search is "
            "ill-defined",
            "the underlying CDF must be non-decreasing; clip or sort "
            "the float CDF before quantizing")
    elif (d < gaps).any():
        ctx.error(
            "freq-zero", path,
            "a symbol has zero frequency - pushing it corrupts the "
            "stack and its slot silently decodes to a neighbour",
            "reserve at least 1/2^precision mass per symbol (the +i "
            "ramp of ans.cdf_to_starts does this)")
    min_freq = max(int(d.min()) if d.size else 1, 1)
    return precision - float(np.floor(np.log2(min_freq)))


def _grid_starts(f: Callable, k: int, lanes: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Evaluate a pointwise starts fn on the bucket grid; returns
    (F[n_pts, lanes] int64, idx[n_pts]). Samples when K is huge."""
    if k <= 4096:
        idx = np.arange(k + 1, dtype=np.int32)
    else:
        stride = k // 2048
        idx = np.unique(np.concatenate(
            [np.arange(0, k + 1, stride, dtype=np.int32),
             np.asarray([0, 1, k - 1, k], np.int32)]))
    grid = jnp.asarray(idx)[:, None] * jnp.ones((1, lanes), jnp.int32)
    try:
        F = jax.vmap(f)(grid)
    except Exception:
        F = jnp.stack([f(jnp.full((lanes,), int(i), jnp.int32))
                       for i in idx])
    return np.asarray(F).astype(np.int64).T, idx.astype(np.int64)


def _check_grid(f: Callable, bits: int, precision: int, lanes: int,
                ctx: _Ctx, path: str) -> float:
    try:
        F, idx = _grid_starts(f, 1 << bits, lanes)
    except Exception as e:
        ctx.error("freq-sum", path,
                  f"starts function failed on the bucket grid: "
                  f"{type(e).__name__}: {e}",
                  "the pointwise CDF must accept any index in [0, 2^bits]")
        return float(precision)
    return _check_starts(F, precision, ctx, path, idx)


# ---------------------------------------------------------------------------
# leaf checks
# ---------------------------------------------------------------------------

def _leaf_mirror(leaf: Codec, stack: ans.ANSStack, ctx: _Ctx, path: str
                 ) -> Tuple[ans.ANSStack, Any]:
    """Pop one symbol, push it back, and require (a) the reversed push
    events to equal the pop events and (b) the stack to return
    bit-identically. Returns the post-pop state so the walk advances."""
    rec = _Recorder()
    try:
        with _recording(rec):
            popped, value = leaf.pop(stack)
            restored = leaf.push(popped, value)
    except Exception as e:
        ctx.error("push-pop-mirror", path,
                  f"pop/push probe raised {type(e).__name__}: {e}",
                  "a leaf must decode from any stack state")
        return stack, None
    pops = [e for e in rec.events if e[0] == "pop"]
    pushes = [e for e in rec.events if e[0] == "push"]
    mismatch = None
    if len(pops) != len(pushes):
        mismatch = (f"{len(pops)} pop event(s) vs {len(pushes)} push "
                    "event(s)")
    else:
        for i, (po, pu) in enumerate(zip(pops, reversed(pushes))):
            if po[3] != pu[3]:
                mismatch = f"precision differs at event {i}"
                break
            if not (np.array_equal(po[1], pu[1])
                    and np.array_equal(po[2], pu[2])):
                mismatch = f"(start, freq) differ at event {i}"
                break
    if mismatch is None:
        mismatch = _stacks_equal(stack, restored)
        if mismatch is not None:
            mismatch = f"stack not restored ({mismatch})"
    if mismatch is not None:
        ctx.error(
            "push-pop-mirror", path,
            f"push is not the mirror inverse of pop: {mismatch}",
            "push(stack, x) and pop must hand ans the identical "
            "(start, freq, precision) for the same symbol")
    return popped, value


def _check_leaf(leaf: Codec, stack: ans.ANSStack, ctx: _Ctx, path: str
                ) -> Tuple[ans.ANSStack, Any, float]:
    """Full leaf battery; returns (advanced stack, value, bits bound)."""
    lanes = stack.lanes
    precision = getattr(leaf, "precision", ans.DEFAULT_PRECISION)
    bound = float(precision)

    if isinstance(leaf, L.Uniform):
        if not 0 < leaf.bits <= precision:
            ctx.error("freq-sum", path,
                      f"Uniform(bits={leaf.bits}) does not fit precision "
                      f"{precision}",
                      "need 0 < bits <= precision")
        bound = float(leaf.bits)
    elif isinstance(leaf, L.DiscretizedGaussian):
        f = discretize.posterior_starts_fn(leaf.mu, leaf.sigma, leaf.bits,
                                           precision)
        bound = _check_grid(f, leaf.bits, precision, lanes, ctx, path)
    elif isinstance(leaf, L.DiscretizedLogistic):
        f = L.logistic_starts_fn(leaf.mu, leaf.scale, leaf.bits, precision)
        bound = _check_grid(f, leaf.bits, precision, lanes, ctx, path)
    elif isinstance(leaf, L.PointwiseCDF):
        try:
            f = leaf._starts()
        except Exception as e:
            ctx.error("freq-sum", path, f"_starts() raised: {e}")
            f = None
        if f is not None:
            bound = _check_grid(f, leaf.bits, precision, lanes, ctx, path)
    elif isinstance(leaf, (Bernoulli, BetaBinomial, Categorical)):
        try:
            if isinstance(leaf, Bernoulli):
                f1 = np.asarray(leaf._freq1()).astype(np.int64)
                total = 1 << precision
                F = np.stack([np.zeros_like(f1), total - f1,
                              np.full_like(f1, total)], axis=-1)
            else:
                F = np.asarray(leaf._table()).astype(np.int64)
        except Exception as e:
            ctx.error("freq-sum", path, f"table build raised: {e}")
            F = None
        if F is not None:
            bound = _check_starts(F, precision, ctx, path)
    elif isinstance(leaf, FactoredCategorical):
        grouped, chunk_logits, n_chunks = leaf._parts()
        inner = Categorical(grouped[:, 0], precision)
        bound = _check_starts(np.asarray(inner._table()).astype(np.int64),
                              precision, ctx, path + "[chunk 0]")
        if n_chunks > 1:
            outer = Categorical(chunk_logits, precision)
            bound += _check_starts(
                np.asarray(outer._table()).astype(np.int64),
                precision, ctx, path + "[chunk marginal]")

    stack, value = _leaf_mirror(leaf, stack, ctx, path)
    _jaxpr_rules(leaf, stack, value, ctx, path)
    return stack, value, bound


# ---------------------------------------------------------------------------
# the collection walk
# ---------------------------------------------------------------------------

_LEAF_TYPES = (L.Uniform, L.DiscretizedGaussian, L.DiscretizedLogistic,
               L.PointwiseCDF, Bernoulli, BetaBinomial, Categorical,
               FactoredCategorical)


def _stream_types():
    from repro.stream import coder as stream_coder
    return stream_coder.BlockChain, stream_coder.KernelTableBlock


def _carries_model_floats(codec: Codec) -> bool:
    """True when coding this subtree evaluates float arithmetic whose
    bits could depend on the surrounding compilation context."""
    codec = _unwrap(codec)
    if isinstance(codec, L.Uniform):
        return False
    if isinstance(codec, C.Serial):
        return any(_carries_model_floats(c) for c in codec.codecs)
    if isinstance(codec, C.Shaped):
        return _carries_model_floats(codec.inner)
    if isinstance(codec, C.TreeCodec):
        leaves, _ = jax.tree_util.tree_flatten(
            codec.tree, is_leaf=lambda c: isinstance(c, Codec))
        return any(_carries_model_floats(c) for c in leaves)
    if isinstance(codec, C.Repeat):
        try:
            return _carries_model_floats(codec.codec_fn(0))
        except Exception:
            return True
    if isinstance(codec, C.Chained):
        return _carries_model_floats(codec.inner)
    return True


def _build_child(fn: Callable, arg, ctx: _Ctx, path: str) -> Optional[Codec]:
    try:
        child = fn(arg)
    except Exception as e:
        ctx.error("child-build", path,
                  f"building the child codec raised {type(e).__name__}: "
                  f"{e}",
                  "likelihood/posterior functions must accept any value "
                  "their argument codec can decode")
        return None
    if not isinstance(child, Codec):
        ctx.error("child-build", path,
                  f"child builder returned {type(child).__name__}, not a "
                  "Codec")
        return None
    return child


def _walk(codec: Codec, path: str, stack: ans.ANSStack, ctx: _Ctx,
          depth: int = 0) -> Tuple[ans.ANSStack, Any]:
    """Decode-ordered traversal; returns (advanced stack, decoded value).

    ``ctx.bound`` accumulates the worst-case bits a *push* of one
    datapoint can add (posterior pops give bits back, so fork-walked
    posteriors are excluded)."""
    if depth > 64:
        ctx.warn("opaque-probe", path, "tree deeper than 64 levels; "
                 "stopping the walk here")
        return stack, None
    codec = _unwrap(codec)

    if isinstance(codec, _LEAF_TYPES):
        stack, value, bound = _check_leaf(codec, stack, ctx, path)
        ctx.bound += bound
        return stack, value

    if isinstance(codec, C.Serial):
        out = []
        for i, child in enumerate(codec.codecs):
            stack, v = _walk(child, f"{path}.codecs[{i}]", stack, ctx,
                             depth + 1)
            out.append(v)
        return stack, tuple(out)

    if isinstance(codec, C.Shaped):
        stack, flat = _walk(codec.inner, path + ".inner", stack, ctx,
                            depth + 1)
        if flat is not None:
            flat = flat.reshape((flat.shape[0],) + tuple(codec.shape))
        return stack, flat

    if isinstance(codec, C.TreeCodec):
        leaves, treedef = jax.tree_util.tree_flatten(
            codec.tree, is_leaf=lambda c: isinstance(c, Codec))
        out = []
        for i, child in enumerate(leaves):
            stack, v = _walk(child, f"{path}.tree[{i}]", stack, ctx,
                             depth + 1)
            out.append(v)
        return stack, treedef.unflatten(out)

    if isinstance(codec, C.Repeat):
        n = codec.n
        probe_bounds = [0.0]
        for d in sorted({0, n // 2, n - 1} & set(range(max(n, 0)))):
            try:
                leaf = codec.codec_fn(d)
            except Exception as e:
                ctx.error("child-build", f"{path}.codec_fn({d})",
                          f"codec_fn raised {type(e).__name__}: {e}")
                continue
            save = ctx.bound
            _walk(leaf, f"{path}.codec_fn({d})", stack, ctx, depth + 1)
            probe_bounds.append(ctx.bound - save)
            ctx.bound = save
        ctx.bound += n * max(probe_bounds)
        try:
            return codec.pop(stack)
        except Exception as e:
            ctx.error("opaque-probe", path,
                      f"Repeat.pop raised {type(e).__name__}: {e}")
            return stack, None

    if isinstance(codec, C.Chained):
        if codec.scan and _carries_model_floats(codec.inner):
            ctx.error(
                "scan-chain", path,
                "Chained(scan=True) over a codec that evaluates model "
                "floats - lax.scan fuses the chain body into one "
                "program per direction, where XLA may produce float32 "
                "bits that differ from the eager path by an ulp",
                "use the default scan=False (the Python chain loop), "
                "or codecs.compile for a fast fused chain")
        save = ctx.bound
        stack, v = _walk(codec.inner, path + ".inner", stack, ctx,
                         depth + 1)
        ctx.bound = save + codec.n * (ctx.bound - save)
        value = None if v is None else jax.tree_util.tree_map(
            lambda a: jnp.stack([a] * codec.n, axis=0), v)
        return stack, value

    if isinstance(codec, C.BBANS):
        stack, y = _walk(codec.prior, path + ".prior", stack, ctx,
                         depth + 1)
        lik = _build_child(codec.likelihood, y, ctx,
                           path + ".likelihood(y)")
        if lik is None:
            return stack, None
        stack, s = _walk(lik, path + ".likelihood(y)", stack, ctx,
                         depth + 1)
        post = _build_child(codec.posterior, s, ctx, path + ".posterior(s)")
        if post is None:
            return stack, s
        save = ctx.bound   # posterior pops give bits back: fork-check only
        _walk(post, path + ".posterior(s)", stack, ctx, depth + 1)
        ctx.bound = save
        try:
            stack = post.push(stack, y)
        except Exception as e:
            ctx.error("push-pop-mirror", path + ".posterior(s)",
                      f"posterior push raised {type(e).__name__}: {e}",
                      "the posterior must encode any value the prior "
                      "decodes")
        return stack, s

    if isinstance(codec, C.BitSwap):
        stack, z = _walk(codec.prior, path + ".prior", stack, ctx,
                         depth + 1)
        for i in range(len(codec.layers) - 1, -1, -1):
            posterior_fn, likelihood_fn = codec.layers[i]
            lik = _build_child(likelihood_fn, z, ctx,
                               f"{path}.layers[{i}].likelihood(z)")
            if lik is None:
                return stack, None
            stack, ctx_val = _walk(lik, f"{path}.layers[{i}].likelihood(z)",
                                   stack, ctx, depth + 1)
            post = _build_child(posterior_fn, ctx_val, ctx,
                                f"{path}.layers[{i}].posterior(ctx)")
            if post is None:
                return stack, ctx_val
            save = ctx.bound
            _walk(post, f"{path}.layers[{i}].posterior(ctx)", stack, ctx,
                  depth + 1)
            ctx.bound = save
            try:
                stack = post.push(stack, z)
            except Exception as e:
                ctx.error("push-pop-mirror",
                          f"{path}.layers[{i}].posterior(ctx)",
                          f"posterior push raised {type(e).__name__}: {e}")
            z = ctx_val
        return stack, z

    BlockChain, KernelTableBlock = _stream_types()
    if isinstance(codec, BlockChain):
        save = ctx.bound
        stack, v = _walk(codec.inner, path + ".inner", stack, ctx,
                         depth + 1)
        ctx.bound = save + codec.k * (ctx.bound - save)
        value = None if v is None else jax.tree_util.tree_map(
            lambda a: jnp.stack([a] * codec.k, axis=0), v)
        return stack, value
    if isinstance(codec, KernelTableBlock):
        per = _check_starts(np.asarray(codec.table).astype(np.int64),
                            codec.precision, ctx, path)
        ctx.bound += codec.k * per
        try:
            return codec.pop(stack)
        except Exception as e:
            ctx.error("opaque-probe", path,
                      f"pop raised {type(e).__name__}: {e}")
            return stack, None

    # Opaque: FnCodec, TokenStream, anything marked or unknown.
    if not getattr(codec, "__analysis_opaque__", False):
        ctx.note(
            "opaque-probe", path,
            f"unknown codec class {type(codec).__name__}: probed for "
            "inversion only (tables and jaxprs not inspected)",
            "mark the class __analysis_opaque__ = True if this is "
            "intentional (a driver codec managing its own jit programs)")
    ctx.bound_exact = False
    try:
        return codec.pop(stack)
    except Exception as e:
        ctx.error("opaque-probe", path,
                  f"pop raised {type(e).__name__}: {e}",
                  "every codec must decode from any stack state")
        return stack, None


# ---------------------------------------------------------------------------
# the two passes + entry points
# ---------------------------------------------------------------------------

def _check_edge_cache(ctx: _Ctx) -> None:
    for name, fn in (("edge_table", discretize.edge_table),
                     ("centre_table", discretize.centre_table)):
        a, b = fn(8), fn(8)
        if a is not b:
            ctx.error(
                "edge-cache", f"core.discretize.{name}",
                "bucket-geometry table is rebuilt per call instead of "
                "cached - ndtri recomputation can hand different bits "
                "to encode and decode",
                "memoize the table per lat_bits and build it inside "
                "jax.ensure_compile_time_eval()")
        elif isinstance(a, jax.core.Tracer):
            ctx.error("edge-cache", f"core.discretize.{name}",
                      "bucket-geometry table is a tracer, not a "
                      "concrete array")


def _inverse_probe(codec: Codec, ctx: _Ctx, lanes: int, seed: int,
                   init_chunks: int, retries: int = 4) -> None:
    chunks, cap = init_chunks, init_chunks + 512
    for _ in range(retries):
        s0 = fresh_stack(lanes, cap, seed, chunks)
        try:
            s1, x = codec.pop(s0)
            s2 = codec.push(s1, x)
        except Exception as e:
            ctx.error(
                "inverse-probe", "codec",
                f"pop/push probe raised {type(e).__name__}: {e}",
                "the tree must decode from a fresh seeded stack and "
                "re-encode what it decoded")
            return
        if int(jnp.sum(s2.underflows)):
            chunks *= 4
            cap = chunks + 512
            continue
        if int(jnp.sum(s2.overflows)):
            cap *= 2
            continue
        diff = _stacks_equal(s0, s2)
        if diff is not None:
            ctx.error(
                "inverse-probe", "codec",
                f"push(pop(stack)) is not bit-identical: {diff}",
                "some leaf or driver in this tree encodes with different "
                "(start, freq) than it decodes - the per-leaf "
                "push-pop-mirror finding (if any) names it")
        return
    ctx.error(
        "inverse-probe", "codec",
        "probe never completed cleanly (persistent stack under/overflow "
        f"after {retries} growth retries)",
        "pushes and pops are likely unbalanced somewhere in this tree")


def verify_codec(codec: Codec, *, lanes: int = 4, seed: int = 0,
                 init_chunks: int = 256, capacity: Optional[int] = None,
                 max_retries: int = 4,
                 context: str = "verify_codec") -> Report:
    """Statically verify a ``Codec`` tree; returns a ``Report``.

    No user data is coded: both passes run against a scratch stack
    seeded deterministically from ``seed``. ``lanes`` is the probe
    width (codecs are lane-polymorphic, so small is fine - but a codec
    built for a fixed lane count must be probed at that count).
    ``capacity`` (in 16-bit chunks per lane), when given, is checked
    against the tree's worst-case bits-per-datapoint bound (rule
    ``capacity-bound``).

    Example::

        report = verify_codec(make_bb_codec(params, cfg), lanes=2)
        assert report.ok, str(report)
    """
    codec = _unwrap(codec)
    ctx = _Ctx()
    _check_edge_cache(ctx)
    _inverse_probe(codec, ctx, lanes, seed, init_chunks)

    chunks = init_chunks
    cap = chunks + 512
    for _ in range(max_retries):
        trial = _Ctx()
        stack = fresh_stack(lanes, cap, seed, chunks)
        stack, _ = _walk(codec, "codec", stack, trial)
        if int(jnp.sum(stack.underflows)):
            chunks *= 4
            cap = chunks + 512
            continue
        if int(jnp.sum(stack.overflows)):
            cap *= 2
            continue
        break
    ctx.findings.extend(trial.findings)
    ctx.notes.extend(trial.notes)
    bound = trial.bound if trial.bound_exact else None

    if capacity is not None and trial.bound > capacity * 16:
        need = int(np.ceil(trial.bound / 16))
        more = "at least " if not trial.bound_exact else ""
        ctx.warn(
            "capacity-bound", "codec",
            f"worst case pushes {more}{trial.bound:.0f} bits/lane per "
            f"datapoint but capacity {capacity} holds {capacity * 16} - "
            "the first encode attempt can overflow and burn a "
            "grow-and-retry cycle",
            f"start with capacity >= {need} chunks/lane per datapoint")

    return Report(context=context, findings=tuple(ctx.findings),
                  notes=tuple(ctx.notes), bits_bound=bound)


def check_codec(codec: Codec, **kwargs) -> Report:
    """``verify_codec`` that raises ``ContractViolation`` on errors
    (warnings and notes do not raise). Returns the clean ``Report``.

    Example::

        report = check_codec(codecs.Uniform(8), lanes=2)
    """
    report = verify_codec(codec, **kwargs)
    if not report.ok:
        raise ContractViolation(report)
    return report


def bits_bound(codec: Codec, *, lanes: int = 4, seed: int = 0
               ) -> Optional[float]:
    """Worst-case bits one datapoint can push per lane, or ``None``
    when the tree contains opaque leaves (their cost is not statically
    knowable).

    Example::

        assert bits_bound(codecs.Uniform(8), lanes=2) == 8.0
    """
    return verify_codec(codec, lanes=lanes, seed=seed).bits_bound
