"""AST-level contract lint for code the jaxpr tracer cannot see.

The tree verifier (``repro.analysis.verifier``) checks what a codec
*does*; this pass checks what coder source *says*, so the rules also
cover Pallas kernel bodies, reference oracles, and lowering code in
``codecs/compile.py`` - none of which appear in a traced coder program
(the verifier deliberately skips ``pallas_call`` equations).

Scope: only files under the coder directories (``repro/core``,
``repro/codecs``, ``repro/kernels``, ``repro/stream``). Model, serving,
and training code evaluate floats by design and are not coder programs.

Escapes: a finding on a line ending in ``# analysis: allow(<rule>)`` is
suppressed, and the float-division rule exempts anything inside a
``with jax.ensure_compile_time_eval():`` block (those divisions run
once at build time and produce concrete tables, which the tree verifier
checks directly).

Run as ``python -m repro.analysis.lint src/``.
"""

from __future__ import annotations

import ast
import os
import re
from typing import Iterable, List, Sequence, Tuple

from repro.analysis.verifier import Finding

# The shared rule catalogue: every rule either the tree verifier or the
# source lint can report, with a one-line description. docs/ANALYSIS.md
# documents each with a minimal offending example.
RULES = {
    # tree-verifier rules
    "freq-sum": "frequency table must span exactly [0, 2^precision]",
    "freq-zero": "no symbol may have zero frequency",
    "starts-monotone": "cumulative starts must be non-decreasing",
    "push-pop-mirror": "push must hand ans the mirror-image "
                       "(start, freq, precision) events of pop",
    "inverse-probe": "push(pop(stack)) must restore the stack "
                     "bit-for-bit",
    "float-leak": "float->int casts in coder programs need an explicit "
                  "floor/round barrier",
    "div-shared": "float division in coder code must be the canonical "
                  "reciprocal-multiply form x * (1.0 / d)",
    "ndtri-coder": "ndtri must not be evaluated inside coder programs; "
                   "use the cached discretize tables",
    "edge-cache": "bucket-geometry tables must be cached concrete "
                  "arrays, not rebuilt per call",
    "scan-chain": "Chained(scan=True) must not fuse model-float codecs "
                  "into a lax.scan body",
    "capacity-bound": "worst-case bits per datapoint should fit the "
                      "initial stack capacity",
    "opaque-probe": "opaque codecs are probed for inversion only",
    "child-build": "BBANS/BitSwap child builders must accept any value "
                   "their argument codec decodes",
    # source-lint rules
    "bare-assert": "coder invariants must raise explicit exceptions, "
                   "not assert (asserts vanish under python -O)",
    "cast-barrier": "float-math results must pass jnp.floor/round "
                    "before .astype(int)",
    "jit-in-table-module": "table-construction modules must stay "
                           "eager; jit belongs to codecs.compile",
    "pallas-call-site": "pl.pallas_call may only appear under "
                        "repro/kernels; everything else goes through "
                        "the dispatched ops (kernels.ans.ops, "
                        "kernels.bucketize.ops)",
}

_CODER_DIRS = ("repro/core", "repro/codecs", "repro/kernels",
               "repro/stream")
_TABLE_MODULES = ("discretize.py", "distributions.py", "leaves.py")
_NDTRI_ALLOWED = ("discretize.py",)   # the one module that owns ndtri
_FLOAT_MATH = ("ndtr", "sigmoid", "exp", "softmax", "cdf", "erf",
               "logistic")
_CAST_BARRIERS = ("floor", "round", "ceil", "rint")
_ALLOW_RE = re.compile(r"#\s*analysis:\s*allow\(([a-z-]+)\)")


def _allow_lines(source: str) -> dict:
    out = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _ALLOW_RE.search(line)
        if m:
            out[i] = m.group(1)
    return out


def _eager_spans(tree: ast.AST) -> List[Tuple[int, int]]:
    """Line spans of ``with jax.ensure_compile_time_eval():`` bodies."""
    spans = []
    for node in ast.walk(tree):
        if isinstance(node, ast.With):
            for item in node.items:
                if "ensure_compile_time_eval" in ast.unparse(
                        item.context_expr):
                    spans.append((node.lineno,
                                  node.end_lineno or node.lineno))
    return spans


def _in_spans(line: int, spans: Sequence[Tuple[int, int]]) -> bool:
    return any(lo <= line <= hi for lo, hi in spans)


def _is_constant_num(node: ast.expr) -> bool:
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (int, float))
    if isinstance(node, ast.UnaryOp) and isinstance(
            node.op, (ast.USub, ast.UAdd)):
        return _is_constant_num(node.operand)
    return False


class _Visitor(ast.NodeVisitor):
    def __init__(self, filename: str, eager_spans, allow,
                 coder_scope: bool = True):
        self.filename = filename
        self.base = os.path.basename(filename)
        self.eager_spans = eager_spans
        self.allow = allow
        self.coder_scope = coder_scope
        self.in_kernels = "repro/kernels" in \
            filename.replace(os.sep, "/")
        self.findings: List[Finding] = []

    def _add(self, rule: str, node: ast.AST, msg: str, hint: str) -> None:
        line = getattr(node, "lineno", 0)
        if self.allow.get(line) == rule:
            return
        self.findings.append(Finding(
            rule, "error", f"{self.filename}:{line}", msg, hint))

    def visit_Assert(self, node: ast.Assert) -> None:
        if not self.coder_scope:
            self.generic_visit(node)
            return
        self._add(
            "bare-assert", node,
            "bare assert guards a coder invariant - it vanishes under "
            "python -O, silently disabling the check",
            "raise ValueError/TypeError with a message instead")
        self.generic_visit(node)

    def visit_BinOp(self, node: ast.BinOp) -> None:
        if self.coder_scope and isinstance(node.op, ast.Div) \
                and not _is_constant_num(node.left) \
                and not _is_constant_num(node.right) \
                and not _in_spans(node.lineno, self.eager_spans):
            self._add(
                "div-shared", node,
                f"float division '{ast.unparse(node)}' is not in "
                "canonical reciprocal form - XLA may rewrite it to "
                "multiply-by-reciprocal in some fusion contexts and "
                "not others",
                "write x * (1.0 / d), or move it inside "
                "jax.ensure_compile_time_eval() if it builds a "
                "concrete table")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        callee = node.func
        name = ""
        if isinstance(callee, ast.Name):
            name = callee.id
        elif isinstance(callee, ast.Attribute):
            name = callee.attr

        # The one rule that applies to EVERY source file, coder scope
        # or not: hand-rolled pallas_call sites bypass the backend
        # dispatcher (and its parity suite) entirely.
        if name == "pallas_call" and not self.in_kernels:
            self._add(
                "pallas-call-site", node,
                "direct pl.pallas_call outside repro/kernels - the "
                "call bypasses kernels.dispatch, so backend pinning, "
                "the tuning cache, and the parity suite never see it",
                "route through the dispatched ops in kernels/ans/ops "
                "or kernels/bucketize/ops (or add "
                "'# analysis: allow(pallas-call-site)' with a reason)")

        if not self.coder_scope:
            self.generic_visit(node)
            return

        if name == "ndtri" and self.base not in _NDTRI_ALLOWED \
                and not _in_spans(node.lineno, self.eager_spans):
            self._add(
                "ndtri-coder", node,
                "ndtri evaluated outside core/discretize.py - its "
                "float32 bits vary with the XLA fusion context",
                "read bucket geometry from discretize.edge_table/"
                "centre_table (concrete cached arrays)")

        if name in ("jit", "pmap") and self.base in _TABLE_MODULES:
            self._add(
                "jit-in-table-module", node,
                f"jax.{name} inside a table-construction module - "
                "tables must be built eagerly (or under "
                "ensure_compile_time_eval) so encode and decode share "
                "one set of bits",
                "keep jit at the codecs.compile layer")

        if name == "astype" and isinstance(callee, ast.Attribute) \
                and node.args:
            dtype_src = ast.unparse(node.args[0])
            recv_src = ast.unparse(callee.value)
            if "int" in dtype_src and "float" not in dtype_src \
                    and any(t in recv_src for t in _FLOAT_MATH) \
                    and not any(b in recv_src for b in _CAST_BARRIERS):
                self._add(
                    "cast-barrier", node,
                    f"float-math expression '{recv_src[:60]}' is cast "
                    "straight to an integer dtype - the implicit "
                    "truncation point is fusion-dependent",
                    "wrap in jnp.floor(...) or jnp.round(...) before "
                    ".astype")
        self.generic_visit(node)


def lint_source(source: str, filename: str = "<string>",
                coder_scope: bool = True) -> List[Finding]:
    """Lint one file's source text; returns a list of ``Finding``.

    ``coder_scope=False`` restricts the pass to the rules that apply
    everywhere (currently ``pallas-call-site``) - how ``lint_paths``
    treats model/serving/training files.

    Example::

        from repro.analysis import lint_source
        findings = lint_source("assert x > 0", "core/foo.py")
        assert findings[0].rule == "bare-assert"
    """
    try:
        tree = ast.parse(source, filename=filename)
    except SyntaxError as e:
        return [Finding("bare-assert", "error", f"{filename}:{e.lineno}",
                        f"file does not parse: {e.msg}", "fix the syntax")]
    visitor = _Visitor(filename, _eager_spans(tree), _allow_lines(source),
                       coder_scope=coder_scope)
    visitor.visit(tree)
    return visitor.findings


def _is_coder_file(path: str) -> bool:
    p = path.replace(os.sep, "/")
    return p.endswith(".py") and any(d in p for d in _CODER_DIRS)


def _is_repro_file(path: str) -> bool:
    p = path.replace(os.sep, "/")
    return p.endswith(".py") and "repro/" in p


def lint_paths(paths: Iterable[str]) -> Tuple[List[Finding], int]:
    """Lint every ``repro`` ``.py`` file under ``paths``.

    Files under the coder scope (``repro/core``, ``repro/codecs``,
    ``repro/kernels``, ``repro/stream``) get the full rule set; every
    other ``repro`` file gets only the everywhere-rules (the
    ``pallas-call-site`` ban). A path naming a ``.py`` file directly
    is linted in full coder scope. Returns
    ``(findings, files_checked)``.

    Example::

        from repro.analysis import lint_paths
        findings, n = lint_paths(["src/"])
        assert findings == []
    """
    files: List[str] = []
    for path in paths:
        if os.path.isfile(path):
            files.append(path)
            continue
        for root, _dirs, names in os.walk(path):
            for name in sorted(names):
                full = os.path.join(root, name)
                if _is_repro_file(full):
                    files.append(full)
    findings: List[Finding] = []
    for f in sorted(set(files)):
        with open(f, "r", encoding="utf-8") as fh:
            findings.extend(lint_source(
                fh.read(), f, coder_scope=_is_coder_file(f)))
    return findings, len(set(files))
