"""``repro.shard_codec`` - dataset-scale lane-parallel coding across
devices.

The paper closes on BB-ANS being "highly amenable to parallelization";
this module is that claim operationalized at dataset scale. The lane
axis of the ``ANSStack`` is already N independent coders, so the
execution model is pure data parallelism over lanes, in two forms
(docs/SCALING.md is the narrative spec):

  * **Sharded segments** (this module): the lane axis is cut into
    ``n_shards`` contiguous shards; each shard streams its datapoints
    through its own ``stream.StreamEncoder`` with its arrays placed on
    its own device, producing one independently-decodable BBX2 segment;
    the segments are gathered into a single ``BBX3`` corpus blob
    (``stream.format``: header + index + segments). Decode mirrors:
    any shard - or all of them - decodes from its segment alone, so a
    cluster can fan the corpus out by index entry.
  * **SPMD coder programs** (``codecs.compile`` + ``sharding.api``):
    under ``sharding.use_lane_mesh``, compiled codecs run their fused
    integer coder calls through ``shard_map`` over a 1-D device mesh -
    one logical stack, lanes split across devices, byte-identical wire.
    ``serve.ShardedCodecEngine`` uses this for its one-shot path.

Both forms hold the PR-4 determinism contract across devices: wire
bytes depend only on (codec, data, shard layout), never on the
physical device count or placement - integer coder ops are exact in
any partitioning, and model floats keep evaluating in canonical eager
form per shard. ``tests/test_shard_codec.py`` proves byte-identity
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

    blob = shard_codec.compress_dataset(codec, data, n_shards=8)
    data2 = shard_codec.decompress_dataset(codec, blob)      # bit-exact
    xs3 = shard_codec.decompress_shard(codec, blob, shard=3)  # just one

The dataset CLI driving this end to end (full synthetic-MNIST through
a trained VAE/HVAE, Table-1 comparison vs gzip/bz2/PNG-proxy) is
``python -m repro.launch.compress``.
"""

from __future__ import annotations

import contextlib
import itertools
from typing import Any, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import stream
from repro.core import ans
from repro.core.codec import Codec
from repro.kernels import dispatch
from repro.stream import format as fmt

__all__ = [
    "shard_devices", "split_lane_tree", "merge_lane_tree",
    "compress_dataset", "decompress_dataset", "decompress_shard",
    "corpus_info",
]


def shard_devices(n_shards: int) -> List[Any]:
    """Device for each shard: local devices, round-robin.

    With fewer devices than shards, several shards share a device (the
    single-device case degenerates to all of them - bytes unchanged,
    see the determinism note in the module docstring).

    Example::

        devs = shard_devices(8)        # 8 entries, cycling jax.devices()
    """
    if n_shards < 1:
        raise ValueError("shard_codec: n_shards must be >= 1")
    local = jax.devices()
    return [local[s % len(local)] for s in range(n_shards)]


def _lane_count(data: Any) -> int:
    leaves = jax.tree_util.tree_leaves(data)
    if not leaves:
        raise ValueError("shard_codec: empty data pytree")
    return leaves[0].shape[1]


def split_lane_tree(data: Any, n_shards: int) -> List[Any]:
    """Split time-major ``[n, lanes, ...]`` data into ``n_shards``
    contiguous lane slices (the data twin of ``ans.split_lanes``).

    Example::

        shards = split_lane_tree(xs, 4)     # each [n, lanes/4, ...]
    """
    lanes = _lane_count(data)
    if n_shards < 1 or lanes % n_shards:
        raise ValueError(
            f"shard_codec: {lanes} lanes do not divide into "
            f"{n_shards} equal shards")
    per = lanes // n_shards
    return [jax.tree_util.tree_map(
        lambda a: a[:, s * per:(s + 1) * per], data)
        for s in range(n_shards)]


def merge_lane_tree(shards: Sequence[Any]) -> Any:
    """Concatenate per-shard ``[n, lanes_s, ...]`` trees back along the
    lane axis (inverse of ``split_lane_tree``).

    Example::

        assert (merge_lane_tree(split_lane_tree(xs, 4)) == xs).all()
    """
    shards = list(shards)
    if not shards:
        raise ValueError("shard_codec: no shards to merge")
    return jax.tree_util.tree_map(
        lambda *ls: jnp.concatenate(ls, axis=1), *shards)


def peek_chunks(data: Any) -> Tuple[Any, Iterable[Any]]:
    """Normalize ``data`` to ``(first_chunk, iterable of chunks)``.

    Lists and iterators are treated as streams of ``[n, lanes, ...]``
    chunks (the loader case); anything else (array, dict/tuple pytree)
    is a single chunk. The first chunk is peeked - without losing it
    from the stream - so callers can size shards/codecs before
    encoding starts. Raises ``ValueError`` on an empty stream. Shared
    by ``compress_dataset`` and ``serve.ShardedCodecEngine``.
    """
    empty = "shard_codec: no data chunks to compress"
    if isinstance(data, list):
        if not data:
            raise ValueError(empty)
        return data[0], data
    if hasattr(data, "__next__"):
        try:
            first = next(data)
        except StopIteration:
            raise ValueError(empty) from None
        return first, itertools.chain([first], data)
    return data, [data]


def _backend_ctx(kernel_backend: Optional[str]):
    """``dispatch.use_backend`` pin for one corpus pass (no-op when
    ``None``: each coder op auto-resolves via the tuning cache /
    platform heuristic - wire bytes are the same either way)."""
    if kernel_backend is None:
        return contextlib.nullcontext()
    return dispatch.use_backend(kernel_backend)


def compress_dataset(codec: Codec, data: Any, *, n_shards: int,
                     block_symbols: int = 8,
                     seed: Optional[int] = 0, init_chunks: int = 32,
                     precision: int = ans.DEFAULT_PRECISION,
                     devices: Optional[Sequence[Any]] = None,
                     kernel_backend: Optional[str] = None,
                     **encoder_kwargs) -> bytes:
    """Compress a dataset to one BBX3 corpus blob, lane-parallel.

    ``data`` is a ``[n, lanes, ...]`` pytree or an iterable of such
    chunks (a streaming loader); ``lanes`` must divide into
    ``n_shards``. Each shard's slice is placed on its device
    (``shard_devices`` by default) and encoded by its own
    ``StreamEncoder`` - the shards' device work overlaps through JAX's
    async dispatch, and the resulting wire bytes depend only on
    (codec, data, n_shards, block_symbols, seed), never on how many
    physical devices the shards landed on.

    ``seed=None`` runs every shard cold (direct coding); an integer
    seed gives shard ``s`` the derived seed ``seed + s`` for its random
    first heads and per-block clean bits. Extra ``encoder_kwargs``
    (``capacity``, ``compile``, ...) pass through to every encoder.

    Example::

        blob = compress_dataset(codec, xs, n_shards=4, block_symbols=8)
        assert (decompress_dataset(codec, blob) == xs).all()
    """
    first, chunks = peek_chunks(data)
    lanes = _lane_count(first)
    if lanes % n_shards:
        raise ValueError(
            f"shard_codec: {lanes} lanes do not divide into "
            f"{n_shards} equal shards")
    devs = list(devices) if devices is not None \
        else shard_devices(n_shards)
    if len(devs) != n_shards:
        raise ValueError(f"shard_codec: got {len(devs)} devices for "
                         f"{n_shards} shards")
    with _backend_ctx(kernel_backend):
        encoders = [stream.StreamEncoder(
            codec, lanes=lanes // n_shards, block_symbols=block_symbols,
            seed=None if seed is None else seed + s,
            init_chunks=init_chunks, precision=precision,
            **encoder_kwargs) for s in range(n_shards)]
        segments = [bytearray() for _ in range(n_shards)]
        for chunk in chunks:
            for s, shard in enumerate(split_lane_tree(chunk, n_shards)):
                placed = jax.tree_util.tree_map(
                    lambda a: jax.device_put(a, devs[s]), shard)
                segments[s].extend(encoders[s].write(placed))
        for s, enc in enumerate(encoders):
            segments[s].extend(enc.flush())
    return fmt.encode_corpus(
        [bytes(seg) for seg in segments],
        [enc.n_symbols for enc in encoders],
        lanes_per_shard=encoders[0].lanes, precision=precision)


def decompress_shard(codec: Codec, blob: bytes, shard: int,
                     kernel_backend: Optional[str] = None,
                     **decoder_kwargs) -> Any:
    """Decode ONE shard of a BBX3 corpus - no other shard's bytes are
    touched (the unit of distributed decode).

    Example::

        xs3 = decompress_shard(codec, blob, 3)   # [n, lanes_per_shard, ...]
    """
    with _backend_ctx(kernel_backend):
        return stream.decode_stream(codec, fmt.corpus_segment(blob, shard),
                                    **decoder_kwargs)


def decompress_dataset(codec: Codec, blob: bytes, *,
                       devices: Optional[Sequence[Any]] = None,
                       kernel_backend: Optional[str] = None,
                       **decoder_kwargs) -> Any:
    """Decode a whole BBX3 corpus back to ``[n, lanes, ...]``,
    bit-exactly, shard by shard (each independently, on its own
    device by default).

    Example::

        xs = decompress_dataset(codec, compress_dataset(
            codec, xs, n_shards=4))
    """
    header, entries = fmt.scan_corpus(blob)
    devs = list(devices) if devices is not None \
        else shard_devices(header.n_shards)
    outs = []
    with _backend_ctx(kernel_backend):
        for s, e in enumerate(entries):
            seg = blob[e.offset:e.offset + e.length]
            with jax.default_device(devs[s % len(devs)]):
                outs.append(stream.decode_stream(codec, seg,
                                                 **decoder_kwargs))
    return merge_lane_tree(outs)


def corpus_info(blob: bytes) -> dict:
    """Summarize a BBX3 corpus from framing alone: shard count, lane
    layout, per-shard byte/symbol totals.

    Example::

        info = corpus_info(blob)
        assert info["n_shards"] == len(info["shard_bytes"])
    """
    header, entries = fmt.scan_corpus(blob)
    return {
        "n_shards": header.n_shards,
        "lanes_per_shard": header.lanes_per_shard,
        "precision": header.precision,
        "total_bytes": len(blob),
        "index_bytes": fmt.CORPUS_HEADER_SIZE
        + header.n_shards * fmt.CORPUS_ENTRY_SIZE,
        "shard_bytes": [e.length for e in entries],
        "shard_symbols": [e.n_symbols for e in entries],
        "total_symbols": sum(e.n_symbols for e in entries),
    }
