"""Observation-model coders: fixed-point (start, freq) interfaces over ANS.

Each coder is a ``repro.core.codec.Codec``: ``push(stack, symbol) ->
stack`` and ``pop(stack) -> (stack, symbol)`` operating lane-wise (one
symbol per lane per call), plus log-probability helpers used by the
ELBO/rate tests. All are exact LIFO inverses of each other - the
property the whole of BB-ANS rests on - so they compose directly as
leaves under the ``repro.codecs`` combinators.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln

from repro.core import ans
from repro.core.codec import Codec


def _stable_softmax(logits: jnp.ndarray) -> jnp.ndarray:
    """Softmax over the last axis in the compilation-context-stable
    reciprocal-multiply form.

    ``jax.nn.softmax`` divides by a row-shared sum; XLA's simplifier
    rewrites such divisions to ``* (1/sum)`` in some fusion contexts
    and not others, so a coding table built from it can differ by one
    fixed-point step between the eager (interpreted codec) and jitted
    (compiled codec) paths. Writing the canonical form directly makes
    every context produce the same bits (see docs/PERF.md).
    """
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    return e * (1.0 / jnp.sum(e, axis=-1, keepdims=True))


# ---------------------------------------------------------------------------
# Bernoulli (binarized-MNIST likelihood)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Bernoulli(Codec):
    """Per-lane Bernoulli with success probability sigmoid(logit).

    Example::

        codec = Bernoulli(logits)              # logits float[lanes]
        stack = codec.push(stack, bits01)      # symbols in {0, 1}
    """

    logits: jnp.ndarray  # float[lanes]
    precision: int = ans.DEFAULT_PRECISION

    def _freq1(self) -> jnp.ndarray:
        total = 1 << self.precision
        p = jax.nn.sigmoid(self.logits.astype(jnp.float32))
        f1 = jnp.round(p * (total - 2)).astype(jnp.uint32) + 1
        return f1  # in [1, total - 1]

    def push(self, stack: ans.ANSStack, sym: jnp.ndarray) -> ans.ANSStack:
        total = 1 << self.precision
        f1 = self._freq1()
        f0 = total - f1
        is1 = sym.astype(bool)
        start = jnp.where(is1, f0, jnp.uint32(0))
        freq = jnp.where(is1, f1, f0)
        return ans.push(stack, start, freq, self.precision)

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, jnp.ndarray]:
        total = 1 << self.precision
        f1 = self._freq1()
        f0 = total - f1
        slot = ans.peek(stack, self.precision)
        is1 = slot >= f0
        start = jnp.where(is1, f0, jnp.uint32(0))
        freq = jnp.where(is1, f1, f0)
        return (ans.pop_update(stack, start, freq, self.precision),
                is1.astype(jnp.int32))

    def log_prob(self, sym: jnp.ndarray) -> jnp.ndarray:
        x = sym.astype(self.logits.dtype)
        return x * jax.nn.log_sigmoid(self.logits) + (1 - x) * \
            jax.nn.log_sigmoid(-self.logits)


# ---------------------------------------------------------------------------
# Beta-binomial (full-MNIST likelihood; paper section 3.2)
# ---------------------------------------------------------------------------

def beta_binomial_log_pmf(k: jnp.ndarray, n: int, alpha: jnp.ndarray,
                          beta: jnp.ndarray) -> jnp.ndarray:
    """log BetaBin(k | n, alpha, beta), exact via lgamma."""
    k = k.astype(jnp.float32)
    return (gammaln(n + 1.0) - gammaln(k + 1.0) - gammaln(n - k + 1.0)
            + gammaln(k + alpha) + gammaln(n - k + beta)
            - gammaln(n + alpha + beta)
            + gammaln(alpha + beta) - gammaln(alpha) - gammaln(beta))


@dataclass(frozen=True)
class BetaBinomial(Codec):
    """Per-lane beta-binomial on {0..n}; two positive params per lane.

    Example (full-MNIST pixels)::

        codec = BetaBinomial(alpha, beta, n=255)   # alpha/beta [lanes]
        stack, pix = codec.pop(stack)              # pix in 0..255
    """

    alpha: jnp.ndarray  # float[lanes]
    beta: jnp.ndarray   # float[lanes]
    n: int = 255
    precision: int = ans.DEFAULT_PRECISION

    def _table(self) -> jnp.ndarray:
        ks = jnp.arange(self.n + 1, dtype=jnp.float32)
        logp = beta_binomial_log_pmf(
            ks[None, :], self.n, self.alpha[:, None].astype(jnp.float32),
            self.beta[:, None].astype(jnp.float32))
        probs = _stable_softmax(logp)          # renormalize in fp
        return ans.probs_to_starts(probs, self.precision)

    def push(self, stack: ans.ANSStack, sym: jnp.ndarray) -> ans.ANSStack:
        return ans.push_with_table(stack, self._table(), sym, self.precision)

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, jnp.ndarray]:
        return ans.pop_with_table(stack, self._table(), self.precision)

    def log_prob(self, sym: jnp.ndarray) -> jnp.ndarray:
        return beta_binomial_log_pmf(sym, self.n,
                                     self.alpha.astype(jnp.float32),
                                     self.beta.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Categorical (small alphabets: routing decisions, factored pieces)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Categorical(Codec):
    """Per-lane categorical over an alphabet of size logits.shape[-1].

    Example::

        codec = Categorical(logits)            # logits float[lanes, A]
        stack = codec.push(stack, sym)         # sym int[lanes] in 0..A-1
    """

    logits: jnp.ndarray  # float[lanes, A]
    precision: int = ans.DEFAULT_PRECISION

    def _table(self) -> jnp.ndarray:
        probs = _stable_softmax(self.logits.astype(jnp.float32))
        return ans.probs_to_starts(probs, self.precision)

    def push(self, stack: ans.ANSStack, sym: jnp.ndarray) -> ans.ANSStack:
        return ans.push_with_table(stack, self._table(), sym, self.precision)

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, jnp.ndarray]:
        return ans.pop_with_table(stack, self._table(), self.precision)

    def log_prob(self, sym: jnp.ndarray) -> jnp.ndarray:
        logp = jax.nn.log_softmax(self.logits.astype(jnp.float32), axis=-1)
        return jnp.take_along_axis(logp, sym[:, None].astype(jnp.int32),
                                   axis=-1)[:, 0]


# ---------------------------------------------------------------------------
# Factored categorical (LM vocabularies beyond 2^(precision-1))
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FactoredCategorical(Codec):
    """Categorical over a large vocabulary, coded as (chunk, offset).

    The vocabulary is split into chunks of ``chunk_size``; a token ``v`` is
    coded as ``hi = v // chunk_size`` under the chunk-marginal followed by
    ``lo = v % chunk_size`` under the within-chunk conditional (chain rule -
    rate unchanged up to rounding). This keeps every alphabet below the
    16-bit fixed-point budget for vocabularies up to ~2^23.

    LIFO discipline: ``push`` pushes *lo then hi* so that ``pop`` pops *hi
    then lo*.

    Example (vocab 400 in chunks of 64)::

        codec = FactoredCategorical(logits, chunk_size=64)
        stack = codec.push(stack, token_ids)   # ids int[lanes] < 400
    """

    logits: jnp.ndarray  # float[lanes, V]
    chunk_size: int = 256
    precision: int = ans.DEFAULT_PRECISION

    def _parts(self):
        lanes, v = self.logits.shape
        cs = self.chunk_size
        n_chunks = -(-v // cs)
        pad = n_chunks * cs - v
        logits = self.logits.astype(jnp.float32)
        if pad:
            logits = jnp.pad(logits, ((0, 0), (0, pad)),
                             constant_values=-1e30)
        grouped = logits.reshape(lanes, n_chunks, cs)
        # Chunk marginal in log space (stable): logsumexp within chunk.
        chunk_logits = jax.nn.logsumexp(grouped, axis=-1)  # [lanes, n_chunks]
        return grouped, chunk_logits, n_chunks

    def push(self, stack: ans.ANSStack, sym: jnp.ndarray) -> ans.ANSStack:
        grouped, chunk_logits, n_chunks = self._parts()
        sym = sym.astype(jnp.int32)
        hi = sym // self.chunk_size
        lo = sym % self.chunk_size
        rows = jnp.arange(grouped.shape[0])
        within = Categorical(grouped[rows, hi], self.precision)
        stack = within.push(stack, lo)
        if n_chunks > 1:  # a 1-chunk outer code carries 0 bits; coding it
            # would need freq = 2^precision which overflows the fixed point.
            outer = Categorical(chunk_logits, self.precision)
            stack = outer.push(stack, hi)
        return stack

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, jnp.ndarray]:
        grouped, chunk_logits, n_chunks = self._parts()
        rows = jnp.arange(grouped.shape[0])
        if n_chunks > 1:
            outer = Categorical(chunk_logits, self.precision)
            stack, hi = outer.pop(stack)
        else:
            hi = jnp.zeros((grouped.shape[0],), jnp.int32)
        within = Categorical(grouped[rows, hi], self.precision)
        stack, lo = within.pop(stack)
        return stack, hi * self.chunk_size + lo

    def log_prob(self, sym: jnp.ndarray) -> jnp.ndarray:
        logp = jax.nn.log_softmax(self.logits.astype(jnp.float32), axis=-1)
        return jnp.take_along_axis(logp, sym[:, None].astype(jnp.int32),
                                   axis=-1)[:, 0]
