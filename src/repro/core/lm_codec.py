"""Lossless token-stream compression with any supported LM backbone.

Two modes (DESIGN.md section 4):

  * ``encode_tokens``/``decode_tokens`` - direct ANS entropy coding with the
    LM's next-token distribution (the latent-free special case of BB-ANS).

  * ``models/latent_lm.py`` - bits-back proper, with a per-sequence
    continuous latent (see that module).

DETERMINISM CONTRACT (the make-or-break property of neural compression):
encoder and decoder must derive *bit-identical* fixed-point tables. A
teacher-forced parallel forward and an incremental cached decode are
mathematically equal but NOT bitwise equal - XLA schedules reductions
differently per fusion context, and a one-ULP logit difference
occasionally flips a table boundary, corrupting the stream (observed;
regression-tested in tests/test_serving.py). Both encoder and decoder
therefore step the network through *the same jit-compiled executable*
(``jitted_decode_step``, cached per config) from Python-level loops: same
artifact, same inputs => bitwise-identical logits on both sides.

The token alphabet is coded with the factored (chunk, offset) categorical,
so any assigned vocabulary (up to 202k) fits the 16-bit fixed-point budget.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ans
from repro.core.codec import Codec
from repro.core.distributions import FactoredCategorical
from repro.models import transformer

BOS = 0


@functools.lru_cache(maxsize=None)
def jitted_decode_step(cfg: Any) -> Callable[..., Any]:
    """One shared compiled decode step per config - the determinism
    anchor for all coding paths (including LatentLM's)."""
    return jax.jit(functools.partial(transformer.decode_step, cfg=cfg))


@functools.lru_cache(maxsize=None)
def jitted_decode_step_embeds(cfg: Any) -> Callable[..., Any]:
    return jax.jit(functools.partial(transformer.decode_step_embeds,
                                     cfg=cfg))


@functools.lru_cache(maxsize=None)
def _jitted_push(precision: int):
    def push(stack, logits_t, toks_t):
        dist = FactoredCategorical(logits_t, precision=precision)
        return dist.push(stack, toks_t)

    return jax.jit(push)


@functools.lru_cache(maxsize=None)
def _jitted_pop(precision: int):
    def pop(stack, logits_t):
        dist = FactoredCategorical(logits_t, precision=precision)
        return dist.pop(stack)

    return jax.jit(pop)


@functools.lru_cache(maxsize=None)
def _jitted_push_masked(precision: int):
    def push(stack, logits_t, toks_t, mask):
        dist = FactoredCategorical(logits_t, precision=precision)
        return ans.select_lanes(mask, dist.push(stack, toks_t), stack)

    return jax.jit(push)


@functools.lru_cache(maxsize=None)
def _jitted_pop_masked(precision: int):
    def pop(stack, logits_t, mask):
        dist = FactoredCategorical(logits_t, precision=precision)
        popped, sym = dist.pop(stack)
        return (ans.select_lanes(mask, popped, stack),
                jnp.where(mask, sym, 0))

    return jax.jit(pop)


def collect_decoder_logits(params: Any, cfg: Any,
                           tokens: jnp.ndarray) -> List[jnp.ndarray]:
    """Teacher-forced logits via the decoder's own compiled step."""
    lanes, n = tokens.shape
    step = jitted_decode_step(cfg)
    state = transformer.init_decode_state(cfg, lanes, max_len=n)
    tok = jnp.full((lanes, 1), BOS, jnp.int32)
    out = []
    for t in range(n):
        logits, state = step(params, tok=tok, state=state)
        out.append(logits[:, 0].astype(jnp.float32))
        tok = tokens[:, t:t + 1]
    return out


def encode_tokens(params: Any, cfg: Any, tokens: jnp.ndarray,
                  stack: ans.ANSStack,
                  precision: int = ans.DEFAULT_PRECISION) -> ans.ANSStack:
    """tokens int32[lanes, N] -> stack with N symbols/lane pushed.

    Pushes in reverse order so the decoder pops tokens forward.
    """
    lanes, n = tokens.shape
    logits = collect_decoder_logits(params, cfg, tokens)
    push = _jitted_push(precision)
    for t in reversed(range(n)):
        stack = push(stack, logits[t], tokens[:, t])
    return stack


def decode_tokens(params: Any, cfg: Any, stack: ans.ANSStack, n: int,
                  precision: int = ans.DEFAULT_PRECISION
                  ) -> Tuple[ans.ANSStack, jnp.ndarray]:
    """Pop n tokens/lane, regenerating logits autoregressively through the
    same compiled step the encoder used."""
    lanes = stack.lanes
    step = jitted_decode_step(cfg)
    pop = _jitted_pop(precision)
    state = transformer.init_decode_state(cfg, lanes, max_len=n)
    tok = jnp.full((lanes, 1), BOS, jnp.int32)
    out = []
    for _ in range(n):
        logits, state = step(params, tok=tok, state=state)
        stack, sym = pop(stack, logits[:, 0].astype(jnp.float32))
        out.append(sym)
        tok = sym[:, None].astype(jnp.int32)
    return stack, jnp.stack(out, axis=1)


def encode_tokens_masked(params: Any, cfg: Any, tokens: jnp.ndarray,
                         n_valid: jnp.ndarray, stack: ans.ANSStack,
                         precision: int = ans.DEFAULT_PRECISION
                         ) -> ans.ANSStack:
    """Ragged batch encode: lane ``l`` pushes only ``tokens[l,
    :n_valid[l]]``; its stack state beyond that is bit-identical to
    never having coded at all (``ans.select_lanes`` freeze).

    Callers pad ``tokens`` with zeros past ``n_valid`` so the network
    inputs on masked lanes match what the masked decoder feeds (the
    logits there are computed but never coded; lanes are independent,
    so they do not perturb valid lanes either way). This is the LM leg
    of the ``repro.stream`` dynamic batcher.
    """
    lanes, n = tokens.shape
    logits = collect_decoder_logits(params, cfg, tokens)
    push = _jitted_push_masked(precision)
    for t in reversed(range(n)):
        stack = push(stack, logits[t], tokens[:, t], t < n_valid)
    return stack


def decode_tokens_masked(params: Any, cfg: Any, stack: ans.ANSStack, n: int,
                         n_valid: jnp.ndarray,
                         precision: int = ans.DEFAULT_PRECISION
                         ) -> Tuple[ans.ANSStack, jnp.ndarray]:
    """Inverse of ``encode_tokens_masked``; masked positions decode to
    0 (the same padding the encoder fed its network)."""
    lanes = stack.lanes
    step = jitted_decode_step(cfg)
    pop = _jitted_pop_masked(precision)
    state = transformer.init_decode_state(cfg, lanes, max_len=n)
    tok = jnp.full((lanes, 1), BOS, jnp.int32)
    out = []
    for t in range(n):
        logits, state = step(params, tok=tok, state=state)
        stack, sym = pop(stack, logits[:, 0].astype(jnp.float32),
                         t < n_valid)
        out.append(sym)
        tok = sym[:, None].astype(jnp.int32)
    return stack, jnp.stack(out, axis=1)


@dataclasses.dataclass(frozen=True)
class TokenStream(Codec):
    """Token-stream coding as a ``Codec``: the latent-free special case
    of BB-ANS (direct ANS with the LM's next-token distribution).

    The symbol is int32[lanes, n]. Composes under the ``repro.codecs``
    combinators and the one-call container:

        blob = codecs.compress(TokenStream(params, cfg, n), tokens,
                               lanes=lanes, seed=None, init_chunks=0)
    """

    params: Any
    cfg: Any
    n: int
    precision: int = ans.DEFAULT_PRECISION

    # Opaque to repro.analysis: the token loop drives jitted model
    # steps; encode and decode share those programs by construction.
    __analysis_opaque__ = True

    def push(self, stack: ans.ANSStack, tokens: jnp.ndarray
             ) -> ans.ANSStack:
        return encode_tokens(self.params, self.cfg, tokens, stack,
                             self.precision)

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, jnp.ndarray]:
        return decode_tokens(self.params, self.cfg, stack, self.n,
                             self.precision)


def expected_bits(params: Any, cfg: Any, tokens: jnp.ndarray) -> float:
    """Cross-entropy of the model on the stream, bits (the coding bound).

    Uses the parallel teacher-forced forward (analysis only - tiny fp
    deviations from the coding path are irrelevant here).
    """
    inp = jnp.concatenate(
        [jnp.full((tokens.shape[0], 1), BOS, tokens.dtype),
         tokens[:, :-1]], axis=1)
    logits, _ = transformer.forward(params, cfg, inp)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tgt = jnp.take_along_axis(logp, tokens[..., None].astype(jnp.int32),
                              axis=-1)[..., 0]
    return float(-jnp.sum(tgt) * (1.0 / jnp.log(2.0)))
