"""BB-ANS core: entropy coding, bits-back, discretization, distributions."""
