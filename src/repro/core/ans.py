"""Lane-vectorized rANS entropy coder in pure JAX.

This is the substrate for BB-ANS (Townsend, Bird & Barber, ICLR 2019).

Design (TPU-native adaptation, see DESIGN.md section 3):

  * 32-bit state per lane, normalized interval ``[2^16, 2^32)``.
  * 16-bit renormalization chunks stored in a per-lane stack (``buf``/``ptr``).
  * Coding precision ``r <= 16`` bits. With ``L = 2^16`` and 16-bit chunks
    this guarantees each ``push`` emits *at most one* chunk and each ``pop``
    reads *at most one* chunk:

      - push renorm: while ``x >= freq << (32 - r)``: emit 16 bits. After one
        emission ``x < 2^16 <= freq << (32 - r)`` for any ``freq >= 1``,
        ``r <= 16``; so a single masked emission suffices.
      - pop renorm: after the state update ``x >= 1``, so one 16-bit read
        brings ``x >= 2^16 = L``; a single masked read suffices.

    This removes the data-dependent while-loop of scalar rANS and makes the
    coder a fixed sequence of vector integer ops - exactly what the TPU VPU
    (and ``jax.jit``) wants.
  * Lanes are fully independent coders (independent stacks). A fused message
    is produced by ``flatten`` and consumed by ``unflatten``; the only
    overhead versus a single-stream coder is one 32-bit head flush per lane.

The coder is *exact*: pushes and pops are bit-precise inverses, verified by
property tests in ``tests/test_ans.py``.

All functions are jittable and differentiable-free (integer only).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# Normalization lower bound: state lives in [RANS_L, 2^32).
RANS_L = jnp.uint32(1 << 16)
_MASK16 = jnp.uint32(0xFFFF)
MAX_PRECISION = 16
#: Default coding precision (bits). 2^r is the total frequency budget.
DEFAULT_PRECISION = 16


class ANSStack(NamedTuple):
    """State of ``lanes`` independent rANS coders.

    Attributes:
      head: uint32[lanes] - rANS state per lane, in ``[2^16, 2^32)``.
      buf:  uint16[lanes, capacity] - renormalization chunk stack per lane.
      ptr:  int32[lanes] - number of valid chunks per lane (stack depth).
      underflows: int32[lanes] - count of pops that tried to read past the
        bottom of the stack. Always 0 in a correctly seeded chain; exposed
        so tests and the BB-ANS driver can assert cleanliness.
      overflows: int32[lanes] - count of renormalization chunks silently
        dropped because the stack was full (scatter past ``capacity``).
        Always 0 in a correctly sized stack; any nonzero value means the
        message is corrupt. ``codecs.compress`` uses this to grow the
        stack and retry instead of producing a broken blob.
    """

    head: jnp.ndarray
    buf: jnp.ndarray
    ptr: jnp.ndarray
    underflows: jnp.ndarray
    overflows: jnp.ndarray

    @property
    def lanes(self) -> int:
        return self.buf.shape[0]

    @property
    def capacity(self) -> int:
        return self.buf.shape[1]


def make_stack(lanes: int, capacity: int,
               key: Optional[jax.Array] = None) -> ANSStack:
    """Create an empty stack; if ``key`` given, heads are random (clean bits).

    A fresh head carries ``log2(head) - 16`` bits of recoverable randomness;
    seeding with random heads drawn *uniformly* over the full normalized
    interval ``[2^16, 2^32)`` provides up to 16 bits/lane (~14.6 in
    expectation) of "extra information" for the first bits-back pop. Use
    ``seed_stack`` to add more. The draw is exactly uniform: a 15-bit-ish
    high half ``hi ~ U[1, 2^16)`` and a low half ``lo ~ U[0, 2^16)``
    compose to ``(hi << 16) | lo ~ U[2^16, 2^32)``.
    """
    if key is None:
        head = jnp.full((lanes,), RANS_L, dtype=jnp.uint32)
    else:
        k_hi, k_lo = jax.random.split(key)
        hi = jax.random.randint(k_hi, (lanes,), 1, 1 << 16,
                                dtype=jnp.int32).astype(jnp.uint32)
        lo = jax.random.randint(k_lo, (lanes,), 0, 1 << 16,
                                dtype=jnp.int32).astype(jnp.uint32)
        head = (hi << 16) | lo
    return ANSStack(
        head=head,
        buf=jnp.zeros((lanes, capacity), dtype=jnp.uint16),
        ptr=jnp.zeros((lanes,), dtype=jnp.int32),
        underflows=jnp.zeros((lanes,), dtype=jnp.int32),
        overflows=jnp.zeros((lanes,), dtype=jnp.int32),
    )


def seed_stack(stack: ANSStack, key: jax.Array, n_chunks: int) -> ANSStack:
    """Push ``n_chunks`` uniform random 16-bit chunks per lane (clean bits).

    This implements the paper's 'initialize the BB-ANS chain with a supply of
    clean bits' (section 3.2): the first posterior pops consume these instead
    of underflowing.
    """
    chunks = jax.random.randint(
        key, (stack.lanes, n_chunks), 0, 1 << 16, dtype=jnp.int32
    ).astype(jnp.uint16)
    rows = jnp.arange(stack.lanes)[:, None]
    cols = stack.ptr[:, None] + jnp.arange(n_chunks)[None, :]
    buf = stack.buf.at[rows, cols].set(chunks, mode="drop")
    dropped = jnp.clip(stack.ptr + n_chunks - stack.capacity, 0, n_chunks)
    return stack._replace(buf=buf, ptr=stack.ptr + n_chunks,
                          overflows=stack.overflows + dropped)


def _as_u32(x: jnp.ndarray) -> jnp.ndarray:
    return x.astype(jnp.uint32)


def _check_precision(precision: int) -> None:
    # An explicit raise, not assert: python -O strips asserts, and this
    # guard protects the coder's core invariant (precision <= 16 is what
    # makes the single-renormalization bound hold).
    if not 0 < precision <= MAX_PRECISION:
        raise ValueError(
            f"ans: precision must be in [1, {MAX_PRECISION}], got "
            f"{precision}")


def push(stack: ANSStack, start: jnp.ndarray, freq: jnp.ndarray,
         precision: int = DEFAULT_PRECISION) -> ANSStack:
    """Encode one symbol per lane, given its (start, freq) at ``precision``.

    ``start``/``freq`` are uint32[lanes] with ``0 < freq``, ``start + freq <=
    2**precision``. Adds ``precision - log2(freq)`` bits per lane.
    """
    _check_precision(precision)
    head, buf, ptr = stack.head, stack.buf, stack.ptr
    start, freq = _as_u32(start), _as_u32(freq)

    # Single masked renormalization (see module docstring for the bound).
    x_max = freq << (32 - precision)
    need = head >= x_max
    rows = jnp.arange(stack.lanes)
    # Masked scatter: lanes that don't emit write out-of-bounds (dropped).
    idx = jnp.where(need, ptr, stack.capacity)
    buf = buf.at[rows, idx].set((head & _MASK16).astype(jnp.uint16),
                                mode="drop")
    over = need & (ptr >= stack.capacity)  # a *real* chunk was dropped
    ptr = ptr + need.astype(jnp.int32)
    head = jnp.where(need, head >> 16, head)

    head = ((head // freq) << precision) + (head % freq) + start
    return stack._replace(head=head, buf=buf, ptr=ptr,
                          overflows=stack.overflows + over.astype(jnp.int32))


def peek(stack: ANSStack, precision: int = DEFAULT_PRECISION) -> jnp.ndarray:
    """Return the decode slot (``head mod 2^precision``) per lane."""
    _check_precision(precision)
    return stack.head & jnp.uint32((1 << precision) - 1)


def pop_update(stack: ANSStack, start: jnp.ndarray, freq: jnp.ndarray,
               precision: int = DEFAULT_PRECISION) -> ANSStack:
    """Advance the decoder after the symbol for ``peek``'s slot was resolved.

    Exactly inverts ``push(stack, start, freq, precision)``.
    """
    _check_precision(precision)
    head, buf, ptr = stack.head, stack.buf, stack.ptr
    start, freq = _as_u32(start), _as_u32(freq)
    slot = peek(stack, precision)

    head = freq * (head >> precision) + slot - start

    # Single masked renormalization read.
    need = head < RANS_L
    rows = jnp.arange(stack.lanes)
    read_idx = jnp.maximum(ptr - 1, 0)
    chunk = buf[rows, read_idx].astype(jnp.uint32)
    head = jnp.where(need, (head << 16) | chunk, head)
    under = need & (ptr <= 0)
    ptr = jnp.maximum(ptr - need.astype(jnp.int32), 0)
    return stack._replace(
        head=head, buf=buf, ptr=ptr,
        underflows=stack.underflows + under.astype(jnp.int32))


def pop_with_table(stack: ANSStack, starts_table: jnp.ndarray,
                   precision: int = DEFAULT_PRECISION
                   ) -> Tuple[ANSStack, jnp.ndarray]:
    """Decode one symbol per lane from a cumulative-starts table.

    ``starts_table``: uint32[lanes, A+1], row ``l`` is the fixed-point CDF
    ``F`` of lane ``l``'s alphabet: ``F[0] = 0 <= F[1] < ... <= F[A] =
    2^precision``, strictly increasing where freq > 0. Returns (new stack,
    symbol int32[lanes]).
    """
    slot = peek(stack, precision)
    # searchsorted per-lane: symbol = max i such that F[i] <= slot.
    sym = jax.vmap(
        lambda row, s: jnp.searchsorted(row, s, side="right") - 1
    )(starts_table, slot).astype(jnp.int32)
    rows = jnp.arange(stack.lanes)
    start = starts_table[rows, sym]
    freq = starts_table[rows, sym + 1] - start
    return pop_update(stack, start, freq, precision), sym


def push_with_table(stack: ANSStack, starts_table: jnp.ndarray,
                    symbol: jnp.ndarray,
                    precision: int = DEFAULT_PRECISION) -> ANSStack:
    """Encode one symbol per lane from a cumulative-starts table."""
    rows = jnp.arange(stack.lanes)
    sym = symbol.astype(jnp.int32)
    start = starts_table[rows, sym]
    freq = starts_table[rows, sym + 1] - start
    return push(stack, start, freq, precision)


def stack_bits(stack: ANSStack) -> jnp.ndarray:
    """Total message length in bits if flushed now (includes 32b/lane head)."""
    return jnp.sum(stack.ptr) * 16 + 32 * stack.lanes


def stack_content_bits(stack: ANSStack) -> jnp.ndarray:
    """Information currently on the stack, *excluding* flush overhead.

    ``log2(head)`` counts the fractional bits held in each head register;
    useful for rate measurements that should match -ELBO without the
    per-lane constant.
    """
    head_bits = jnp.log2(stack.head.astype(jnp.float64)
                         if getattr(jax.config, "jax_enable_x64", False)
                         else stack.head.astype(jnp.float32))
    return jnp.sum(stack.ptr).astype(jnp.float32) * 16.0 + jnp.sum(head_bits)


def flatten(stack: ANSStack) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Serialize to (message uint16[lanes, cap+2], lengths int32[lanes]).

    Row layout: [head_hi16, head_lo16, chunks...(ptr of them)]. The fused
    wire format is the concatenation of ``message[l, :lengths[l]]``; lengths
    must be transmitted (or derivable) as framing, as in any blocked codec.
    """
    head_hi = (stack.head >> 16).astype(jnp.uint16)[:, None]
    head_lo = (stack.head & _MASK16).astype(jnp.uint16)[:, None]
    msg = jnp.concatenate([head_hi, head_lo, stack.buf], axis=1)
    return msg, stack.ptr + 2


def unflatten(msg: jnp.ndarray, lengths: jnp.ndarray,
              capacity: Optional[int] = None) -> ANSStack:
    """Inverse of ``flatten``."""
    lanes = msg.shape[0]
    cap = capacity if capacity is not None else msg.shape[1] - 2
    head = (msg[:, 0].astype(jnp.uint32) << 16) | msg[:, 1].astype(jnp.uint32)
    buf = msg[:, 2:2 + cap]
    if buf.shape[1] < cap:
        buf = jnp.pad(buf, ((0, 0), (0, cap - buf.shape[1])))
    return ANSStack(head=head, buf=buf.astype(jnp.uint16),
                    ptr=lengths - 2,
                    underflows=jnp.zeros((lanes,), dtype=jnp.int32),
                    overflows=jnp.zeros((lanes,), dtype=jnp.int32))


def split_lanes(stack: ANSStack, n_shards: int) -> Tuple[ANSStack, ...]:
    """Cut the lane axis into ``n_shards`` contiguous, equal shards.

    Lanes are fully independent coders, so each shard is a complete
    ``ANSStack`` in its own right: coding on a shard then merging is
    bit-identical to coding the same lanes in the full stack - the
    invariant that makes ``repro.shard_codec``'s per-device shards
    (which split the *data* lane axis and code on per-shard stacks)
    byte-compatible with whole-stack coding, asserted by
    ``tests/test_shard_codec.py``. This is the stack-level counterpart
    of ``shard_codec.split_lane_tree``, for callers holding a live
    stack; ``merge_lanes`` is the exact inverse.

    Example::

        shards = split_lanes(stack, 4)      # 4 stacks of lanes/4 lanes
        assert merge_lanes(shards).lanes == stack.lanes
    """
    if n_shards < 1 or stack.lanes % n_shards:
        raise ValueError(
            f"ans.split_lanes: {stack.lanes} lanes do not divide into "
            f"{n_shards} equal shards")
    per = stack.lanes // n_shards
    return tuple(
        jax.tree_util.tree_map(lambda a: a[s * per:(s + 1) * per], stack)
        for s in range(n_shards))


def merge_lanes(stacks: Sequence[ANSStack]) -> ANSStack:
    """Concatenate per-shard stacks back into one stack (inverse of
    ``split_lanes``). All shards must share capacity.

    Example::

        full = merge_lanes(split_lanes(stack, 4))
        assert (full.head == stack.head).all()
    """
    stacks = list(stacks)
    if not stacks:
        raise ValueError("ans.merge_lanes: no shards")
    caps = {s.capacity for s in stacks}
    if len(caps) != 1:
        raise ValueError(
            f"ans.merge_lanes: shards disagree on capacity ({caps})")
    return jax.tree_util.tree_map(
        lambda *ls: jnp.concatenate(ls, axis=0), *stacks)


def check_clean(stack: ANSStack, context: str = "ANS") -> ANSStack:
    """Raise if the stack ever under- or overflowed; returns it unchanged.

    Underflow means pops consumed past the clean-bit supply (seed more
    initial bits); overflow means pushes silently dropped chunks (grow
    ``capacity``). Either way the message is corrupt - drivers call this
    at Python level after every encode.
    """
    under = int(jnp.sum(stack.underflows))
    over = int(jnp.sum(stack.overflows))
    if under:
        raise RuntimeError(
            f"{context}: {under} stack underflow(s) - pops consumed past "
            "the bottom of the stack; seed more clean bits (init_chunks)")
    if over:
        raise RuntimeError(
            f"{context}: {over} chunk(s) dropped on overflow - stack "
            "capacity too small for this message; increase capacity")
    return stack


def select_lanes(mask: jnp.ndarray, on_true: ANSStack,
                 on_false: ANSStack) -> ANSStack:
    """Per-lane select between two stacks of identical shape.

    Lane ``l`` of the result is ``on_true``'s lane where ``mask[l]`` and
    ``on_false``'s lane otherwise. Because lanes are fully independent
    coders, this turns any unmasked codec operation into a masked one:
    run ``codec.push``/``pop`` on the whole stack, then keep the old
    state in the lanes that should not advance. ``repro.stream`` uses
    this to admit/retire streams mid-batch and to code ragged final
    blocks without padding symbols.
    """
    m = mask.astype(bool)
    return ANSStack(
        head=jnp.where(m, on_true.head, on_false.head),
        buf=jnp.where(m[:, None], on_true.buf, on_false.buf),
        ptr=jnp.where(m, on_true.ptr, on_false.ptr),
        underflows=jnp.where(m, on_true.underflows, on_false.underflows),
        overflows=jnp.where(m, on_true.overflows, on_false.overflows))


# ---------------------------------------------------------------------------
# Fixed-point CDF helpers ("freq tables")
# ---------------------------------------------------------------------------

def cdf_to_starts(cdf: jnp.ndarray,
                  precision: int = DEFAULT_PRECISION) -> jnp.ndarray:
    """Quantize a float CDF to a fixed-point starts table with freqs >= 1.

    ``cdf``: float[..., A+1], non-decreasing with cdf[...,0]=0, cdf[...,A]=1.
    Returns uint32[..., A+1] table F with F[0]=0, F[A]=2^precision and
    F[i+1]-F[i] >= 1 for all i (every symbol codable), via

        F[i] = floor((2^p - A) * cdf[i]) + i

    which is exact-total and strictly increasing. Requires A <= 2^p - A,
    i.e. alphabet at most ~2^(p-1) (use factored coders beyond that).
    """
    a = cdf.shape[-1] - 1
    total = 1 << precision
    if a >= total:
        raise ValueError(
            f"alphabet {a} too large for precision {precision}; "
            "use a factored codec (core.distributions.FactoredCategorical)")
    if a < 2:
        # A 1-symbol alphabet needs freq = 2^precision, which overflows the
        # uint32 renormalization bound (freq << 16). It also carries zero
        # information - callers must skip the push/pop instead.
        raise ValueError("degenerate alphabet (< 2 symbols): skip coding")
    scaled = jnp.floor(cdf * (total - a)).astype(jnp.uint32)
    ramp = jnp.arange(a + 1, dtype=jnp.uint32)
    ramp = ramp.reshape((1,) * (cdf.ndim - 1) + (-1,))
    return scaled + ramp


def probs_to_starts(probs: jnp.ndarray,
                    precision: int = DEFAULT_PRECISION) -> jnp.ndarray:
    """Like ``cdf_to_starts`` but from a probability vector float[..., A].

    The normalization is written as a reciprocal-multiply (not a
    division with a divisor shared across the row): that is the
    canonical form XLA's simplifier produces, so the fixed-point table
    comes out bit-identical whether this runs eagerly, inside a jit, or
    inside a fused compiled-codec program (docs/PERF.md).
    """
    cdf = jnp.cumsum(probs, axis=-1)
    cdf = cdf * (1.0 / cdf[..., -1:])
    zero = jnp.zeros(cdf.shape[:-1] + (1,), cdf.dtype)
    cdf = jnp.concatenate([zero, cdf], axis=-1)
    # Guard against float drift: clamp into [0, 1] monotonically.
    cdf = jnp.clip(cdf, 0.0, 1.0)
    return cdf_to_starts(cdf, precision)
