"""The ``Codec`` abstraction: the composable unit of the coding API.

A codec is a pair of exact LIFO inverses over an ``ans.ANSStack``:

    push(stack, x) -> stack          encode one symbol (per lane)
    pop(stack)     -> (stack, x)     decode it back

``pop(push(stack, x)) == (stack, x)`` bit-for-bit - this is the only
contract, and it is what makes bits-back composition work (Townsend,
Bird & Barber, ICLR 2019, App. C): any codec can serve as a prior,
likelihood, or posterior inside ``repro.codecs.BBANS``, and combinators
(``Serial``, ``Repeat``, ``TreeCodec``, ``Chained``, ``BitSwap``)
preserve the contract by construction.

The class lives in ``repro.core`` (not ``repro.codecs``) so that leaf
distributions in ``core.distributions`` can subclass it without a
circular import; ``repro.codecs`` re-exports it as the public name.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

from repro.core import ans


class Codec:
    """Base class for composable push/pop coders.

    Subclasses implement ``push`` and ``pop``; dataclass subclasses get
    value semantics for free. Symbols ``x`` are pytrees with a leading
    ``lanes`` axis on every leaf.

    Example (a shift-by-7 codec; runnable in docs/API.md)::

        class Add7(Codec):
            def push(self, stack, x):
                return Uniform(4).push(stack, x + 7)
            def pop(self, stack):
                stack, x = Uniform(4).pop(stack)
                return stack, x - 7
    """

    #: Set True on subclasses whose float evaluation happens inside
    #: jitted programs they manage themselves (driver codecs like the
    #: LM ``TokenStream``). ``repro.analysis`` then probes them for
    #: bit-exact inversion only instead of tracing their internals.
    __analysis_opaque__ = False

    def push(self, stack: ans.ANSStack, x: Any) -> ans.ANSStack:
        raise NotImplementedError

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, Any]:
        raise NotImplementedError


class FnCodec(Codec):
    """Adapter: wrap a raw (push_fn, pop_fn) pair as a Codec.

    The escape hatch for codecs whose hooks are closures over model
    state (e.g. the legacy six-hook ``BBANSCodec``) or that drive
    Python-level jitted-step loops (the LM likelihoods).

    Example::

        inner = Uniform(4)
        codec = FnCodec(inner.push, inner.pop)   # same wire bytes
    """

    # Opaque to repro.analysis: the wrapped fns are arbitrary closures.
    __analysis_opaque__ = True

    def __init__(self, push_fn: Callable, pop_fn: Callable):
        self._push = push_fn
        self._pop = pop_fn

    def push(self, stack: ans.ANSStack, x: Any) -> ans.ANSStack:
        return self._push(stack, x)

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, Any]:
        return self._pop(stack)
