"""Bits Back with ANS (BB-ANS) - DEPRECATED six-hook interface.

The implementation lives in ``repro.codecs`` (the composable
``BBANS``/``Chained``/``BitSwap`` combinators - paper Table 1 /
section 2.3); this module is kept only as a thin compatibility shim so
pre-codecs call sites keep working, and every function here delegates
to the combinators (coding is bit-identical). New code should:

  * build the codec with ``models.vae.make_bb_codec`` (single layer),
    ``models.hvae.make_bitswap_codec`` (hierarchical), or a
    ``codecs.BBANS`` of its own;
  * ship bytes with ``codecs.compress``/``decompress`` (one-shot BBX1)
    or ``repro.stream`` (chunked BBX2);
  * see docs/API.md for runnable examples of every public name.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import ans
from repro.core.codec import FnCodec
from repro.codecs import combinators


class BBANSCodec(NamedTuple):
    """The six coder hooks of a bits-back model (DEPRECATED form).

    Symbols ``s`` and latents ``y`` are pytrees with a leading ``lanes``
    axis. Every *_push must exactly invert the corresponding *_pop (and
    vice versa) - this is the only requirement (paper App. C).

    Prefer ``codecs.BBANS(prior, likelihood, posterior)``: it is the
    same object with the hook pairs grouped into ``Codec`` values
    (``as_codec`` converts; ``models.vae.make_bb_codec`` builds it
    directly).
    """

    posterior_pop: Callable   # (stack, s) -> (stack, y)      decode y~Q(y|s)
    posterior_push: Callable  # (stack, s, y) -> stack        inverse
    likelihood_push: Callable  # (stack, y, s) -> stack       encode s~p(s|y)
    likelihood_pop: Callable   # (stack, y) -> (stack, s)     inverse
    prior_push: Callable       # (stack, y) -> stack          encode y~p(y)
    prior_pop: Callable        # (stack) -> (stack, y)        inverse


def as_codec(codec: BBANSCodec) -> combinators.BBANS:
    """Adapt the six hooks into the composable ``codecs.BBANS``."""
    return combinators.BBANS(
        prior=FnCodec(codec.prior_push, codec.prior_pop),
        likelihood=lambda y: FnCodec(
            lambda stack, s: codec.likelihood_push(stack, y, s),
            lambda stack: codec.likelihood_pop(stack, y)),
        posterior=lambda s: FnCodec(
            lambda stack, y: codec.posterior_push(stack, s, y),
            lambda stack: codec.posterior_pop(stack, s)))


def append(codec: BBANSCodec, stack: ans.ANSStack, s) -> ans.ANSStack:
    """Encode one datapoint per lane (paper Table 1).

    Net expected stack growth = -ELBO(s) bits.
    """
    return as_codec(codec).push(stack, s)


def pop(codec: BBANSCodec, stack: ans.ANSStack) -> Tuple[ans.ANSStack, object]:
    """Decode one datapoint per lane - exact inverse of ``append``."""
    return as_codec(codec).pop(stack)


def _chain_len(data) -> int:
    return jax.tree_util.tree_leaves(data)[0].shape[0]


def append_batch(codec: BBANSCodec, stack: ans.ANSStack,
                 data, scan: bool = True) -> ans.ANSStack:
    """Chain-encode ``data`` (pytree with leading [N, lanes, ...] axes).

    Datapoint ``t``'s compressed stack is datapoint ``t+1``'s extra
    information (section 2.3). Decoding must pop in reverse order, which
    ``pop_batch`` does. The encode asserts no chunk was dropped on
    overflow (silent data loss -> raise instead of a corrupt message);
    underflow stays observable via ``stack.underflows`` since running
    without clean bits is a legitimate (measured) ablation.

    ``scan=False`` runs a Python-level loop instead of ``lax.scan``:
    required for codecs whose hooks internally drive jit-compiled network
    steps from Python (LatentLM - see lm_codec's determinism contract).
    """
    chained = combinators.Chained(as_codec(codec), _chain_len(data),
                                  scan=scan)
    out = chained.push(stack, data)
    new_over = int(jnp.sum(out.overflows)) - int(jnp.sum(stack.overflows))
    if new_over:
        raise RuntimeError(
            f"bbans.append_batch: {new_over} chunk(s) dropped on overflow "
            "- stack capacity too small for this chain")
    return out


def pop_batch(codec: BBANSCodec, stack: ans.ANSStack, n: int,
              scan: bool = True) -> Tuple[ans.ANSStack, object]:
    """Chain-decode ``n`` datapoints; returns them in original order."""
    return combinators.Chained(as_codec(codec), n, scan=scan).pop(stack)


def chain_rate_bits_per_dim(stack_before: ans.ANSStack,
                            stack_after: ans.ANSStack,
                            n_dims_total: int) -> jnp.ndarray:
    """Achieved compression rate of a chained encode, in bits/dim.

    Uses content bits (head registers counted fractionally) so short chains
    aren't distorted by the 32-bit/lane flush constant; the flush constant is
    reported separately by benchmarks.
    """
    return ((ans.stack_content_bits(stack_after)
             - ans.stack_content_bits(stack_before)) / n_dims_total)
