"""Bits Back with ANS (BB-ANS) - the paper's core contribution.

Implements Table 1 / Appendix C of Townsend, Bird & Barber (ICLR 2019) as a
generic codec over any latent-variable model, plus the *chaining* driver
(section 2.3): the ANS stack left by one datapoint is the "extra
information" consumed by the next, with zero per-datapoint overhead - the
property that makes ANS (LIFO) work where arithmetic coding (FIFO) fails.

A model plugs in six lane-vectorized coder callables (see ``BBANSCodec``).
``append``/``pop`` are exact inverses; ``append_batch``/``pop_batch`` chain
across a dataset under ``lax.scan``.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import ans


class BBANSCodec(NamedTuple):
    """The six coder hooks of a bits-back model.

    Symbols ``s`` and latents ``y`` are pytrees with a leading ``lanes``
    axis. Every *_push must exactly invert the corresponding *_pop (and vice
    versa) - this is the only requirement (paper App. C).
    """

    posterior_pop: Callable   # (stack, s) -> (stack, y)      decode y~Q(y|s)
    posterior_push: Callable  # (stack, s, y) -> stack        inverse
    likelihood_push: Callable  # (stack, y, s) -> stack       encode s~p(s|y)
    likelihood_pop: Callable   # (stack, y) -> (stack, s)     inverse
    prior_push: Callable       # (stack, y) -> stack          encode y~p(y)
    prior_pop: Callable        # (stack) -> (stack, y)        inverse


def append(codec: BBANSCodec, stack: ans.ANSStack, s) -> ans.ANSStack:
    """Encode one datapoint per lane (paper Table 1).

    Net expected stack growth = -ELBO(s) bits.
    """
    stack, y = codec.posterior_pop(stack, s)      # get bits back
    stack = codec.likelihood_push(stack, y, s)    # pay -log p(s|y)
    stack = codec.prior_push(stack, y)            # pay -log p(y)
    return stack


def pop(codec: BBANSCodec, stack: ans.ANSStack) -> Tuple[ans.ANSStack, object]:
    """Decode one datapoint per lane - exact inverse of ``append``."""
    stack, y = codec.prior_pop(stack)
    stack, s = codec.likelihood_pop(stack, y)
    stack = codec.posterior_push(stack, s, y)     # return the bits
    return stack, s


def append_batch(codec: BBANSCodec, stack: ans.ANSStack,
                 data, scan: bool = True) -> ans.ANSStack:
    """Chain-encode ``data`` (pytree with leading [N, lanes, ...] axes).

    Datapoint ``t``'s compressed stack is datapoint ``t+1``'s extra
    information (section 2.3). Decoding must pop in reverse order, which
    ``pop_batch`` does.

    ``scan=False`` runs a Python-level loop instead of ``lax.scan``:
    required for codecs whose hooks internally drive jit-compiled network
    steps from Python (LatentLM - see lm_codec's determinism contract).
    """
    if scan:
        def body(stack, s):
            return append(codec, stack, s), None

        stack, _ = jax.lax.scan(body, stack, data)
        return stack
    n = jax.tree_util.tree_leaves(data)[0].shape[0]
    for i in range(n):
        s_i = jax.tree_util.tree_map(lambda x: x[i], data)
        stack = append(codec, stack, s_i)
    return stack


def pop_batch(codec: BBANSCodec, stack: ans.ANSStack, n: int,
              scan: bool = True) -> Tuple[ans.ANSStack, object]:
    """Chain-decode ``n`` datapoints; returns them in original order."""
    if scan:
        def body(stack, _):
            stack, s = pop(codec, stack)
            return stack, s

        stack, data_rev = jax.lax.scan(body, stack, None, length=n)
        data = jax.tree_util.tree_map(lambda x: jnp.flip(x, axis=0),
                                      data_rev)
        return stack, data
    outs = []
    for _ in range(n):
        stack, s = pop(codec, stack)
        outs.append(s)
    data = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs, axis=0), *reversed(outs))
    return stack, data


def chain_rate_bits_per_dim(stack_before: ans.ANSStack,
                            stack_after: ans.ANSStack,
                            n_dims_total: int) -> jnp.ndarray:
    """Achieved compression rate of a chained encode, in bits/dim.

    Uses content bits (head registers counted fractionally) so short chains
    aren't distorted by the 32-bit/lane flush constant; the flush constant is
    reported separately by benchmarks.
    """
    return ((ans.stack_content_bits(stack_after)
             - ans.stack_content_bits(stack_before)) / n_dims_total)
