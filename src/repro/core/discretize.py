"""Maximum-entropy discretization of continuous latents (paper App. B).

The latent space of each dimension is partitioned into ``K = 2^lat_bits``
buckets of *equal mass under the prior* ``N(0, 1)``:

  * bucket edges  ``z_i = ndtri(i / K)``  (z_0 = -inf, z_K = +inf),
  * bucket centre ``c_i = ndtri((i + 0.5) / K)``.

Consequences exploited here:

  * **Prior coding is uniform**: pushing bucket ``i`` under the prior is a
    uniform code - ``start = i << (prec - lat_bits)``, ``freq = 2^(prec -
    lat_bits)`` - exactly ``lat_bits`` bits, no CDF evaluation at all.
  * **Posterior coding** uses the fixed-point CDF
    ``F(i) = floor((2^prec - K) * ndtr((z_i - mu) / sigma)) + i`` which is
    strictly increasing with ``F(0) = 0`` and ``F(K) = 2^prec``, so every
    bucket has nonzero frequency and the total is exact. ``F`` is evaluated
    *pointwise* (no K-sized tables), and decoding inverts it with a
    ``lat_bits``-step vectorized bisection. Encoder and decoder evaluate the
    identical jitted function, so the roundtrip is bit-exact.

Rate note: the ``+ i`` ramp makes the *coded* posterior the mixture
``Q' = (1 - eps) Q + eps P`` with ``eps = 2^(lat_bits - precision)`` (the
smeared mass lands uniformly on buckets = the prior, by max-entropy
construction). The rate penalty is at most ``-log2(1 - eps) + eps *
E_Q[log Q/P]`` bits per latent dimension - with the default
``lat_bits=10, precision=16`` that is < 0.03 bits/dim, measured end-to-end
in ``benchmarks/table2_rates.py``. In exchange, F stays pointwise-evaluable
(O(1) memory, bisection decode) - the TPU-friendly trade.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.special import ndtr, ndtri

from repro.core import ans


# ndtri is a long op composition whose float32 result bits can vary
# with the surrounding XLA fusion context (jit vs eager, push program
# vs pop program). Coding correctness requires the *identical* bits on
# both sides of a roundtrip, so the grid geometry - a pure function of
# the bucket index - is computed once, eagerly, per lat_bits, and every
# path (core pointwise coders, codec compiler, Pallas kernels) gathers
# from the same concrete table. Gathers are exact in any context.
_EDGE_TABLES: dict = {}
_CENTRE_TABLES: dict = {}


def edge_table(lat_bits: int) -> jnp.ndarray:
    """z[i] = Phi^-1(i/K) for i = 0..K as a concrete float32[K+1]."""
    if lat_bits not in _EDGE_TABLES:
        with jax.ensure_compile_time_eval():   # concrete even under jit
            k = 1 << lat_bits
            frac = jnp.arange(k + 1, dtype=jnp.float32) / k
            z = ndtri(jnp.clip(frac, 1e-38, 1.0 - 1e-7))
            _EDGE_TABLES[lat_bits] = jnp.asarray(np.asarray(z))
    return _EDGE_TABLES[lat_bits]


def centre_table(lat_bits: int) -> jnp.ndarray:
    """c[i] = Phi^-1((i+0.5)/K) for i = 0..K-1, concrete float32[K]."""
    if lat_bits not in _CENTRE_TABLES:
        with jax.ensure_compile_time_eval():
            k = 1 << lat_bits
            frac = (jnp.arange(k, dtype=jnp.float32) + 0.5) / k
            _CENTRE_TABLES[lat_bits] = jnp.asarray(np.asarray(ndtri(frac)))
    return _CENTRE_TABLES[lat_bits]


def bucket_edge(i: jnp.ndarray, lat_bits: int) -> jnp.ndarray:
    """z_i = Phi^-1(i / K); ends are special-cased by callers via ndtr
    saturation (see _posterior_cdf)."""
    k = 1 << lat_bits
    return jnp.take(edge_table(lat_bits), jnp.clip(i, 0, k))


def bucket_centre(i: jnp.ndarray, lat_bits: int) -> jnp.ndarray:
    """Representative latent value for bucket i (its prior median)."""
    k = 1 << lat_bits
    return jnp.take(centre_table(lat_bits), jnp.clip(i, 0, k - 1))


def _posterior_cdf(i: jnp.ndarray, mu: jnp.ndarray, sigma: jnp.ndarray,
                   lat_bits: int) -> jnp.ndarray:
    """Phi((z_i - mu) / sigma) with exact 0/1 at i = 0 / K.

    The standardization is written ``(z - mu) * (1/sigma)`` on purpose:
    it is the canonical form XLA's simplifier rewrites shared divisions
    into, so eager, jitted, and kernel evaluations of this CDF produce
    the same float32 bits in every compilation context (the coder's
    roundtrip-exactness depends on that - see docs/PERF.md).
    """
    k = 1 << lat_bits
    z = bucket_edge(i, lat_bits)
    c = ndtr((z - mu) * (1.0 / sigma))
    c = jnp.where(i <= 0, 0.0, c)
    c = jnp.where(i >= k, 1.0, c)
    return c


def posterior_starts_fn(mu: jnp.ndarray, sigma: jnp.ndarray, lat_bits: int,
                        precision: int
                        ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Return pointwise fixed-point CDF ``F(i)`` for a diag-Gaussian
    posterior over the max-entropy prior buckets.

    F maps int32[...] bucket indices (same shape as mu after broadcast) to
    uint32 cumulative starts.
    """
    k = 1 << lat_bits
    total = 1 << precision
    scale = float(total - k)
    if scale <= 0:
        raise ValueError("need precision > lat_bits")

    def f(i):
        c = _posterior_cdf(i, mu, sigma, lat_bits)
        return jnp.floor(c * scale).astype(jnp.uint32) + i.astype(jnp.uint32)

    return f


def push_posterior(stack: ans.ANSStack, idx: jnp.ndarray, mu: jnp.ndarray,
                   sigma: jnp.ndarray, lat_bits: int,
                   precision: int = ans.DEFAULT_PRECISION) -> ans.ANSStack:
    """Encode bucket indices (one per lane) under Q(y|s)."""
    f = posterior_starts_fn(mu, sigma, lat_bits, precision)
    start = f(idx)
    freq = f(idx + 1) - start
    return ans.push(stack, start, freq, precision)


def pop_posterior(stack: ans.ANSStack, mu: jnp.ndarray, sigma: jnp.ndarray,
                  lat_bits: int,
                  precision: int = ans.DEFAULT_PRECISION
                  ) -> Tuple[ans.ANSStack, jnp.ndarray]:
    """Decode bucket indices (one per lane) under Q(y|s) == sample from the
    discretized posterior using stack bits as the randomness source."""
    f = posterior_starts_fn(mu, sigma, lat_bits, precision)
    slot = ans.peek(stack, precision)
    # Bisection for the largest i with F(i) <= slot, i in [0, K).
    lo = jnp.zeros_like(slot, dtype=jnp.int32)
    hi = jnp.full_like(lo, 1 << lat_bits)  # exclusive

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi + 1) // 2
        go_up = f(mid) <= slot
        return jnp.where(go_up, mid, lo), jnp.where(go_up, hi, mid)

    # After lat_bits+1 halvings of a K+1-point range the bracket is exact.
    lo, hi = jax.lax.fori_loop(0, lat_bits + 1, body, (lo, hi))
    idx = lo
    start = f(idx)
    freq = f(idx + 1) - start
    return ans.pop_update(stack, start, freq, precision), idx


def push_prior(stack: ans.ANSStack, idx: jnp.ndarray, lat_bits: int,
               precision: int = ans.DEFAULT_PRECISION) -> ans.ANSStack:
    """Encode bucket indices under the prior: exact uniform code."""
    shift = precision - lat_bits
    if shift < 0:
        raise ValueError("need precision >= lat_bits")
    start = idx.astype(jnp.uint32) << shift
    freq = jnp.full_like(start, 1 << shift)
    return ans.push(stack, start, freq, precision)


def pop_prior(stack: ans.ANSStack, lat_bits: int,
              precision: int = ans.DEFAULT_PRECISION
              ) -> Tuple[ans.ANSStack, jnp.ndarray]:
    """Decode bucket indices under the prior."""
    shift = precision - lat_bits
    slot = ans.peek(stack, precision)
    idx = (slot >> shift).astype(jnp.int32)
    start = idx.astype(jnp.uint32) << shift
    freq = jnp.full_like(start, 1 << shift)
    return ans.pop_update(stack, start, freq, precision), idx
