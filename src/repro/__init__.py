"""repro: BB-ANS lossless compression framework at pod scale (JAX)."""
__version__ = "1.0.0"
