"""Serving/compression launcher.

``python -m repro.launch.serve --arch qwen2-0.5b --mode compress``
trains nothing: it builds a (reduced) model, runs the compression
service end to end on a synthetic corpus and reports rates; ``--mode
stream`` runs the chunked BBX2 streaming path (and verifies a
mid-stream resume); ``--mode serve-many`` drives the dynamic batcher
over many requests of different lengths; ``--mode generate`` runs
batched greedy decoding; ``--mode hvae`` serves the hierarchical image
codec through ``serve.CodecEngine`` at several image shapes from one
parameter set. The same Engine runs on pod meshes via the
dryrun-validated decode/prefill programs.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs, stream
from repro.configs import base as cfg_base
from repro.data import tokens as tok_data
from repro.models import transformer
from repro.serve.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--mode", default="compress",
                    choices=["compress", "stream", "serve-many",
                             "generate", "hvae"])
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--block-symbols", type=int, default=16)
    ap.add_argument("--requests", type=int, default=12,
                    help="number of client streams for --mode serve-many")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compile", action="store_true",
                    help="route codecs through codecs.compile (fused "
                         "kernel programs; byte-identical wire)")
    ap.add_argument("--kv-dtype", default="bfloat16")
    args = ap.parse_args()

    if args.mode == "hvae":
        return main_hvae(args)

    cfg = dataclasses.replace(
        cfg_base.reduced(cfg_base.get(args.arch)),
        vocab=256, kv_cache_dtype=args.kv_dtype)
    params = transformer.init(jax.random.PRNGKey(args.seed), cfg)
    eng = Engine(params, cfg, max_len=args.tokens, jit=False)

    if args.mode == "generate":
        prompt = {"tokens": jnp.asarray(
            np.random.default_rng(args.seed).integers(
                0, cfg.vocab, (args.lanes, 8)), jnp.int32)}
        t0 = time.perf_counter()
        out = eng.generate(prompt, args.tokens)
        dt = time.perf_counter() - t0
        print(f"generated {out.shape} in {dt:.2f}s "
              f"({out.size / dt:.1f} tok/s, untrained weights)")
        return

    corpus, entropy = tok_data.markov_corpus(
        50_000, vocab=cfg.vocab, seed=args.seed)
    rng = np.random.default_rng(args.seed + 1)

    if args.mode == "serve-many":
        reqs = []
        for _ in range(args.requests):
            n = int(rng.integers(4, args.tokens + 1))
            s = int(rng.integers(0, len(corpus) - n))
            reqs.append(jnp.asarray(corpus[s:s + n], jnp.int32))
        t0 = time.perf_counter()
        blobs = eng.serve_many(reqs, max_lanes=args.lanes,
                               block_symbols=args.block_symbols)
        enc = time.perf_counter() - t0
        outs = eng.decompress_many(blobs, max_lanes=args.lanes,
                                   block_symbols=args.block_symbols)
        ok = all(bool(jnp.array_equal(o, r)) for o, r in zip(outs, reqs))
        total = sum(int(r.size) for r in reqs)
        bits = sum(len(b) * 8 for b in blobs)
        print(f"served {len(reqs)} streams ({total} tokens) through "
              f"{args.lanes} lanes in {enc:.2f}s; {bits / total:.3f} "
              f"wire bits/tok (untrained model: ~log2 V); lossless={ok}")
        return

    starts = rng.integers(0, len(corpus) - args.tokens, args.lanes)
    toks = jnp.asarray(
        np.stack([corpus[s:s + args.tokens] for s in starts]), jnp.int32)

    if args.mode == "stream":
        t0 = time.perf_counter()
        blob = eng.compress_stream(toks,
                                   block_symbols=args.block_symbols)
        enc = time.perf_counter() - t0
        header, offsets, trailer = stream.format.scan(blob)
        out = eng.decompress_stream(blob)
        ok = bool(jnp.array_equal(out, toks))
        print(f"corpus entropy {entropy:.3f} bits/tok; streamed "
              f"{len(blob) * 8 / toks.size:.3f} wire bits/tok over "
              f"{len(offsets)} blocks; lossless={ok}; encode {enc:.2f}s")
        if len(offsets) > 1:
            tail = stream.decode_from_offset(
                None, blob, offsets[1],
                block_codec_fn=eng._block_codec_fn())
            ok2 = bool(jnp.array_equal(
                tail.T, toks[:, args.block_symbols:]))
            print(f"mid-stream resume from block 1 "
                  f"(byte {offsets[1]}): lossless={ok2}")
        return

    t0 = time.perf_counter()
    blob = eng.compress(toks)
    enc = time.perf_counter() - t0
    bits = codecs.blob_info(blob)["payload_bits"]
    out = eng.decompress(blob, args.tokens)
    ok = bool(jnp.array_equal(out, toks))
    print(f"corpus entropy {entropy:.3f} bits/tok; achieved "
          f"{bits / toks.size:.3f} bits/tok (untrained model: ~log2 V); "
          f"lossless={ok}; encode {enc:.2f}s")


def main_hvae(args):
    """Image-codec service demo: one fully convolutional model, several
    request shapes, one-shot + streaming wire paths, all lossless."""
    from repro.configs import hvae_img
    from repro.data import images as img_data
    from repro.models import hvae
    from repro.serve.engine import CodecEngine

    cfg = hvae_img.get("hvae-small2")
    params = hvae.init(jax.random.PRNGKey(args.seed), cfg)
    eng = CodecEngine(hvae.codec_family(params, cfg), seed=args.seed,
                      compile=args.compile)
    lanes = args.lanes
    for shape in ((16, 16), (20, 12)):
        raw = img_data.load("test", 2 * lanes, args.seed, hw=shape)
        data = jnp.asarray(raw.reshape(2, lanes, *shape), jnp.int32)
        t0 = time.perf_counter()
        blob = eng.compress(data)
        enc = time.perf_counter() - t0
        ok = bool(jnp.array_equal(eng.decompress(blob, 2, shape), data))
        wire = eng.compress_stream(data, block_symbols=1)
        ok2 = bool(jnp.array_equal(eng.decompress_stream(wire, shape),
                                   data))
        print(f"{shape[0]}x{shape[1]}: one-shot {len(blob) * 8 / data.size:.2f} "
              f"wire bits/dim (untrained), lossless={ok}; "
              f"stream lossless={ok2}; encode {enc:.2f}s")


if __name__ == "__main__":
    main()
