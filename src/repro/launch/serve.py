"""Serving/compression launcher.

``python -m repro.launch.serve --arch qwen2-0.5b --mode compress``
trains nothing: it builds a (reduced) model, runs the compression
service end to end on a synthetic corpus and reports rates; ``--mode
stream`` runs the chunked BBX2 streaming path (and verifies a
mid-stream resume); ``--mode serve-many`` drives the dynamic batcher
over many requests of different lengths; ``--mode generate`` runs
batched greedy decoding; ``--mode hvae`` serves the hierarchical image
codec through ``serve.CodecEngine`` at several image shapes from one
parameter set; ``--mode gateway`` drives concurrent ragged clients
through the async ``repro.gateway`` tier (admission, backpressure,
recovery); ``--mode cluster`` spreads clients and a BBX3 corpus across
a multi-host ``GatewayCluster`` (each host on its own event loop,
engines attached from ``EngineHandle`` recipes), kills one host
mid-stream, and verifies the failed-over wires stay byte-identical.
The same Engine runs on pod meshes via the dryrun-validated
decode/prefill programs.

Shutdown is clean: open ``StreamEncoder``s register themselves, and a
SIGINT mid-stream flushes each one (ragged tail + valid BBX2 trailer)
before the process exits, so an interrupted run never leaves a
truncated wire.
"""

from __future__ import annotations

import argparse
import dataclasses
import signal
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs, stream
from repro.configs import base as cfg_base
from repro.data import tokens as tok_data
from repro.models import transformer
from repro.serve.engine import Engine

# Open streaming encoders, flushed on SIGINT so every wire ends in a
# valid BBX2 trailer (satellite of the gateway PR; see module docstring).
_OPEN_ENCODERS: Dict[str, stream.StreamEncoder] = {}


def flush_open_encoders() -> Dict[str, bytes]:
    """Flush every registered open ``StreamEncoder`` (ragged tail +
    trailer) and deregister it; returns ``{name: tail_bytes}``. Safe to
    call twice - a flushed encoder is removed, and ``flush`` on an
    already-finished encoder is a no-op anyway."""
    tails: Dict[str, bytes] = {}
    for name in list(_OPEN_ENCODERS):
        tails[name] = _OPEN_ENCODERS.pop(name).flush()
    return tails


def install_sigint_flush():
    """Install a SIGINT handler that flushes open encoders before
    re-raising ``KeyboardInterrupt``. Returns the handler (tests call
    it directly). The previous handler is restored after one firing."""
    prev = signal.getsignal(signal.SIGINT)

    def handler(signum=signal.SIGINT, frame=None):
        tails = flush_open_encoders()
        if tails:
            total = sum(len(t) for t in tails.values())
            print(f"\nSIGINT: flushed {len(tails)} open stream(s) to "
                  f"valid trailers (+{total} bytes)")
        signal.signal(signal.SIGINT, prev)
        raise KeyboardInterrupt

    signal.signal(signal.SIGINT, handler)
    return handler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--mode", default="compress",
                    choices=["compress", "stream", "serve-many",
                             "generate", "hvae", "gateway", "cluster"])
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--block-symbols", type=int, default=16)
    ap.add_argument("--requests", type=int, default=12,
                    help="number of client streams for --mode serve-many")
    ap.add_argument("--hosts", type=int, default=2,
                    help="gateway hosts for --mode cluster")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compile", action="store_true",
                    help="route codecs through codecs.compile (fused "
                         "kernel programs; byte-identical wire)")
    ap.add_argument("--kv-dtype", default="bfloat16")
    args = ap.parse_args()

    if args.mode == "hvae":
        return main_hvae(args)
    if args.mode == "gateway":
        return main_gateway(args)
    if args.mode == "cluster":
        return main_cluster(args)

    cfg = dataclasses.replace(
        cfg_base.reduced(cfg_base.get(args.arch)),
        vocab=256, kv_cache_dtype=args.kv_dtype)
    params = transformer.init(jax.random.PRNGKey(args.seed), cfg)
    eng = Engine(params, cfg, max_len=args.tokens, jit=False)

    if args.mode == "generate":
        prompt = {"tokens": jnp.asarray(
            np.random.default_rng(args.seed).integers(
                0, cfg.vocab, (args.lanes, 8)), jnp.int32)}
        t0 = time.perf_counter()
        out = eng.generate(prompt, args.tokens)
        dt = time.perf_counter() - t0
        print(f"generated {out.shape} in {dt:.2f}s "
              f"({out.size / dt:.1f} tok/s, untrained weights)")
        return

    corpus, entropy = tok_data.markov_corpus(
        50_000, vocab=cfg.vocab, seed=args.seed)
    rng = np.random.default_rng(args.seed + 1)

    if args.mode == "serve-many":
        reqs = []
        for _ in range(args.requests):
            n = int(rng.integers(4, args.tokens + 1))
            s = int(rng.integers(0, len(corpus) - n))
            reqs.append(jnp.asarray(corpus[s:s + n], jnp.int32))
        t0 = time.perf_counter()
        blobs = eng.serve_many(reqs, max_lanes=args.lanes,
                               block_symbols=args.block_symbols)
        enc = time.perf_counter() - t0
        outs = eng.decompress_many(blobs, max_lanes=args.lanes,
                                   block_symbols=args.block_symbols)
        ok = all(bool(jnp.array_equal(o, r)) for o, r in zip(outs, reqs))
        total = sum(int(r.size) for r in reqs)
        bits = sum(len(b) * 8 for b in blobs)
        print(f"served {len(reqs)} streams ({total} tokens) through "
              f"{args.lanes} lanes in {enc:.2f}s; {bits / total:.3f} "
              f"wire bits/tok (untrained model: ~log2 V); lossless={ok}")
        return

    starts = rng.integers(0, len(corpus) - args.tokens, args.lanes)
    toks = jnp.asarray(
        np.stack([corpus[s:s + args.tokens] for s in starts]), jnp.int32)

    if args.mode == "stream":
        install_sigint_flush()
        t0 = time.perf_counter()
        # Built explicitly (same parameters as Engine.compress_stream)
        # and registered, so a SIGINT mid-write flushes to a valid
        # trailer instead of leaving a truncated wire.
        encoder = stream.StreamEncoder(
            block_codec_fn=eng._block_codec_fn(), lanes=args.lanes,
            block_symbols=args.block_symbols, seed=None,
            capacity=int(args.block_symbols * 1.5) + 8)
        _OPEN_ENCODERS["stream"] = encoder
        blob = encoder.write(toks.T) + encoder.flush()
        _OPEN_ENCODERS.pop("stream", None)
        enc = time.perf_counter() - t0
        header, offsets, trailer = stream.format.scan(blob)
        out = eng.decompress_stream(blob)
        ok = bool(jnp.array_equal(out, toks))
        print(f"corpus entropy {entropy:.3f} bits/tok; streamed "
              f"{len(blob) * 8 / toks.size:.3f} wire bits/tok over "
              f"{len(offsets)} blocks; lossless={ok}; encode {enc:.2f}s")
        if len(offsets) > 1:
            tail = stream.decode_from_offset(
                None, blob, offsets[1],
                block_codec_fn=eng._block_codec_fn())
            ok2 = bool(jnp.array_equal(
                tail.T, toks[:, args.block_symbols:]))
            print(f"mid-stream resume from block 1 "
                  f"(byte {offsets[1]}): lossless={ok2}")
        return

    t0 = time.perf_counter()
    blob = eng.compress(toks)
    enc = time.perf_counter() - t0
    bits = codecs.blob_info(blob)["payload_bits"]
    out = eng.decompress(blob, args.tokens)
    ok = bool(jnp.array_equal(out, toks))
    print(f"corpus entropy {entropy:.3f} bits/tok; achieved "
          f"{bits / toks.size:.3f} bits/tok (untrained model: ~log2 V); "
          f"lossless={ok}; encode {enc:.2f}s")


def main_gateway(args):
    """Async serving demo: ragged concurrent clients stream through the
    ``repro.gateway`` admission tier over one ``CodecEngine`` (toy
    uniform family - the point here is scheduling, not the model).
    SIGINT flushes open sessions to valid trailers before exit."""
    import asyncio

    from repro import gateway as gw_mod
    from repro.serve import CodecEngine

    def family(shape):
        n = int(np.prod(shape))
        return codecs.Shaped(
            codecs.Repeat(lambda d: codecs.Uniform(8), n), tuple(shape))

    shape, lanes = (4, 4), args.lanes
    eng = CodecEngine(family, seed=args.seed, init_chunks=0,
                      max_inflight_lanes=2 * lanes,
                      compile=args.compile)
    rng = np.random.default_rng(args.seed)

    async def client(gw, i: int):
        n_blocks = int(rng.integers(2, 6))
        data = jnp.asarray(rng.integers(
            0, 256, (n_blocks * args.block_symbols, lanes, *shape)),
            jnp.int32)
        sess = await gw.open_stream(shape, lanes=lanes,
                                    session_id=f"client-{i}",
                                    tenant=f"tenant-{i % 2}",
                                    block_symbols=args.block_symbols)
        wire = await sess.write(data)
        wire += await sess.close()
        out = eng.decompress_stream(wire, shape)
        if not bool(jnp.array_equal(out, data)):
            raise SystemExit(f"client {i}: lossy round trip")
        return len(wire), int(data.size)

    async def run():
        async with gw_mod.Gateway(eng, queue_depth=args.requests) as gw:
            loop = asyncio.get_running_loop()
            stop = asyncio.Event()
            try:
                loop.add_signal_handler(signal.SIGINT, stop.set)
            except NotImplementedError:
                pass   # non-Unix event loop
            work = asyncio.gather(*(client(gw, i)
                                    for i in range(args.requests)))
            stopper = asyncio.create_task(stop.wait())
            done, _ = await asyncio.wait(
                {work, stopper}, return_when=asyncio.FIRST_COMPLETED)
            if work in done:
                stopper.cancel()
                sizes = work.result()
                wire = sum(s for s, _ in sizes)
                syms = sum(n for _, n in sizes)
                print(f"gateway served {len(sizes)} clients: "
                      f"{wire * 8 / syms:.3f} wire bits/dim, "
                      f"stats={gw.stats()}")
            else:
                work.cancel()
                tails = await gw.stop()
                print(f"SIGINT: flushed {len(tails)} open session(s) "
                      "to valid trailers")

    asyncio.run(run())


def main_cluster(args):
    """Multi-host serving demo: ``--hosts`` gateways, each with its own
    event loop and an engine attached from an ``EngineHandle`` recipe.
    Ragged clients and a sharded BBX3 corpus spread across the hosts,
    one host is killed mid-stream, and every failed-over wire is
    checked byte-identical to the synchronous single-host path."""
    import asyncio
    import tempfile

    from repro import shard_codec
    from repro.gateway import GatewayCluster, TenantQuota
    from repro.serve import CodecEngine, EngineHandle, \
        register_engine_factory

    def family(shape):
        n = int(np.prod(shape))
        return codecs.Shaped(
            codecs.Repeat(lambda d: codecs.Uniform(8), n), tuple(shape))

    register_engine_factory(
        "launch-cluster-uniform",
        lambda **kw: CodecEngine(family, **kw), overwrite=True)
    shape, lanes = (4, 4), args.lanes
    handle = EngineHandle("launch-cluster-uniform",
                          {"seed": args.seed, "init_chunks": 0,
                           "max_inflight_lanes": 8 * lanes,
                           "compile": args.compile})
    rng = np.random.default_rng(args.seed)
    ref_eng = CodecEngine(family, seed=args.seed, init_chunks=0,
                          max_inflight_lanes=8 * lanes,
                          compile=args.compile)
    corpora = [jnp.asarray(rng.integers(
        0, 256, (int(rng.integers(2, 5)) * args.block_symbols, lanes,
                 *shape)), jnp.int32) for _ in range(args.requests)]
    refs = [ref_eng.compress_stream(d, block_symbols=args.block_symbols)
            for d in corpora]
    ds = jnp.asarray(rng.integers(
        0, 256, (2 * args.block_symbols, 2 * lanes, *shape)), jnp.int32)
    ds_ref = shard_codec.compress_dataset(
        family(shape), ds, n_shards=2, seed=args.seed, init_chunks=0,
        block_symbols=args.block_symbols)

    async def client(cluster, i: int):
        data = corpora[i]
        sess = await cluster.open_stream(
            shape, lanes=lanes, session_id=f"client-{i}",
            tenant=f"tenant-{i % 2}",
            block_symbols=args.block_symbols)
        wire = b""
        for s in range(0, int(data.shape[0]), args.block_symbols):
            wire += await sess.write(data[s:s + args.block_symbols])
            if i == 0 and s == 0:
                # One deterministic mid-stream kill: whichever host
                # serves client 0 dies after its first block.
                await cluster.kill_host(sess.host)
        wire += await sess.close()
        if wire != refs[i]:
            raise SystemExit(f"client {i}: cluster wire diverged")
        return len(wire), int(data.size), sess.failovers

    async def run(tmp: str):
        cluster = GatewayCluster(
            [handle] * args.hosts, loop_per_host=True,
            recovery_root=tmp, queue_depth=args.requests,
            default_quota=TenantQuota(max_lanes=8 * lanes,
                                      max_queued=args.requests))
        async with cluster:
            results = await asyncio.gather(
                *(client(cluster, i) for i in range(args.requests)))
            blob = await cluster.compress_corpus(
                ds, n_shards=2, seed=args.seed, init_chunks=0,
                block_symbols=args.block_symbols, tag="launch-corpus")
            if blob != ds_ref:
                raise SystemExit("cluster corpus wire diverged")
            out = await cluster.decompress_corpus(blob, shape)
            if not bool(jnp.array_equal(out, ds)):
                raise SystemExit("cluster corpus round trip lossy")
            st = cluster.stats()
        wire = sum(w for w, _, _ in results)
        syms = sum(n for _, n, _ in results)
        fails = sum(f for _, _, f in results)
        print(f"cluster served {len(results)} clients + 1 corpus over "
              f"{args.hosts} hosts ({len(st['healthy_hosts'])} "
              f"survived a mid-stream kill): {wire * 8 / syms:.3f} "
              f"wire bits/dim, {fails} stream failover(s), all wires "
              "byte-identical to single-host")
        print(f"stats={st}")
        if st["cluster_held_lanes"] or st["inflight_lanes"]:
            raise SystemExit("lane leak after drain")

    with tempfile.TemporaryDirectory() as tmp:
        asyncio.run(run(tmp))


def main_hvae(args):
    """Image-codec service demo: one fully convolutional model, several
    request shapes, one-shot + streaming wire paths, all lossless."""
    from repro.configs import hvae_img
    from repro.data import images as img_data
    from repro.models import hvae
    from repro.serve.engine import CodecEngine

    cfg = hvae_img.get("hvae-small2")
    params = hvae.init(jax.random.PRNGKey(args.seed), cfg)
    eng = CodecEngine(hvae.codec_family(params, cfg), seed=args.seed,
                      compile=args.compile)
    lanes = args.lanes
    for shape in ((16, 16), (20, 12)):
        raw = img_data.load("test", 2 * lanes, args.seed, hw=shape)
        data = jnp.asarray(raw.reshape(2, lanes, *shape), jnp.int32)
        t0 = time.perf_counter()
        blob = eng.compress(data)
        enc = time.perf_counter() - t0
        ok = bool(jnp.array_equal(eng.decompress(blob, 2, shape), data))
        wire = eng.compress_stream(data, block_symbols=1)
        ok2 = bool(jnp.array_equal(eng.decompress_stream(wire, shape),
                                   data))
        print(f"{shape[0]}x{shape[1]}: one-shot {len(blob) * 8 / data.size:.2f} "
              f"wire bits/dim (untrained), lossless={ok}; "
              f"stream lossless={ok2}; encode {enc:.2f}s")


if __name__ == "__main__":
    main()
