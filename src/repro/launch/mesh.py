"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state - required by the dry-run, whose
``XLA_FLAGS`` must be set before any jax initialization.
"""

from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` across jax versions: ``axis_types`` /
    ``jax.sharding.AxisType`` only exist in newer releases (the default
    there - Auto - matches the older behaviour)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production meshes: 16x16 single pod (256 chips),
    2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh():
    """All local devices on a single 'data' axis (tests, examples)."""
    n = len(jax.devices())
    return make_mesh_compat((n,), ("data",))
