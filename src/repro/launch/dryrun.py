import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the real jit program (train_step / prefill_step /
serve_step) with full production shardings, AOT-lowers it against
ShapeDtypeStruct inputs (no allocation), compiles under the 512-host-device
emulation, and records:

  * memory_analysis()   - bytes/device: proves the cell fits a v5e (16 GB)
  * cost_analysis()     - per-device HLO FLOPs + bytes for the roofline
  * collective bytes    - parsed from the optimized HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute)

Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json; the
roofline report (launch/roofline.py) and EXPERIMENTS.md read from there.

Usage:
  python -m repro.launch.dryrun                     # full sweep, both meshes
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k \
      --mesh single                                 # one cell
"""

import argparse
import functools
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as cfg_base
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.sharding import api as shard_api
from repro.sharding import policies
from repro.train import trainer

OUT_ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "experiments", "dryrun")

COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]))\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "c64": 8, "c128": 16}


def _tensor_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


def parse_collectives(hlo_text: str):
    """Sum per-device link bytes by collective kind from optimized HLO.

    Ring-algorithm accounting per device: all-reduce moves ~2*S*(g-1)/g,
    all-gather/reduce-scatter/all-to-all ~S*(g-1)/g, collective-permute S,
    where S is the (per-device) tensor size and g the replica-group size.
    """
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0, "count": 0}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        size = _tensor_bytes(m.group(1))
        kind = m.group(2).lower()
        gm = GROUPS_RE.search(line)
        g = len(gm.group(1).split(",")) if gm else 2
        frac = (g - 1) / g if g > 1 else 0.0
        if kind == "all-reduce":
            out[kind] += 2.0 * size * frac
        elif kind == "collective-permute":
            out[kind] += float(size)
        else:
            out[kind] += size * frac
        out["count"] += 1
    out["total_bytes"] = sum(v for k, v in out.items()
                             if k not in ("count",))
    return out


def _named(mesh, spec_tree, shapes_tree=None):
    return policies.to_named(mesh, spec_tree, shapes_tree)


def _abstract(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _batch_structs(cfg, cell, mesh):
    shapes = cfg_base.input_shapes(cfg, cell)
    specs = {k: P(policies.FSDP, *(None,) * (len(shp) - 1))
             for k, (shp, _) in shapes.items()}
    structs = {
        k: jax.ShapeDtypeStruct(shp, dt)
        for k, (shp, dt) in shapes.items()}
    return structs, _named(mesh, specs, structs)


def build_train(cfg, cell, mesh):
    opt = trainer.make_optimizer(cfg)
    state_shapes = jax.eval_shape(
        functools.partial(trainer.init_state, jax.random.PRNGKey(0), cfg,
                          opt))
    pspec = policies.param_pspecs(state_shapes.params)
    ospec = policies.opt_state_pspecs(state_shapes.opt_state,
                                      state_shapes.params, pspec)
    state_spec = trainer.TrainState(step=P(), params=pspec,
                                    opt_state=ospec, compress_state=None)
    batch_structs, batch_sh = _batch_structs(cfg, cell, mesh)
    state_sh = _named(mesh, state_spec, state_shapes)
    regather = None
    if cfg.fsdp_regather_once and cfg.grad_accum > 1:
        regather = _named(mesh, policies.drop_fsdp(pspec),
                          state_shapes.params)
    step_fn = trainer.make_train_step(cfg, opt, accum=cfg.grad_accum,
                                      regather_shardings=regather)
    jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=0)
    return jitted, (state_shapes, batch_structs)


def build_prefill(cfg, cell, mesh):
    batch_structs, batch_sh = _batch_structs(cfg, cell, mesh)
    params_shapes = jax.eval_shape(
        functools.partial(transformer.init, jax.random.PRNGKey(0), cfg))
    pspec = policies.param_pspecs(params_shapes)
    params_sh = _named(mesh, pspec, params_shapes)
    seq = (batch_structs["tokens"].shape[1])
    fn = functools.partial(transformer.prefill, cfg=cfg, max_len=seq)
    wrapped = lambda params, batch: fn(params, batch=batch)
    # Shard the *output* session state (the filled KV cache dominates
    # prefill memory: batch on data, cache sequence on model).
    out_shapes = jax.eval_shape(wrapped, params_shapes, batch_structs)
    logits_shapes, state_shapes = out_shapes
    sspec = policies.decode_state_pspecs(state_shapes)
    state_sh = _named(mesh, sspec, state_shapes)
    jitted = jax.jit(wrapped, in_shardings=(params_sh, batch_sh),
                     out_shardings=(None, state_sh))
    return jitted, (params_shapes, batch_structs)


def build_decode(cfg, cell, mesh):
    b, t = cell.global_batch, cell.seq_len
    params_shapes = jax.eval_shape(
        functools.partial(transformer.init, jax.random.PRNGKey(0), cfg))
    pspec = policies.param_pspecs(params_shapes)
    params_sh = _named(mesh, pspec, params_shapes)

    if cfg.enc_dec:
        enc_struct = jax.ShapeDtypeStruct((b, t // 2, cfg.d_model),
                                          jnp.bfloat16)
        state_shapes = jax.eval_shape(
            lambda enc: transformer.init_decode_state(cfg, b, t,
                                                      enc_out=enc),
            enc_struct)
    else:
        state_shapes = jax.eval_shape(
            functools.partial(transformer.init_decode_state, cfg, b, t))
    sspec = policies.decode_state_pspecs(state_shapes)
    state_sh = _named(mesh, sspec, state_shapes)
    tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tok_sh = _named(mesh, P(policies.FSDP, None), tok)
    fn = functools.partial(transformer.decode_step, cfg=cfg)
    jitted = jax.jit(lambda params, tok, state: fn(params, tok=tok,
                                                   state=state),
                     in_shardings=(params_sh, tok_sh, state_sh),
                     out_shardings=(None, state_sh),
                     donate_argnums=2)
    return jitted, (params_shapes, tok, state_shapes)


def run_cell(arch: str, shape: str, multi_pod: bool, overrides=None):
    import dataclasses
    cfg = cfg_base.get(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cell = cfg_base.SHAPES[shape]
    skip = cfg_base.cell_is_skipped(cfg, cell)
    rec = {"arch": arch, "shape": shape,
           "mesh": "multi" if multi_pod else "single",
           "kind": cell.kind}
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = {"seq": "model"} if cell.kind in ("train", "prefill") else {}
    t0 = time.time()
    with shard_api.use_mesh(mesh, rules):
        if cell.kind == "train":
            jitted, args = build_train(cfg, cell, mesh)
        elif cell.kind == "prefill":
            jitted, args = build_prefill(cfg, cell, mesh)
        else:
            jitted, args = build_decode(cfg, cell, mesh)
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "peak_device_bytes": int(mem.argument_size_in_bytes
                                 + mem.output_size_in_bytes
                                 + mem.temp_size_in_bytes
                                 - mem.alias_size_in_bytes),
    }
    cost = compiled.cost_analysis()
    rec["cost"] = {k: float(v) for k, v in cost.items()
                   if k in ("flops", "bytes accessed")
                   or k.startswith("bytes accessed")}
    rec["collectives"] = parse_collectives(compiled.as_text())
    rec["n_params"] = int(cfg.n_params())
    rec["active_params"] = int(cfg.active_params())
    rec["status"] = "ok"
    return rec


def _is_struct(x):
    return isinstance(x, jax.ShapeDtypeStruct)


def _identity(x):
    return x


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=OUT_ROOT)
    ap.add_argument("--remat", default=None,
                    help="override cfg.remat (perf experiments)")
    ap.add_argument("--loss-chunk", type=int, default=None)
    ap.add_argument("--param-dtype", default=None)
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--regather", default=None, choices=["on", "off"])
    ap.add_argument("--kv-dtype", default=None)
    args = ap.parse_args()
    overrides = {}
    if args.remat:
        overrides["remat"] = args.remat
    if args.loss_chunk:
        overrides["loss_chunk"] = args.loss_chunk
    if args.param_dtype:
        overrides["param_dtype"] = args.param_dtype
    if args.grad_accum:
        overrides["grad_accum"] = args.grad_accum
    if args.regather:
        overrides["fsdp_regather_once"] = args.regather == "on"
    if args.kv_dtype:
        overrides["kv_cache_dtype"] = args.kv_dtype

    archs = [args.arch] if args.arch else sorted(cfg_base.all_archs())
    shapes = [args.shape] if args.shape else list(cfg_base.SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for multi in meshes:
        mesh_name = "multi" if multi else "single"
        for arch in archs:
            for shape in shapes:
                path = os.path.join(args.out,
                                    f"{mesh_name}__{arch}__{shape}.json")
                try:
                    rec = run_cell(arch, shape, multi, overrides)
                except Exception as e:  # record and continue the sweep
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-2000:]}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    gb = rec["memory"]["peak_device_bytes"] / 2 ** 30
                    extra = (f" mem/dev={gb:.2f}GiB "
                             f"flops/dev={rec['cost'].get('flops', 0):.3g} "
                             f"coll={rec['collectives']['total_bytes']:.3g}B"
                             f" compile={rec.get('compile_s')}s")
                elif status == "error":
                    extra = " " + rec["error"][:120]
                elif status == "skipped":
                    extra = " (" + rec["reason"][:60] + ")"
                print(f"[{mesh_name}] {arch} x {shape}: {status}{extra}",
                      flush=True)
    print(f"done; failures={failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
