"""Dataset compression launcher - the Table-1 reproduction CLI.

``python -m repro.launch.compress`` trains the paper's VAE (or the
hierarchical HVAE) on synthetic MNIST, then streams the full test set
through the lane-sharded BB-ANS pipeline (``repro.shard_codec``):
the lane axis splits into per-device shards, every shard encodes its
own independently-decodable BBX2 segment, and the segments gather
into one BBX3 corpus blob. It finishes with the paper's Table-1
comparison - achieved BB-ANS bits/dim against gzip, bz2, lzma and
(real or proxy) per-image PNG - plus a lossless full-corpus decode
check.

    PYTHONPATH=src python -m repro.launch.compress \
        --arch vae-bernoulli --images 512 --train-steps 400

``--arch vae-beta_binomial`` runs the paper's full-range (0..255)
Table-1 model; ``--arch hvae-small2`` the 2-level convolutional
Bit-Swap codec. ``--shards`` defaults to every local device (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to exercise
the multi-device path on CPU - wire bytes are identical either way;
docs/SCALING.md). The benchmark-suite twin of this launcher is
``benchmarks/dataset_rate.py``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import shard_codec
from repro.data import baselines as baseline_lib
from repro.data import synthetic_mnist
from repro.models import vae as vae_lib
from repro.optim import adamw

ARCHS = ("vae-bernoulli", "vae-beta_binomial", "hvae-small2")


def train_dataset_model(arch: str, *, steps: int, seed: int = 0,
                        n_train: int = 8000, batch: int = 128,
                        lr: float = 1e-3):
    """Train the model behind ``--arch``; returns
    ``(per-datapoint codec factory, binary?, elbo bits/dim)``.

    The factory takes no arguments for the dense VAEs (fixed 784-dim
    input) and builds the Bit-Swap codec at 28x28 for the HVAE.
    """
    if arch.startswith("vae-"):
        cfg = vae_lib.paper_config(arch.split("-", 1)[1])
        binary = cfg.likelihood == "bernoulli"
        train_imgs, _ = synthetic_mnist.load("train", n_train, seed)
        if binary:
            train_imgs = synthetic_mnist.binarize(train_imgs, seed)
        test_imgs, _ = synthetic_mnist.load("test", 1024, seed)
        if binary:
            test_imgs = synthetic_mnist.binarize(test_imgs, seed + 1)
        params = vae_lib.init(jax.random.PRNGKey(seed), cfg)
        opt = adamw.AdamW(learning_rate=adamw.cosine_lr(lr, 100, steps))
        state = opt.init(params)

        @jax.jit
        def step(params, state, key, imgs):
            loss, grads = jax.value_and_grad(vae_lib.loss)(
                params, cfg, key, imgs)
            params, state = opt.update(grads, state, params)
            return params, state, loss

        rng = np.random.default_rng(seed)
        key = jax.random.PRNGKey(seed + 1)
        for _ in range(steps):
            idx = rng.integers(0, len(train_imgs), batch)
            key, sub = jax.random.split(key)
            params, state, _ = step(
                params, state, sub, jnp.asarray(train_imgs[idx],
                                                jnp.int32))
        keys = jax.random.split(jax.random.PRNGKey(seed + 2), 4)
        elbo = float(np.mean([float(vae_lib.elbo_bits_per_dim(
            params, cfg, k, jnp.asarray(test_imgs, jnp.int32)))
            for k in keys]))
        return (lambda: vae_lib.make_bb_codec(params, cfg)), binary, elbo

    if arch == "hvae-small2":
        from repro.configs import hvae_img
        from repro.data import images as img_data
        from repro.models import hvae as hvae_lib
        cfg = hvae_img.SMALL2
        binary = cfg.likelihood == "bernoulli"
        train_imgs = img_data.load("train", n_train // 2, seed,
                                   hw=(28, 28), binarized=binary)
        test_imgs = img_data.load("test", 256, seed + 1, hw=(28, 28),
                                  binarized=binary)
        params = hvae_lib.init(jax.random.PRNGKey(seed), cfg)
        opt = adamw.AdamW(learning_rate=adamw.cosine_lr(2e-3, 100, steps))
        state = opt.init(params)

        @jax.jit
        def hstep(params, state, key, imgs):
            loss, grads = jax.value_and_grad(hvae_lib.loss)(
                params, cfg, key, imgs)
            params, state = opt.update(grads, state, params)
            return params, state, loss

        rng = np.random.default_rng(seed)
        key = jax.random.PRNGKey(seed + 1)
        for _ in range(steps):
            idx = rng.integers(0, len(train_imgs), 64)
            key, sub = jax.random.split(key)
            params, state, _ = hstep(
                params, state, sub, jnp.asarray(train_imgs[idx],
                                                jnp.int32))
        keys = jax.random.split(jax.random.PRNGKey(seed + 2), 4)
        elbo = float(np.mean([float(hvae_lib.elbo_bits_per_dim(
            params, cfg, k, jnp.asarray(test_imgs, jnp.int32)))
            for k in keys]))
        return (lambda: hvae_lib.make_bitswap_codec(
            params, cfg, (28, 28))), binary, elbo

    raise ValueError(f"unknown --arch {arch!r}; choose from {ARCHS}")


def load_corpus(arch: str, n_images: int, lanes: int,
                seed: int = 123) -> tuple:
    """The benchmark corpus: ``(images uint8 [n, 784], data [n_chain,
    lanes, ...] as the codec expects, binary?)``."""
    binary = arch != "vae-beta_binomial"
    imgs, _ = synthetic_mnist.load("test", n_images, seed)
    if binary:
        imgs = synthetic_mnist.binarize(imgs, seed)
    if arch == "hvae-small2":
        data = jnp.asarray(imgs.reshape(-1, lanes, 28, 28), jnp.int32)
    else:
        data = jnp.asarray(imgs.reshape(-1, lanes, 784), jnp.int32)
    return imgs, data, binary


def compress_corpus(codec, data, *, n_shards: int, block_symbols: int,
                    seed: int, init_chunks: int = 32,
                    compile: bool = True) -> bytes:
    """``shard_codec.compress_dataset`` with the CLI's defaults."""
    return shard_codec.compress_dataset(
        codec, data, n_shards=n_shards, block_symbols=block_symbols,
        seed=seed, init_chunks=init_chunks, compile=compile)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vae-bernoulli", choices=ARCHS)
    ap.add_argument("--images", type=int, default=512,
                    help="test images to compress (the 'full set')")
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--lanes", type=int, default=8,
                    help="total ANS lanes (must divide by --shards)")
    ap.add_argument("--shards", type=int, default=0,
                    help="lane shards / BBX3 segments (0 = one per "
                         "local device)")
    ap.add_argument("--block-symbols", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-compile", action="store_true",
                    help="skip codecs.compile (slow interpreted path)")
    ap.add_argument("--skip-decode", action="store_true",
                    help="skip the lossless full-decode verification")
    args = ap.parse_args()

    n_shards = args.shards or len(jax.devices())
    if args.lanes % n_shards:
        raise SystemExit(f"--lanes {args.lanes} must divide into "
                         f"{n_shards} shards")
    if args.images % args.lanes:
        raise SystemExit(f"--images {args.images} must be a multiple "
                         f"of --lanes {args.lanes}")
    print(f"devices={len(jax.devices())} shards={n_shards} "
          f"lanes={args.lanes} arch={args.arch}")

    t0 = time.time()
    make_codec, binary, elbo = train_dataset_model(
        args.arch, steps=args.train_steps, seed=args.seed)
    print(f"trained in {time.time() - t0:.0f}s; "
          f"test -ELBO = {elbo:.4f} bits/dim")

    imgs, data, _ = load_corpus(args.arch, args.images, args.lanes)
    codec = make_codec()
    t0 = time.time()
    blob = compress_corpus(codec, data, n_shards=n_shards,
                           block_symbols=args.block_symbols,
                           seed=args.seed, compile=not args.no_compile)
    t_enc = time.time() - t0
    bpd = len(blob) * 8 / imgs.size
    info = shard_codec.corpus_info(blob)
    print(f"encoded {args.images} images in {t_enc:.1f}s "
          f"({imgs.size / t_enc / 1e6:.2f} Mdim/s): "
          f"{len(blob)} wire bytes over {info['n_shards']} shards")

    if not args.skip_decode:
        t0 = time.time()
        out = shard_codec.decompress_dataset(
            codec, blob, compile=not args.no_compile)
        ok = bool(jnp.array_equal(out, data))
        print(f"decoded in {time.time() - t0:.1f}s; lossless={ok}")
        if not ok:
            raise SystemExit("decode mismatch - corrupt corpus")

    rates = baseline_lib.baseline_rates(imgs, binary, with_png=True)
    print("\nTable 1 (bits/dim, lower is better; "
          f"{args.images} synthetic-MNIST images"
          f"{', binarized' if binary else ''}):")
    rows = [("BB-ANS (sharded, wire)", bpd),
            ("-ELBO bound", elbo)]
    rows += sorted(rates.items(), key=lambda kv: kv[1])
    for name, rate in rows:
        marker = "  <- this work" if name.startswith("BB-ANS") else ""
        print(f"  {name:24s} {rate:.4f}{marker}")
    worse = [k for k in ("gzip", "bz2") if rates[k] <= bpd]
    if worse:
        raise SystemExit(f"BB-ANS did not beat {worse} - "
                         "train longer (--train-steps)")
    print(f"\nBB-ANS beats gzip by "
          f"{(1 - bpd / rates['gzip']) * 100:.1f}% and bz2 by "
          f"{(1 - bpd / rates['bz2']) * 100:.1f}%")


if __name__ == "__main__":
    main()
