"""Codec roofline: achieved vs peak wire MB/s per device.

Reads a ``BENCH_codec_compile.json`` (fresh run or the committed
baseline) and, for each fixed-point workload row, sets the measured
``*_mb_per_s_per_device`` against two analytic ceilings:

  * **compute**: integer MACs + coder ops per wire byte, divided into
    the platform's peak integer op rate. The MAC counts come from the
    same model configs the bench constructs (``models.vae.paper_config``
    and the HVAE-L2 bench config), accounted layer by layer below.
  * **memory**: bytes the fused program must move per wire byte
    (weights once per block, activations twice per layer, the ANS
    stack stream), divided into peak memory bandwidth.

The roofline bound is ``min(compute, memory)`` and the report gives the
achieved fraction of it - the number that says whether the fused
one-program coder is worth more kernel work or is already at the
platform ceiling.

Platform peaks are nominal datasheet numbers (``--platform`` to
override the auto-pick); on CPU the point is the *shape* of the gap,
not its third digit.

Usage::

    python -m repro.launch.roofline [--bench BENCH_codec_compile.json]
                                    [--platform cpu|tpu-v5e] [--json out]

Runnable example (docs/PERF.md): ``report(load_rows(path))`` returns
the table as a list of dicts.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Dict, List, Optional

#: nominal per-device peaks: (integer ops/s, memory bytes/s).
#: cpu: ~8 cores x 3 GHz x 8-lane int32 SIMD x 2 ops (mul+add);
#: tpu-v5e: datasheet 394 TOPS int8, 819 GB/s HBM.
PEAKS: Dict[str, tuple] = {
    "cpu": (0.4e12, 40e9),
    "tpu-v5e": (394e12, 819e9),
}

#: integer ops a lane spends per coded symbol in the fused coder
#: (bucketize + start/freq lookup + renorm + stack write, amortized).
CODER_OPS_PER_SYMBOL = 32


def _conv_macs(h: int, w: int, cin: int, cout: int, k: int = 3) -> float:
    return float(h * w * k * k * cin * cout)


def _stage_macs(h: int, w: int, cin: int, ch: int, cout: int,
                n_res: int) -> float:
    """conv in -> n_res resblocks (2 convs each) -> conv head."""
    return (_conv_macs(h, w, cin, ch)
            + n_res * 2 * _conv_macs(h, w, ch, ch)
            + _conv_macs(h, w, ch, cout))


@dataclasses.dataclass(frozen=True)
class WorkloadModel:
    """Analytic per-datapoint terms for one fixed-point bench workload."""

    macs: float            # integer MACs per datapoint (one direction)
    symbols: float         # coded symbols per datapoint
    weight_bytes: float    # int32 weight footprint, read once per block
    act_bytes: float       # activation bytes touched per datapoint


def vae_terms() -> WorkloadModel:
    """The table2 MNIST VAE at ``models.vae.paper_config`` shapes.

    One coder direction runs both the posterior net (784->100->2x40)
    and the likelihood net (40->100->784).
    """
    from repro.models import vae as vae_lib
    cfg = vae_lib.paper_config("bernoulli")
    d, h, z = cfg.input_dim, cfg.hidden, cfg.latent
    enc = d * h + h * 2 * z
    dec = z * h + h * d
    weights = 4 * (enc + dec)                      # int32 params
    acts = 4 * 2 * (d + h + 2 * z + z + h + d)     # int32, read+write
    return WorkloadModel(macs=float(enc + dec),
                         symbols=float(d + z),
                         weight_bytes=float(weights),
                         act_bytes=float(acts))


def hvae_terms(hw: int = 8) -> WorkloadModel:
    """The HVAE-L2 bench config (ch=8, z_ch=2, n_res=1) on hw x hw.

    One Bit-Swap direction runs q1 (stem + stage), p_obs (stage + up +
    out), q2 and p2 (stages at latent resolution).
    """
    from repro.models import hvae
    cfg = hvae.HVAEConfig(levels=2, ch=8, z_ch=2, n_res=1)
    h2 = hw // 2
    ch, z = cfg.ch, cfg.z_ch
    macs = _conv_macs(h2, h2, cfg.in_channels, ch)            # stem (s2)
    macs += _stage_macs(h2, h2, ch, ch, 2 * z, cfg.n_res)     # q1
    macs += _stage_macs(h2, h2, z, ch, ch, cfg.n_res)         # p_obs
    macs += _conv_macs(h2, h2, ch, ch)                        # up (t2)
    macs += _conv_macs(hw, hw, ch, cfg.in_channels)           # out
    for _ in range(2, cfg.levels + 1):                        # q_l, p_l
        macs += 2 * _stage_macs(h2, h2, z, ch, 2 * z, cfg.n_res)
    n_lat = h2 * h2 * z
    symbols = hw * hw + 2 * cfg.levels * n_lat   # obs + z popped+pushed
    weights = 4.0 * sum(p.size for p in _iter_leaves(hvae.init(
        __import__("jax").random.PRNGKey(0), cfg)))
    acts = 4.0 * 2 * (hw * hw + 8 * h2 * h2 * ch)
    return WorkloadModel(macs=macs, symbols=float(symbols),
                         weight_bytes=weights, act_bytes=acts)


def _iter_leaves(tree):
    import jax
    return jax.tree_util.tree_leaves(tree)


#: bench workload name -> analytic terms builder (hw from the row).
WORKLOADS = {
    "vae-fixedpoint": lambda row: vae_terms(),
    "hvae-l2-fixedpoint": lambda row: hvae_terms(int(row.get("hw", 8))),
}


def load_rows(path: str) -> List[dict]:
    """Fixed-point compiled rows of a ``BENCH_codec_compile.json``."""
    with open(path) as f:
        payload = json.load(f)
    return [r for r in payload.get("rows", [])
            if isinstance(r, dict) and r.get("path") == "compiled"
            and r.get("workload", "").endswith("fixedpoint")]


def resolved_backends(lanes: Optional[int] = None) -> List[dict]:
    """Which backend each hot coder op resolves to right now.

    One row per op in ``kernels.tuning.OPS`` with the full
    :class:`~repro.kernels.dispatch.Decision` (backend, lane tile,
    unroll) under the active env / context / tuning-cache state - the
    selection the bench rows actually ran under.
    """
    from repro.kernels import dispatch, tuning
    rows = []
    for op in tuning.OPS:
        d = dispatch.resolve(op, lanes=lanes)
        rows.append({"op": op, "backend": d.backend,
                     "lane_tile": d.lane_tile, "unroll": d.unroll})
    return rows


def analyse(row: dict, platform: str, hw: Optional[int] = None) -> dict:
    """Roofline terms for one fixed-point bench row."""
    peak_ops, peak_bw = PEAKS[platform]
    name = row["workload"]
    if hw is not None and name.startswith("hvae"):
        row = dict(row, hw=hw)
    terms = WORKLOADS[name](row)
    wire_bytes = row["wire_mb"] * 1e6
    bytes_per_dp = wire_bytes / row["n_datapoints"]
    ops_per_dp = terms.macs * 2 + terms.symbols * CODER_OPS_PER_SYMBOL
    # Weights amortize over the datapoints of one fused block.
    mem_per_dp = (terms.act_bytes + bytes_per_dp
                  + terms.weight_bytes / row["n_datapoints"])
    compute_peak = peak_ops / ops_per_dp * bytes_per_dp / 1e6
    memory_peak = peak_bw / mem_per_dp * bytes_per_dp / 1e6
    bound = min(compute_peak, memory_peak)
    # Backend the bench row was measured under: recorded by newer bench
    # runs; resolved live for older BENCH files (same answer unless the
    # env/cache changed since the run).
    backend = row.get("kernel_backend")
    if backend is None:
        from repro.kernels import dispatch
        backend = dispatch.resolve("push_many").backend
    out = {"workload": name, "platform": platform,
           "kernel_backend": backend,
           "wire_bytes_per_datapoint": bytes_per_dp,
           "int_ops_per_datapoint": ops_per_dp,
           "compute_peak_mb_per_s": compute_peak,
           "memory_peak_mb_per_s": memory_peak,
           "roofline_mb_per_s": bound,
           "dominant": ("compute" if compute_peak <= memory_peak
                        else "memory")}
    for d in ("enc", "dec"):
        achieved = row[f"{d}_mb_per_s_per_device"]
        out[f"{d}_achieved_mb_per_s"] = achieved
        out[f"{d}_fraction_of_roofline"] = achieved / bound
    return out


def report(rows: List[dict], platform: str = "cpu",
           hw: Optional[int] = None) -> List[dict]:
    """Analyse every fixed-point row; returns the printable table."""
    return [analyse(r, platform, hw) for r in rows]


def _default_bench_path() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    repo = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    fresh = os.path.join(repo, "BENCH_codec_compile.json")
    if os.path.exists(fresh):
        return fresh
    return os.path.join(repo, "benchmarks", "baselines",
                        "BENCH_codec_compile.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=None,
                    help="BENCH_codec_compile.json (default: fresh file "
                         "in the repo root, else the committed baseline)")
    ap.add_argument("--platform", default="cpu", choices=sorted(PEAKS))
    ap.add_argument("--hw", type=int, default=None,
                    help="HVAE image side in the bench run (quick=8, "
                         "full=12); default 8")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    rows = load_rows(args.bench or _default_bench_path())
    table = report(rows, args.platform, args.hw)
    print("resolved kernel backends (op -> backend/tile/unroll):")
    for b in resolved_backends():
        print(f"  {b['op']}: {b['backend']} "
              f"(lane_tile={b['lane_tile']}, unroll={b['unroll']})")
    print("| workload | backend | dir | achieved MB/s/dev | "
          "roofline MB/s | fraction | dominant |")
    print("|" + "---|" * 7)
    for r in table:
        for d in ("enc", "dec"):
            print(f"| {r['workload']} | {r['kernel_backend']} | {d} | "
                  f"{r[f'{d}_achieved_mb_per_s']:.3f} | "
                  f"{r['roofline_mb_per_s']:.1f} | "
                  f"{r[f'{d}_fraction_of_roofline']:.2e} | "
                  f"{r['dominant']} |")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(table, f, indent=1)


if __name__ == "__main__":
    main()
