"""Roofline analysis (deliverable g): three terms per (arch x shape).

Two measurement sources, used for what each is reliable for:

  * **Analytic terms** (this module): FLOPs / HBM bytes / collective link
    bytes per device from the config + cell + sharding policy, with the
    standard accounting (6*N*D training FLOPs, flash-attention S^2 terms,
    FSDP gathers ~ P*(dp-1)/dp, TP reduces ~ 2/layer, MoE a2a, decode KV
    sweeps). These set the roofline denominators and the dominant term.
  * **HLO-measured values** (from the dry-run JSONs): `cost_analysis` and
    the collective parse. CAVEAT, verified empirically: XLA:CPU cost
    analysis counts while/scan bodies ONCE, so with scan-over-layers these
    are per-iteration values - useless as absolutes, but *valid for
    relative before/after comparison* in the perf loop (same loop
    structure on both sides). Reported as `hlo_*` columns.

Hardware: TPU v5e - 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Usage: python -m repro.launch.roofline [--mesh single] [--json out.json]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s/link
V5E_HBM_BYTES = 16 * 2 ** 30

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")


def _mesh_dims(mesh: str):
    return (2, 16, 16) if mesh == "multi" else (1, 16, 16)  # pod, dp, tp


def analytic_terms(cfg, cell, mesh: str):
    """Per-device (flops, hbm_bytes, collective_bytes) for one step."""
    pod, dp, tp = _mesh_dims(mesh)
    chips = pod * dp * tp
    ddp = pod * dp                      # data-parallel degree
    n_act = cfg.active_params()
    pbytes = 4 if cfg.param_dtype == "float32" else 2
    p_dev = cfg.n_params() * pbytes / chips
    d, l = cfg.d_model, cfg.n_layers + cfg.n_enc_layers
    hq, dh = max(cfg.n_heads, 1), cfg.head_dim
    b, s = cell.global_batch, cell.seq_len
    tokens = b * s
    tok_dev = tokens / ddp              # tokens a data shard owns
    act = tok_dev * d * 2               # one residual tensor, bytes/device

    if cell.kind == "train":
        accum = cfg.grad_accum
        flops = 6 * n_act * tokens / chips
        if cfg.mixer != "rwkv6":
            # flash fwd 4 + bwd 8 + fwd-recompute 4 = 16 matmul units of
            # B*S^2*H*Dh, no causal skip in the blockwise path (see Perf).
            flops += 16 * b * s * s * hq * dh / chips
        # HBM: params fwd+bwd per microbatch, grads + factored update,
        # ~20 activation-tensor r/w per layer per microbatch.
        hbm = accum * 2 * p_dev + 3 * p_dev + 20 * act * l
        # Collectives: FSDP gathers (fwd+bwd per microbatch; ONCE per
        # step under regather-once) + grad RS + 2 TP reduces per layer.
        # Gathers move the bf16 compute copy regardless of param dtype
        # (XLA commutes the cast below the gather - measured, see Perf).
        p_gather = cfg.n_params() * 2 / chips
        n_gathers = 3 if cfg.fsdp_regather_once else (2 * accum + 1)
        coll = n_gathers * p_gather * (ddp - 1) \
            + 2 * l * (act / 1) * 2 * (tp - 1) / tp
        if cfg.n_experts:
            # MoE a2a both ways per layer per microbatch (+ bwd).
            coll += 2 * 2 * l * act * cfg.top_k * cfg.capacity_factor
    elif cell.kind == "prefill":
        flops = 2 * n_act * tokens / chips
        if cfg.mixer != "rwkv6":
            flops += 4 * b * s * s * hq * dh / chips
        kv_dev = (l * b * s * cfg.n_kv_heads * dh * 2 * 2) / (ddp * tp)
        hbm = p_dev + 8 * act * l + kv_dev
        coll = p_dev * (ddp - 1) + 2 * l * act * (tp - 1) / tp
        if cfg.n_experts:
            coll += 2 * l * act * cfg.top_k * cfg.capacity_factor
    else:  # decode: one token against a cache of length s
        flops = 2 * n_act * b / chips
        if cfg.mixer != "rwkv6":
            flops += 4 * b * s * cfg.n_kv_heads * dh / chips
        # KV cache sweep dominates HBM:
        kv_dev = (l * b * s * cfg.n_kv_heads * dh * 2 * 2) / (ddp * tp)
        if cfg.mixer == "rwkv6":
            h = d // dh
            kv_dev = l * (b / max(ddp, 1)) * h * dh * dh * 4 / tp
        tok_act = (b / ddp) * d * 2
        hbm = p_dev + kv_dev + 10 * tok_act * l
        coll = 2 * l * tok_act * 2 * (tp - 1) / tp \
            + p_dev * 0  # params stay resident, no per-step gather
        if cfg.n_experts:
            coll += 2 * l * tok_act * cfg.top_k * cfg.capacity_factor
    return flops, hbm, coll


def model_flops(cfg, cell) -> float:
    """The 'useful' FLOPs: 6*N_active*D train / 2*N_active*D inference."""
    n_act = cfg.active_params()
    if cell.kind == "train":
        return 6.0 * n_act * cell.seq_len * cell.global_batch
    if cell.kind == "prefill":
        return 2.0 * n_act * cell.seq_len * cell.global_batch
    return 2.0 * n_act * cell.global_batch


def analyse(rec, mesh: str):
    from repro.configs import base as cfg_base
    cfg = cfg_base.get(rec["arch"])
    cell = cfg_base.SHAPES[rec["shape"]]
    pod, dp, tp = _mesh_dims(mesh)
    chips = pod * dp * tp

    flops, hbm, coll = analytic_terms(cfg, cell, mesh)
    terms = {"compute": flops / PEAK_FLOPS, "memory": hbm / HBM_BW,
             "collective": coll / ICI_BW}
    dominant = max(terms, key=terms.get)
    total = sum(terms.values())
    step_time = max(terms.values())     # perfect-overlap bound
    mf = model_flops(cfg, cell)
    mfu = mf / (chips * PEAK_FLOPS * step_time) if step_time else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "kind": rec["kind"],
        "compute_s": terms["compute"], "memory_s": terms["memory"],
        "collective_s": terms["collective"], "dominant": dominant,
        "roofline_fraction": terms[dominant] / total if total else 0.0,
        "model_flops": mf,
        "mfu_bound": mfu,
        "hlo_flops_periter": rec["cost"].get("flops", 0.0),
        "hlo_bytes_periter": rec["cost"].get("bytes accessed", 0.0),
        "hlo_coll_periter": rec["collectives"]["total_bytes"],
        "mem_gib": rec["memory"]["peak_device_bytes"] / 2 ** 30,
        "fits_v5e": rec["memory"]["peak_device_bytes"] < V5E_HBM_BYTES,
    }


def load(mesh: str = "single", dryrun_dir: str = DRYRUN_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir,
                                              f"{mesh}__*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi"])
    ap.add_argument("--dir", default=DRYRUN_DIR)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    rows = []
    for rec in load(args.mesh, args.dir):
        if rec.get("status") == "ok":
            rows.append(analyse(rec, args.mesh))
        elif rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skipped": rec["reason"]})
        else:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "error": rec.get("error", "?")[:80]})

    print("| arch | shape | compute_s | memory_s | collective_s | "
          "dominant | fraction | MFU-bound | mem GiB | fits |")
    print("|" + "---|" * 10)
    for r in rows:
        if "skipped" in r:
            print(f"| {r['arch']} | {r['shape']} | - | - | - | skipped "
                  f"| - | - | - | - |")
            continue
        if "error" in r:
            print(f"| {r['arch']} | {r['shape']} | - | - | - | ERROR | "
                  f"- | - | - | - |")
            continue
        print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
              f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
              f"{r['dominant']} | {r['roofline_fraction']:.2f} | "
              f"{r['mfu_bound']:.3f} | {r['mem_gib']:.2f} | "
              f"{'y' if r['fits_v5e'] else 'NO'} |")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
