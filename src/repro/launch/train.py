"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Builds the mesh (host devices by default; --mesh single/multi for the
production meshes under dry-run emulation), applies the sharding policies,
and runs the fault-tolerant training loop on synthetic data. The same code
path scales from the CPU container to a pod: only the mesh differs.

Two workload families share the launcher:

  * LM archs from ``configs.base`` (``--arch qwen2-0.5b`` ...): token
    streams through the transformer trainer.
  * hierarchical image VAEs from ``configs.hvae_img`` (``--arch
    hvae-small2`` ...): synthetic images through ``models.hvae``, ending
    with a lossless Bit-Swap round-trip demo at two image shapes (the
    fully-convolutional "any size" check).
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfg_base
from repro.data import pipeline, tokens as tok_data
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.sharding import api as shard_api
from repro.sharding import policies
from repro.train import fault, trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--scale", type=float, default=0.0,
                    help="0 = smoke-reduced config; 1 = full config; "
                    "fractions interpolate layer count/width")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hw", type=int, nargs=2, default=(28, 28),
                    help="hvae archs: training image shape H W")
    args = ap.parse_args()

    if args.arch.startswith("hvae"):
        return main_hvae(args)

    cfg = cfg_base.get(args.arch)
    if args.scale <= 0:
        cfg = cfg_base.reduced(cfg)
    elif args.scale < 1:
        cfg = dataclasses.replace(
            cfg, n_layers=max(2, int(cfg.n_layers * args.scale)),
            d_model=max(64, int(cfg.d_model * args.scale) // 16 * 16),
            d_ff=max(128, int(cfg.d_ff * args.scale) // 16 * 16),
            vocab=min(cfg.vocab, 8192), remat="none")
    cfg = dataclasses.replace(cfg, loss_chunk=min(cfg.loss_chunk,
                                                  args.seq))
    print(f"arch={cfg.name}  params~{cfg.n_params()/1e6:.1f}M")

    mesh = make_host_mesh()
    opt = trainer.make_optimizer(cfg, lr=args.lr, total_steps=args.steps)
    corpus, entropy = tok_data.markov_corpus(
        max(200_000, args.batch * args.seq * 4),
        vocab=min(cfg.vocab, 512), seed=args.seed)
    print(f"synthetic corpus entropy rate: {entropy:.3f} bits/token")
    raw_batch = pipeline.lm_batch_fn(corpus, args.batch, args.seq)

    with shard_api.use_mesh(mesh):
        step_fn = jax.jit(trainer.make_train_step(
            cfg, opt, compress_grads=args.grad_compress),
            donate_argnums=0)

        def init_fn():
            return trainer.init_state(
                jax.random.PRNGKey(args.seed), cfg, opt,
                use_grad_compression=args.grad_compress)

        def batch_fn(step):
            return jax.tree_util.tree_map(
                jnp.asarray, raw_batch(args.seed, step, 0, 1))

        t0 = time.time()
        log = []

        def on_metrics(step, metrics):
            if step % 10 == 0 or step == 1:
                bpt = float(metrics.get("bits_per_token", 0.0))
                print(f"step {step:5d}  loss={float(metrics['loss']):.4f}"
                      f"  bits/token={bpt:.3f}"
                      f"  ({(time.time()-t0)/max(step,1):.2f}s/step)",
                      flush=True)
            log.append(float(metrics["loss"]))

        wd = fault.StepWatchdog()
        state, restarts = fault.run_training(
            init_fn=init_fn, step_fn=step_fn, batch_fn=batch_fn,
            n_steps=args.steps, ckpt_dir=args.ckpt_dir,
            save_every=args.save_every, watchdog=wd,
            on_metrics=on_metrics)
        print(f"finished {args.steps} steps, restarts={restarts}, "
              f"final loss={log[-1]:.4f}, "
              f"entropy floor={entropy * np.log(2):.4f} nats")


def main_hvae(args):
    """Train a hierarchical image VAE and verify the Bit-Swap codec.

    The trained (fully convolutional) model is round-tripped at two
    different image shapes through ``codecs.compress`` - the HiLLoC
    claim, demonstrated end-to-end from one training run.
    """
    import jax.random as jrandom

    from repro import codecs
    from repro.configs import hvae_img
    from repro.data import images as img_data
    from repro.models import hvae

    cfg = hvae_img.get(args.arch)
    hw = tuple(args.hw)
    cfg.latent_shape(hw)  # fail fast on odd dims, not inside the jit
    # Checkpoints are param-tree-shaped: keep families/archs apart so a
    # stale LM checkpoint is never restored into HVAE params.
    ckpt_dir = os.path.join(args.ckpt_dir, args.arch)
    print(f"arch={args.arch}  levels={cfg.levels}  ch={cfg.ch} "
          f"z_ch={cfg.z_ch}  train shape={hw[0]}x{hw[1]}")

    binary = cfg.likelihood == "bernoulli"
    train_imgs = img_data.load("train", max(2000, args.batch * 16),
                               args.seed, hw=(28, 28), binarized=binary)
    raw_batch = img_data.image_batch_fn(train_imgs, args.batch, hw)

    opt = trainer.make_optimizer(cfg, lr=args.lr, total_steps=args.steps)

    def loss_fn(params, batch):
        l = hvae.loss(params, cfg, batch["key"], batch["images"])
        bpd = l / (batch["images"][0].size * np.log(2.0))
        return l, {"bits_per_dim": bpd}

    step_fn = jax.jit(trainer.make_train_step(cfg, opt, loss_fn=loss_fn),
                      donate_argnums=0)

    def init_fn():
        return trainer.init_state(jrandom.PRNGKey(args.seed), cfg, opt,
                                  init_params_fn=hvae.init)

    def batch_fn(step):
        b = raw_batch(args.seed, step, 0, 1)
        return {"images": jnp.asarray(b["images"]),
                "key": jrandom.PRNGKey(args.seed * 100_003 + step)}

    t0 = time.time()

    def on_metrics(step, metrics):
        if step % 10 == 0 or step == 1:
            print(f"step {step:5d}  loss={float(metrics['loss']):.2f}  "
                  f"bits/dim={float(metrics['bits_per_dim']):.3f}  "
                  f"({(time.time()-t0)/max(step,1):.2f}s/step)",
                  flush=True)

    state, restarts = fault.run_training(
        init_fn=init_fn, step_fn=step_fn, batch_fn=batch_fn,
        n_steps=args.steps, ckpt_dir=ckpt_dir,
        save_every=args.save_every, watchdog=fault.StepWatchdog(),
        on_metrics=on_metrics)
    print(f"finished {args.steps} steps, restarts={restarts}")

    # One model, any image size: round-trip two shapes losslessly.
    lanes = 4
    for shape in (hw, (hw[0] + 12, max(2, hw[1] - 4))):
        test = img_data.load("test", lanes, args.seed + 1, hw=shape,
                             binarized=binary)
        data = jnp.asarray(test, jnp.int32)
        codec = hvae.make_bitswap_codec(state.params, cfg, shape)
        blob, info = codecs.compress(codec, data, lanes=lanes,
                                     seed=args.seed, with_info=True)
        out = codecs.decompress(codec, blob)
        ok = bool(jnp.array_equal(out, data))
        print(f"{shape[0]}x{shape[1]}: lossless={ok}  "
              f"{info['net_bits'] / data.size:.4f} bits/dim  "
              f"({len(blob)} wire bytes)")
        if not ok:
            raise SystemExit("hvae round-trip failed")


if __name__ == "__main__":
    main()
