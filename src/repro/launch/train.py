"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Builds the mesh (host devices by default; --mesh single/multi for the
production meshes under dry-run emulation), applies the sharding policies,
and runs the fault-tolerant training loop on synthetic data. The same code
path scales from the CPU container to a pod: only the mesh differs.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfg_base
from repro.data import pipeline, tokens as tok_data
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.sharding import api as shard_api
from repro.sharding import policies
from repro.train import fault, trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--scale", type=float, default=0.0,
                    help="0 = smoke-reduced config; 1 = full config; "
                    "fractions interpolate layer count/width")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = cfg_base.get(args.arch)
    if args.scale <= 0:
        cfg = cfg_base.reduced(cfg)
    elif args.scale < 1:
        cfg = dataclasses.replace(
            cfg, n_layers=max(2, int(cfg.n_layers * args.scale)),
            d_model=max(64, int(cfg.d_model * args.scale) // 16 * 16),
            d_ff=max(128, int(cfg.d_ff * args.scale) // 16 * 16),
            vocab=min(cfg.vocab, 8192), remat="none")
    cfg = dataclasses.replace(cfg, loss_chunk=min(cfg.loss_chunk,
                                                  args.seq))
    print(f"arch={cfg.name}  params~{cfg.n_params()/1e6:.1f}M")

    mesh = make_host_mesh()
    opt = trainer.make_optimizer(cfg, lr=args.lr, total_steps=args.steps)
    corpus, entropy = tok_data.markov_corpus(
        max(200_000, args.batch * args.seq * 4),
        vocab=min(cfg.vocab, 512), seed=args.seed)
    print(f"synthetic corpus entropy rate: {entropy:.3f} bits/token")
    raw_batch = pipeline.lm_batch_fn(corpus, args.batch, args.seq)

    with shard_api.use_mesh(mesh):
        step_fn = jax.jit(trainer.make_train_step(
            cfg, opt, compress_grads=args.grad_compress),
            donate_argnums=0)

        def init_fn():
            return trainer.init_state(
                jax.random.PRNGKey(args.seed), cfg, opt,
                use_grad_compression=args.grad_compress)

        def batch_fn(step):
            return jax.tree_util.tree_map(
                jnp.asarray, raw_batch(args.seed, step, 0, 1))

        t0 = time.time()
        log = []

        def on_metrics(step, metrics):
            if step % 10 == 0 or step == 1:
                bpt = float(metrics.get("bits_per_token", 0.0))
                print(f"step {step:5d}  loss={float(metrics['loss']):.4f}"
                      f"  bits/token={bpt:.3f}"
                      f"  ({(time.time()-t0)/max(step,1):.2f}s/step)",
                      flush=True)
            log.append(float(metrics["loss"]))

        wd = fault.StepWatchdog()
        state, restarts = fault.run_training(
            init_fn=init_fn, step_fn=step_fn, batch_fn=batch_fn,
            n_steps=args.steps, ckpt_dir=args.ckpt_dir,
            save_every=args.save_every, watchdog=wd,
            on_metrics=on_metrics)
        print(f"finished {args.steps} steps, restarts={restarts}, "
              f"final loss={log[-1]:.4f}, "
              f"entropy floor={entropy * np.log(2):.4f} nats")


if __name__ == "__main__":
    main()
