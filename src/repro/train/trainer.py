"""Train-step builder: grad accumulation, optimizer dispatch, gradient
compression hook, donation-ready TrainState.

The produced ``train_step(state, batch) -> (state, metrics)`` is a pure jit
target; the launcher jits it with in/out shardings and donates ``state``.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.optim import adafactor, adamw, grad_compress


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt_state: Any
    compress_state: Optional[Any] = None


def make_optimizer(cfg, lr: float = 3e-4, total_steps: int = 10000):
    sched = adamw.cosine_lr(lr, warmup=min(500, total_steps // 10),
                            total=total_steps)
    if getattr(cfg, "optimizer", "adamw") == "adafactor":
        return adafactor.Adafactor(learning_rate=sched)
    return adamw.AdamW(learning_rate=sched, weight_decay=0.01)


def init_state(key, cfg, optimizer, use_grad_compression: bool = False,
               init_params_fn: Optional[Callable] = None) -> TrainState:
    """Build a fresh ``TrainState``.

    ``init_params_fn(key, cfg) -> params`` selects the model family;
    the default is the LM transformer. Image models pass their own init
    (e.g. ``models.hvae.init``) and reuse the same optimizer/train-step
    machinery - the trainer is model-agnostic from here down.
    """
    params = (init_params_fn or transformer.init)(key, cfg)
    opt_state = optimizer.init(params)
    cstate = grad_compress.init_state(params) if use_grad_compression \
        else None
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt_state=opt_state, compress_state=cstate)


def make_train_step(cfg, optimizer, *, accum: int = 1,
                    loss_fn: Optional[Callable] = None,
                    compress_grads: bool = False,
                    regather_shardings: Optional[Any] = None) -> Callable:
    """Build the pure train step.

    ``accum`` > 1 splits the batch into microbatches under lax.scan
    (sequential grad accumulation - the standard memory/throughput trade).
    ``compress_grads`` routes gradients through the int8+error-feedback
    transport codec (simulating the cross-pod DCN reduce).

    ``regather_shardings`` (a params-shaped tree of NamedShardings with
    the FSDP axes dropped) enables the *regather-once* optimization:
    params are cast to the compute dtype and unsharded along the data
    axis ONCE per step, *outside* the microbatch scan, and the whole scan
    is differentiated in one backward pass - so the FSDP all-gather and
    the gradient reduce-scatter each happen once per step instead of once
    per microbatch ((2*accum+1) -> 3 P-sized collectives). Only valid
    when the TP-sharded bf16 params fit per device (launchers gate this).
    """
    loss_fn = loss_fn or (lambda p, b: transformer.loss_fn(p, cfg, b))

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def _regather(params):
        cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" \
            else jnp.float32

        def one(p, s):
            p = p.astype(cdt) if p.dtype == jnp.float32 else p
            return jax.lax.with_sharding_constraint(p, s)

        return jax.tree_util.tree_map(one, params, regather_shardings)

    def accum_grads_regathered(params, micro):
        """One backward pass through the whole micro-scan: the gather of
        params (and the reduce-scatter of their cotangent) sit outside
        the scan -> once per step."""

        def total_loss(params):
            pu = _regather(params)

            def body(carry, mb):
                loss, metrics = loss_fn(pu, mb)
                return carry + loss, metrics

            tot, metrics = jax.lax.scan(
                body, jnp.zeros((), jnp.float32), micro)
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
            return tot / accum, metrics

        (loss, metrics), grads = jax.value_and_grad(
            total_loss, has_aux=True)(params)
        return loss, metrics, grads

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]
                   ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        if accum > 1:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)
            if regather_shardings is not None:
                loss, metrics, grads = accum_grads_regathered(
                    state.params, micro)
            else:
                def body(carry, mb):
                    loss_sum, grads_sum = carry
                    loss, metrics, grads = grads_of(state.params, mb)
                    grads_sum = jax.tree_util.tree_map(
                        jnp.add, grads_sum, grads)
                    return (loss_sum + loss, grads_sum), metrics

                zero_grads = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32),
                    state.params)
                (loss, grads), metrics = jax.lax.scan(
                    body, (jnp.zeros((), jnp.float32), zero_grads), micro)
                loss = loss / accum
                grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
                metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        else:
            loss, metrics, grads = grads_of(state.params, batch)

        cstate = state.compress_state
        if compress_grads and cstate is not None:
            grads, cstate = grad_compress.compress_grads(grads, cstate)

        params, opt_state = optimizer.update(grads, state.opt_state,
                                             state.params)
        metrics = dict(metrics, loss=loss,
                       grad_norm=adamw.global_norm(grads))
        return TrainState(step=state.step + 1, params=params,
                          opt_state=opt_state,
                          compress_state=cstate), metrics

    return train_step
