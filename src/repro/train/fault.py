"""Fault tolerance: checkpoint/restart driver, straggler watchdog,
failure injection for tests.

``run_training`` is the production loop shape: every step is
step-indexed (data too), checkpoints land every ``save_every`` steps, and
any exception marked restartable triggers a reload of the latest
checkpoint and a replay from there. Because data, init and optimizer are
all pure functions of (seed, step), a run interrupted k times is
*bitwise identical* to an uninterrupted one - asserted by
tests/test_fault.py.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.train import checkpoint


class SimulatedNodeFailure(RuntimeError):
    """Injected in tests to emulate a node loss / preemption."""


@dataclass
class WatchdogReport:
    step_times: List[float] = field(default_factory=list)
    stragglers: List[int] = field(default_factory=list)


class StepWatchdog:
    """Flags steps whose wall time is a z-score outlier (straggler
    mitigation hook: on a real fleet this triggers checkpoint-and-rebalance;
    here it records and calls the callback)."""

    def __init__(self, z_threshold: float = 4.0, warmup: int = 5,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.z = z_threshold
        self.warmup = warmup
        self.on_straggler = on_straggler
        self.report = WatchdogReport()

    def observe(self, step: int, dt: float):
        times = self.report.step_times
        if len(times) >= self.warmup:
            mu = float(np.mean(times))
            sd = float(np.std(times)) + 1e-9
            if (dt - mu) / sd > self.z:
                self.report.stragglers.append(step)
                if self.on_straggler:
                    self.on_straggler(step, dt)
        times.append(dt)


def run_training(*, init_fn: Callable[[], Any],
                 step_fn: Callable[[Any, Dict], Any],
                 batch_fn: Callable[[int], Dict],
                 n_steps: int,
                 ckpt_dir: str,
                 save_every: int = 50,
                 max_restarts: int = 10,
                 watchdog: Optional[StepWatchdog] = None,
                 failure_injector: Optional[Callable[[int], None]] = None,
                 on_metrics: Optional[Callable[[int, Dict], None]] = None):
    """Run to ``n_steps`` with checkpoint/restart. Returns final state."""
    restarts = 0
    state = None
    start = checkpoint.latest_step(ckpt_dir)
    if start is not None:
        state = checkpoint.restore(init_fn(), ckpt_dir, step=start)
    else:
        state = init_fn()
        start = 0

    step = start
    while step < n_steps:
        try:
            t0 = time.monotonic()
            if failure_injector is not None:
                failure_injector(step)
            batch = batch_fn(step)
            out = step_fn(state, batch)
            state, metrics = out if isinstance(out, tuple) else (out, {})
            step += 1
            if watchdog is not None:
                watchdog.observe(step, time.monotonic() - t0)
            if on_metrics is not None and metrics:
                on_metrics(step, metrics)
            if step % save_every == 0 or step == n_steps:
                checkpoint.save(step, state, ckpt_dir)
        except SimulatedNodeFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            latest = checkpoint.latest_step(ckpt_dir)
            if latest is None:
                state, step = init_fn(), 0
            else:
                state = checkpoint.restore(init_fn(), ckpt_dir,
                                           step=latest)
                step = latest
    return state, restarts
