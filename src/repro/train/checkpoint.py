"""Sharded checkpointing with elastic restore (resharding loader).

Layout: ``<dir>/step_<N>/`` containing one ``.npy`` per pytree leaf plus
``index.json`` (treedef paths, shapes, dtypes). Writes are atomic
(tmp-dir + rename), so a node loss mid-save never corrupts the latest
checkpoint. Restore places leaves onto the *current* mesh via
``jax.device_put`` with the caller's shardings - restoring a 512-chip
checkpoint onto any other topology is the same code path (elastic
restart, DESIGN.md section 5).

On a real multi-host pod each host would write only the shards it owns
(``jax.experimental.multihost_utils``); in this single-process container
the gather is a no-op.
"""

from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((name, leaf))
    return out, treedef


def save(step: int, tree: Any, ckpt_dir: str, keep: int = 3) -> str:
    """Atomically save a pytree; prune to the ``keep`` most recent."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, _ = _flatten_with_paths(tree)
    index = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        index["leaves"].append({"name": name, "file": fname,
                                "shape": list(arr.shape),
                                "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump(index, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "index.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(tree_like: Any, ckpt_dir: str, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional pytree (same structure) of ``NamedSharding`` -
    leaves are placed directly onto the current mesh (the elastic path).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "index.json")) as f:
        index = json.load(f)
    leaves_meta = index["leaves"]
    flat_like, treedef = jax.tree_util.tree_flatten(tree_like)
    if len(flat_like) != len(leaves_meta):
        raise ValueError(
            f"checkpoint has {len(leaves_meta)} leaves, expected "
            f"{len(flat_like)}")
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat_like))
    out = []
    for meta, like, shd in zip(leaves_meta, flat_like, shard_flat):
        arr = np.load(os.path.join(d, meta["file"]))
        if tuple(arr.shape) != tuple(np.shape(like)):
            raise ValueError(f"shape mismatch for {meta['name']}: "
                             f"{arr.shape} vs {np.shape(like)}")
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
