"""``repro.codecs`` - the composable coding API.

One abstraction (``Codec``: push/pop exact inverses over an ANS stack),
leaf codecs wrapping ``core.distributions`` / ``core.discretize``,
combinators that build structured codecs out of smaller ones, and a
one-call container format:

    blob = codecs.compress(codec, data, lanes=16, seed=0)
    data = codecs.decompress(codec, blob)

The container owns stack sizing (grow-and-retry on overflow), clean-bit
seeding, and flatten/unflatten framing, so callers never touch
``make_stack``/``seed_stack`` directly.

Any latent-variable model plugs in via ``BBANS(prior, likelihood,
posterior)`` (paper Table 1); hierarchical models via ``BitSwap``
(e.g. ``models.hvae.make_bitswap_codec``). Runnable examples for every
exported name: docs/API.md; BBX1 wire layout: docs/FORMATS.md.
"""

from repro.core.codec import Codec, FnCodec
from repro.core.distributions import (Bernoulli, BetaBinomial, Categorical,
                                      FactoredCategorical)
from repro.codecs.leaves import (DiscretizedGaussian, DiscretizedLogistic,
                                 PointwiseCDF, Uniform)
from repro.codecs.combinators import (BBANS, BitSwap, Chained, Repeat,
                                      Serial, Shaped, TreeCodec)
from repro.codecs.container import (ContainerError, blob_info, compress,
                                    decompress, fresh_stack)
from repro.codecs.quantize import (FixedPointFn, LutBernoulli, QuantConfig,
                                   quantize_params)
from repro.codecs.compile import CompiledCodec, compile

__all__ = [
    "Codec", "FnCodec",
    # leaves
    "Bernoulli", "BetaBinomial", "Categorical", "FactoredCategorical",
    "DiscretizedGaussian", "DiscretizedLogistic", "PointwiseCDF", "Uniform",
    # combinators
    "BBANS", "BitSwap", "Chained", "Repeat", "Serial", "Shaped", "TreeCodec",
    # compiler
    "compile", "CompiledCodec",
    # fixed-point inference (codecs.quantize)
    "FixedPointFn", "LutBernoulli", "QuantConfig", "quantize_params",
    # container
    "compress", "decompress", "blob_info", "fresh_stack",
    "ContainerError",
]
