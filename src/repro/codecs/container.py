"""One-call container format: ``compress(codec, data) -> bytes``.

The container owns everything callers used to hand-thread:

  * stack sizing      - starts from a heuristic capacity and
                        grows-and-retries on overflow (detected via the
                        ``ANSStack.overflows`` counter, never silent);
  * clean-bit seeding - deterministic from ``seed`` (paper section 3.2:
                        the first posterior pops consume seeded bits
                        instead of underflowing); on underflow the
                        supply is grown and the encode retried;
  * framing           - a self-describing header (magic, version,
                        precision, lanes, per-lane lengths) followed by
                        the concatenated per-lane 16-bit chunk streams,
                        so ``decompress`` needs only the codec and the
                        blob.

Wire layout (little-endian; canonical spec with invariants and a
worked example: docs/FORMATS.md):

    offset  size        field
    0       4           magic  b"BBX1"
    4       1           version (=1)
    5       1           precision (informational)
    6       2           flags (reserved, 0)
    8       4           lanes (u32)
    12      4*lanes     lengths (u32 each, in 16-bit chunks, >= 2)
    ...     2*sum(len)  payload: lane l's [head_hi, head_lo, chunks...]
"""

from __future__ import annotations

import struct
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ans
from repro.core.codec import Codec

_MAGIC = b"BBX1"
_VERSION = 1
_HEADER = struct.Struct("<4sBBHI")
# Lanes is bounded by what a header this size can sanely describe: the
# lengths block alone is 4 bytes per lane, so anything above this is a
# corrupt count, not a real message.
_MAX_LANES = 1 << 24


class ContainerError(ValueError):
    """A blob failed header or framing validation (corrupt, truncated,
    or not a BBX1 container). Raised by ``decompress``/``blob_info``
    before any coder state is built, so corruption is reported by name
    instead of as an index error deep inside ``ans``."""


def fresh_stack(lanes: int, capacity: int, seed: Optional[int] = 0,
                init_chunks: int = 0) -> ans.ANSStack:
    """A ready-to-code stack: random heads + ``init_chunks`` clean
    16-bit chunks per lane, all derived deterministically from ``seed``.

    ``seed=None`` gives the deterministic cold stack (head = 2^16, no
    clean bits) - right for latent-free direct coding.

    Example::

        stack = fresh_stack(lanes=16, capacity=4096, seed=0,
                            init_chunks=32)   # bits-back ready
    """
    if seed is None:
        if init_chunks:
            raise ValueError(
                "fresh_stack: init_chunks requires a seed - clean bits "
                "are derived from it (pass seed=<int> or init_chunks=0)")
        stack = ans.make_stack(lanes, capacity)
    else:
        key = jax.random.PRNGKey(seed)
        k_head, k_bits = jax.random.split(key)
        stack = ans.make_stack(lanes, capacity, key=k_head)
        if init_chunks:
            stack = ans.seed_stack(stack, k_bits, init_chunks)
    return stack


def _default_capacity(data: Any, lanes: int, init_chunks: int) -> int:
    n_elems = sum(int(np.prod(x.shape))
                  for x in jax.tree_util.tree_leaves(data))
    # One 16-bit chunk per element per lane is a generous starting guess
    # for typical sub-16-bit/symbol sources; overflow-retry doubles it.
    return max(256, n_elems // max(lanes, 1) + init_chunks + 64)


def compress(codec: Codec, data: Any, *, lanes: int,
             seed: Optional[int] = 0, init_chunks: int = 32,
             capacity: Optional[int] = None, max_retries: int = 6,
             precision: int = ans.DEFAULT_PRECISION,
             with_info: bool = False
             ) -> Union[bytes, Tuple[bytes, Dict[str, Any]]]:
    """Encode ``data`` with ``codec`` into a self-contained blob.

    ``data`` is a pytree whose leaves carry a leading ``lanes`` axis
    (wrap with ``Chained`` for a [n, lanes, ...] chain). The encode is
    verified clean (no under/overflow) before the blob is emitted; on
    overflow the capacity doubles and on underflow the clean-bit supply
    quadruples, then the encode reruns - a corrupt blob is impossible.

    With ``with_info=True`` returns ``(blob, info)`` where
    ``info["net_bits"]`` is the information *added* by the encode
    (content bits after minus before - the quantity that matches -ELBO,
    free of clean-bit and flush constants).

    Example::

        codec = Chained(make_bb_codec(params, cfg), n)
        blob, info = compress(codec, data, lanes=16, seed=0,
                              with_info=True)
        rate_bpd = info["net_bits"] / data.size
    """
    cap = capacity or _default_capacity(data, lanes, init_chunks)
    # A cold stack (seed=None) has no clean-bit source; direct-coding
    # codecs don't need one, so the supply is simply 0 there.
    chunks = 0 if seed is None else init_chunks
    for attempt in range(max_retries):
        stack0 = fresh_stack(lanes, cap, seed, chunks)
        # Content bits are read *before* the push (a compiled codec
        # donates the input stack's buffers), and only when requested
        # (it costs a device reduction + host sync).
        bits_before = float(ans.stack_content_bits(stack0)) \
            if with_info else 0.0
        stack = codec.push(stack0, data)
        over = int(jnp.sum(stack.overflows))
        under = int(jnp.sum(stack.underflows))
        if not over and not under:
            blob = _pack(stack, precision)
            if not with_info:
                return blob
            info = {
                "capacity": cap, "init_chunks": chunks, "seed": seed,
                "net_bits": float(ans.stack_content_bits(stack))
                - bits_before,
                "retries": attempt,
                **blob_info(blob),
            }
            return blob, info
        if over:
            cap *= 2
        if under:
            if seed is None:
                raise RuntimeError(
                    "codecs.compress: stack underflow with seed=None - "
                    "this codec pops initial bits (bits-back); pass a "
                    "seed so clean bits can be supplied")
            chunks = max(32, chunks * 4)
    raise RuntimeError(
        f"codecs.compress: could not encode cleanly after {max_retries} "
        f"attempts (last capacity={cap}, init_chunks={chunks})")


def decompress(codec: Codec, blob: bytes) -> Any:
    """Decode a ``compress`` blob back to the original data, bit-exactly.

    Example::

        assert (decompress(codec, compress(codec, data, lanes=16))
                == data).all()
    """
    msg, lengths, _ = _unpack(blob)
    stack = ans.unflatten(jnp.asarray(msg), jnp.asarray(lengths))
    stack, data = codec.pop(stack)
    ans.check_clean(stack, "codecs.decompress")
    return data


def blob_info(blob: bytes) -> Dict[str, Any]:
    """Parse a blob header: lanes, lengths, payload/header sizes in bits.

    ``payload_bits`` equals ``ans.stack_bits`` of the encoded stack -
    the message proper; ``header_bits`` is the framing overhead.

    Example::

        info = blob_info(blob)
        overhead = info["header_bits"] / info["total_bits"]

    Byte-level layout: docs/FORMATS.md.
    """
    msg, lengths, precision = _unpack(blob)
    payload_bits = int(np.sum(lengths)) * 16
    return {
        "lanes": int(msg.shape[0]),
        "lengths": lengths,
        "precision": precision,
        "payload_bits": payload_bits,
        "header_bits": (len(blob) - payload_bits // 8) * 8,
        "total_bits": len(blob) * 8,
    }


def pack_lane_rows(msg: np.ndarray, lengths: np.ndarray) -> bytes:
    """Concatenate per-lane ``msg[l, :lengths[l]]`` rows into wire bytes.

    The shared payload primitive of the BBX1 one-shot container and the
    ``repro.stream`` BBX2 block format (little-endian u16 chunks).
    """
    msg = np.asarray(msg)
    lengths = np.asarray(lengths)
    return b"".join(msg[l, :lengths[l]].astype("<u2").tobytes()
                    for l in range(msg.shape[0]))


def unpack_lane_rows(buf: bytes, offset: int,
                     lengths: np.ndarray) -> np.ndarray:
    """Inverse of ``pack_lane_rows``: rebuild the padded [lanes, width]
    uint16 message from concatenated rows at ``offset`` in ``buf``."""
    lengths = np.asarray(lengths)
    total = int(lengths.sum())
    if len(buf) < offset + 2 * total:
        raise ValueError("codecs: truncated payload (lane rows short)")
    flat = np.frombuffer(buf, dtype="<u2", count=total, offset=offset)
    width = int(lengths.max()) if lengths.size else 0
    msg = np.zeros((lengths.shape[0], width), np.uint16)
    pos = 0
    for l in range(lengths.shape[0]):
        n = int(lengths[l])
        msg[l, :n] = flat[pos:pos + n]
        pos += n
    return msg


def _pack(stack: ans.ANSStack, precision: int) -> bytes:
    msg, lengths = ans.flatten(stack)
    msg_np = np.asarray(msg)
    lengths_np = np.asarray(lengths)
    lanes = msg_np.shape[0]
    return b"".join([
        _HEADER.pack(_MAGIC, _VERSION, precision, 0, lanes),
        lengths_np.astype("<u4").tobytes(),
        pack_lane_rows(msg_np, lengths_np),
    ])


def _unpack(blob: bytes) -> Tuple[np.ndarray, np.ndarray, int]:
    if len(blob) < _HEADER.size:
        raise ContainerError("codecs: truncated blob (no header)")
    magic, version, precision, _flags, lanes = _HEADER.unpack_from(blob, 0)
    if magic != _MAGIC:
        raise ContainerError(
            f"codecs: bad magic {magic!r} (not a BBX1 blob)")
    if version != _VERSION:
        raise ContainerError(
            f"codecs: unsupported container version {version}")
    if not 0 < precision <= ans.MAX_PRECISION:
        raise ContainerError(
            f"codecs: corrupt header (precision {precision} outside "
            f"[1, {ans.MAX_PRECISION}])")
    if not 0 < lanes <= _MAX_LANES:
        raise ContainerError(
            f"codecs: corrupt header (lane count {lanes})")
    off = _HEADER.size
    if len(blob) < off + 4 * lanes:
        raise ContainerError(
            f"codecs: truncated blob (header promises {lanes} lane "
            "lengths but the lengths block is short)")
    lengths = np.frombuffer(blob, dtype="<u4", count=lanes,
                            offset=off).astype(np.int64)
    if (lengths < 2).any():
        raise ContainerError("codecs: corrupt header (lane length < 2; "
                             "every lane carries a 2-chunk head flush)")
    off += 4 * lanes
    payload = len(blob) - off
    need = 2 * int(lengths.sum())
    if payload != need:
        raise ContainerError(
            f"codecs: payload is {payload} bytes but the lane lengths "
            f"sum to {need} (truncated or trailing garbage)")
    msg = unpack_lane_rows(blob, off, lengths.astype(np.int32))
    return msg, lengths.astype(np.int32), precision
