"""Codec combinators: structured codecs out of smaller ones.

Every combinator preserves the push/pop exact-inverse contract by
construction: a composite push is a sequence of component pushes, and
the composite pop runs the component pops in exactly the reverse order
(LIFO discipline - the property BB-ANS chaining rests on).

  * ``Serial``    - a fixed tuple of heterogeneous codecs.
  * ``Repeat``    - one codec per position of a [lanes, n] array
                    (``lax.fori_loop``-driven, jittable).
  * ``Shaped``    - present a flat [lanes, k] codec as [lanes, *shape].
  * ``TreeCodec`` - a pytree of codecs coding a matching pytree symbol.
  * ``Chained``   - the BB-ANS *chain* (paper section 2.3): datapoint
                    t's compressed stack is datapoint t+1's extra
                    information.
  * ``BBANS``     - the paper's Table 1 as a combinator over (prior,
                    likelihood, posterior).
  * ``BitSwap``   - hierarchical multi-layer latents with interleaved
                    pop/push (Kingma et al., 2019), so initial clean
                    bits are needed for one layer only.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import ans
from repro.core.codec import Codec


@dataclasses.dataclass(frozen=True)
class Serial(Codec):
    """Code a tuple of symbols with a tuple of codecs.

    ``push`` runs components in *reverse* so that ``pop`` yields them in
    natural order.

    Example::

        codec = Serial([Uniform(6), Categorical(logits)])
        stack = codec.push(stack, (a, b))      # b pushed first
        stack, (a2, b2) = codec.pop(stack)     # natural order back

    (All combinator examples run, with data, in docs/API.md.)
    """

    codecs: Tuple[Codec, ...]

    def __init__(self, codecs: Sequence[Codec]):
        object.__setattr__(self, "codecs", tuple(codecs))

    def push(self, stack: ans.ANSStack, x: Sequence[Any]) -> ans.ANSStack:
        if len(x) != len(self.codecs):
            raise ValueError(f"Serial: {len(self.codecs)} codecs, "
                             f"{len(x)} symbols")
        for codec, xi in reversed(list(zip(self.codecs, x))):
            stack = codec.push(stack, xi)
        return stack

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, Tuple]:
        out = []
        for codec in self.codecs:
            stack, xi = codec.pop(stack)
            out.append(xi)
        return stack, tuple(out)


@dataclasses.dataclass(frozen=True)
class Repeat(Codec):
    """Code a [lanes, n] array one position at a time.

    ``codec_fn(d)`` returns the leaf codec for position ``d`` (it may
    close over per-position parameters, e.g. ``mu[:, d]``); with
    ``scan=True`` the loop is a ``lax.fori_loop`` and ``codec_fn`` must
    be traceable with a traced index. ``scan=False`` runs a Python loop
    for codec_fns that drive jitted network steps from Python.

    Example::

        codec = Repeat(lambda d: DiscretizedGaussian(
            mu[:, d], sigma[:, d], bits), n=mu.shape[1])
        stack, idx = codec.pop(stack)          # idx int32[lanes, n]
    """

    codec_fn: Callable[[Any], Codec]
    n: int
    out_dtype: Any = jnp.int32
    scan: bool = True

    def push(self, stack: ans.ANSStack, x: jnp.ndarray) -> ans.ANSStack:
        n, fn = self.n, self.codec_fn
        if not self.scan:
            for d in reversed(range(n)):
                stack = fn(d).push(stack, x[:, d])
            return stack

        def body(k, stack):
            d = n - 1 - k
            return fn(d).push(stack, x[:, d])

        return jax.lax.fori_loop(0, n, body, stack)

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, jnp.ndarray]:
        n, fn = self.n, self.codec_fn
        if not self.scan:
            cols = []
            for d in range(n):
                stack, v = fn(d).pop(stack)
                cols.append(v)
            return stack, jnp.stack(cols, axis=1).astype(self.out_dtype)

        def body(d, carry):
            stack, out = carry
            stack, v = fn(d).pop(stack)
            return stack, out.at[:, d].set(v.astype(self.out_dtype))

        out0 = jnp.zeros((stack.lanes, n), self.out_dtype)
        return jax.lax.fori_loop(0, n, body, (stack, out0))


@dataclasses.dataclass(frozen=True)
class Shaped(Codec):
    """View a codec over flat [lanes, k] symbols as [lanes, *shape].

    Example::

        codec = Shaped(Repeat(lambda d: Uniform(4), 6), (2, 3))
        stack = codec.push(stack, x)           # x int[lanes, 2, 3]
    """

    inner: Codec
    shape: Tuple[int, ...]

    def push(self, stack: ans.ANSStack, x: jnp.ndarray) -> ans.ANSStack:
        return self.inner.push(stack, x.reshape(x.shape[0], -1))

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, jnp.ndarray]:
        stack, flat = self.inner.pop(stack)
        return stack, flat.reshape((flat.shape[0],) + tuple(self.shape))


@dataclasses.dataclass(frozen=True)
class TreeCodec(Codec):
    """Code a pytree symbol with a matching pytree of codecs.

    Example::

        codec = TreeCodec({"z": Uniform(5), "x": Bernoulli(logits)})
        stack = codec.push(stack, {"z": z, "x": x})
        stack, out = codec.pop(stack)          # same dict structure
    """

    tree: Any  # pytree whose leaves are Codecs

    def _parts(self, x: Any):
        leaves, treedef = jax.tree_util.tree_flatten(
            self.tree, is_leaf=lambda c: isinstance(c, Codec))
        xs = treedef.flatten_up_to(x) if x is not None else None
        return leaves, treedef, xs

    def push(self, stack: ans.ANSStack, x: Any) -> ans.ANSStack:
        leaves, _, xs = self._parts(x)
        for codec, xi in reversed(list(zip(leaves, xs))):
            stack = codec.push(stack, xi)
        return stack

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, Any]:
        leaves, treedef, _ = self._parts(None)
        out = []
        for codec in leaves:
            stack, xi = codec.pop(stack)
            out.append(xi)
        return stack, treedef.unflatten(out)


@dataclasses.dataclass(frozen=True)
class Chained(Codec):
    """Chain ``inner`` over a leading [n, ...] axis (paper section 2.3).

    Each datapoint's compressed stack is the next one's extra
    information; decode pops in reverse and returns natural order.

    The default is the Python chain loop (``scan=False``): coding is
    only lossless when encode and decode compute bit-identical
    fixed-point CDFs, and a ``lax.scan`` compiles the chain body into
    one fused program per direction, where XLA may produce float32
    bits that differ between the two (and from the eager path) by an
    ulp - enough to flip a ``floor`` boundary roughly once per 10^4
    symbols (docs/PERF.md). ``scan=True`` remains available for
    integer-only or otherwise context-stable inners; it is also what
    codecs driving jit-compiled network steps from Python must NOT use
    (the lm_codec determinism contract). For a fast chain over a
    model codec, use ``codecs.compile(Chained(...))``.

    Example::

        codec = Chained(make_bb_codec(params, cfg), n)
        blob = compress(codec, data, lanes=16, seed=0)  # data [n, 16, D]
    """

    inner: Codec
    n: int
    scan: bool = False

    def push(self, stack: ans.ANSStack, data: Any) -> ans.ANSStack:
        inner = self.inner
        for leaf in jax.tree_util.tree_leaves(data):
            if leaf.shape[0] != self.n:
                raise ValueError(
                    f"Chained(n={self.n}): data leading axis is "
                    f"{leaf.shape[0]} - a mismatch would silently code "
                    "the wrong number of datapoints")
        if self.scan:
            def body(stack, s):
                return inner.push(stack, s), None

            stack, _ = jax.lax.scan(body, stack, data)
            return stack
        for i in range(self.n):
            s_i = jax.tree_util.tree_map(lambda x: x[i], data)
            stack = inner.push(stack, s_i)
        return stack

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, Any]:
        inner, n = self.inner, self.n
        if self.scan:
            def body(stack, _):
                stack, s = inner.pop(stack)
                return stack, s

            stack, rev = jax.lax.scan(body, stack, None, length=n)
            return stack, jax.tree_util.tree_map(
                lambda x: jnp.flip(x, axis=0), rev)
        outs = []
        for _ in range(n):
            stack, s = inner.pop(stack)
            outs.append(s)
        return stack, jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=0), *reversed(outs))


@dataclasses.dataclass(frozen=True)
class BBANS(Codec):
    """Bits back with ANS (paper Table 1) as a codec combinator.

    ``prior`` is a Codec over the latent ``y``; ``likelihood(y)`` and
    ``posterior(s)`` are functions returning Codecs over the data ``s``
    and latent ``y`` respectively. ``push`` nets -ELBO(s) bits:

        pop  y ~ Q(y|s)      (get bits back)
        push s ~ p(s|y)      (pay -log p(s|y))
        push y ~ p(y)        (pay -log p(y))

    Example (the VAE shape; runnable version in docs/API.md)::

        codec = BBANS(prior=Uniform(bits),
                      likelihood=lambda y: Bernoulli(dec(y)),
                      posterior=lambda s: DiscretizedGaussian(
                          *enc(s), bits))
        blob = compress(codec, s, lanes=s.shape[0], seed=0)
    """

    prior: Codec
    likelihood: Callable[[Any], Codec]
    posterior: Callable[[Any], Codec]

    def push(self, stack: ans.ANSStack, s: Any) -> ans.ANSStack:
        stack, y = self.posterior(s).pop(stack)
        stack = self.likelihood(y).push(stack, s)
        return self.prior.push(stack, y)

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, Any]:
        stack, y = self.prior.pop(stack)
        stack, s = self.likelihood(y).pop(stack)
        stack = self.posterior(s).push(stack, y)
        return stack, s


@dataclasses.dataclass(frozen=True)
class BitSwap(Codec):
    """Hierarchical bits-back with interleaved pop/push (Bit-Swap).

    For a Markov latent hierarchy s <- z_1 <- ... <- z_L, ``layers`` is
    a bottom-up tuple of ``(posterior_fn, likelihood_fn)`` pairs where
    layer l's context is the variable below it (``s`` for l=1, else
    ``z_{l-1}``): ``posterior_fn(ctx)`` is a Codec over ``z_l`` and
    ``likelihood_fn(z_l)`` a Codec over the context. ``prior`` codes
    ``z_L``. Interleaving (pop z_l, immediately push the level below)
    bounds the transient clean-bit demand by *one* layer's posterior
    instead of the sum over layers - the Bit-Swap advantage (Kingma,
    Abbeel & Ho, 2019). With one layer this is exactly ``BBANS``.

    Example (2 layers; ``models.hvae.make_bitswap_codec`` builds the
    convolutional version of exactly this)::

        codec = BitSwap(prior=Uniform(bits),
                        layers=((post1, lik1), (post2, lik2)))
        blob = compress(codec, s, lanes=s.shape[0], seed=0)
    """

    prior: Codec
    layers: Tuple[Tuple[Callable[[Any], Codec],
                        Callable[[Any], Codec]], ...]

    def push(self, stack: ans.ANSStack, s: Any) -> ans.ANSStack:
        ctx = s
        for posterior_fn, likelihood_fn in self.layers:
            stack, z = posterior_fn(ctx).pop(stack)
            stack = likelihood_fn(z).push(stack, ctx)
            ctx = z
        return self.prior.push(stack, ctx)

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, Any]:
        stack, z = self.prior.pop(stack)
        for posterior_fn, likelihood_fn in reversed(self.layers):
            stack, ctx = likelihood_fn(z).pop(stack)
            stack = posterior_fn(ctx).push(stack, z)
            z = ctx
        return stack, z
