"""Leaf codecs: one symbol per lane per push/pop.

The discrete observation models (``Bernoulli``, ``Categorical``,
``FactoredCategorical``, ``BetaBinomial``) live in
``core.distributions`` and already implement the ``Codec`` contract;
``repro.codecs`` re-exports them. This module adds the latent-side
leaves:

  * ``Uniform``      - exact ``bits``-bit uniform code (the max-entropy
                       prior over equal-mass buckets, paper App. B).
  * ``PointwiseCDF`` - generic codec from a pointwise-evaluable
                       fixed-point CDF with bisection decode (O(1)
                       memory; no alphabet-sized tables).
  * ``DiscretizedGaussian`` - diag-Gaussian posterior over the
                       max-entropy prior buckets (paper App. B); a
                       direct delegate of ``core.discretize.push/
                       pop_posterior`` (bit-identical by construction).
  * ``DiscretizedLogistic`` - logistic CDF over the same bucket grid
                       (PixelCNN-style likelihood, usable as posterior).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import ans, discretize
from repro.core.codec import Codec


@dataclasses.dataclass(frozen=True)
class Uniform(Codec):
    """Exact ``bits``-bit uniform code over {0 .. 2^bits - 1} per lane.

    Example::

        stack = Uniform(8).push(stack, jnp.asarray([17, 255]))
        stack, x = Uniform(8).pop(stack)       # exactly 8 bits/lane
    """

    bits: int
    precision: int = ans.DEFAULT_PRECISION

    def push(self, stack: ans.ANSStack, x: jnp.ndarray) -> ans.ANSStack:
        return discretize.push_prior(stack, x, self.bits, self.precision)

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, jnp.ndarray]:
        return discretize.pop_prior(stack, self.bits, self.precision)


@dataclasses.dataclass(frozen=True)
class PointwiseCDF(Codec):
    """Codec over {0 .. 2^bits - 1} from a pointwise float CDF.

    ``cdf_fn(i)`` maps int32[lanes] bucket indices to float[lanes]
    cumulative mass in [0, 1] (must saturate to exactly 0 at i <= 0 and
    1 at i >= 2^bits). The fixed-point table is

        F(i) = floor((2^precision - 2^bits) * cdf_fn(i)) + i

    - strictly increasing with exact total, evaluated on demand (no
    K-sized tables); decode inverts it with a ``bits``-step bisection.
    Encoder and decoder evaluate the identical function, so roundtrips
    are bit-exact (the determinism contract of ``core.lm_codec``).

    Example (a linear CDF == uniform)::

        codec = PointwiseCDF(
            lambda i: i.astype(jnp.float32) / (1 << 8), bits=8)
        stack, idx = codec.pop(stack)
    """

    cdf_fn: Callable[[jnp.ndarray], jnp.ndarray]
    bits: int
    precision: int = ans.DEFAULT_PRECISION

    def _starts(self) -> Callable[[jnp.ndarray], jnp.ndarray]:
        k = 1 << self.bits
        scale = float((1 << self.precision) - k)
        if scale <= 0:
            raise ValueError("need precision > bits")
        cdf_fn = self.cdf_fn

        def f(i):
            c = jnp.clip(cdf_fn(i), 0.0, 1.0)
            c = jnp.where(i <= 0, 0.0, c)
            c = jnp.where(i >= k, 1.0, c)
            return jnp.floor(c * scale).astype(jnp.uint32) \
                + i.astype(jnp.uint32)

        return f

    def push(self, stack: ans.ANSStack, x: jnp.ndarray) -> ans.ANSStack:
        f = self._starts()
        x = x.astype(jnp.int32)
        start = f(x)
        freq = f(x + 1) - start
        return ans.push(stack, start, freq, self.precision)

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, jnp.ndarray]:
        f = self._starts()
        slot = ans.peek(stack, self.precision)
        lo = jnp.zeros_like(slot, dtype=jnp.int32)
        hi = jnp.full_like(lo, 1 << self.bits)  # exclusive

        def body(_, lohi):
            lo, hi = lohi
            mid = (lo + hi + 1) // 2
            go_up = f(mid) <= slot
            return jnp.where(go_up, mid, lo), jnp.where(go_up, hi, mid)

        lo, hi = jax.lax.fori_loop(0, self.bits + 1, body, (lo, hi))
        start = f(lo)
        freq = f(lo + 1) - start
        return ans.pop_update(stack, start, freq, self.precision), lo


@dataclasses.dataclass(frozen=True)
class DiscretizedGaussian(Codec):
    """N(mu, sigma^2) over the max-entropy N(0,1)-prior buckets.

    Delegates to ``core.discretize.push_posterior``/``pop_posterior``
    (the paper-App.-B coder), so it is bit-identical to the pre-codecs
    coding path by construction; this is the posterior leaf of every
    diag-Gaussian bits-back model here.

    Example::

        leaf = DiscretizedGaussian(mu, sigma, bits=10)  # mu/sigma [lanes]
        stack, idx = leaf.pop(stack)   # sample Q(y|s) from stack bits
        stack = leaf.push(stack, idx)  # exact inverse
    """

    mu: jnp.ndarray     # float[lanes]
    sigma: jnp.ndarray  # float[lanes]
    bits: int
    precision: int = ans.DEFAULT_PRECISION

    def push(self, stack: ans.ANSStack, x: jnp.ndarray) -> ans.ANSStack:
        return discretize.push_posterior(stack, x, self.mu, self.sigma,
                                         self.bits, self.precision)

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, jnp.ndarray]:
        return discretize.pop_posterior(stack, self.mu, self.sigma,
                                        self.bits, self.precision)


def _logistic_cdf(i: jnp.ndarray, mu: jnp.ndarray, scale: jnp.ndarray,
                  bits: int) -> jnp.ndarray:
    """sigmoid((z_i - mu)/scale) with exact 0/1 at i = 0 / K.

    Broadcastable over leading axes (the codec compiler evaluates it on
    whole [n, lanes] grids; the leaf per position)."""
    k = 1 << bits
    z = discretize.bucket_edge(i, bits)
    # Reciprocal-multiply form: bit-stable across compilation contexts
    # (see discretize._posterior_cdf).
    c = jax.nn.sigmoid((z - mu) * (1.0 / scale))
    c = jnp.where(i <= 0, 0.0, c)
    c = jnp.where(i >= k, 1.0, c)
    return c


def logistic_starts_fn(mu: jnp.ndarray, scale: jnp.ndarray, bits: int,
                       precision: int
                       ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Pointwise fixed-point starts F(i) of ``DiscretizedLogistic``.

    Exactly the arithmetic of ``PointwiseCDF._starts`` over the logistic
    CDF (same clip, same saturation, same floor), shared between the
    per-position leaf and the vectorized codec-compiler path so the two
    are bit-identical by construction.
    """
    k = 1 << bits
    scale_fp = float((1 << precision) - k)
    if scale_fp <= 0:
        raise ValueError("need precision > bits")

    def f(i):
        c = jnp.clip(_logistic_cdf(i, mu, scale, bits), 0.0, 1.0)
        c = jnp.where(i <= 0, 0.0, c)
        c = jnp.where(i >= k, 1.0, c)
        return jnp.floor(c * scale_fp).astype(jnp.uint32) \
            + i.astype(jnp.uint32)

    return f


@dataclasses.dataclass(frozen=True)
class DiscretizedLogistic(Codec):
    """Logistic(mu, scale) over the max-entropy N(0,1)-prior buckets.

    A first-class dataclass leaf (the codec compiler reads ``mu`` and
    ``scale`` to build fused multi-step decode kernels); push/pop
    delegate to the identical ``PointwiseCDF`` the old factory built,
    so wire bytes are unchanged.

    Example::

        leaf = DiscretizedLogistic(mu, scale, bits=8)
        stack, idx = leaf.pop(stack)           # bucket indices [lanes]
    """

    mu: jnp.ndarray     # float[lanes]
    scale: jnp.ndarray  # float[lanes]
    bits: int
    precision: int = ans.DEFAULT_PRECISION

    def _pointwise(self) -> PointwiseCDF:
        mu, scale, bits = self.mu, self.scale, self.bits
        return PointwiseCDF(lambda i: _logistic_cdf(i, mu, scale, bits),
                            bits, self.precision)

    def push(self, stack: ans.ANSStack, x: jnp.ndarray) -> ans.ANSStack:
        return self._pointwise().push(stack, x)

    def pop(self, stack: ans.ANSStack) -> Tuple[ans.ANSStack, jnp.ndarray]:
        return self._pointwise().pop(stack)
